"""BiCGStab as a :class:`RecoverableSolver`.

Preconditioned BiCGStab (van der Vorst '92), right-preconditioned form:
the state carries the *true* residual ``r = b - A x``, so convergence
monitoring and recovery share PCG's invariants.

Minimal recovery set: ``{r^(k), p^(k), rho_k, alpha_k, omega_k}`` —
**two** vectors and **three** scalars, history 1 (no consecutive pair):
the first zoo member exercising genuinely multi-vector schema slots.
Reconstruction at the recovery point:

    r_F, p_F              <- persisted
    A[F,F] x_F = b_F - r_F - A[F,~F] x_{~F}     (local solve, Alg. 3 l.7-8)
    v_F = (A P p)[F] = A[F,F](P p)_F + A[F,~F](P p)_{~F}   (recompute)

The shadow residual ``rhat0 = r^(0)`` is *derived static data* (``b - A
x0``): regenerable on a replacement node without persistence, like ``A``
and ``b`` themselves (paper §3 static-data model), so it is deliberately
not part of the persisted set.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.reconstruction import solve_x_from_residual
from repro.core.state import RecoverySchema, RecoverySet
from repro.solvers.base import RecoverableSolver, solver_dot

BICGSTAB_SCHEMA = RecoverySchema(
    "bicgstab", vectors=("r", "p"), scalars=("rho", "alpha", "omega"),
    history=1)


class BiCGStabState(NamedTuple):
    x: jax.Array
    r: jax.Array      # true residual b - A x
    p: jax.Array
    v: jax.Array      # A P p
    rho: jax.Array
    alpha: jax.Array
    omega: jax.Array
    k: jax.Array


def make_step(op_apply, precond_apply, dot, rhat0,
              barrier=jax.lax.optimization_barrier):
    """One BiCGStab iteration as a jittable pure fn.  ``rhat0`` may be a
    concrete array (solo path) or a traced per-lane vector (batched
    service path) — the body is shared."""

    def step(state: BiCGStabState) -> BiCGStabState:
        rho_new = dot(rhat0, state.r)
        beta = (rho_new / state.rho) * (state.alpha / state.omega)
        p = state.r + beta * (state.p - state.omega * state.v)
        # phat/shat feed both an SpMV and the x update; without a
        # barrier XLA re-fuses their recomputation into the x
        # kernel, and that fusion choice is placement-dependent —
        # sharded and unsharded compilations split by ~1 ulp in x
        # (and only x).  Materializing them once pins the bits.
        phat = barrier(precond_apply(p))
        v = op_apply(phat)
        alpha = rho_new / dot(rhat0, v)
        s = state.r - alpha * v
        shat = barrier(precond_apply(s))
        t = op_apply(shat)
        omega = dot(t, s) / dot(t, t)
        x = state.x + alpha * phat + omega * shat
        r = s - omega * t
        return BiCGStabState(x=x, r=r, p=p, v=v, rho=rho_new, alpha=alpha,
                             omega=omega, k=state.k + 1)

    return step


class BiCGStabSolver(RecoverableSolver):
    name = "bicgstab"
    schema = BICGSTAB_SCHEMA
    state_vector_fields = ("x", "r", "p", "v")
    state_nan_scalars = ()
    batchable = True

    def __init__(self):
        self._rhat0 = None

    def init_state(self, op, precond, b, x0=None) -> BiCGStabState:
        x0 = jnp.zeros_like(b) if x0 is None else x0
        r0 = b - op.apply(x0)
        self._rhat0 = r0  # derived static data (see module docstring)
        one = jnp.ones((), b.dtype)
        zero = jnp.zeros_like(b)
        return BiCGStabState(x=x0, r=r0, p=zero, v=zero, rho=one, alpha=one,
                             omega=one, k=jnp.zeros((), jnp.int32))

    def make_step(self, op, precond):
        if self._rhat0 is None:
            raise RuntimeError("init_state must run before make_step")
        return jax.jit(make_step(op.apply, precond.apply, solver_dot(op),
                                 self._rhat0))

    @classmethod
    def lane_step(cls, op_apply, precond_apply, dot, params):
        # No barrier under vmap: optimization_barrier has no batching
        # rule, and its purpose — sharded/unsharded fusion agreement —
        # doesn't apply to lanes, whose bit-identity contract is scoped
        # to the one compiled bucket program (docs/serving.md).
        return make_step(op_apply, precond_apply, dot, params["rhat0"],
                         barrier=lambda u: u)

    def lane_params(self):
        if self._rhat0 is None:
            raise RuntimeError("init_state must run before lane_params")
        return {"rhat0": self._rhat0}

    def recovery_set(self, state) -> RecoverySet:
        return RecoverySet(
            k=int(state.k),
            scalars={"rho": float(state.rho), "alpha": float(state.alpha),
                     "omega": float(state.omega)},
            vectors={"r": self.host_shard(state.r),
                     "p": self.host_shard(state.p)},
        )

    def reconstruct(self, op, precond, b, snapshot, failed_blocks,
                    sets: Sequence[RecoverySet], local_method: str = "auto"):
        part = op.partition
        failed = list(failed_blocks)
        cur = sets[-1]
        dt = b.dtype
        r_f = jnp.asarray(cur.vectors["r"], dt)
        p_f = jnp.asarray(cur.vectors["p"], dt)
        r = part.scatter(snapshot.r, r_f, failed)
        p = part.scatter(snapshot.p, p_f, failed)
        x = solve_x_from_residual(op, b, snapshot.x, r_f, failed, local_method)
        # v = A P p is derivable once p is whole again (one restricted SpMV)
        phat = precond.apply(p)
        v_f = (op.inblock_apply(part.restrict(phat, failed), failed)
               + op.offblock_apply(phat, failed))
        v = part.scatter(snapshot.v, v_f, failed)
        return BiCGStabState(
            x=x, r=r, p=p, v=v,
            rho=jnp.asarray(cur.scalars["rho"], dt),
            alpha=jnp.asarray(cur.scalars["alpha"], dt),
            omega=jnp.asarray(cur.scalars["omega"], dt),
            k=snapshot.k,
        )
