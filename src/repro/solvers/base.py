"""The :class:`RecoverableSolver` interface.

An ESR-recoverable solver is a fixed-point/Krylov iteration whose lost
state is exactly derivable from (a) a few persisted vectors/scalars — its
:class:`~repro.core.state.RecoverySchema` — plus (b) the surviving shards
and (c) static data (``A`` rows, ``P`` rows, ``b``; regenerated
matrix-free here).  The generic driver (:mod:`repro.solvers.driver`)
handles scheduling, failure injection, snapshots, and reporting; each
solver supplies:

- ``init_state`` / ``make_step``: the jitted iteration over a NamedTuple
  state pytree that carries an integer ``k`` (completed iterations) and
  a residual vector ``r`` (for convergence monitoring).
- ``recovery_set``: extraction of the minimal persisted payload.
- ``reconstruct``: the paper's Algorithm 3/5 pattern — rebuild the failed
  shards exactly from persisted + surviving + static data.
- ``wipe``: the failure model (which state fields live in failed VM).
"""
from __future__ import annotations

import abc
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.state import RecoverySchema, RecoverySet, wipe_vectors


def solver_dot(op):
    """The inner product a zoo solver must use: block-hierarchical with a
    pinned combine order (:func:`repro.core.spmv.make_det_dot`), so the
    trajectory is bitwise identical whether ``op`` is a plain operator or
    a :class:`~repro.distributed.sharding.ShardedOperator` on any shard
    count — the sharded-exactness contract (DESIGN.md §10)."""
    from repro.core.spmv import make_det_dot

    return make_det_dot(op.nblocks, getattr(op, "mesh", None))


def base_operator(op):
    """Unwrap a :class:`~repro.distributed.sharding.ShardedOperator` (or
    any delegating wrapper exposing ``base``) for code that dispatches on
    the concrete operator type, e.g. closed-form spectral bounds."""
    return getattr(op, "base", op)


class RecoverableSolver(abc.ABC):
    """Base class / protocol for ESR-recoverable iterative solvers."""

    #: registry name ("pcg", "jacobi", ...)
    name: str = ""
    #: minimal recovery set declaration (drives backend slot layout)
    schema: RecoverySchema
    #: state fields holding block-distributed vectors (failure wipes them)
    state_vector_fields: Sequence[str] = ()
    #: state fields holding non-replicated reduction scalars (NaN'd on
    #: failure; restored by reconstruction)
    state_nan_scalars: Sequence[str] = ()

    #: whether the solver offers a :meth:`lane_step` for the batched
    #: multi-tenant service path (DESIGN.md §12); GMRES's restart-cycle
    #: step is host-orchestrated and stays solo-only
    batchable = False

    # ------------------------------------------------------------------
    @abc.abstractmethod
    def init_state(self, op, precond, b, x0=None):
        """State after 0 completed iterations (pytree with ``k`` and ``r``)."""

    @abc.abstractmethod
    def make_step(self, op, precond):
        """Return the jitted one-iteration transition ``state -> state``.

        Called once per solve, after :meth:`init_state` (so solvers may
        close over per-solve derived static data, e.g. BiCGStab's shadow
        residual).
        """

    @abc.abstractmethod
    def recovery_set(self, state) -> RecoverySet:
        """The minimal persisted payload at this iteration (host arrays)."""

    @abc.abstractmethod
    def reconstruct(self, op, precond, b, snapshot, failed_blocks,
                    sets: Sequence[RecoverySet], local_method: str = "auto"):
        """Exactly rebuild the failed shards at ``snapshot.k``.

        ``sets`` holds the recovered payload unions, oldest -> newest,
        with ``sets[-1].k == snapshot.k`` and ``len(sets) ==
        schema.history``; each union vector is concatenated in
        ``failed_blocks`` order.
        """

    # ------------------------------------------------------------------
    @classmethod
    def lane_step(cls, op_apply, precond_apply, dot, params):
        """Un-jitted one-iteration transition for ONE lane of a batched
        (vmapped) solve — the multi-tenant service path (DESIGN.md §12).

        Unlike :meth:`make_step`, which may close over per-solve Python
        constants, every per-tenant quantity (Chebyshev recurrence
        coefficients, the Jacobi weight, BiCGStab's shadow residual)
        arrives through ``params`` as *traced* values, so one compiled
        ``vmap`` body serves heterogeneous tenants.  Solvers share the
        step body with :meth:`make_step` (a module-level builder), so
        the solo path stays bit-identical.
        """
        raise NotImplementedError(
            f"solver {cls.name!r} has no batched lane step "
            f"(batchable={cls.batchable})")

    def lane_params(self):
        """The per-lane ``params`` pytree :meth:`lane_step` consumes, read
        off a solver built for this tenant (after :meth:`init_state` for
        solvers whose params are derived there).  Default: none."""
        return {}

    # ------------------------------------------------------------------
    def residual_norm(self, state) -> float:
        # Host-side numpy norm: gathers the (possibly device-sharded)
        # residual and reduces in a fixed order, so the convergence check
        # reads the same bits whether the solve is sharded or not.
        return float(np.linalg.norm(np.asarray(state.r)))

    def wipe(self, state, partition, blocks):
        """Simulate failure: failed shards of every distributed vector (and
        any non-replicated reduction scalar) become garbage."""
        return wipe_vectors(state, partition, blocks,
                            self.state_vector_fields, self.state_nan_scalars)

    # ------------------------------------------------------------------
    def host_shard(self, arr) -> np.ndarray:
        """Device -> host pull of a persisted vector (the NVM-ESR tap is a
        host-side copy of the local shard; no collective)."""
        return np.asarray(arr)

    @classmethod
    def from_problem(cls, op=None, precond=None, **opts) -> "RecoverableSolver":
        """Registry hook: build a solver tuned to (op, precond).  The
        default ignores the problem; solvers needing derived parameters
        (Chebyshev bounds, Jacobi weight) override this."""
        return cls(**opts)


class IterateOnlyRecovery:
    """Shared implementation for solvers whose minimal recovery set is the
    iterate itself — schema ``{x}``, history 1 (weighted Jacobi, restarted
    GMRES).  The state class must be ``(x, r, k)``; reconstruction is a
    scatter of the persisted shard plus the direct residual restriction
    ``r_F = b_F - A[F,F] x_F - A[F,~F] x_{~F}`` (no local solve)."""

    state_cls: type
    state_vector_fields = ("x", "r")
    state_nan_scalars = ()

    def init_state(self, op, precond, b, x0=None):
        x0 = jnp.zeros_like(b) if x0 is None else x0
        return self.state_cls(x=x0, r=b - op.apply(x0),
                              k=jnp.zeros((), jnp.int32))

    def recovery_set(self, state) -> RecoverySet:
        return RecoverySet(k=int(state.k), scalars={},
                           vectors={"x": self.host_shard(state.x)})

    def reconstruct(self, op, precond, b, snapshot, failed_blocks,
                    sets: Sequence[RecoverySet], local_method: str = "auto"):
        from repro.core.reconstruction import residual_on_failed

        part = op.partition
        failed = list(failed_blocks)
        x_f = jnp.asarray(sets[-1].vectors["x"], b.dtype)
        x = part.scatter(snapshot.x, x_f, failed)
        r = part.scatter(snapshot.r, residual_on_failed(op, b, x, failed), failed)
        return self.state_cls(x=x, r=r, k=snapshot.k)
