"""The recoverable solver zoo: generic ESR for distributed iterative solvers.

The paper formulates exact state reconstruction (ESR) for PCG; the
mechanism — persist a minimal recovery set, rebuild lost shards exactly
from it plus surviving shards and static data — applies to any iteration
whose state is derivable from a few persisted vectors.  This package
generalizes the machinery:

- :mod:`repro.solvers.base` — the :class:`RecoverableSolver` interface
  and :class:`~repro.core.state.RecoverySchema`-driven payloads.
- :mod:`repro.solvers.driver` — the generic solve loop (persistence
  schedule, failure injection, survivor snapshot, recovery, reporting).
- solver adapters: :mod:`~repro.solvers.pcg` (history-2 pair, the paper),
  :mod:`~repro.solvers.chebyshev` (reduction-free scalars),
  :mod:`~repro.solvers.jacobi` and :mod:`~repro.solvers.gmres`
  (single-vector ``{x}`` sets), :mod:`~repro.solvers.bicgstab`
  (multi-vector ``{r, p}`` set).
- :mod:`repro.solvers.registry` — sweep solvers x backends by name.
"""
from repro.solvers.base import RecoverableSolver  # noqa: F401
from repro.solvers.bicgstab import BICGSTAB_SCHEMA, BiCGStabSolver  # noqa: F401
from repro.solvers.chebyshev import (  # noqa: F401
    CHEBYSHEV_SCHEMA,
    ChebyshevSolver,
    spectral_bounds,
)
from repro.solvers.driver import (  # noqa: F401
    CampaignPlan,
    FailureCampaign,
    FailureEvent,
    FailurePlan,
    PlannedRecovery,
    SolveConfig,
    SolveReport,
    SpecAdvice,
    SpecRanking,
    UnsurvivableCampaignError,
    advise_spec,
    plan_campaign,
    should_persist,
    solve,
)
from repro.solvers.gmres import GMRES_SCHEMA, RestartedGMRESSolver  # noqa: F401
from repro.solvers.jacobi import JACOBI_SCHEMA, WeightedJacobiSolver  # noqa: F401
from repro.solvers.pcg import PCGSolver  # noqa: F401
from repro.solvers.registry import (  # noqa: F401
    BACKENDS,
    SOLVERS,
    make_backend,
    make_solver,
)
