"""Restarted GMRES(m) as a :class:`RecoverableSolver` (stretch member).

One driver "iteration" is a full restart cycle: an m-step Arnoldi process
(right-preconditioned, classical Gram-Schmidt with reorthogonalization,
fully jitted) followed by the small least-squares solve and the update
``x <- x + P V y``.

ESR fits restarted GMRES naturally at cycle boundaries: the Krylov basis
``V`` (``m+1`` vectors!) would be prohibitively expensive to persist, but
at a restart the entire algorithm state collapses to the iterate ``x``.
Minimal recovery set: ``{x^(k)}``, history 1 — the iterate-only pattern
shared with weighted Jacobi
(:class:`~repro.solvers.base.IterateOnlyRecovery`); a mid-cycle failure
costs at most one cycle of wasted work (the ESRP trade-off, amortized by
design).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spmv import make_det_dot, make_det_rowdots
from repro.core.state import RecoverySchema
from repro.solvers.base import IterateOnlyRecovery, RecoverableSolver

GMRES_SCHEMA = RecoverySchema("gmres", vectors=("x",), scalars=(), history=1)


class GMRESState(NamedTuple):
    x: jax.Array
    r: jax.Array  # true residual b - A x at the cycle boundary
    k: jax.Array  # completed restart cycles


class RestartedGMRESSolver(IterateOnlyRecovery, RecoverableSolver):
    name = "gmres"
    schema = GMRES_SCHEMA
    state_cls = GMRESState

    def __init__(self, m: int = 20):
        if m < 1:
            raise ValueError(f"restart length must be >= 1, got {m}")
        self.m = int(m)

    def make_step(self, op, precond):
        m = self.m
        op_apply, precond_apply = op.apply, precond.apply
        # Order-pinned reductions (sharded bit-exactness): the Arnoldi
        # projections become block-hierarchical row-dots, the dense
        # ``basis.T @ h`` combines become explicit row-weighted sums over
        # the (replicated) basis axis — no reduction ever crosses the
        # sharded vector axis with an XLA-chosen order.
        dot = make_det_dot(op.nblocks, getattr(op, "mesh", None))
        rowdots = make_det_rowdots(op.nblocks, getattr(op, "mesh", None))

        def combine(rows, coeffs):
            # sum_i coeffs[i] * rows[i] — elementwise along the vector
            # axis, reduced over the small replicated row axis.
            return (rows * coeffs[:, None]).sum(axis=0)

        def cycle(state: GMRESState) -> GMRESState:
            x, r = state.x, state.r
            n = r.shape[0]
            dt = r.dtype
            beta = jnp.sqrt(dot(r, r))
            tiny = jnp.asarray(np.finfo(np.dtype(dt)).tiny, dt)
            v0 = r / jnp.maximum(beta, tiny)
            basis = jnp.zeros((m + 1, n), dt).at[0].set(v0)
            hess = jnp.zeros((m + 1, m), dt)

            def arnoldi(j, carry):
                basis, hess = carry
                w = op_apply(precond_apply(basis[j]))
                # CGS2: unset rows of ``basis`` are zero, so the full-matrix
                # products only project onto the j+1 built vectors; the
                # second pass restores MGS-grade orthogonality.
                h1 = rowdots(basis, w)
                w = w - combine(basis, h1)
                h2 = rowdots(basis, w)
                w = w - combine(basis, h2)
                h = h1 + h2
                hnorm = jnp.sqrt(dot(w, w))
                basis = basis.at[j + 1].set(w / jnp.maximum(hnorm, tiny))
                hess = hess.at[:, j].set(h).at[j + 1, j].set(hnorm)
                return basis, hess

            basis, hess = jax.lax.fori_loop(0, m, arnoldi, (basis, hess))
            rhs = jnp.zeros((m + 1,), dt).at[0].set(beta)
            y, *_ = jnp.linalg.lstsq(hess, rhs)
            dx = precond_apply(combine(basis[:m], y))
            x_new = x + dx
            r_new = r - op_apply(dx)  # = b - A x_new (exact arithmetic)
            return GMRESState(x=x_new, r=r_new, k=state.k + 1)

        return jax.jit(cycle)
