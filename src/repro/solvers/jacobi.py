"""Weighted (damped) Jacobi as a :class:`RecoverableSolver`.

The stationary iteration ``x^(k+1) = x^(k) + omega * P r^(k)`` with
``P = preconditioner`` (classically ``D^{-1}``) and ``r = b - A x``.

Minimal recovery set: ``{x^(k)}`` alone — the entire lost state is
derivable from the persisted ``x`` shard plus static data:

    r_F = b_F - A[F,F] x_F - A[F,~F] x_{~F}

so ``history = 1`` (no consecutive-iteration pair needed) and recovery
requires **no local solve at all**: the cheapest reconstruction in the
zoo (shared with restarted GMRES via
:class:`~repro.solvers.base.IterateOnlyRecovery`).  This is the
degenerate case of Pachajoa et al.'s generic strategy where the
persisted vector is the iterate itself.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.state import RecoverySchema
from repro.solvers.base import IterateOnlyRecovery, RecoverableSolver

JACOBI_SCHEMA = RecoverySchema("jacobi", vectors=("x",), scalars=(), history=1)


class JacobiState(NamedTuple):
    x: jax.Array
    r: jax.Array
    k: jax.Array


def make_step(op_apply, precond_apply, omega):
    """One weighted-Jacobi iteration as a jittable pure fn.  ``omega``
    may be a Python float (solo path) or a traced per-lane scalar
    (batched service path) — the body is shared."""

    def step(state: JacobiState) -> JacobiState:
        z = precond_apply(state.r)
        x = state.x + omega * z
        r = state.r - omega * op_apply(z)   # r = b - A x, incrementally
        return JacobiState(x=x, r=r, k=state.k + 1)

    return step


class WeightedJacobiSolver(IterateOnlyRecovery, RecoverableSolver):
    name = "jacobi"
    schema = JACOBI_SCHEMA
    state_cls = JacobiState
    batchable = True

    def __init__(self, omega: float = 2.0 / 3.0):
        self.omega = float(omega)

    def make_step(self, op, precond):
        return jax.jit(make_step(op.apply, precond.apply, self.omega))

    @classmethod
    def lane_step(cls, op_apply, precond_apply, dot, params):
        return make_step(op_apply, precond_apply, params["omega"])

    def lane_params(self):
        return {"omega": self.omega}

    # ------------------------------------------------------------------
    @classmethod
    def from_problem(cls, op=None, precond=None,
                     omega: Optional[float] = None) -> "WeightedJacobiSolver":
        """Pick the damping weight.  With spectral bounds of ``P A``
        available the optimal stationary weight is ``2/(mu_min+mu_max)``;
        otherwise the classic smoother default 2/3."""
        if omega is not None:
            return cls(omega=omega)
        if op is not None and precond is not None:
            from repro.solvers.chebyshev import spectral_bounds

            try:
                lo, hi = spectral_bounds(op, precond)
                return cls(omega=2.0 / (lo + hi))
            except (ValueError, NotImplementedError):
                pass
        return cls()
