"""Chebyshev iteration as a :class:`RecoverableSolver`.

Preconditioned Chebyshev semi-iteration (Saad, Alg. 12.1) in three-term
direction form, driven by spectral bounds ``[lmin, lmax]`` of ``P A``:

    sigma = d / c,  d = (lmax + lmin)/2,  c = (lmax - lmin)/2
    rho_0 = 1/sigma,   alpha_0 = 1/d,   p_0 = z_0
    rho_{k+1}  = 1 / (2 sigma - rho_k)
    beta_{k+1} = rho_k * c * alpha_k / 2
    alpha_{k+1}= 2 rho_{k+1} / c
    p_{k+1} = z_{k+1} + beta_{k+1} p_k,   x_{k+1} = x_k + alpha_k p_k

Unlike PCG the scalars come from a *deterministic recurrence* — no inner
products — which makes Chebyshev the communication-minimal member of the
zoo (one SpMV, zero reductions per iteration) and its recovery trivial
for scalars.  The direction structure ``p = z + beta p_prev`` is the same
as PCG's, so exact reconstruction reuses Algorithm 3 verbatim
(:func:`repro.core.reconstruction.reconstruct_direction_form`) with the
persisted pair ``(p^(k-1), p^(k))`` — recovery set
``{p, beta, alpha, rho, k}``, history 2.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.reconstruction import reconstruct_direction_form
from repro.core.spmv import make_det_dot
from repro.core.state import RecoverySchema, RecoverySet
from repro.solvers.base import RecoverableSolver

CHEBYSHEV_SCHEMA = RecoverySchema(
    "chebyshev", vectors=("p",), scalars=("beta", "alpha", "rho"), history=2)


class ChebyshevState(NamedTuple):
    x: jax.Array
    r: jax.Array
    z: jax.Array
    p: jax.Array
    alpha: jax.Array      # alpha_k: the step applied by the NEXT iteration
    rho: jax.Array        # rho_k of the Chebyshev recurrence
    beta_prev: jax.Array  # beta_k linking p_k = z_k + beta_k p_{k-1}
    k: jax.Array


def spectral_bounds(op, precond, power_iters: int = 100,
                    seed: int = 0) -> Tuple[float, float]:
    """Bounds ``[lmin, lmax]`` on the spectrum of ``P A``.

    Three routes, most exact first:

    - closed form for the 7-point stencil with identity/Jacobi
      preconditioning (the paper's workload: eigenvalues of the 3-D
      Dirichlet Laplacian are known analytically),
    - dense eigenvalues for small problems (any operator/preconditioner),
    - shifted power iteration otherwise (with safety margins: Chebyshev
      tolerates slightly-wide bounds, diverges on too-narrow ones).
    """
    from repro.core.poisson import (
        IdentityPreconditioner,
        JacobiPreconditioner,
        StencilOperator,
    )
    from repro.solvers.base import base_operator

    # Bounds are placement-independent: unwrap a ShardedOperator so the
    # closed-form stencil route still fires (a sharded solve must use the
    # SAME lam_min/lam_max as the unsharded one, bit for bit).
    op = base_operator(op)

    if isinstance(op, StencilOperator) and isinstance(
            precond, (IdentityPreconditioner, JacobiPreconditioner)):
        spread = sum(np.cos(np.pi / (dim + 1)) for dim in op.grid)
        lo, hi = 6.0 - 2.0 * spread, 6.0 + 2.0 * spread
        if isinstance(precond, JacobiPreconditioner):
            lo, hi = lo / 6.0, hi / 6.0  # P = D^{-1} = I/6 for the stencil
        return lo, hi

    def m_apply(v):
        return precond.apply(op.apply(v))

    if op.n <= 2048:
        cols = jax.vmap(m_apply)(jnp.eye(op.n, dtype=op.dtype)).T
        eigs = np.linalg.eigvals(np.asarray(cols)).real  # P A ~ P^1/2 A P^1/2: real
        return float(eigs.min()), float(eigs.max())

    # power iteration for lmax; shifted power iteration for lmin
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.standard_normal(op.n), op.dtype)
    det_dot = make_det_dot(getattr(op, "nblocks", 1),
                           getattr(op, "mesh", None))

    def power(apply_fn, v):
        lam = 0.0
        for _ in range(power_iters):
            w = apply_fn(v)
            lam = float(det_dot(v, w) / det_dot(v, v))
            v = w / jnp.linalg.norm(w)
        return lam

    hi = power(m_apply, v)
    lo = hi - power(lambda u: hi * u - m_apply(u), v)
    return 0.9 * max(lo, 1e-12 * hi), 1.05 * hi


def make_step(op_apply, precond_apply, c, sigma):
    """One Chebyshev iteration as a jittable pure fn.  ``c``/``sigma``
    may be Python floats (solo path) or traced per-lane scalars (batched
    service path) — the recurrence body is shared."""

    def step(state: ChebyshevState) -> ChebyshevState:
        ap = op_apply(state.p)                    # the only SpMV
        x = state.x + state.alpha * state.p
        r = state.r - state.alpha * ap
        z = precond_apply(r)
        rho_new = 1.0 / (2.0 * sigma - state.rho)   # scalar recurrence:
        beta = state.rho * c * state.alpha / 2.0    # no reductions
        alpha_new = 2.0 * rho_new / c
        p = z + beta * state.p
        return ChebyshevState(x=x, r=r, z=z, p=p, alpha=alpha_new,
                              rho=rho_new, beta_prev=beta, k=state.k + 1)

    return step


class ChebyshevSolver(RecoverableSolver):
    name = "chebyshev"
    schema = CHEBYSHEV_SCHEMA
    state_vector_fields = ("x", "r", "z", "p")
    state_nan_scalars = ()
    batchable = True

    def __init__(self, lam_min: float, lam_max: float):
        if not (0.0 < lam_min < lam_max):
            raise ValueError(f"need 0 < lam_min < lam_max, got [{lam_min}, {lam_max}]")
        self.lam_min = float(lam_min)
        self.lam_max = float(lam_max)
        self.d = (lam_max + lam_min) / 2.0
        self.c = (lam_max - lam_min) / 2.0

    def init_state(self, op, precond, b, x0=None) -> ChebyshevState:
        x0 = jnp.zeros_like(b) if x0 is None else x0
        r0 = b - op.apply(x0)
        z0 = precond.apply(r0)
        dt = b.dtype
        return ChebyshevState(
            x=x0, r=r0, z=z0, p=z0,
            alpha=jnp.asarray(1.0 / self.d, dt),
            rho=jnp.asarray(self.c / self.d, dt),
            beta_prev=jnp.zeros((), dt),
            k=jnp.zeros((), jnp.int32),
        )

    def make_step(self, op, precond):
        return jax.jit(make_step(op.apply, precond.apply,
                                 self.c, self.d / self.c))

    @classmethod
    def lane_step(cls, op_apply, precond_apply, dot, params):
        return make_step(op_apply, precond_apply,
                         params["c"], params["sigma"])

    def lane_params(self):
        # Bounds are computed host-side from the tenant's *real* operator
        # (spectral_bounds in from_problem); only the recurrence
        # coefficients travel into the compiled lane.
        return {"c": self.c, "sigma": self.d / self.c}

    def recovery_set(self, state) -> RecoverySet:
        return RecoverySet(
            k=int(state.k),
            scalars={"beta": float(state.beta_prev),
                     "alpha": float(state.alpha),
                     "rho": float(state.rho)},
            vectors={"p": self.host_shard(state.p)},
        )

    def reconstruct(self, op, precond, b, snapshot, failed_blocks,
                    sets: Sequence[RecoverySet], local_method: str = "auto"):
        prev, cur = sets[-2], sets[-1]
        x, r, z, p = reconstruct_direction_form(
            op, precond, b, snapshot, list(failed_blocks),
            p_prev_f=jnp.asarray(prev.vectors["p"], b.dtype),
            p_cur_f=jnp.asarray(cur.vectors["p"], b.dtype),
            beta=cur.scalars["beta"],
            local_method=local_method,
        )
        dt = b.dtype
        return ChebyshevState(
            x=x, r=r, z=z, p=p,
            alpha=jnp.asarray(cur.scalars["alpha"], dt),
            rho=jnp.asarray(cur.scalars["rho"], dt),
            beta_prev=jnp.asarray(cur.scalars["beta"], dt),
            k=snapshot.k,
        )

    # ------------------------------------------------------------------
    @classmethod
    def from_problem(cls, op=None, precond=None,
                     lam_min: Optional[float] = None,
                     lam_max: Optional[float] = None) -> "ChebyshevSolver":
        if lam_min is None or lam_max is None:
            if op is None or precond is None:
                raise ValueError(
                    "chebyshev needs spectral bounds: pass lam_min/lam_max "
                    "or (op, precond) to estimate them")
            lo, hi = spectral_bounds(op, precond)
            lam_min = lo if lam_min is None else lam_min
            lam_max = hi if lam_max is None else lam_max
        return cls(lam_min=lam_min, lam_max=lam_max)
