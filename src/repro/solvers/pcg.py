"""PCG as a :class:`RecoverableSolver` (the zoo's first citizen).

The algorithm itself (paper Algorithm 1) and its exact reconstruction
(Algorithm 3/5) live in :mod:`repro.core.pcg` and
:mod:`repro.core.reconstruction`; this module adapts them to the generic
driver interface.  Recovery set: ``{p^(k), p^(k-1), beta^(k-1), k}``
(Pachajoa et al. [14]) — one vector, one scalar, history 2.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import reconstruction

# Module (not name) import: core.pcg re-exports the generic driver API and
# is mid-initialization when this module loads through it — binding the
# module object defers attribute lookup to call time.
from repro.core import pcg as _core_pcg
from repro.core.state import PCG_SCHEMA, RecoverySet
from repro.solvers.base import RecoverableSolver, solver_dot


class PCGSolver(RecoverableSolver):
    name = "pcg"
    schema = PCG_SCHEMA
    state_vector_fields = ("x", "r", "z", "p")
    state_nan_scalars = ("rz",)
    batchable = True

    def init_state(self, op, precond, b, x0=None):
        return _core_pcg.init_state(op, precond, b, x0, dot=solver_dot(op))

    def make_step(self, op, precond):
        return jax.jit(_core_pcg.make_step(op.apply, precond.apply,
                                           dot=solver_dot(op)))

    @classmethod
    def lane_step(cls, op_apply, precond_apply, dot, params):
        # PCG's scalars (rz, beta) live in the state; no per-lane params.
        return _core_pcg.make_step(op_apply, precond_apply, dot=dot)

    def recovery_set(self, state) -> RecoverySet:
        return RecoverySet(
            k=int(state.k),
            scalars={"beta": float(state.beta_prev)},
            vectors={"p": self.host_shard(state.p)},
        )

    def reconstruct(self, op, precond, b, snapshot, failed_blocks,
                    sets: Sequence[RecoverySet], local_method: str = "auto"):
        prev, cur = sets[-2], sets[-1]
        return reconstruction.reconstruct(
            op, precond, b,
            state_surviving=snapshot,
            failed_blocks=list(failed_blocks),
            p_prev_f=jnp.asarray(prev.vectors["p"], b.dtype),
            p_cur_f=jnp.asarray(cur.vectors["p"], b.dtype),
            beta=cur.scalars["beta"],
            local_method=local_method,
            dot=solver_dot(op),
        )
