"""The solver zoo registry: sweep solvers and backends by name.

Benchmarks, examples, and tests iterate ``SOLVERS`` to run every
ESR-recoverable solver against every persistence backend; the factories
wire schemas through so each backend's slot layout matches the solver it
protects.  Backends resolve through the single registry in
:mod:`repro.nvm.backend`, including composable spec strings::

    solver  = make_solver("chebyshev", op, precond)
    backend = make_backend("replicated(nvm-prd x2)", op, solver=solver)
    state, report, _ = driver.solve(solver, op, b, precond, backend=backend)

Unknown names raise with a did-you-mean hint (the closest registered
name) in both directions.
"""
from __future__ import annotations

from typing import Dict, Optional, Type

import numpy as np

# Deprecated table view (``BACKENDS[name](...)`` warns); the live
# registry is repro.nvm.backend.
from repro.core.nvm_esr import BACKENDS  # noqa: F401
from repro.core.state import RecoverySchema
from repro.nvm.backend import (
    PersistenceBackend,
    create_backend,
    unknown_name_error,
)
from repro.solvers.base import RecoverableSolver
from repro.solvers.bicgstab import BiCGStabSolver
from repro.solvers.chebyshev import ChebyshevSolver
from repro.solvers.gmres import RestartedGMRESSolver
from repro.solvers.jacobi import WeightedJacobiSolver
from repro.solvers.pcg import PCGSolver

SOLVERS: Dict[str, Type[RecoverableSolver]] = {
    "pcg": PCGSolver,
    "jacobi": WeightedJacobiSolver,
    "chebyshev": ChebyshevSolver,
    "bicgstab": BiCGStabSolver,
    "gmres": RestartedGMRESSolver,
}


def make_solver(name: str, op=None, precond=None, **opts) -> RecoverableSolver:
    """Build a registered solver, deriving problem-dependent parameters
    (Chebyshev bounds, Jacobi weight) from ``(op, precond)`` when given."""
    try:
        cls = SOLVERS[name]
    except KeyError:
        raise unknown_name_error("solver", name, SOLVERS) from None
    return cls.from_problem(op, precond, **opts)


def make_backend(
    name: str,
    op,
    dtype=np.float64,
    solver: Optional[RecoverableSolver] = None,
    schema: Optional[RecoverySchema] = None,
    **opts,
) -> PersistenceBackend:
    """Build a registered backend sized for ``op``'s partition, persisting
    ``solver``'s (or ``schema``'s) recovery set; defaults to PCG's.

    ``name`` may be any registry name or a composable spec string —
    ``"replicated(nvm-prd x2)"``, ``"erasure(nvm-prd x4+p)"``,
    ``"tiered(nvm-homogeneous)"``."""
    if solver is not None:
        if schema is not None and schema != solver.schema:
            raise ValueError(
                f"conflicting schemas: solver {solver.name!r} declares "
                f"{solver.schema.solver!r} but schema={schema.solver!r} was "
                f"passed explicitly — give one or the other")
        schema = solver.schema
    return create_backend(name, op.nblocks, op.partition.block_size, dtype,
                          schema=schema, **opts)
