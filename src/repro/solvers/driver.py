"""Generic ESR solve loop for any :class:`RecoverableSolver`.

Extracted from the original ``core/pcg.solve`` so every solver in the zoo
shares one implementation of the paper's runtime machinery:

- the persistence schedule (classic ESR: every iteration; ESRP: bursts of
  ``schema.history`` successive iterations every period ``T``),
- failure injection (block crashes wiping volatile shards),
- the survivor-side snapshot at the last completed persistence run,
- recovery (backend fetch + solver-specific exact reconstruction),
- convergence monitoring and reporting.

The solver contributes only algorithm-specific pieces through the
:class:`~repro.solvers.base.RecoverableSolver` interface: the jitted
iteration, the minimal recovery set, and the Algorithm-3/5-style exact
reconstruction.  The backend contributes schema-driven persistence
(:mod:`repro.core.esr`, :mod:`repro.core.nvm_esr`).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SolveConfig:
    tol: float = 1e-10            # relative residual tolerance ||r|| / ||b||
    maxiter: int = 10_000
    persistence_period: int = 1   # T=1: classic ESR; T>1: ESRP bursts
    local_solve: str = "auto"     # reconstruction local solver


@dataclasses.dataclass(frozen=True)
class FailurePlan:
    """Inject a failure of ``blocks`` right after iteration ``at_iteration``."""

    at_iteration: int
    blocks: Tuple[int, ...]


@dataclasses.dataclass
class SolveReport:
    iterations: int = 0
    wasted_iterations: int = 0
    failures_recovered: int = 0
    converged: bool = False
    final_relres: float = float("nan")
    persist_cost_s: float = 0.0
    persist_events: int = 0
    residual_history: List[float] = dataclasses.field(default_factory=list)
    solver: str = ""


def should_persist(k: int, period: int, history: int = 2) -> bool:
    """Persistence schedule: classic ESR persists every iteration; ESRP
    persists bursts of ``history`` successive iterations every ``period``
    (the burst must complete a full recovery run, so its length is the
    schema's history)."""
    if period <= 1:
        return True
    return k % period < history


class _LegacyBackendAdapter:
    """Wrap a pre-zoo backend (``persist(k, beta, p)`` / ``recover(blocks,
    k)``, PCG payloads only) so external backend implementations written
    against the original ``core.pcg.solve`` contract keep working."""

    def __init__(self, backend, schema):
        from repro.core.state import require_pcg_schema

        try:
            require_pcg_schema(schema, "persist/recover")
        except TypeError as e:
            raise ValueError(
                f"backend {type(backend).__name__} implements only the "
                f"legacy API: {e}") from None
        self._backend = backend

    def __getattr__(self, name):
        return getattr(self._backend, name)

    def persist_set(self, k, scalars, vectors):
        return self._backend.persist(k, scalars["beta"], vectors["p"])

    def recover_set(self, failed_blocks, ks):
        from repro.core.state import RecoverySet

        prev, cur = self._backend.recover(failed_blocks, ks[-1])
        if (prev.k, cur.k) != (ks[0], ks[-1]):
            # external, untrusted contract: refuse loudly rather than
            # reconstruct from a stale pair
            raise RuntimeError(
                f"legacy backend {type(self._backend).__name__}.recover "
                f"returned iterations {(prev.k, cur.k)}, wanted {tuple(ks)}")
        return [RecoverySet(prev.k, {"beta": prev.beta}, {"p": prev.p}),
                RecoverySet(cur.k, {"beta": cur.beta}, {"p": cur.p})]


def solve(
    solver,
    op,
    b,
    precond,
    config: SolveConfig = SolveConfig(),
    backend=None,
    failures: Sequence[FailurePlan] = (),
    x0=None,
    capture_states_at: Sequence[int] = (),
):
    """Run ``solver`` with optional ESR/NVM-ESR fault tolerance.

    ``backend`` is an in-memory-ESR or NVM-ESR recovery backend (or None
    for an unprotected run).  ``failures`` injects block crashes.  Returns
    the final state, a report, and any states captured for verification.
    """
    schema = solver.schema
    if backend is not None:
        if getattr(backend, "schema", None) is not None and backend.schema != schema:
            raise ValueError(
                f"backend persists schema {backend.schema.solver!r} but solver "
                f"{solver.name!r} needs {schema.solver!r}; construct the backend "
                f"with the solver's schema (see repro.solvers.registry.make_backend)")
        if not hasattr(backend, "persist_set"):
            backend = _LegacyBackendAdapter(backend, schema)
    history = schema.history

    state = solver.init_state(op, precond, b, x0)
    step = solver.make_step(op, precond)
    bnorm = float(jnp.linalg.norm(b))
    report = SolveReport(solver=solver.name)
    captured: Dict[int, object] = {}
    pending = sorted(failures, key=lambda f: f.at_iteration)
    if pending and pending[0].at_iteration < 1:
        # a plan that can never fire would also block every later plan
        # (injection matches the sorted list head) — fail loudly instead
        raise ValueError(
            f"FailurePlan.at_iteration must be >= 1 (iteration 0 precedes "
            f"the first persisted recovery point), got "
            f"{pending[0].at_iteration}")
    pending_idx = 0

    # Survivor-side snapshot at the last completed persistence run: the
    # surviving processes' own state copy kept in their local RAM (cheap,
    # one shard each).  Needed to roll back to the recovery point when
    # persistence is periodic (ESRP trade-off, paper §2).
    snapshot = None
    last_persisted_k: Optional[int] = None
    consecutive = 0

    def persist_now(st) -> None:
        nonlocal snapshot, last_persisted_k, consecutive
        if backend is None:
            return
        rset = solver.recovery_set(st)
        cost = backend.persist_set(rset.k, rset.scalars, rset.vectors)
        report.persist_cost_s += cost
        report.persist_events += 1
        consecutive = consecutive + 1 if last_persisted_k == rset.k - 1 else 1
        last_persisted_k = rset.k
        if consecutive >= history:
            # a full history-run is now durable -> new recovery point.
            # (The k=0 persist alone is NOT one for history >= 2; the
            # schedule persists iterations 0..history-1 consecutively, so
            # the first recovery point completes at k = history-1.  A
            # failure injected before that trips the snapshot assert
            # below with a clear message.)
            snapshot = st

    # Iteration 0 counts as persisted so the first run completes early.
    persist_now(state)

    while int(state.k) < config.maxiter:
        k = int(state.k)
        if k in capture_states_at:
            captured[k] = state

        relres = solver.residual_norm(state) / bnorm
        report.residual_history.append(relres)
        if relres < config.tol:
            report.converged = True
            break

        # ---- failure injection + recovery ----
        if pending_idx < len(pending) and k == pending[pending_idx].at_iteration:
            plan = pending[pending_idx]
            pending_idx += 1
            if backend is None:
                raise RuntimeError("failure injected but no recovery backend configured")
            state = solver.wipe(state, op.partition, plan.blocks)  # VM lost
            backend.fail(plan.blocks)
            assert snapshot is not None, "no completed persistence run before failure"
            k_rec = int(snapshot.k)
            report.wasted_iterations += k - k_rec  # ESRP discard cost
            ks = tuple(range(k_rec - history + 1, k_rec + 1))
            sets = backend.recover_set(plan.blocks, ks)
            state = solver.reconstruct(
                op, precond, b,
                snapshot=snapshot,
                failed_blocks=list(plan.blocks),
                sets=sets,
                local_method=config.local_solve,
            )
            report.failures_recovered += 1
            if int(state.k) in capture_states_at:
                captured[int(state.k)] = state
            continue

        state = step(state)
        if backend is not None and should_persist(
                int(state.k), config.persistence_period, history):
            persist_now(state)

    report.iterations = int(state.k)
    report.final_relres = solver.residual_norm(state) / bnorm
    report.converged = report.converged or report.final_relres < config.tol
    return state, report, captured
