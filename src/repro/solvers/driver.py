"""Generic ESR solve loop for any :class:`RecoverableSolver`.

Extracted from the original ``core/pcg.solve`` so every solver in the zoo
shares one implementation of the paper's runtime machinery:

- the persistence schedule (classic ESR: every iteration; ESRP: bursts of
  ``schema.history`` successive iterations every period ``T``),
- the persistence *pipeline*: synchronous (persist on the critical path,
  the paper's host-pull baseline) or overlapped (``session.begin`` stages
  the payload, ``session.commit`` flushes it while the next iteration's
  compute is in flight — DESIGN.md §6),
- failure injection — single plans or multi-event :class:`FailureCampaign`
  scenarios (overlapping failures during an in-flight recovery, failures
  mid-burst falling back to the previous durable run, repeated failures
  of the same block, and ``prd=True`` events that crash the persistence
  service / PRD node itself),
- campaign *planning* (:func:`plan_campaign`, DESIGN.md §8): before
  iteration 0, every recovery the campaign will force is budgeted
  against the backend's declared
  :class:`~repro.nvm.backend.BackendCapabilities`; a campaign the
  backend provably cannot survive is rejected with an
  :class:`UnsurvivableCampaignError` naming the violating event,
- the survivor-side snapshot at the last *durable* persistence run,
- recovery (backend fetch + solver-specific exact reconstruction),
  with a rollback-agreement cross-check: after every recovery fetch the
  backend's own ``durable_run()`` must name the same iteration the
  driver is about to reconstruct from,
- convergence monitoring and reporting.

The solver contributes only algorithm-specific pieces through the
:class:`~repro.solvers.base.RecoverableSolver` interface: the jitted
iteration, the minimal recovery set, and the Algorithm-3/5-style exact
reconstruction.  The backend contributes a declared-capability
:class:`~repro.nvm.backend.PersistSession` (DESIGN.md §7): any
:class:`~repro.nvm.backend.PersistenceBackend`, any schema-duck-typed
object (``persist_set``/``recover_set``), or — deprecated — a pre-zoo
``persist``/``recover`` object, all normalized through
:func:`repro.nvm.backend.open_persist_session`.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.nvm.backend import (
    BackendCapabilities,
    UnrecoverableFailure,
    open_persist_session,
)
from repro.obs.metrics import MetricsRegistry

PERSIST_MODES = ("sync", "overlap")


@dataclasses.dataclass(frozen=True)
class SolveConfig:
    tol: float = 1e-10            # relative residual tolerance ||r|| / ||b||
    maxiter: int = 10_000
    persistence_period: int = 1   # T=1: classic ESR; T>1: ESRP bursts
    local_solve: str = "auto"     # reconstruction local solver
    persist_mode: str = "sync"    # "sync": persist on the critical path;
    #                               "overlap": commit hides behind compute
    plan_campaign: bool = True    # pre-flight plan_campaign() against the
    #                               backend's declared capabilities; False
    #                               runs unplanned (failures surface at the
    #                               recovery fetch instead)
    fused_persist: bool = False   # fused persist path (DESIGN.md §13):
    #                               stripe sessions encode parity through
    #                               the Pallas kernel (repro.kernels.ops.
    #                               rs_encode) and, in overlap mode, the
    #                               staging pass is deferred into the next
    #                               iteration's timed window so it rides
    #                               the compute it overlaps.  Slot bytes
    #                               and commit ordering are identical to
    #                               the numpy path — solves are
    #                               bit-identical either way
    tracer: Optional[object] = None  # a repro.obs.Tracer records spans /
    #                               events through the whole pipeline
    #                               (DESIGN.md §9); None (or any falsy
    #                               tracer) keeps the hot path a strict
    #                               no-op — zero tracer callables per
    #                               iteration, enforced by the obs tests


@dataclasses.dataclass(frozen=True)
class FailurePlan:
    """Inject a failure of ``blocks`` right after iteration ``at_iteration``
    (the single-event form, kept for the pre-campaign API)."""

    at_iteration: int
    blocks: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    """One failure in a :class:`FailureCampaign`.

    Exactly one trigger must be set:

    - ``at_iteration`` — fire when the solver reaches this iteration
      (equivalent to a :class:`FailurePlan`).
    - ``during_recovery_at`` — fire *while the recovery* of the
      ``at_iteration`` event with this trigger value is in flight: the
      driver has already fetched recovery payloads for the earlier failed
      set when this event lands, so that fetch is discarded and the
      recovery restarts with the enlarged union (an overlapping failure).
      ``blocks`` may repeat already-failed blocks (a second crash of the
      same node mid-recovery).

    ``prd=True`` additionally crashes the **persistence-service node**
    (the PRD node / pool service) at the trigger: staged payloads die,
    unflushed epochs are torn away, and — unless the backend's
    :class:`~repro.nvm.backend.BackendCapabilities` declare
    ``survives_prd_loss`` (e.g. a
    :class:`~repro.nvm.backend.ReplicatedBackend` with a surviving
    mirror) — any later recovery fetch raises
    :class:`~repro.nvm.backend.UnrecoverableFailure`.  A ``prd`` event
    may carry no blocks (the PRD dies alone; the solve itself
    continues, unprotected).

    ``shard`` names a *device shard* instead of (or in addition to)
    explicit blocks: on a sharded solve the event kills every block the
    shard owns (the paper's per-node failure unit).  The driver resolves
    ``shard`` against the operator's
    :class:`~repro.distributed.sharding.ShardLayout` before planning, so
    the planner and the recovery engine only ever see blocks; a
    ``shard`` event on an unsharded solve is an error (there is no
    device to kill)."""

    blocks: Tuple[int, ...] = ()
    at_iteration: Optional[int] = None
    during_recovery_at: Optional[int] = None
    prd: bool = False
    shard: Optional[int] = None

    def __post_init__(self):
        if not self.blocks and self.shard is None and not self.prd:
            raise ValueError("a FailureEvent needs at least one block")
        if (self.at_iteration is None) == (self.during_recovery_at is None):
            raise ValueError(
                "set exactly one of at_iteration / during_recovery_at")
        if self.at_iteration is not None and self.at_iteration < 1:
            raise ValueError(
                f"FailureEvent.at_iteration must be >= 1 (iteration 0 "
                f"precedes the first persisted recovery point), got "
                f"{self.at_iteration}")
        if self.shard is not None and self.shard < 0:
            raise ValueError(
                f"FailureEvent.shard must be >= 0, got {self.shard}")


@dataclasses.dataclass(frozen=True)
class FailureCampaign:
    """A multi-failure scenario: iteration-triggered events plus
    overlapping events that land during those events' recoveries."""

    events: Tuple[FailureEvent, ...]

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))
        triggers = {e.at_iteration for e in self.events
                    if e.at_iteration is not None}
        for e in self.events:
            if (e.during_recovery_at is not None
                    and e.during_recovery_at not in triggers):
                raise ValueError(
                    f"during_recovery_at={e.during_recovery_at} matches no "
                    f"at_iteration event in the campaign")


class UnsurvivableCampaignError(UnrecoverableFailure):
    """Raised by :func:`plan_campaign` *before iteration 0* for a
    campaign the backend's declared capabilities provably cannot
    survive.  Subclasses :class:`~repro.nvm.backend.UnrecoverableFailure`
    because it reports the same fact — a recovery fetch that cannot be
    served — just at plan time instead of mid-solve."""


@dataclasses.dataclass(frozen=True)
class PlannedRecovery:
    """One recovery the campaign will force: the iteration that triggers
    it, the final failed-block union its fetch must serve (after all
    overlapping events), how many persistence-service losses will have
    accumulated by its last fetch, and how many stale-fetch restarts
    overlapping events will cause."""

    at_iteration: int
    blocks: Tuple[int, ...]
    storage_losses: int
    restarts: int


@dataclasses.dataclass(frozen=True)
class CampaignPlan:
    """The planner's verdict on a survivable campaign: the recoveries it
    will force, in trigger order, and the total storage losses."""

    recoveries: Tuple[PlannedRecovery, ...]
    storage_losses: int


def resolve_shard_events(campaign, layout) -> "FailureCampaign":
    """Resolve ``FailureEvent(shard=...)`` triggers into block sets.

    ``layout`` is the operator's
    :class:`~repro.distributed.sharding.ShardLayout` (None for an
    unsharded solve).  Each shard event's block set becomes the union of
    its explicit blocks and the blocks the shard owns, so everything
    downstream — the planner's budget walk, ``solver.wipe``,
    ``session.fail``, the recovery fetch — speaks blocks only.  A shard
    event without a layout is refused (there is no device to kill), and
    an out-of-range shard index fails here, before iteration 0."""
    campaign = _as_campaign(campaign)
    if not any(e.shard is not None for e in campaign.events):
        return campaign
    if layout is None:
        raise ValueError(
            "FailureEvent(shard=...) needs a sharded solve: the operator "
            "carries no ShardLayout (wrap the problem with "
            "repro.distributed.sharding.shard_problem, or address blocks "
            "directly)")
    events = []
    for ev in campaign.events:
        if ev.shard is None:
            events.append(ev)
            continue
        blocks = tuple(sorted(set(ev.blocks) | set(layout.blocks_of(ev.shard))))
        events.append(dataclasses.replace(ev, blocks=blocks, shard=None))
    return FailureCampaign(tuple(events))


def plan_campaign(campaign, capabilities: BackendCapabilities,
                  tracer=None, layout=None) -> CampaignPlan:
    """Check a campaign against a backend's declared capabilities.

    Walks the campaign exactly as the solve loop will execute it —
    iteration-triggered events in order, each recovery absorbing its
    ``during_recovery_at`` events one refetch at a time — and verifies
    that every recovery *fetch* the campaign forces can be served:

    - the failed-block union at each fetch must not exceed
      ``capabilities.max_block_failures`` (peer-RAM copy placement),
    - the persistence-service losses accumulated by each fetch must not
      exceed ``capabilities.max_storage_failures`` (mirror / parity
      budget) — a ``prd=True`` event *after* the last fetch is
      survivable and accepted, matching the runtime semantics,
    - any failed blocks at all require ``capabilities.survives_node_loss``.

    Returns the :class:`CampaignPlan` for a survivable campaign; raises
    :class:`UnsurvivableCampaignError` naming the violating
    :class:`FailureEvent` otherwise.  ``campaign`` may be a
    :class:`FailureCampaign` or any sequence :func:`solve` accepts.
    ``layout`` (a :class:`~repro.distributed.sharding.ShardLayout`)
    resolves ``shard=`` events to their block sets first.
    A ``tracer`` (repro.obs) records the verdict as a ``plan.accept``
    or ``plan.reject`` event.
    """
    trace = tracer or None
    campaign = resolve_shard_events(campaign, layout)
    try:
        plan = _plan_campaign_walk(campaign, capabilities)
    except UnsurvivableCampaignError as e:
        if trace is not None:
            trace.event("plan.reject", reason=str(e))
        raise
    if trace is not None:
        trace.event("plan.accept", recoveries=len(plan.recoveries),
                    storage_losses=plan.storage_losses)
    return plan


def _plan_campaign_walk(campaign,
                        capabilities: BackendCapabilities) -> CampaignPlan:
    campaign = _as_campaign(campaign)
    max_storage = capabilities.max_storage_failures
    max_blocks = capabilities.max_block_failures
    during: Dict[int, List[FailureEvent]] = {}
    ordered: List[FailureEvent] = []
    for ev in campaign.events:
        if ev.at_iteration is None:
            during.setdefault(ev.during_recovery_at, []).append(ev)
        else:
            ordered.append(ev)
    ordered.sort(key=lambda e: e.at_iteration)

    losses = 0
    fatal_loss: Optional[FailureEvent] = None  # the loss past the budget
    recoveries: List[PlannedRecovery] = []
    for ev in ordered:
        if ev.prd:
            losses += 1
            if losses > max_storage and fatal_loss is None:
                fatal_loss = ev
        if not ev.blocks:
            # Storage-only event: no compute state lost, no recovery
            # fetch here; the loss is latent until a later fetch.
            continue
        queue = list(during.pop(ev.at_iteration, ()))
        union: set = set()
        cur, restarts = ev, 0
        while True:
            union |= set(cur.blocks)
            if union and not capabilities.survives_node_loss:
                raise UnsurvivableCampaignError(
                    f"campaign rejected before iteration 0: {cur} fails "
                    f"compute blocks but the backend declares "
                    f"survives_node_loss=False")
            if max_blocks is not None and len(union) > max_blocks:
                raise UnsurvivableCampaignError(
                    f"campaign rejected before iteration 0: the recovery "
                    f"at iteration {ev.at_iteration} must fetch the "
                    f"{len(union)}-block union {tuple(sorted(union))}, "
                    f"beyond capabilities.max_block_failures={max_blocks}; "
                    f"violating event: {cur}")
            if losses > max_storage:
                raise UnsurvivableCampaignError(
                    f"campaign rejected before iteration 0: the recovery "
                    f"at iteration {ev.at_iteration} fetches after "
                    f"{losses} persistence-service (PRD) losses, beyond "
                    f"capabilities.max_storage_failures={max_storage}; "
                    f"violating event: {fatal_loss}")
            if not queue:
                break
            cur = queue.pop(0)
            restarts += 1
            if cur.prd:
                losses += 1
                if losses > max_storage and fatal_loss is None:
                    fatal_loss = cur
        recoveries.append(PlannedRecovery(
            at_iteration=ev.at_iteration, blocks=tuple(sorted(union)),
            storage_losses=losses, restarts=restarts))
    return CampaignPlan(tuple(recoveries), losses)


# ----------------------------------------------------------------------
# The cheapest-spec advisor (DESIGN.md §8): plan_campaign as a filter,
# declared footprint + modeled persist cost as the ranking.
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SpecRanking:
    """One candidate's evaluation by :func:`advise_spec`.

    - ``spec`` — the candidate's spec string (registry-composable).
    - ``survivable`` — whether :func:`plan_campaign` accepted the
      campaign against the candidate's declared capabilities.
    - ``reason`` — the planner's rejection message ("" when survivable).
    - ``storage_values`` — declared redundancy footprint in values (RAM
      overhead + persistent-tier residency), the primary ranking key.
    - ``persist_cost_s`` — modeled cost of one full persist event
      through the candidate (the probe write), the tie-breaker; NaN
      when no probe size was given.
    """

    spec: str
    survivable: bool
    reason: str
    storage_values: int
    persist_cost_s: float


@dataclasses.dataclass(frozen=True)
class SpecAdvice:
    """The advisor's verdict: the cheapest survivable spec (``chosen``,
    None when nothing survives), every survivor cheapest-first
    (``ranked``), and the rejected candidates with the planner's reason
    (``rejected``)."""

    chosen: Optional[str]
    ranked: Tuple[SpecRanking, ...]
    rejected: Tuple[SpecRanking, ...]


def _probe_persist_cost(backend, nvalues: int) -> float:
    """Modeled per-event cost of persisting one full durable run
    (``schema.history`` synthetic zero events) through ``backend``.
    The probe fills slots ``k=0..history-1``, so callers hand the
    advisor disposable, freshly built candidates — it also settles
    residency-based footprint accounting (the in-memory backend counts
    *resident* values, which are zero before anything is persisted)."""
    schema = backend.schema
    session = backend.open_session(schema)
    scalars = {s: 0.0 for s in schema.scalars}
    vectors = {v: np.zeros(nvalues) for v in schema.vectors}
    costs = [session.persist(k, scalars, vectors)
             for k in range(schema.history)]
    return float(sum(costs) / len(costs))


def advise_spec(campaign, candidates,
                probe_values: Optional[int] = None,
                tracer=None) -> SpecAdvice:
    """Pick the cheapest candidate spec whose declared capabilities
    carry ``campaign``.

    ``candidates`` maps spec strings to *freshly built* backends (a
    mapping or a ``(spec, backend)`` sequence — build them with
    :func:`repro.solvers.registry.make_backend`; ``repro.api.advise``
    does this from a :class:`~repro.api.Problem`).  Each candidate is
    filtered through :func:`plan_campaign` against its
    :class:`~repro.nvm.backend.BackendCapabilities`, then the survivors
    are ranked by declared storage footprint
    (``memory_overhead_values() + nvm_values()``, the paper's Fig. 2/8
    quantity) with the modeled per-event persist cost as tie-breaker —
    probed with one synthetic event of ``probe_values`` values when
    given (candidates are disposable: the probe writes their slot 0).

    Returns a :class:`SpecAdvice`; ``advice.chosen`` is None when no
    candidate survives (callers decide whether that is an error — the
    :meth:`repro.api.ResilienceSpec.advise` surface raises
    :class:`UnsurvivableCampaignError`).  A ``tracer`` (repro.obs)
    records one ``advise.candidate`` event per candidate and a final
    ``advise.chosen`` verdict.
    """
    trace = tracer or None
    items = (list(candidates.items()) if hasattr(candidates, "items")
             else list(candidates))
    ranked: List[SpecRanking] = []
    rejected: List[SpecRanking] = []
    for spec, backend in items:
        try:
            plan_campaign(campaign, backend.capabilities)
        except UnsurvivableCampaignError as e:
            storage = int(backend.memory_overhead_values()
                          + backend.nvm_values())
            rejected.append(SpecRanking(spec, False, str(e), storage,
                                        float("nan")))
            if trace is not None:
                trace.event("advise.candidate", spec=spec, survivable=False,
                            storage_values=storage)
            continue
        cost = (float("nan") if probe_values is None
                else _probe_persist_cost(backend, probe_values))
        # footprint measured after the probe, so residency-based
        # accounting (peer-RAM ESR) reflects a persisted run too
        storage = int(backend.memory_overhead_values() + backend.nvm_values())
        ranked.append(SpecRanking(spec, True, "", storage, cost))
        if trace is not None:
            trace.event("advise.candidate", spec=spec, survivable=True,
                        storage_values=storage, persist_cost_s=cost)
    ranked.sort(key=lambda r: (r.storage_values,
                               math.inf if math.isnan(r.persist_cost_s)
                               else r.persist_cost_s))
    chosen = ranked[0].spec if ranked else None
    if trace is not None:
        trace.event("advise.chosen", spec=chosen,
                    survivors=len(ranked), rejected=len(rejected))
    return SpecAdvice(chosen=chosen,
                      ranked=tuple(ranked), rejected=tuple(rejected))


@dataclasses.dataclass
class SolveReport:
    """Outcome and accounting of one driver run.

    Progress / outcome:

    - ``iterations`` — completed iterations at exit (``int(state.k)``).
    - ``wasted_iterations`` — iterations discarded by rollbacks: for each
      recovery, the distance from the failure iteration back to the
      durable recovery point (the ESRP trade-off, paper §2; also > 0 in
      overlap mode when the failure aborts a staged-but-uncommitted
      persist).
    - ``failures_recovered`` — failure *events* recovered, including
      overlapping events absorbed into a restarted recovery.
    - ``recovery_restarts`` — recoveries that had to discard an
      already-fetched payload and refetch because an overlapping failure
      enlarged the failed set mid-recovery.
    - ``storage_failures`` — persistence-service (PRD-node) crashes
      injected by ``FailureEvent(prd=True)`` campaign events; survived
      only by backends declaring ``survives_prd_loss``.
    - ``converged`` — relative residual reached ``SolveConfig.tol``.
    - ``final_relres`` — ``||b - A x|| / ||b||`` proxy at exit
      (``solver.residual_norm / ||b||``).
    - ``residual_history`` — the relative residual at the top of every
      main-loop pass (recovered iterations appear twice, by design).
    - ``solver`` — the solver's registry name.

    Persistence accounting (modeled seconds — see ``nvm/store.py`` for
    the simulation contract):

    - ``persist_events`` — committed persistence events (aborted staged
      events are not counted).
    - ``persist_cost_s`` — total commit cost: the tier/network write the
      backend models for a full persist of all blocks.
    - ``persist_stage_s`` — staging cost (the local DRAM copy of the slot
      payload) paid on the critical path in overlap mode; 0 in sync mode,
      where the whole persist is on the critical path.
    - ``persist_hidden_s`` — the part of ``persist_cost_s`` hidden behind
      the next iteration's compute (overlap mode; per event
      ``min(commit_cost, measured compute wall)``).
    - ``persist_exposed_s`` — ``persist_cost_s - persist_hidden_s``: what
      the solver actually waits for.  In sync mode this equals
      ``persist_cost_s``.
    - ``persist_drain_s`` — drain-barrier cost paid at recoveries and at
      exit (committing leftover staged payloads; for the PRD backend also
      joining the target-side exposure epoch).
    - ``persist_mode`` — the pipeline that produced these numbers.

    ``persist_hidden_fraction`` is the derived headline metric:
    ``persist_hidden_s / persist_cost_s`` (0.0 for a sync run or when
    nothing was persisted).

    Sharded-solve accounting (DESIGN.md §10) — logical slot-payload
    bytes at the driver/session boundary, metered by the session's
    :class:`~repro.nvm.backend.SessionTraffic` and surfaced through the
    registry as ``persist.bytes`` / ``recovery.fetch_bytes`` counters
    labeled ``shard=N``:

    - ``nshards`` — device shards of the solve (1 when unsharded).
    - ``persist_bytes`` / ``persist_bytes_by_shard`` — slot bytes each
      shard's blocks shipped to the persistence service.
    - ``recovery_fetch_bytes`` / ``recovery_fetch_bytes_by_shard`` —
      slot bytes recovery fetches moved back; proportional to the lost
      shard, not the problem (the paper's recovery-traffic claim).

    Observability (DESIGN.md §9):

    - ``persist_aborts`` — staged-but-uncommitted persist events dropped
      because the staging nodes died before the commit window.
    - ``metrics`` — the :class:`~repro.obs.MetricsRegistry` the solve
      loop incremented; every numeric counter above is a *derived view*
      of it (read back out at exit), so
      :func:`repro.obs.check_report_consistency` can re-verify the
      derivation and :func:`repro.obs.check_trace_report` can close the
      triangle against a tracer's event counts.

    Service residency (docs/serving.md §5) — set only when the solve ran
    as a tenant of :class:`repro.serving.SolveService`; all three stay 0
    on solo driver runs.  The counters are derived views too
    (``SERVICE_REPORT_PAIRS``), measured in deterministic service steps,
    never wall-clock:

    - ``service_queue_wait_steps`` — steps spent queued before a lane
      seated the tenant.
    - ``service_lane_steps`` — steps resident in a lane (vmapped batch
      steps the tenant rode).
    - ``service_batch_occupancy`` — mean live-lane fraction of the
      tenant's bucket over its residency.
    """

    iterations: int = 0
    wasted_iterations: int = 0
    failures_recovered: int = 0
    recovery_restarts: int = 0
    storage_failures: int = 0
    converged: bool = False
    final_relres: float = float("nan")
    persist_cost_s: float = 0.0
    persist_stage_s: float = 0.0
    persist_hidden_s: float = 0.0
    persist_exposed_s: float = 0.0
    persist_drain_s: float = 0.0
    persist_events: int = 0
    persist_aborts: int = 0
    persist_mode: str = "sync"
    nshards: int = 1
    persist_bytes: int = 0
    recovery_fetch_bytes: int = 0
    persist_bytes_by_shard: Dict[int, int] = dataclasses.field(
        default_factory=dict)
    recovery_fetch_bytes_by_shard: Dict[int, int] = dataclasses.field(
        default_factory=dict)
    residual_history: List[float] = dataclasses.field(default_factory=list)
    solver: str = ""
    metrics: Optional[MetricsRegistry] = None
    # Service-path extras (repro.serving.solve_service, DESIGN.md §12) —
    # zero on solo driver runs.  Wait is measured in deterministic
    # service steps (not seconds) so BENCH's queue stats survive the
    # determinism gate; occupancy is the mean fraction of live lanes in
    # the tenant's bucket over the steps it was resident.
    service_queue_wait_steps: int = 0
    service_lane_steps: int = 0
    service_batch_occupancy: float = 0.0

    @property
    def persist_hidden_fraction(self) -> float:
        if self.persist_cost_s <= 0.0:
            return 0.0
        return self.persist_hidden_s / self.persist_cost_s

    @property
    def persist_exposed_per_iteration(self) -> float:
        """Exposed persist seconds per completed iteration — the
        paper's time-overhead quantity normalized to solver progress
        (0.0 before any iteration completes)."""
        if self.iterations <= 0:
            return 0.0
        return self.persist_exposed_s / self.iterations


def should_persist(k: int, period: int, history: int = 2) -> bool:
    """Persistence schedule: classic ESR persists every iteration; ESRP
    persists bursts of ``history`` successive iterations every ``period``
    (the burst must complete a full recovery run, so its length is the
    schema's history)."""
    if period <= 1:
        return True
    return k % period < history


def _as_campaign(failures) -> FailureCampaign:
    """Normalize the ``failures`` argument: a campaign passes through; a
    sequence of plans/events becomes an iteration-triggered campaign."""
    if isinstance(failures, FailureCampaign):
        return failures
    events = []
    for f in failures:
        if isinstance(f, FailureEvent):
            events.append(f)
        elif isinstance(f, FailurePlan):
            # FailureEvent.__post_init__ re-validates at_iteration >= 1
            events.append(FailureEvent(blocks=tuple(f.blocks),
                                       at_iteration=f.at_iteration))
        else:
            raise TypeError(
                f"failures must be FailurePlan/FailureEvent entries or a "
                f"FailureCampaign, got {type(f).__name__}")
    return FailureCampaign(tuple(events))


class PersistencePipeline:
    """The per-solve persistence + recovery engine, extracted from
    :func:`solve` so one engine instance serves exactly one tenant —
    the multi-tenant service (:mod:`repro.serving.solve_service`,
    DESIGN.md §12) runs one pipeline per admitted request while
    :func:`solve` keeps running one for the whole solo loop.

    The pipeline owns everything that is *not* the iteration itself:
    the :class:`~repro.nvm.backend.PersistSession` (opened, traced, and
    shard-bound here), campaign normalization
    (:func:`resolve_shard_events`) and pre-flight planning
    (:func:`plan_campaign`), the survivor-side snapshot at the last
    durable run, the sync/overlap persist pipeline
    (:meth:`persist_point` / :meth:`persist_commit` /
    :meth:`persist_abort`), failure injection (:meth:`pop_event` /
    :meth:`inject`), the recovery engine (:meth:`run_recovery`), and
    the derived-view report readback (:meth:`finalize`).  The caller
    owns the state, the step function, and the loop.

    ``layout`` overrides the operator's
    :class:`~repro.distributed.sharding.ShardLayout` — the service
    passes a tenant's *declared logical* layout so ``shard=`` failure
    events resolve to block sets without any device mesh.
    """

    def __init__(self, solver, op, precond, b, config: SolveConfig,
                 backend, failures=(), *, layout=None, metrics=None):
        if config.persist_mode not in PERSIST_MODES:
            raise ValueError(
                f"persist_mode must be one of {PERSIST_MODES}, "
                f"got {config.persist_mode!r}")
        self.solver = solver
        self.op = op
        self.precond = precond
        self.b = b
        self.config = config
        self.overlap = config.persist_mode == "overlap"
        # Normalize the tracer ONCE: a falsy tracer (None, NULL_TRACER)
        # becomes None here, and every instrumentation site below guards
        # with an identity check — so with tracing disabled the loop
        # executes zero tracer callables per iteration (the obs guard
        # test).
        self.trace = config.tracer or None
        # Sharded solve? The operator carries the block -> device-shard
        # layout and the 1-D data mesh (repro.distributed.sharding); both
        # stay None on a plain single-device operator.  A service tenant
        # overrides ``layout`` with its declared logical one instead.
        self.layout = getattr(op, "layout", None) if layout is None else layout
        self.mesh = getattr(op, "mesh", None)
        self.history = solver.schema.history
        self.metrics = (MetricsRegistry(solver=solver.name,
                                        mode=config.persist_mode)
                        if metrics is None else metrics)
        part = getattr(op, "partition", None)
        self.session = None
        if backend is not None:
            self.session = open_persist_session(backend, solver.schema, part)
            if self.trace is not None:
                self.session.set_tracer(self.trace)
            binder = getattr(self.session, "bind_shards", None)
            if part is not None and binder is not None:
                # Per-shard session addressing (DESIGN.md §10): each
                # block's slot chunks belong to its owning device shard,
                # and the session meters persist/fetch bytes against that
                # shard.  (External sessions without bind_shards simply
                # go unmetered.)
                shard_map = (self.layout.shard_of_block_map()
                             if self.layout is not None
                             else {blk: 0 for blk in range(part.nblocks)})
                binder(shard_of_block=shard_map,
                       slot_nbytes=solver.schema.slot_nbytes(
                           part.block_size, np.dtype(b.dtype)))

        # Fused persist path (DESIGN.md §13): route stripe parity
        # encodes through the Pallas kernel.  External/duck-typed
        # sessions without the hook simply keep their own encode.
        self.fused = bool(config.fused_persist) and self.session is not None
        if self.fused:
            setter = getattr(self.session, "set_encode_mode", None)
            if setter is not None:
                setter("pallas")

        # shard=... events become block events before anything else sees
        # them
        campaign = resolve_shard_events(failures, self.layout)
        if config.plan_campaign and campaign.events and backend is not None:
            caps = getattr(backend, "capabilities", None)
            if isinstance(caps, BackendCapabilities):
                # Pre-flight: reject a campaign the backend provably
                # cannot survive before any iteration runs (duck-typed
                # backends declare nothing, so nothing is provable — they
                # run unplanned and fail at the fetch instead).
                plan_campaign(campaign, caps, tracer=self.trace)

        self.at_events: Dict[int, List[FailureEvent]] = {}
        self.during_events: Dict[int, List[FailureEvent]] = {}
        for ev in campaign.events:
            if ev.at_iteration is not None:
                self.at_events.setdefault(ev.at_iteration, []).append(ev)
            else:
                self.during_events.setdefault(ev.during_recovery_at,
                                              []).append(ev)

        # Survivor-side snapshot at the last *durable* persistence run:
        # the surviving processes' own state copy kept in their local RAM
        # (cheap, one shard each).  Needed to roll back to the recovery
        # point when persistence is periodic (ESRP trade-off, paper §2).
        # In overlap mode the snapshot only advances when the run's final
        # commit lands — a staged-but-uncommitted persist is not a
        # recovery point.
        self.snapshot = None
        self.last_persisted_k: Optional[int] = None
        self.consecutive = 0
        self.staged_state = None  # payload staged, pending commit
        # Fused overlap only: persist point reached but staging deferred
        # into the next iteration's timed window (flush_pending_stage).
        # At most one of staged_state / pending_state is set at a time.
        self.pending_state = None

    # ------------------------------------------------------------------
    def _note_committed(self, st, cost: float, window_s: float) -> None:
        metrics, trace = self.metrics, self.trace
        metrics.histogram("persist.commit_s", phase="persist").observe(cost)
        metrics.counter("persist.commit").inc()
        hidden = min(cost, window_s)
        metrics.histogram("persist.hidden_s", phase="persist").observe(hidden)
        metrics.histogram("persist.exposed_s",
                          phase="persist").observe(cost - hidden)
        if trace is not None:
            trace.event("persist.commit", k=int(st.k), cost_s=cost,
                        hidden_s=hidden, exposed_s=cost - hidden)
        k_c = int(st.k)
        self.consecutive = (self.consecutive + 1
                            if self.last_persisted_k == k_c - 1 else 1)
        self.last_persisted_k = k_c
        if self.consecutive >= self.history:
            # a full history-run is now durable -> new recovery point.
            # (The k=0 persist alone is NOT one for history >= 2; the
            # schedule persists iterations 0..history-1 consecutively, so
            # the first recovery point completes at k = history-1.  A
            # failure injected before that trips the snapshot assert in
            # run_recovery with a clear message.)
            self.snapshot = st

    def persist_begin(self, st) -> None:
        rset = self.solver.recovery_set(st)
        stage_cost = self.session.begin(rset.k, rset.scalars, rset.vectors)
        self.metrics.histogram("persist.stage_s",
                               phase="persist").observe(stage_cost)
        trace = self.trace
        if trace is not None:
            trace.event("persist.begin", k=rset.k, stage_s=stage_cost)
        self.staged_state = st

    def persist_commit(self, window_s: float = 0.0) -> None:
        if self.staged_state is None:
            return
        cost = self.session.commit()
        self._note_committed(self.staged_state, cost, window_s)
        self.staged_state = None

    def persist_abort(self) -> None:
        # The session side is aborted by session.fail() / fail_storage();
        # here we only drop the driver-side bookkeeping so the dead event
        # is never counted or committed (it does count as an abort).  A
        # fused-mode pending (deferred, never staged) event aborts the
        # same way, so persist_aborts agree between the two routes.
        st = (self.staged_state if self.staged_state is not None
              else self.pending_state)
        if st is not None:
            self.metrics.counter("persist.abort").inc()
            trace = self.trace
            if trace is not None:
                trace.event("persist.abort", k=int(st.k))
        self.staged_state = None
        self.pending_state = None

    def flush_pending_stage(self) -> None:
        """Fused overlap only: run the deferred staging pass (no-op
        otherwise).  The solve loop calls this inside the timed window
        right after the next iteration's step — the staging copy and
        parity encode then ride the same window that hides the commit,
        instead of sitting exposed on the critical path between
        iterations (DESIGN.md §13)."""
        if self.pending_state is not None:
            st, self.pending_state = self.pending_state, None
            self.persist_begin(st)

    def persist_point(self, st) -> None:
        """One scheduled persistence event.  Sync mode is the paper's
        fully synchronous host pull: write straight through, no staging
        copy, everything exposed.  Overlap mode stages now and commits
        behind the next iteration's compute; fused overlap defers even
        the staging into that window (same commit ordering — the event
        is still staged and committed before the following persist
        point)."""
        if self.overlap:
            if self.fused:
                self.pending_state = st
            else:
                self.persist_begin(st)
        else:
            rset = self.solver.recovery_set(st)
            cost = self.session.persist(rset.k, rset.scalars, rset.vectors)
            self._note_committed(st, cost, 0.0)

    # ------------------------------------------------------------------
    def pop_event(self, k: int) -> Optional[FailureEvent]:
        """The next iteration-triggered event pending at ``k`` (one per
        loop pass — a second event at the same k fires on the repeated
        pass after the first one's rollback), or None."""
        pending = self.at_events.get(k)
        if not pending:
            return None
        ev = pending.pop(0)
        if not pending:
            del self.at_events[k]
        return ev

    def storage_kill(self, k: int) -> None:
        self.session.fail_storage()
        self.metrics.counter("storage.kill").inc()
        trace = self.trace
        if trace is not None:
            trace.event("storage.kill", k=k)

    def inject(self, ev: FailureEvent, state, k: int):
        """Apply one iteration-triggered event: a storage-only event
        kills the persistence service and returns the state unchanged
        (the solve continues); a block event runs the full recovery and
        returns the rolled-back, reconstructed state."""
        if self.session is None:
            raise RuntimeError(
                "failure injected but no recovery backend configured")
        trace = self.trace
        if trace is not None:
            trace.event("failure.inject", k=k, blocks=tuple(ev.blocks),
                        prd=ev.prd, overlapping=False)
        if not ev.blocks:
            # Storage-only event: the PRD node dies but no compute
            # state is lost, so the solve continues.  The loss
            # surfaces — loudly — at the next recovery fetch unless
            # the backend's capabilities cover it.
            self.storage_kill(k)
            return state
        return self.run_recovery(ev, state, k)

    def run_recovery(self, ev: FailureEvent, st, k: int):
        """The campaign recovery engine.  Handles ``ev`` plus any events
        triggered *during* this recovery: each overlapping event enlarges
        the failed union and forces a refetch (the already-fetched
        payloads are stale — their hosts may just have died).  A
        ``prd=True`` event additionally crashes the persistence-service
        node before its blocks are processed; the fetch then succeeds
        only if the backend's capabilities cover the loss (mirrors)."""
        solver, session = self.solver, self.session
        metrics, trace, history = self.metrics, self.trace, self.history
        self.persist_abort()  # an in-flight staged persist dies with the nodes
        overlap_queue = list(self.during_events.pop(ev.at_iteration, ()))
        failed: List[int] = []
        new = list(ev.blocks)
        prd_hit = ev.prd
        st_wiped = st
        while True:
            metrics.counter("recovery.absorbed").inc()
            if trace is not None:
                trace.event("recovery.absorbed", blocks=tuple(new),
                            prd=prd_hit)
            if prd_hit:
                session.fail_storage()
                metrics.counter("storage.kill").inc()
                if trace is not None:
                    trace.event("storage.kill", k=k)
                prd_hit = False
            failed = sorted(set(failed) | set(new))
            if new:
                st_wiped = solver.wipe(st_wiped, self.op.partition, new)
                session.fail(tuple(new))  # VM lost
            # Drain barrier: outstanding persistence settles (or is torn
            # away) before the durable recovery point is read.
            drain_cost = session.drain()
            metrics.histogram("persist.drain_s",
                              phase="recovery").observe(drain_cost)
            if trace is not None:
                trace.event("persist.drain", cost_s=drain_cost)
            assert self.snapshot is not None, \
                "no completed persistence run before failure"
            k_rec = int(self.snapshot.k)
            ks = tuple(range(k_rec - history + 1, k_rec + 1))
            if trace is None:
                sets = session.fetch(tuple(failed), ks)
            else:
                with trace.span("recovery.fetch", blocks=tuple(failed),
                                runs=ks):
                    sets = session.fetch(tuple(failed), ks)
            if overlap_queue:
                # A second failure lands while this recovery is in
                # flight: the fetch above is stale, restart with the
                # enlarged union.
                nxt = overlap_queue.pop(0)
                new = list(nxt.blocks)
                prd_hit = nxt.prd
                metrics.counter("recovery.restart").inc()
                if trace is not None:
                    trace.event("failure.inject", k=k,
                                blocks=tuple(nxt.blocks), prd=nxt.prd,
                                overlapping=True)
                    trace.event("recovery.restart", blocks=tuple(nxt.blocks))
                continue
            # Rollback-agreement cross-check (DESIGN.md §8): the backend
            # answers the rollback question from its own slots; it must
            # name the same durable run the driver's snapshot ends at.
            # (Sessions without slot knowledge answer None and are
            # exempt — there is nothing to cross-check against.)
            dr = session.durable_run()
            if dr is not None and dr != k_rec:
                raise RuntimeError(
                    f"rollback-point disagreement after recovery: the "
                    f"driver's durable snapshot ends at iteration {k_rec} "
                    f"but the backend's durable_run() reports {dr}; "
                    f"backend and driver must agree before reconstruction "
                    f"(DESIGN.md §8)")
            if trace is None:
                st_new = solver.reconstruct(
                    self.op, self.precond, self.b,
                    snapshot=self.snapshot,
                    failed_blocks=list(failed),
                    sets=sets,
                    local_method=self.config.local_solve,
                )
            else:
                with trace.span("recovery.reconstruct",
                                blocks=tuple(failed), k_rec=k_rec):
                    st_new = solver.reconstruct(
                        self.op, self.precond, self.b,
                        snapshot=self.snapshot,
                        failed_blocks=list(failed),
                        sets=sets,
                        local_method=self.config.local_solve,
                    )
            metrics.counter("solve.wasted_iterations").inc(k - k_rec)
            if trace is not None:
                trace.event("recovery.rollback", from_k=k, to_k=k_rec,
                            wasted=k - k_rec)
            if self.mesh is not None:
                # the replacement shard rejoins the canonical placement;
                # without this the jitted step would recompile against
                # whatever layout reconstruction's scatters produced
                from repro.distributed.sharding import place_state

                st_new = place_state(st_new, self.mesh,
                                     solver.state_vector_fields)
            return st_new

    # ------------------------------------------------------------------
    def finalize(self, report: SolveReport, state, bnorm: float) -> None:
        """Exit drain + derived-view readback (DESIGN.md §9): a staged
        final event still commits (exposed — there is no further compute
        to hide behind), then every numeric report counter is read back
        OUT of the registry the loop incremented, so registry and report
        agree by construction (check_report_consistency re-verifies;
        check_trace_report closes the triangle to the trace)."""
        self.flush_pending_stage()  # a deferred final event still stages
        self.persist_commit(0.0)
        metrics = self.metrics
        report.iterations = int(state.k)
        report.final_relres = self.solver.residual_norm(state) / bnorm
        report.converged = (report.converged
                            or report.final_relres < self.config.tol)
        report.wasted_iterations = metrics.counter_value(
            "solve.wasted_iterations")
        report.failures_recovered = metrics.counter_value("recovery.absorbed")
        report.recovery_restarts = metrics.counter_value("recovery.restart")
        report.storage_failures = metrics.counter_value("storage.kill")
        report.persist_events = metrics.counter_value("persist.commit")
        report.persist_aborts = metrics.counter_value("persist.abort")
        report.persist_cost_s = metrics.histogram_total("persist.commit_s",
                                                        phase="persist")
        report.persist_stage_s = metrics.histogram_total("persist.stage_s",
                                                         phase="persist")
        report.persist_hidden_s = metrics.histogram_total("persist.hidden_s",
                                                          phase="persist")
        report.persist_exposed_s = metrics.histogram_total("persist.exposed_s",
                                                           phase="persist")
        report.persist_drain_s = metrics.histogram_total("persist.drain_s",
                                                         phase="recovery")
        # Per-shard traffic (DESIGN.md §10): fold the session's byte
        # meter into the registry as shard-labeled counters, then read
        # the report fields back OUT of the registry like every other
        # counter above.
        report.nshards = 1 if self.layout is None else self.layout.nshards
        traffic = getattr(self.session, "traffic", None)
        if traffic is not None:
            for shard, nbytes in sorted(traffic.persist_bytes.items()):
                metrics.counter("persist.bytes", shard=shard).inc(nbytes)
            for shard, nbytes in sorted(traffic.fetch_bytes.items()):
                metrics.counter("recovery.fetch_bytes", shard=shard).inc(nbytes)
        report.persist_bytes = metrics.counter_total("persist.bytes")
        report.recovery_fetch_bytes = metrics.counter_total(
            "recovery.fetch_bytes")
        report.persist_bytes_by_shard = metrics.counter_by_label(
            "persist.bytes", "shard")
        report.recovery_fetch_bytes_by_shard = metrics.counter_by_label(
            "recovery.fetch_bytes", "shard")
        metrics.gauge("solve.iterations").set(report.iterations)
        metrics.gauge("solve.converged").set(1.0 if report.converged else 0.0)
        trace = self.trace
        if trace is not None:
            trace.event("solve.end", iterations=report.iterations,
                        converged=report.converged,
                        final_relres=report.final_relres)


def make_batched_step(solver_cls, make_lane_ops):
    """One jitted, vmapped driver step over a bucket of tenant lanes —
    the batched entry of the multi-tenant service (DESIGN.md §12).

    ``make_lane_ops(lane)`` receives one lane's traced data pytree and
    returns ``(op_apply, precond_apply, dot, params)``; the solver
    class's :meth:`~repro.solvers.base.RecoverableSolver.lane_step`
    consumes them.  The returned function maps
    ``(stacked_states, stacked_lanes) -> stacked_states`` with every
    lane fully independent — lane ``i``'s output depends only on lane
    ``i``'s inputs, which is what makes cohabitant trajectories
    bit-identical to their solo runs through the same bucket.
    """
    if not getattr(solver_cls, "batchable", False):
        raise NotImplementedError(
            f"solver {solver_cls.name!r} is not batchable "
            f"(no lane_step)")

    def one(state, lane):
        op_apply, precond_apply, dot, params = make_lane_ops(lane)
        return solver_cls.lane_step(op_apply, precond_apply, dot,
                                    params)(state)

    return jax.jit(jax.vmap(one))


def solve(
    solver,
    op,
    b,
    precond,
    config: SolveConfig = SolveConfig(),
    backend=None,
    failures: Union[FailureCampaign, Sequence[FailurePlan]] = (),
    x0=None,
    capture_states_at: Sequence[int] = (),
):
    """Run ``solver`` with optional ESR/NVM-ESR fault tolerance.

    ``backend`` is any recovery backend :func:`repro.nvm.backend.
    open_persist_session` accepts — a first-class
    :class:`~repro.nvm.backend.PersistenceBackend` (including the
    composite ``replicated``/``tiered`` backends), a schema-duck-typed
    object, or a deprecated pre-zoo object — or None for an unprotected
    run.  ``failures`` injects block crashes — either a sequence of
    :class:`FailurePlan` (the single-event form) or a
    :class:`FailureCampaign` with overlapping / mid-burst / repeated /
    PRD-loss events.  Returns the final state, a report, and any states
    captured for verification.

    The persistence/recovery machinery lives in
    :class:`PersistencePipeline`; this function owns the state, the
    jitted step, and the loop.
    """
    trace = config.tracer or None
    if trace is not config.tracer:
        # Normalize the falsy tracer away HERE so the pipeline's own
        # `config.tracer or None` sees None — one truthiness call total
        # on a disabled tracer (the obs zero-callable guard test).
        config = dataclasses.replace(config, tracer=trace)
    pipe = PersistencePipeline(solver, op, precond, b, config, backend,
                               failures)
    session = pipe.session

    state = solver.init_state(op, precond, b, x0)
    if pipe.mesh is not None:
        # Pin the canonical placement before the step jits: vectors
        # block-sharded on "data", scalars replicated.  Recovery re-pins
        # in the pipeline so the step never recompiles for a drifted
        # layout.
        from repro.distributed.sharding import place_state

        state = place_state(state, pipe.mesh, solver.state_vector_fields)
    step = solver.make_step(op, precond)
    # host-side norm: gathers a sharded b and reduces deterministically
    bnorm = float(np.linalg.norm(np.asarray(b)))
    report = SolveReport(solver=solver.name, persist_mode=config.persist_mode,
                         metrics=pipe.metrics)
    captured: Dict[int, object] = {}
    if trace is not None:
        trace.event("solve.begin", solver=solver.name,
                    mode=config.persist_mode, maxiter=config.maxiter)

    # Iteration 0 counts as persisted so the first run completes early.
    if session is not None:
        pipe.persist_point(state)

    while int(state.k) < config.maxiter:
        k = int(state.k)
        if k in capture_states_at:
            captured[k] = state

        relres = solver.residual_norm(state) / bnorm
        report.residual_history.append(relres)
        if relres < config.tol:
            report.converged = True
            break

        # ---- failure injection + recovery ----
        ev = pipe.pop_event(k)
        if ev is not None:
            state = pipe.inject(ev, state, k)
            if int(state.k) in capture_states_at:
                captured[int(state.k)] = state
            continue

        t0 = time.perf_counter()
        if trace is None:          # identity guard: the disabled hot path
            state = step(state)    # runs zero tracer callables
        else:
            with trace.span("iteration.step", k=k):
                state = step(state)
        if pipe.pending_state is not None:
            # Fused overlap (DESIGN.md §13): the deferred staging pass
            # (payload copy + Pallas parity encode) runs inside this
            # window too, so its wall time is absorbed by the same
            # compute that hides the commit below.
            jax.block_until_ready(state)
            pipe.flush_pending_stage()
        if pipe.staged_state is not None:
            # Overlap window: the commit of iteration k's payload rides
            # behind iteration k+1's compute.
            jax.block_until_ready(state)
            pipe.persist_commit(time.perf_counter() - t0)
        if session is not None and should_persist(
                int(state.k), config.persistence_period, pipe.history):
            pipe.persist_point(state)

    pipe.finalize(report, state, bnorm)
    return state, report, captured
