"""Mixture-of-Experts layer with expert parallelism (EP) over the "model"
mesh axis, aligned with tensor parallelism.

Design (DESIGN.md §5): activations enter replicated across "model" (they
are batch-sharded over ("pod","data")), so each model-rank can compute the
contribution of *its own* expert shard to *its local* tokens with **zero
token all-to-all**; partial outputs combine with the same psum the dense
TP MLP needs.  Dispatch inside a rank is sort-based (no O(T*E*C) one-hot
dispatch tensors): tokens are ordered by expert id, positioned within
segment, and gathered into (E_local, capacity, d) blocks.  Over-capacity
tokens are dropped (standard Switch/GShard semantics, ``capacity_factor``
controls head-room).

Weights are ZeRO-3 sharded: (E/model, d/data, f) at rest; the d-axis is
all-gathered just-in-time inside the shard_map body (explicit FSDP; the
gradient transposes to a reduce-scatter automatically).

Load-balancing: the standard Switch aux loss, returned alongside the
output.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.distributed.sharding import current_rules
from repro.models.config import ModelConfig
from repro.models.layers import Params, _dense_init


def init_moe(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    params = {
        "router": _dense_init(ks[0], (d, e), jnp.float32),  # fp32 router
        "w_in": _dense_init(ks[1], (e, d, f), cfg.pdt),
        "w_out": _dense_init(ks[3], (e, f, d), cfg.pdt, fan_in=f),
    }
    specs = {
        "router": (None, None),
        "w_in": ("experts", "fsdp", None),
        "w_out": ("experts", None, "fsdp"),
    }
    if "gated" in cfg.mlp_act:
        params["w_gate"] = _dense_init(ks[2], (e, d, f), cfg.pdt)
        specs["w_gate"] = ("experts", "fsdp", None)
    return params, specs


def _moe_local(x, router, w_in, w_gate, w_out, *, cfg: ModelConfig,
               tp_axis: Optional[str], fsdp_axis: Optional[str],
               batch_axes: Tuple[str, ...]):
    """Per-device body (inside shard_map). x: (B_loc, S, d)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    tp = jax.lax.axis_size(tp_axis) if tp_axis else 1
    e_loc = e // tp
    rank = jax.lax.axis_index(tp_axis) if tp_axis else 0
    cap = int(math.ceil(t * k / e * cfg.capacity_factor))

    xt = x.reshape(t, d)

    # -------- router (fp32, replicated across model ranks) --------
    logits = (xt.astype(jnp.float32) @ router).astype(jnp.float32)  # (t, e)
    top_w, top_e = jax.lax.top_k(logits, k)
    top_w = jax.nn.softmax(top_w, axis=-1)

    # Switch aux loss: e * sum_e( frac_tokens_e * mean_router_prob_e )
    probs = jax.nn.softmax(logits, axis=-1)
    counts = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(1.0)
    frac = counts / (t * k)
    aux = e * jnp.sum(frac * jnp.mean(probs, axis=0))

    # -------- sort-based dispatch --------
    flat_e = top_e.reshape(-1)                       # (t*k,)
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_e)                      # stable
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    pos = jnp.arange(t * k) - jnp.searchsorted(se, se, side="left")

    slot = se - rank * e_loc
    valid = (slot >= 0) & (slot < e_loc) & (pos < cap)
    dest = jnp.where(valid, slot * cap + pos, e_loc * cap)  # overflow bucket
    tok_table = jnp.full((e_loc * cap + 1,), t, jnp.int32).at[dest].set(st.astype(jnp.int32))
    w_table = jnp.zeros((e_loc * cap + 1,), jnp.float32).at[dest].set(sw)
    tok_table = tok_table[:-1].reshape(e_loc, cap)
    w_table = w_table[:-1].reshape(e_loc, cap)

    # -------- gather -> expert matmuls -> combine --------
    cdt = cfg.cdt
    xpad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    xg = xpad[tok_table].astype(cdt)                 # (e_loc, cap, d)

    def gathered(w):  # JIT FSDP: cast to bf16 BEFORE the all-gather (2x less ICI)
        if fsdp_axis is None:
            return w.astype(cdt)
        return jax.lax.all_gather(w.astype(cdt), fsdp_axis, axis=1, tiled=True)

    h = jnp.einsum("ecd,edf->ecf", xg, gathered(w_in))
    if w_gate is not None:
        g = jnp.einsum("ecd,edf->ecf", xg, gathered(w_gate))
        h = jax.nn.silu(g) * h if cfg.mlp_act == "silu_gated" else jax.nn.gelu(g) * h
    else:
        h = jax.nn.gelu(h)
    wo = w_out if fsdp_axis is None else jax.lax.all_gather(w_out, fsdp_axis, axis=2, tiled=True)
    y = jnp.einsum("ecf,efd->ecd", h, wo.astype(cdt))
    y = y * w_table[..., None].astype(cdt)

    out = jnp.zeros((t + 1, d), cdt).at[tok_table.reshape(-1)].add(y.reshape(-1, d))[:t]
    if tp_axis:
        out = jax.lax.psum(out, tp_axis)             # combine expert shards
    axes = tuple(a for a in (batch_axes + ((tp_axis,) if tp_axis else ()))
                 if a is not None)
    aux = jax.lax.pmean(aux, axes)
    return out.reshape(b, s, d), aux


def moe(p: Params, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """MoE block. x: (B, S, d) batch-sharded. Returns (y, aux_loss)."""
    rules = current_rules()
    mesh = rules.mesh
    w_gate = p.get("w_gate")
    if mesh is None:
        # single-device path (smoke tests): same math, no collectives
        out, aux = _moe_local(x, p["router"], p["w_in"], w_gate, p["w_out"],
                              cfg=cfg, tp_axis=None, fsdp_axis=None, batch_axes=())
        return out.astype(x.dtype), aux

    tp_axis = rules.physical("experts")
    fsdp_axis = rules.physical("fsdp")
    batch_axes = rules.physical("batch")
    batch_axes = batch_axes if isinstance(batch_axes, tuple) else (
        (batch_axes,) if batch_axes else ())

    body = partial(_moe_local, cfg=cfg, tp_axis=tp_axis, fsdp_axis=fsdp_axis,
                   batch_axes=batch_axes)
    x_spec = P(batch_axes if batch_axes else None, None, None)
    gate_spec = rules.spec("experts", "fsdp", None) if w_gate is not None else None
    out, aux = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(x_spec, P(None, None), rules.spec("experts", "fsdp", None),
                  gate_spec, rules.spec("experts", None, "fsdp")),
        out_specs=(x_spec, P()),
    )(x, p["router"], p["w_in"], w_gate, p["w_out"])
    return out.astype(x.dtype), aux
