"""Mamba-2 block: state-space duality (SSD), chunked scan (arXiv:2405.21060).

The SSD algorithm splits the sequence into chunks of ``Q`` tokens:
intra-chunk terms are computed as a (Q x Q) decay-masked attention-like
product (MXU-friendly), inter-chunk terms flow through a sequential scan
over per-chunk states — O(S*Q) + O(S/Q) work instead of a length-S
recurrence.  Decode carries the (nh, N, hp) state per layer: the SSM state
*is* the minimal persisted decode state (DESIGN.md §4: the closest NN
analogue of the paper's finite-term-recurrence minimal set).

Decay exponentials run in fp32; matmuls in the compute dtype.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.config import ModelConfig
from repro.models.layers import Params, _dense_init, rmsnorm


def init_ssm_block(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    d = cfg.d_model
    di = cfg.expand * d
    n = cfg.ssm_state
    hp = cfg.ssm_head_dim
    nh = di // hp
    w = cfg.d_conv
    ks = jax.random.split(key, 10)
    params = {
        "w_z": _dense_init(ks[0], (d, di), cfg.pdt),
        "w_x": _dense_init(ks[1], (d, di), cfg.pdt),
        "w_b": _dense_init(ks[2], (d, n), cfg.pdt),
        "w_c": _dense_init(ks[3], (d, n), cfg.pdt),
        "w_dt": _dense_init(ks[4], (d, nh), cfg.pdt),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "a_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "conv_x": _dense_init(ks[5], (w, di), cfg.pdt, fan_in=w),
        "conv_b": _dense_init(ks[6], (w, n), cfg.pdt, fan_in=w),
        "conv_c": _dense_init(ks[7], (w, n), cfg.pdt, fan_in=w),
        "norm": jnp.ones((di,), cfg.pdt),
        "w_out": _dense_init(ks[8], (di, d), cfg.pdt, fan_in=di),
    }
    specs = {
        "w_z": ("fsdp", "mlp"), "w_x": ("fsdp", "mlp"),
        "w_b": ("fsdp", None), "w_c": ("fsdp", None),
        "w_dt": ("fsdp", None), "dt_bias": (None,), "a_log": (None,),
        "d_skip": (None,), "conv_x": (None, "mlp"), "conv_b": (None, None),
        "conv_c": (None, None), "norm": ("mlp",), "w_out": ("mlp", "fsdp"),
    }
    return params, specs


def _causal_conv(x: jax.Array, kernel: jax.Array,
                 tail: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over seq. x: (B,S,C); kernel: (w,C).

    Returns (y, new_tail) where tail carries the last w-1 inputs for
    decode continuation.
    """
    w = kernel.shape[0]
    pad = tail if tail is not None else jnp.zeros(
        (x.shape[0], w - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * kernel[i][None, None] for i in range(w))
    return jax.nn.silu(y), xp[:, -(w - 1):]


def ssd_chunked(
    x: jax.Array,       # (B, S, nh, hp)
    dt: jax.Array,      # (B, S, nh)   post-softplus
    a: jax.Array,       # (nh,)        negative
    bm: jax.Array,      # (B, S, N)
    cm: jax.Array,      # (B, S, N)
    chunk: int,
    h0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y, final_state (B, nh, N, hp))."""
    b, s, nh, hp = x.shape
    n = bm.shape[-1]
    q = min(chunk, s)
    while s % q:        # odd lengths (tests): shrink to a divisor
        q -= 1
    nc = s // q
    cdt = x.dtype

    xc = jnp.moveaxis(x.reshape(b, nc, q, nh, hp), 1, 0)        # (nc,b,q,nh,hp)
    dtc = jnp.moveaxis(dt.reshape(b, nc, q, nh), 1, 0).astype(jnp.float32)
    bc = jnp.moveaxis(bm.reshape(b, nc, q, n), 1, 0)
    cc = jnp.moveaxis(cm.reshape(b, nc, q, n), 1, 0)
    causal = jnp.tril(jnp.ones((q, q), bool))

    def chunk_body(h, inp):
        """One SSD chunk: intra-chunk (Q x Q decay-masked, MXU-friendly)
        plus the contribution of the carried state.  The whole body is
        checkpointed — the (b,Q,Q,nh) decay/score tensors are recomputed
        in backward instead of being saved per chunk (which would
        materialize O(S*Q) fp32 and dominate train memory)."""
        xq, dtq, bq, cq_ = inp                                  # per-chunk slices
        da = dtq * a                                            # (b,q,nh)
        cum = jnp.cumsum(da, axis=1)
        seg = cum[:, :, None, :] - cum[:, None, :, :]           # (b,qi,qj,nh)
        # mask BEFORE exp: exp(+large)=inf and inf*0 in the where-gradient
        # poisons backward with NaNs
        seg = jnp.where(causal[None, :, :, None], seg, -1e30)
        decay = jnp.exp(seg)
        cb = jnp.einsum("bqn,bkn->bqk", cq_, bq,
                        preferred_element_type=jnp.float32)
        scores = (cb[..., None] * decay * dtq[:, None, :, :]).astype(cdt)
        y_diag = jnp.einsum("bqkh,bkhp->bqhp", scores, xq)
        # carried-state contribution + state update
        inner_decay = jnp.exp(cum).astype(cdt)                  # (b,q,nh)
        y_off = jnp.einsum("bqn,bhnp,bqh->bqhp", cq_, h, inner_decay)
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)
        wx = (dtq * decay_to_end).astype(cdt)
        h_new = jnp.exp(cum[:, -1, :]).astype(cdt)[..., None, None] * h \
            + jnp.einsum("bqn,bqh,bqhp->bhnp", bq, wx, xq)
        return h_new, y_diag + y_off

    h_init = jnp.zeros((b, nh, n, hp), cdt) if h0 is None else h0.astype(cdt)
    body = jax.checkpoint(chunk_body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    h_final, y = jax.lax.scan(body, h_init, (xc, dtc, bc, cc))
    y = jnp.moveaxis(y, 0, 1).reshape(b, s, nh, hp)
    return y, h_final


def ssm_block(
    p: Params,
    u: jax.Array,
    cfg: ModelConfig,
    cache: Optional[Dict[str, jax.Array]] = None,
    cache_index: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full Mamba-2 mixer. Train/prefill when cache is None or s>1;
    single-token recurrent decode when s == 1 with a cache."""
    b, s, d = u.shape
    di = cfg.expand * d
    hp = cfg.ssm_head_dim
    nh = di // hp
    n = cfg.ssm_state
    cdt = cfg.cdt

    z = u @ p["w_z"].astype(cdt)
    x = u @ p["w_x"].astype(cdt)
    bm = u @ p["w_b"].astype(cdt)
    cm = u @ p["w_c"].astype(cdt)
    x = shard(x, "batch", None, "mlp")
    dt = jax.nn.softplus(
        (u.astype(jnp.float32) @ p["w_dt"].astype(jnp.float32)) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])  # (nh,)

    if cache is not None and s == 1:
        # ---- decode: recurrent update ----
        cx = jnp.concatenate([cache["conv_x"], x], axis=1)
        xb = jnp.concatenate([cache["conv_b"], bm], axis=1)
        xcn = jnp.concatenate([cache["conv_c"], cm], axis=1)
        w = cfg.d_conv
        xcv = jax.nn.silu(sum(cx[:, -w + i] * p["conv_x"][i].astype(cdt) for i in range(w)))
        bcv = jax.nn.silu(sum(xb[:, -w + i] * p["conv_b"][i].astype(cdt) for i in range(w)))
        ccv = jax.nn.silu(sum(xcn[:, -w + i] * p["conv_c"][i].astype(cdt) for i in range(w)))
        xh = xcv.reshape(b, nh, hp)
        dt1 = dt[:, 0]                                         # (b, nh)
        decay = jnp.exp(dt1 * a).astype(cdt)                   # (b, nh)
        upd = jnp.einsum("bn,bh,bhp->bhnp", bcv, dt1.astype(cdt), xh)
        h = decay[..., None, None] * cache["ssm"] + upd
        y = jnp.einsum("bn,bhnp->bhp", ccv, h)
        y = y + p["d_skip"].astype(cdt)[None, :, None] * xh
        y = y.reshape(b, 1, di)
        new_cache = {
            "ssm": h,
            "conv_x": cx[:, 1:], "conv_b": xb[:, 1:], "conv_c": xcn[:, 1:],
        }
    else:
        # ---- train/prefill: chunked SSD ----
        tail_x = cache["conv_x"] if cache is not None else None
        tail_b = cache["conv_b"] if cache is not None else None
        tail_c = cache["conv_c"] if cache is not None else None
        xcv, ntx = _causal_conv(x, p["conv_x"].astype(cdt), tail_x)
        bcv, ntb = _causal_conv(bm, p["conv_b"].astype(cdt), tail_b)
        ccv, ntc = _causal_conv(cm, p["conv_c"].astype(cdt), tail_c)
        xh = xcv.reshape(b, s, nh, hp)
        h0 = cache["ssm"] if cache is not None else None
        y, h_final = ssd_chunked(xh, dt, a, bcv, ccv, cfg.ssm_chunk, h0)
        y = y + p["d_skip"].astype(cdt)[None, None, :, None] * xh
        y = y.reshape(b, s, di)
        new_cache = None
        if cache is not None:
            new_cache = {"ssm": h_final, "conv_x": ntx, "conv_b": ntb, "conv_c": ntc}

    # gated RMSNorm (mamba2) + output projection
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + cfg.norm_eps)
         ).astype(cdt) * p["norm"].astype(cdt)
    out = y @ p["w_out"].astype(cdt)
    return shard(out, "batch", "seq", None), new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    di = cfg.expand * cfg.d_model
    nh = di // cfg.ssm_head_dim
    w = cfg.d_conv
    return {
        "ssm": jnp.zeros((batch, nh, cfg.ssm_state, cfg.ssm_head_dim), dtype),
        "conv_x": jnp.zeros((batch, w - 1, di), dtype),
        "conv_b": jnp.zeros((batch, w - 1, cfg.ssm_state), dtype),
        "conv_c": jnp.zeros((batch, w - 1, cfg.ssm_state), dtype),
    }
