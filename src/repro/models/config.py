"""Architecture configuration (covers all 10 assigned families)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # "lm" | "encdec" | "ssm" | "hybrid"
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None

    # attention layout: ``attn_pattern`` cycles per layer.  entries:
    #   "global" (full causal), "local" (sliding window), "rec" (RG-LRU)
    attn_pattern: Tuple[str, ...] = ("global",)
    window: int = 0            # sliding-window size for "local" layers
    rope_theta: float = 1e4
    use_rope: bool = True      # False -> learned absolute positions (whisper)
    max_pos: int = 0           # learned-position table size (use_rope=False)
    mrope: bool = False        # qwen2-vl multimodal rotary (3 sections)
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)

    # MoE (0 experts -> dense MLP)
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    d_conv: int = 4
    expand: int = 2
    ssm_chunk: int = 128

    # hybrid (recurrentgemma): RG-LRU width defaults to d_model
    lru_width: Optional[int] = None

    # encoder-decoder (whisper): encoder depth + stub frontend length
    enc_layers: int = 0
    enc_seq: int = 1500        # precomputed frame embeddings (stub frontend)

    # numerics / compute
    mlp_act: str = "silu_gated"  # or "gelu", "gelu_gated"
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    logit_dtype: str = "float32"
    attn_chunk: int = 512       # q-chunk for memory-efficient attention
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # modality stub: "none" | "audio" (frame embeds) | "vision" (patch embeds)
    frontend: str = "none"

    # sub-quadratic long-context capable (SSM/hybrid/sliding-window) —
    # gates the long_500k cell (DESIGN.md skip list)
    long_ok: bool = False

    # unroll the layer-group scan (used by roofline calibration variants:
    # XLA cost_analysis counts a rolled scan body once regardless of the
    # trip count, so calibration compiles shallow UNROLLED models)
    unroll_groups: bool = False

    # §Perf hillclimb lever (serving): keep weights RESIDENT (replicated
    # over the data axis, sharded over model only) instead of ZeRO-3 —
    # decode otherwise re-gathers every layer's weights per generated token
    serve_resident: bool = False

    # §Perf hillclimb lever: gradient-accumulation microbatches (halves
    # the per-step activation live set per doubling)
    microbatches: int = 1

    # §Perf hillclimb lever: ZeRO-3 parameter gathers move bf16 instead of
    # fp32 (cast-before-gather): halves the per-layer FSDP all-gather bytes
    bf16_gather: bool = False

    # §Perf hillclimb lever: remat policy for the group scan:
    # "none" (full recompute) | "dots" (save matmul outputs)
    remat_policy: str = "none"

    # §Perf hillclimb lever: pin the Megatron-SP transition explicitly —
    # the normed block input is constrained to seq-REPLICATED right after
    # the (seq-sharded, fp32-internal) norm, so the all-gather moves bf16
    # norm OUTPUT instead of whatever fp32 intermediate GSPMD picks, and
    # its transpose becomes a bf16 reduce-scatter of the block cotangent.
    explicit_sp: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 128 so the logits dimension
        always shards across the TP axis (an unshardable vocab — e.g.
        mamba2's 50280 on a 16-way axis — replicates (B,S,V) fp32 logits
        and their gradients: tens of GiB).  Padded columns are masked to
        -inf in the forward pass."""
        return (self.vocab + 127) // 128 * 128

    @property
    def pdt(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdt(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def group_size(self) -> int:
        return len(self.attn_pattern)

    @property
    def n_groups(self) -> int:
        return self.n_layers // self.group_size

    @property
    def n_tail(self) -> int:
        """Layers beyond the scanned groups (e.g. recurrentgemma 38 = 12*3+2)."""
        return self.n_layers - self.n_groups * self.group_size

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        h, k, hd = self.n_heads, self.n_kv_heads, self.hd
        attn = d * hd * (h + 2 * k) + h * hd * d
        if self.n_experts > 0:
            gates = 3 if "gated" in self.mlp_act else 2
            mlp = self.n_experts * gates * d * f + d * self.n_experts
        else:
            gates = 3 if "gated" in self.mlp_act else 2
            mlp = gates * d * f
        if self.family == "ssm":
            di = self.expand * d
            nh = di // self.ssm_head_dim
            blk = d * (2 * di + 2 * self.ssm_state + nh) + di * d + 2 * di
        elif self.family == "hybrid":
            lru = self.lru_width or d
            rec = d * 2 * lru + 2 * lru * self.d_conv + 2 * lru * lru + lru * d
            n_rec = sum(1 for p in self.attn_pattern if p == "rec") * self.n_layers // len(self.attn_pattern)
            n_att = self.n_layers - n_rec
            return v * d + n_rec * (rec + mlp + 2 * d) + n_att * (attn + mlp + 2 * d) + d
        else:
            blk = attn + mlp + 2 * d
        total = v * d + self.n_layers * (blk if self.family == "ssm" else attn + mlp + 2 * d) + d
        if not self.tie_embeddings:
            total += v * d
        if self.enc_layers:
            total += self.enc_layers * (attn + mlp + 2 * d) + self.n_layers * attn  # cross-attn
        return total
