"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_i x_t + b_i)          (input gate)
    log a_t = -c * softplus(Lambda) * r_t  (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill evaluates the recurrence with ``associative_scan``
(log-depth, TPU-friendly); decode carries ``h`` — again, a finite-term
recurrence whose state is the exact minimal persisted set (DESIGN.md §4).

The full Griffin recurrent *block* is: linear in -> causal conv(4) ->
RG-LRU, gated by a parallel GeLU branch, then linear out.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.config import ModelConfig
from repro.models.layers import Params, _dense_init
from repro.models.ssm import _causal_conv

_C = 8.0


def init_rglru_block(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    params = {
        "w_gate": _dense_init(ks[0], (d, w), cfg.pdt),
        "w_lin": _dense_init(ks[1], (d, w), cfg.pdt),
        "conv": _dense_init(ks[2], (cfg.d_conv, w), cfg.pdt, fan_in=cfg.d_conv),
        "w_a": _dense_init(ks[3], (w, w), cfg.pdt),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": _dense_init(ks[4], (w, w), cfg.pdt),
        "b_i": jnp.zeros((w,), jnp.float32),
        # init so a^c in ~(0.9, 0.999): Lambda = softplus^{-1}(-log(a)/c)
        "lam": jnp.full((w,), -4.0, jnp.float32),
        "w_out": _dense_init(ks[5], (w, d), cfg.pdt, fan_in=w),
    }
    specs = {
        "w_gate": ("fsdp", "mlp"), "w_lin": ("fsdp", "mlp"),
        "conv": (None, "mlp"), "w_a": ("fsdp", "mlp"), "b_a": (None,),
        "w_i": ("fsdp", "mlp"), "b_i": (None,), "lam": (None,),
        "w_out": ("mlp", "fsdp"),
    }
    return params, specs


def _rg_lru(x: jax.Array, p: Params, h0: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, w). Returns (h_seq, h_final). fp32 recurrence."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(xf @ p["w_i"].astype(jnp.float32) + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r               # (B,S,w)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)
    if h0 is not None:
        # fold the carried state in as a virtual step at t = -1
        a = jnp.concatenate([jnp.zeros_like(a[:, :1]), a], axis=1)
        gated = jnp.concatenate([h0.astype(jnp.float32)[:, None], gated], axis=1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    return h.astype(x.dtype), h[:, -1]


def rglru_block(
    p: Params,
    u: jax.Array,
    cfg: ModelConfig,
    cache: Optional[Dict[str, jax.Array]] = None,
    cache_index: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    b, s, d = u.shape
    cdt = cfg.cdt
    w = cfg.lru_width or d

    gate = jax.nn.gelu(u @ p["w_gate"].astype(cdt))
    x = u @ p["w_lin"].astype(cdt)
    gate = shard(gate, "batch", None, "mlp")
    x = shard(x, "batch", None, "mlp")

    if cache is not None and s == 1:
        # decode: conv tail + single recurrence step
        cx = jnp.concatenate([cache["conv"], x], axis=1)
        kw = cfg.d_conv
        xc = jax.nn.silu(sum(cx[:, -kw + i] * p["conv"][i].astype(cdt)
                             for i in range(kw)))            # (B, w)
        xf = xc.astype(jnp.float32)
        r = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32) + p["b_a"])
        i = jax.nn.sigmoid(xf @ p["w_i"].astype(jnp.float32) + p["b_i"])
        a = jnp.exp(-_C * jax.nn.softplus(p["lam"]) * r)
        h = a * cache["h"].astype(jnp.float32) + \
            jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)
        h = h.astype(cdt)
        y = (h * gate[:, 0])[:, None]                        # (B,1,w)
        new_cache = {"h": h, "conv": cx[:, 1:]}
    else:
        tail = cache["conv"] if cache is not None else None
        xc, ntail = _causal_conv(x, p["conv"].astype(cdt), tail)
        h0 = cache["h"] if cache is not None else None
        h, h_final = _rg_lru(xc, p, h0)
        y = h * gate
        new_cache = None
        if cache is not None:
            new_cache = {"h": h_final.astype(cdt), "conv": ntail}

    out = y @ p["w_out"].astype(cdt)
    return shard(out, "batch", "seq", None), new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), dtype),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, w), dtype),
    }
