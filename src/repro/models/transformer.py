"""Decoder-stack assembly for the "lm", "ssm" and "hybrid" families.

Layer stacking uses ``lax.scan`` over *pattern groups* so compile time is
O(pattern period), not O(depth): the layer pattern (e.g. gemma3's
5 local + 1 global, recurrentgemma's rec/rec/local) forms one group;
``n_layers // period`` groups are scanned with stacked parameters, and any
remainder layers run unrolled (recurrentgemma: 38 = 12*3 + 2).

Activation-memory policy: the residual stream between blocks is
sequence-sharded over "model" (Megatron SP) and each scanned group is
``jax.checkpoint``-ed (full remat) during training.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models import moe as M
from repro.models import recurrent as R
from repro.models import ssm as S
from repro.models.config import ModelConfig

Params = Dict[str, Any]


# ----------------------------------------------------------------------
# per-layer init/apply, dispatched on the pattern kind
# ----------------------------------------------------------------------
def init_layer(key, cfg: ModelConfig, kind: str) -> Tuple[Params, Params]:
    ks = jax.random.split(key, 4)
    params: Params = {}
    specs: Params = {}
    params["ln1"], specs["ln1"] = L.init_rmsnorm(cfg.d_model, cfg.pdt)
    if kind in ("global", "local"):
        params["attn"], specs["attn"] = L.init_attention(ks[0], cfg)
    elif kind == "rec":
        params["rec"], specs["rec"] = R.init_rglru_block(ks[0], cfg)
    elif kind == "ssm":
        params["ssm"], specs["ssm"] = S.init_ssm_block(ks[0], cfg)
    else:
        raise ValueError(f"unknown layer kind {kind!r}")
    if cfg.d_ff > 0:
        params["ln2"], specs["ln2"] = L.init_rmsnorm(cfg.d_model, cfg.pdt)
        if cfg.n_experts > 0:
            params["moe"], specs["moe"] = M.init_moe(ks[1], cfg)
        else:
            params["mlp"], specs["mlp"] = L.init_mlp(ks[1], cfg)
    return params, specs


def apply_layer(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    kind: str,
    cache: Optional[Params] = None,
    cache_index: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.explicit_sp:
        # pin the SP->TP transition: gather the bf16 norm output (not an
        # fp32 intermediate); transpose = bf16 reduce-scatter of cotangent
        h = shard(h, "batch", None, None)
    if kind in ("global", "local"):
        mix, new_cache = L.attention(
            p["attn"], h, cfg, kind=kind, positions=positions,
            cache=cache, cache_index=cache_index)
    elif kind == "rec":
        mix, new_cache = R.rglru_block(p["rec"], h, cfg, cache, cache_index)
    else:  # ssm
        mix, new_cache = S.ssm_block(p["ssm"], h, cfg, cache, cache_index)
    x = x + mix
    x = shard(x, "batch", "seq", None)
    aux = jnp.zeros((), jnp.float32)
    if cfg.d_ff > 0:
        h2 = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if cfg.explicit_sp:
            h2 = shard(h2, "batch", None, None)
        if cfg.n_experts > 0:
            y, aux = M.moe(p["moe"], h2, cfg)
        else:
            y = L.mlp(p["mlp"], h2, cfg)
        x = x + y
        x = shard(x, "batch", "seq", None)
    return x, new_cache, aux


def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_seq: int,
                     dtype) -> Tuple[Params, Params]:
    """Decode-cache pytree + logical sharding specs for one layer."""
    if kind == "global":
        c = {
            "k": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.hd), dtype),
        }
        s = {"k": ("batch", "kv_seq", None, None), "v": ("batch", "kv_seq", None, None)}
    elif kind == "local":
        w = min(cfg.window, max_seq)
        c = {
            "k": jnp.zeros((batch, w, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((batch, w, cfg.n_kv_heads, cfg.hd), dtype),
            "pos": jnp.full((w,), -1, jnp.int32),
        }
        s = {"k": ("batch", "kv_seq", None, None), "v": ("batch", "kv_seq", None, None),
             "pos": ("kv_seq",)}
    elif kind == "rec":
        c = R.init_rglru_cache(cfg, batch, dtype)
        s = {"h": ("batch", "mlp"), "conv": ("batch", None, "mlp")}
    elif kind == "ssm":
        c = S.init_ssm_cache(cfg, batch, dtype)
        s = {"ssm": ("batch", "mlp", None, None), "conv_x": ("batch", None, "mlp"),
             "conv_b": ("batch", None, None), "conv_c": ("batch", None, None)}
    else:
        raise ValueError(kind)
    return c, s


# ----------------------------------------------------------------------
# stack init
# ----------------------------------------------------------------------
def _stack_init(fn, key, n: int):
    """vmap an init over ``n`` keys; specs get a leading (unsharded) layer axis."""
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: fn(k)[0])(keys)
    _, specs = fn(key)  # structure only (cheap single-layer init)
    specs = jax.tree.map(lambda sp: (None,) + tuple(sp), specs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return params, specs


def init_decoder(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    ks = jax.random.split(key, 8)
    params: Params = {}
    specs: Params = {}

    params["embed"] = L._dense_init(ks[0], (cfg.padded_vocab, cfg.d_model), cfg.pdt)
    specs["embed"] = ("vocab", "fsdp")
    if not cfg.use_rope and cfg.max_pos > 0:
        params["pos_embed"] = L._dense_init(ks[5], (cfg.max_pos, cfg.d_model), cfg.pdt)
        specs["pos_embed"] = (None, "fsdp")

    pattern = cfg.attn_pattern
    period = len(pattern)
    n_groups = cfg.n_layers // period

    def one_group(k):
        gk = jax.random.split(k, period)
        ps, ss = {}, {}
        for j, kind in enumerate(pattern):
            ps[str(j)], ss[str(j)] = init_layer(gk[j], cfg, kind)
        return ps, ss

    params["groups"], specs["groups"] = _stack_init(one_group, ks[1], n_groups)

    tail_kinds = pattern[: cfg.n_tail]
    params["tail"], specs["tail"] = {}, {}
    tk = jax.random.split(ks[2], max(cfg.n_tail, 1))
    for i, kind in enumerate(tail_kinds):
        params["tail"][str(i)], specs["tail"][str(i)] = init_layer(tk[i], cfg, kind)

    params["final_norm"], specs["final_norm"] = L.init_rmsnorm(cfg.d_model, cfg.pdt)
    if not cfg.tie_embeddings:
        params["unembed"] = L._dense_init(ks[3], (cfg.d_model, cfg.padded_vocab), cfg.pdt)
        specs["unembed"] = ("fsdp", "vocab")
    return params, specs


def init_decoder_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype
                       ) -> Tuple[Params, Params]:
    pattern = cfg.attn_pattern
    period = len(pattern)
    n_groups = cfg.n_layers // period

    caches: Params = {"groups": {}, "tail": {}}
    cspecs: Params = {"groups": {}, "tail": {}}
    for j, kind in enumerate(pattern):
        c, s = init_layer_cache(cfg, kind, batch, max_seq, dtype)
        caches["groups"][str(j)] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape), c)
        cspecs["groups"][str(j)] = jax.tree.map(
            lambda sp: (None,) + tuple(sp), s, is_leaf=lambda x: isinstance(x, tuple))
    for i, kind in enumerate(pattern[: cfg.n_tail]):
        caches["tail"][str(i)], cspecs["tail"][str(i)] = init_layer_cache(
            cfg, kind, batch, max_seq, dtype)
    return caches, cspecs


# ----------------------------------------------------------------------
# forward
# ----------------------------------------------------------------------
def decoder_forward(
    params: Params,
    tokens: jax.Array,                      # (B, S) int32, or (B, S, d) embeds
    cfg: ModelConfig,
    caches: Optional[Params] = None,
    cache_index: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
    remat: bool = False,
) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Returns (logits, new_caches, aux_loss)."""
    pattern = cfg.attn_pattern
    cdt = cfg.cdt

    if tokens.ndim == 3:
        x = tokens.astype(cdt)              # stubbed modality embeddings
    else:
        x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    if not cfg.use_rope and "pos_embed" in params:
        s = x.shape[1]
        start = jnp.zeros((), jnp.int32) if cache_index is None else cache_index
        pe = jax.lax.dynamic_slice_in_dim(params["pos_embed"], start, s, 0)
        x = x + pe.astype(cdt)[None]
    x = shard(x, "batch", "seq", None)

    aux_total = jnp.zeros((), jnp.float32)
    policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
              if cfg.remat_policy == "dots"
              else jax.checkpoint_policies.nothing_saveable)

    def group_step(x, group_params, group_caches):
        new_caches = {} if group_caches is not None else None
        aux_sum = jnp.zeros((), jnp.float32)
        for j, kind in enumerate(pattern):
            c = None if group_caches is None else group_caches[str(j)]
            if remat and c is None:
                # nested per-LAYER remat: the backward live set is one
                # layer's activations, not the whole pattern group's
                # (a 6-layer gemma3 group would otherwise hold ~6x)
                def one_layer(xx, lp, kind=kind):
                    out, _, aux = apply_layer(lp, xx, cfg, kind,
                                              positions=positions)
                    return out, aux
                x, aux = jax.checkpoint(one_layer, policy=policy)(
                    x, group_params[str(j)])
                nc = None
            else:
                x, nc, aux = apply_layer(group_params[str(j)], x, cfg, kind,
                                         cache=c, cache_index=cache_index,
                                         positions=positions)
            aux_sum = aux_sum + aux
            if new_caches is not None:
                new_caches[str(j)] = nc
        return x, new_caches, aux_sum

    uniform_attn = (set(pattern) <= {"local", "global"} and len(pattern) > 1
                    and cfg.n_tail == 0 and caches is None)
    if uniform_attn:
        # Mixed local/global ATTENTION patterns (gemma3 5:1): all positions
        # share parameter shapes, so flatten the (n_groups, period) stacks
        # into one per-LAYER scan with the mask kind as a traced lax.cond.
        # A period-P group body would otherwise keep P layers' gathered
        # params + activations live through its backward (~P x memory).
        period = len(pattern)
        n_groups = cfg.n_layers // period
        flat = jax.tree.map(
            lambda *ls: jnp.stack(ls, axis=1).reshape((cfg.n_layers,) + ls[0].shape[1:]),
            *[params["groups"][str(j)] for j in range(period)])
        is_global = jnp.asarray([k == "global" for k in pattern] * n_groups)

        def layer_body(carry, xs):
            x, aux = carry
            lp, is_g = xs
            out, _, a = jax.lax.cond(
                is_g,
                lambda xx, pp: apply_layer(pp, xx, cfg, "global", positions=positions),
                lambda xx, pp: apply_layer(pp, xx, cfg, "local", positions=positions),
                x, lp)
            return (out, aux + a), None

        body = jax.checkpoint(layer_body, policy=policy) if remat else layer_body
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), (flat, is_global),
                                         unroll=cfg.unroll_groups)
        new_caches = None
    elif caches is None:
        def scan_body(carry, gp):
            x, aux = carry
            x, _, a = group_step(x, gp, None)
            return (x, aux + a), None
        body = jax.checkpoint(scan_body, policy=policy) if remat else scan_body
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["groups"],
                                          unroll=cfg.unroll_groups)
        new_caches = None
    else:
        def scan_body(carry, xs):
            x, aux = carry
            gp, gc = xs
            x, nc, a = group_step(x, gp, gc)
            return (x, aux + a), nc
        (x, aux_total), new_group_caches = jax.lax.scan(
            scan_body, (x, aux_total), (params["groups"], caches["groups"]),
            unroll=cfg.unroll_groups)
        new_caches = {"groups": new_group_caches, "tail": {}}

    for i, kind in enumerate(pattern[: cfg.n_tail]):
        c = None if caches is None else caches["tail"][str(i)]
        x, nc, aux = apply_layer(params["tail"][str(i)], x, cfg, kind,
                                 cache=c, cache_index=cache_index,
                                 positions=positions)
        aux_total = aux_total + aux
        if new_caches is not None:
            new_caches["tail"][str(i)] = nc

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", x, unembed.astype(cdt))
    logits = shard(logits, "batch", None, "vocab")
    logits = logits.astype(jnp.dtype(cfg.logit_dtype))
    if cfg.padded_vocab != cfg.vocab:
        # mask padding columns (elementwise; fuses into the loss)
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad, jnp.asarray(-1e30, logits.dtype), logits)
    return logits, new_caches, aux_total
