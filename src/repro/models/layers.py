"""Pure-JAX neural building blocks (no flax in this environment).

Conventions
-----------
- Parameters are nested dicts of arrays; every ``init_*`` returns
  ``(params, specs)`` where ``specs`` mirrors the tree with tuples of
  *logical* axis names (see :mod:`repro.distributed.sharding`).
- Param storage dims use the ``fsdp`` logical axis for ZeRO-3 sharding;
  tensor-parallel dims use ``heads`` / ``mlp`` / ``vocab`` / ``experts``.
- Compute runs in ``cfg.cdt`` (bf16) with fp32 accumulation where it
  matters (attention softmax, reductions, logits).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.config import ModelConfig

Params = Dict[str, jax.Array]


# ----------------------------------------------------------------------
# init helpers
# ----------------------------------------------------------------------
def _dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / math.sqrt(max(fan, 1))
    return jax.random.normal(key, shape, dtype) * jnp.asarray(scale, dtype)


def init_rmsnorm(d: int, dtype) -> Tuple[Params, Params]:
    return {"scale": jnp.ones((d,), dtype)}, {"scale": ("embed",)}


def use_param(param: jax.Array, cfg: ModelConfig, *logical) -> jax.Array:
    """Bring a ZeRO-3-sharded parameter to compute dtype at point of use.

    With ``cfg.bf16_gather`` the bf16 cast is pinned BEFORE the FSDP
    all-gather (the constraint drops the fsdp axis on a bf16 value), so
    the gather moves half the bytes; the gradient transposes to a bf16
    reduce-scatter.  ``logical`` is the param's spec with fsdp removed.
    """
    w = param.astype(cfg.cdt)
    if cfg.bf16_gather:
        w = shard(w, *logical)
    return w


def rmsnorm(p: Params, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(x.dtype)


# ----------------------------------------------------------------------
# rotary embeddings (RoPE + M-RoPE)
# ----------------------------------------------------------------------
def _rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = _rope_freqs(hd, theta)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: Tuple[int, int, int]) -> jax.Array:
    """Multimodal RoPE (qwen2-vl): 3 position streams (t, h, w) rotate
    disjoint sections of the frequency spectrum.

    x: (B, S, H, hd); positions3: (3, B, S).  For text-only inputs the
    three streams are identical and M-RoPE reduces to RoPE.
    """
    hd = x.shape[-1]
    freqs = _rope_freqs(hd, theta)  # (hd/2,)
    sec = jnp.cumsum(jnp.asarray((0,) + tuple(sections)))
    idx = jnp.arange(hd // 2)
    which = jnp.clip(jnp.searchsorted(sec[1:], idx, side="right"), 0, 2)  # 0/1/2
    ang_all = positions3[..., None].astype(jnp.float32) * freqs  # (3, B, S, hd/2)
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang_all, 0, -1), which[None, None, :, None], axis=-1
    )[..., 0]
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# attention (GQA, chunked-causal / banded-local / decode)
# ----------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    params = {
        "wq": _dense_init(ks[0], (d, h, hd), cfg.pdt),
        "wk": _dense_init(ks[1], (d, k, hd), cfg.pdt),
        "wv": _dense_init(ks[2], (d, k, hd), cfg.pdt),
        "wo": _dense_init(ks[3], (h, hd, d), cfg.pdt, fan_in=h * hd),
    }
    specs = {
        "wq": ("fsdp", "heads", None),
        "wk": ("fsdp", "kv_heads", None),
        "wv": ("fsdp", "kv_heads", None),
        "wo": ("heads", None, "fsdp"),
    }
    return params, specs


def _sdpa_chunked(q, k, v, *, causal: bool, window: int, chunk_q: int,
                  q_offset=0) -> jax.Array:
    """Memory-efficient attention: scan over q chunks against full K/V.

    Flat-head layout: q (B, S, H, hd); k/v (B, Skv, H, hd) — K/V already
    repeated to full heads so everything shards over the "heads" axis
    (kv_heads alone is rarely divisible by the TP degree).
    O(S * chunk) live memory instead of O(S^2).  fp32 softmax.
    """
    b, s, h, hd = q.shape
    skv = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    cq = min(chunk_q, s)
    while s % cq:       # odd lengths (tests): shrink to a divisor
        cq -= 1
    nq = s // cq

    kv_pos = jnp.arange(skv)

    def one_chunk(i, qc):
        # qc: (B, cq, H, hd)
        scores = jnp.einsum("bqhd,bshd->bhqs", qc, k,
                            preferred_element_type=jnp.float32) * scale
        q_pos = q_offset + i * cq + jnp.arange(cq)
        m = jnp.ones((cq, skv), bool)
        if causal:
            m &= kv_pos[None, :] <= q_pos[:, None]
        if window > 0:
            m &= kv_pos[None, :] > q_pos[:, None] - window
        scores = jnp.where(m[None, None], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return jnp.einsum("bhqs,bshd->bqhd", p, v)

    if nq == 1:
        return one_chunk(0, q)

    qs = q.reshape(b, nq, cq, h, hd)

    # checkpoint the chunk: without it the scan SAVES each chunk's softmax
    # for backward — i.e. the full S x S attention matrix, defeating the
    # chunking. Recompute-in-backward keeps live memory O(chunk).
    ck = jax.checkpoint(one_chunk, policy=jax.checkpoint_policies.nothing_saveable)

    def body(_, xs):
        i, qc = xs
        return None, ck(i, qc)

    _, out = jax.lax.scan(body, None, (jnp.arange(nq), jnp.moveaxis(qs, 1, 0)))
    return jnp.moveaxis(out, 0, 1).reshape(b, s, h, hd)


def _sdpa_banded(q, k, v, *, window: int, chunk: int) -> jax.Array:
    """Sliding-window attention with *static banded* kv access: each q
    chunk gathers only the ``band`` kv chunks that intersect its window —
    true sub-quadratic compute (used for "local" layers; starcoder2,
    gemma3 local, recurrentgemma local)."""
    b, s, h, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    c = min(chunk, s)
    while s % c:        # odd lengths (tests): shrink to a divisor
        c -= 1
    n = s // c
    band = min(n, window // c + 2)
    qs = q.reshape(b, n, c, h, hd)
    ks_ = k.reshape(b, n, c, h, hd)
    vs = v.reshape(b, n, c, h, hd)

    def one(i, qc):
        # gather kv chunks [i-band+1 .. i] (clamped; masked below)
        offs = i - jnp.arange(band - 1, -1, -1)  # ascending chunk ids
        offs_c = jnp.clip(offs, 0, n - 1)
        kg = jnp.take(ks_, offs_c, axis=1).reshape(b, band * c, h, hd)
        vg = jnp.take(vs, offs_c, axis=1).reshape(b, band * c, h, hd)
        scores = jnp.einsum("bqhd,bshd->bhqs", qc, kg,
                            preferred_element_type=jnp.float32) * scale
        q_pos = i * c + jnp.arange(c)
        kv_pos = (offs_c[:, None] * c + jnp.arange(c)[None, :]).reshape(-1)
        valid_chunk = jnp.repeat(offs >= 0, c)
        m = (kv_pos[None, :] <= q_pos[:, None]) \
            & (kv_pos[None, :] > q_pos[:, None] - window) \
            & valid_chunk[None, :]
        scores = jnp.where(m[None, None], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1).astype(vg.dtype)
        return jnp.einsum("bhqs,bshd->bqhd", p, vg)

    if n == 1:
        return one(jnp.asarray(0), q)

    # checkpoint: see _sdpa_chunked — avoid saving per-chunk softmax
    ck = jax.checkpoint(one, policy=jax.checkpoint_policies.nothing_saveable)

    def body(_, xs):
        i, qc = xs
        return None, ck(i, qc)

    _, out = jax.lax.scan(body, None, (jnp.arange(n), jnp.moveaxis(qs, 1, 0)))
    return jnp.moveaxis(out, 0, 1).reshape(b, s, h, hd)


def attention(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    kind: str = "global",          # "global" | "local"
    positions: Optional[jax.Array] = None,
    cache: Optional[Dict[str, jax.Array]] = None,
    cache_index: Optional[jax.Array] = None,
    kv_source: Optional[jax.Array] = None,   # cross-attention (enc-dec)
    causal: bool = True,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """GQA attention. Returns (output, updated_cache).

    Modes:
      - train/prefill: ``cache is None`` -> chunked causal / banded local.
        (prefill-with-cache: pass a zeroed cache to also return K/V.)
      - decode: ``cache`` + ``cache_index`` -> attend over the cache.
        A cache with a ``pos`` entry is a *ring buffer* (sliding-window
        layers keep only ``window`` slots -> O(window) decode memory).
      - cross: ``kv_source`` given -> no causal mask, no cache update
        (K/V computed from the encoder output).
    """
    b, s, d = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = h // kh
    cdt = cfg.cdt

    decode_step = cache is not None and s == 1
    q = jnp.einsum("bsd,dhk->bshk", x, use_param(p["wq"], cfg, None, "heads", None))
    kv_in = x if kv_source is None else kv_source
    k = jnp.einsum("bsd,dhk->bshk", kv_in, use_param(p["wk"], cfg, None, "kv_heads", None))
    v = jnp.einsum("bsd,dhk->bshk", kv_in, use_param(p["wv"], cfg, None, "kv_heads", None))
    if not decode_step:
        # train/prefill: long-seq activations shard over batch + heads.
        # decode must NOT pin shardings: the single-token q is tiny and
        # the cache is sequence-sharded — forcing a head layout would
        # reshard the whole cache every generated token.
        q = shard(q, "batch", None, "heads", None)
        k = shard(k, "batch", None, "kv_heads", None)
        v = shard(v, "batch", None, "kv_heads", None)

    def full_heads(t):
        # GQA K/V repeated to all H query heads so attention shards over
        # "heads" (kv_heads alone rarely divides the TP degree; replicated
        # attention blows both memory and per-chip FLOPs).  The repeat is
        # a broadcast XLA folds into the einsums.
        rep = jnp.repeat(t, g, axis=2)
        return rep if decode_step else shard(rep, "batch", None, "heads", None)

    if kv_source is None and cfg.use_rope:
        if positions is None:
            pos = jnp.arange(s)[None] if cache_index is None else (
                cache_index + jnp.arange(s)[None])
            q = apply_rope(q, pos, cfg.rope_theta)
            k = apply_rope(k, pos, cfg.rope_theta)
        elif cfg.mrope:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        ring = "pos" in cache
        cdtc = cache["k"].dtype
        if ring:
            w = cache["k"].shape[1]
            if s == 1:
                slot = jnp.mod(cache_index, w)
                ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cdtc), slot, 1)
                cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cdtc), slot, 1)
                cpos = jax.lax.dynamic_update_slice_in_dim(
                    cache["pos"], cache_index[None].astype(cache["pos"].dtype), slot, 0)
            else:
                # prefill into the ring: keep the last `w` positions
                if s >= w:
                    shift = (s - w) % w
                    ck = jnp.roll(k[:, -w:].astype(cdtc), shift, axis=1)
                    cv = jnp.roll(v[:, -w:].astype(cdtc), shift, axis=1)
                    cpos = jnp.roll(jnp.arange(s - w, s, dtype=cache["pos"].dtype), shift)
                else:
                    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cdtc), 0, 1)
                    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cdtc), 0, 1)
                    cpos = cache["pos"].at[:s].set(jnp.arange(s, dtype=cache["pos"].dtype))
            new_cache = {"k": ck, "v": cv, "pos": cpos}
            kv_pos = cpos[None, :]
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cdtc), cache_index, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cdtc), cache_index, 1)
            new_cache = {"k": ck, "v": cv}
            kv_pos = jnp.arange(ck.shape[1])[None, :]

        if s == 1:
            # decode: attend over the (seq-sharded) cache — the distributed
            # softmax reductions lower to psums (flash-decode pattern).
            # GROUPED einsums here: repeating K/V to full heads inserts a
            # broadcast GSPMD cannot propagate seq-sharding through, which
            # replicates the whole cache every generated token (§Perf D2).
            valid = (kv_pos <= cache_index) & (kv_pos >= 0)
            if kind == "local" and cfg.window > 0:
                valid &= kv_pos > cache_index - cfg.window
            qg = q.reshape(b, 1, kh, g, hd)
            scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, ck.astype(cdt),
                                preferred_element_type=jnp.float32)
            scores = scores / math.sqrt(hd)
            scores = jnp.where(valid[:, None, None, None], scores, -1e30)
            pr = jax.nn.softmax(scores, axis=-1).astype(cdt)
            out = jnp.einsum("bkgqs,bskh->bqkgh", pr, cv.astype(cdt))
            out = out.reshape(b, 1, h, hd)
            y = jnp.einsum("bshk,hkd->bsd", out, use_param(p["wo"], cfg, "heads", None, None))
            return shard(y, "batch", "seq", None), new_cache

    # train / prefill path (flat heads, sharded over "heads")
    kf, vf = full_heads(k), full_heads(v)
    if kv_source is not None or not causal:
        out = _sdpa_chunked(q, kf, vf, causal=False, window=0,
                            chunk_q=cfg.attn_chunk)
    elif kind == "local" and cfg.window > 0:
        out = _sdpa_banded(q, kf, vf, window=cfg.window, chunk=cfg.attn_chunk)
    else:
        out = _sdpa_chunked(q, kf, vf, causal=True, window=0,
                            chunk_q=cfg.attn_chunk)
    y = jnp.einsum("bshk,hkd->bsd", out, use_param(p["wo"], cfg, "heads", None, None))
    return shard(y, "batch", "seq", None), new_cache


# ----------------------------------------------------------------------
# dense MLP
# ----------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if "gated" in cfg.mlp_act:
        params = {
            "w_in": _dense_init(ks[0], (d, f), cfg.pdt),
            "w_gate": _dense_init(ks[1], (d, f), cfg.pdt),
            "w_out": _dense_init(ks[2], (f, d), cfg.pdt, fan_in=f),
        }
        specs = {"w_in": ("fsdp", "mlp"), "w_gate": ("fsdp", "mlp"),
                 "w_out": ("mlp", "fsdp")}
    else:
        params = {
            "w_in": _dense_init(ks[0], (d, f), cfg.pdt),
            "w_out": _dense_init(ks[2], (f, d), cfg.pdt, fan_in=f),
        }
        specs = {"w_in": ("fsdp", "mlp"), "w_out": ("mlp", "fsdp")}
    return params, specs


def _act(name: str, h: jax.Array, g: Optional[jax.Array]) -> jax.Array:
    if name == "silu_gated":
        return jax.nn.silu(g) * h
    if name == "gelu_gated":
        return jax.nn.gelu(g) * h
    return jax.nn.gelu(h)


def mlp(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    cdt = cfg.cdt
    h = jnp.einsum("bsd,df->bsf", x, use_param(p["w_in"], cfg, None, "mlp"))
    h = shard(h, "batch", None, "mlp")
    g = None
    if "w_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, use_param(p["w_gate"], cfg, None, "mlp"))
        g = shard(g, "batch", None, "mlp")
    a = _act(cfg.mlp_act, h, g)
    y = jnp.einsum("bsf,fd->bsd", a, use_param(p["w_out"], cfg, "mlp", None))
    return shard(y, "batch", "seq", None)
