"""Architecture registry: config discovery + step-function builders.

Every assigned architecture is a module in :mod:`repro.configs` exposing
``CONFIG`` (the exact published shape) and ``SMOKE`` (a reduced same-family
config for CPU tests).  This registry builds, per (arch, shape) cell, the
jit-able step function plus ``ShapeDtypeStruct`` input stand-ins and
shardings — everything the multi-pod dry-run and the roofline need.
"""
from __future__ import annotations

import dataclasses
import importlib
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import AxisRules, current_rules
from repro.models import encdec as E
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_init_specs
from repro.training.train_step import TrainConfig, make_train_step

ARCH_IDS = [
    "moonshot_v1_16b_a3b",
    "dbrx_132b",
    "granite_20b",
    "starcoder2_3b",
    "llama3_8b",
    "gemma3_12b",
    "whisper_small",
    "mamba2_370m",
    "recurrentgemma_9b",
    "qwen2_vl_72b",
]


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str      # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_')}")
    return mod.SMOKE if smoke else mod.CONFIG


def cells_for(cfg: ModelConfig) -> List[str]:
    """The assigned shape cells applicable to this arch (DESIGN.md skips)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.long_ok:
        cells.append("long_500k")
    return cells


# ----------------------------------------------------------------------
# init / forward dispatch
# ----------------------------------------------------------------------
def init_params(cfg: ModelConfig, key) -> Tuple[Any, Any]:
    if cfg.family == "encdec":
        return E.init_encdec(key, cfg)
    return T.init_decoder(key, cfg)


def init_caches(cfg: ModelConfig, batch: int, max_seq: int) -> Tuple[Any, Any]:
    if cfg.family == "encdec":
        return E.init_encdec_cache(cfg, batch, max_seq, cfg.cdt)
    return T.init_decoder_cache(cfg, batch, max_seq, cfg.cdt)


def make_train_forward(cfg: ModelConfig) -> Callable:
    """forward(params, batch) -> (logits, aux)."""
    if cfg.family == "encdec":
        def forward(params, batch):
            enc_out = E.encode(params, batch["frames"], cfg, remat=True)
            logits, _ = E.decode(params, batch["tokens"], enc_out, cfg, remat=True)
            return logits, jnp.zeros((), jnp.float32)
        return forward

    def forward(params, batch):
        logits, _, aux = T.decoder_forward(
            params, batch["tokens"], cfg, positions=batch.get("positions"),
            remat=True)
        return logits, aux
    return forward


def make_prefill(cfg: ModelConfig) -> Callable:
    """prefill(params, inputs, caches) -> (logits, caches)."""
    if cfg.family == "encdec":
        def prefill(params, inputs, caches):
            enc_out = E.encode(params, inputs["frames"], cfg)
            logits, caches = E.decode(params, inputs["tokens"], enc_out, cfg,
                                      caches=caches,
                                      cache_index=jnp.zeros((), jnp.int32))
            return logits, caches
        return prefill

    def prefill(params, inputs, caches):
        tokens = inputs["tokens"] if isinstance(inputs, dict) else inputs
        pos = inputs.get("positions") if isinstance(inputs, dict) else None
        logits, caches, _ = T.decoder_forward(
            params, tokens, cfg, caches=caches,
            cache_index=jnp.zeros((), jnp.int32), positions=pos)
        return logits, caches
    return prefill


def make_decode(cfg: ModelConfig) -> Callable:
    """decode(params, tok (B,1), caches, index) -> (logits, caches)."""
    if cfg.family == "encdec":
        def decode(params, tok, caches, index):
            logits, caches = E.decode(params, tok, None, cfg, caches=caches,
                                      cache_index=index)
            return logits, caches
        return decode

    def decode(params, tok, caches, index):
        logits, caches, _ = T.decoder_forward(params, tok, cfg, caches=caches,
                                              cache_index=index)
        return logits, caches
    return decode


# ----------------------------------------------------------------------
# dry-run cell construction (ShapeDtypeStructs + shardings, no allocation)
# ----------------------------------------------------------------------
def _specs_to_shardings(spec_tree, rules: AxisRules, struct_tree=None):
    """Logical specs -> NamedShardings; with ``struct_tree`` the mapping is
    shape-aware (non-divisible axes degrade to replication per tensor)."""
    from repro.distributed.sharding import spec_for_shape

    is_leaf = lambda x: isinstance(x, tuple)
    if struct_tree is None:
        return jax.tree.map(
            lambda sp: NamedSharding(rules.mesh, rules.spec(*sp)),
            spec_tree, is_leaf=is_leaf)
    return jax.tree.map(
        lambda sp, st: NamedSharding(rules.mesh, spec_for_shape(rules, st.shape, sp)),
        spec_tree, struct_tree, is_leaf=is_leaf)


def batch_structs(cfg: ModelConfig, shape: Shape) -> Tuple[Dict, Dict]:
    """(structs, logical spec tuples) for one training batch."""
    b, s = shape.batch, shape.seq
    structs: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    if cfg.frontend == "vision":
        structs["tokens"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.cdt)
        specs["tokens"] = ("batch", None, None)
        structs["positions"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
        specs["positions"] = (None, "batch", None)
    else:
        structs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        specs["tokens"] = ("batch", None)
    if cfg.family == "encdec":
        structs["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_seq, cfg.d_model), cfg.cdt)
        specs["frames"] = ("batch", None, None)
    structs["targets"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    specs["targets"] = ("batch", None)
    return structs, specs


@dataclasses.dataclass
class Cell:
    """One (arch x shape) dry-run cell: callable + abstract inputs."""

    arch: str
    shape: Shape
    fn: Callable
    in_structs: Tuple
    in_shardings: Tuple
    donate: Tuple[int, ...] = ()


def abstract_params(cfg: ModelConfig) -> Tuple[Any, Any]:
    """(ShapeDtypeStruct tree, logical spec tree) without any allocation.

    ``eval_shape`` traces the init abstractly; the static spec tree (plain
    Python tuples of logical names) is captured from the trace via closure.
    """
    captured = {}

    def f(key):
        params, specs = init_params(cfg, key)
        captured["specs"] = specs
        return params

    structs = jax.eval_shape(f, jax.random.PRNGKey(0))
    return structs, captured["specs"]


def abstract_caches(cfg: ModelConfig, batch: int, max_seq: int) -> Tuple[Any, Any]:
    captured = {}

    def f():
        caches, specs = init_caches(cfg, batch, max_seq)
        captured["specs"] = specs
        return caches

    structs = jax.eval_shape(f)
    return structs, captured["specs"]


def build_cell(cfg: ModelConfig, arch: str, shape_name: str, rules: AxisRules,
               opt_cfg: Optional[AdamWConfig] = None) -> Cell:
    shape = SHAPES[shape_name]
    if cfg.serve_resident and shape.kind != "train":
        # serving keeps weights resident: drop the ZeRO (fsdp) axis so
        # decode stops re-gathering every layer's weights per token
        r = dict(rules.rules)
        r["fsdp"] = None
        rules = AxisRules(rules.mesh, r)
    params_structs, param_specs = abstract_params(cfg)
    params_sh = _specs_to_shardings(param_specs, rules, params_structs)

    if shape.kind == "train":
        opt_cfg = opt_cfg or AdamWConfig()
        fwd = make_train_forward(cfg)
        step = make_train_step(fwd, opt_cfg,
                               TrainConfig(microbatches=cfg.microbatches))
        opt_structs = jax.eval_shape(adamw_init, params_structs)
        opt_specs = adamw_init_specs(param_specs)
        opt_sh = _specs_to_shardings(opt_specs, rules, opt_structs)
        # the step scalar stays replicated
        opt_sh["step"] = NamedSharding(rules.mesh, P())
        bstructs, bspecs = batch_structs(cfg, shape)
        b_sh = _specs_to_shardings(bspecs, rules, bstructs)
        return Cell(arch, shape, step,
                    (params_structs, opt_structs, bstructs),
                    (params_sh, opt_sh, b_sh), donate=(0, 1))

    cache_structs, cache_specs = abstract_caches(cfg, shape.batch, shape.seq)
    cache_sh = _specs_to_shardings(cache_specs, rules, cache_structs)

    if shape.kind == "prefill":
        fn = make_prefill(cfg)
        bstructs, bspecs = batch_structs(cfg, shape)
        bstructs.pop("targets")
        bspecs.pop("targets")
        b_sh = _specs_to_shardings(bspecs, rules, bstructs)
        return Cell(arch, shape, fn,
                    (params_structs, bstructs, cache_structs),
                    (params_sh, b_sh, cache_sh), donate=(2,))

    # decode: one new token against a seq_len cache
    from repro.distributed.sharding import spec_for_shape
    fn = make_decode(cfg)
    tok = jax.ShapeDtypeStruct((shape.batch, 1), jnp.int32)
    tok_sh = NamedSharding(rules.mesh,
                           spec_for_shape(rules, tok.shape, ("batch", None)))
    idx = jax.ShapeDtypeStruct((), jnp.int32)
    idx_sh = NamedSharding(rules.mesh, P())
    return Cell(arch, shape, fn,
                (params_structs, tok, cache_structs, idx),
                (params_sh, tok_sh, cache_sh, idx_sh), donate=(2,))
