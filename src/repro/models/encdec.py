"""Encoder-decoder family (whisper-small backbone).

The conv/log-mel audio frontend is a STUB per the assignment:
``input_specs()`` supplies precomputed frame embeddings ``(B, enc_seq, d)``.
The encoder is a bidirectional transformer; the decoder adds causal
self-attention (+KV cache) and cross-attention to the encoder output
(cross K/V computed once at prefill and cached).

Whisper uses learned absolute positions (``use_rope=False``).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.transformer import _stack_init

Params = Dict[str, Any]


def _init_enc_layer(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    ks = jax.random.split(key, 2)
    p, s = {}, {}
    p["ln1"], s["ln1"] = L.init_rmsnorm(cfg.d_model, cfg.pdt)
    p["attn"], s["attn"] = L.init_attention(ks[0], cfg)
    p["ln2"], s["ln2"] = L.init_rmsnorm(cfg.d_model, cfg.pdt)
    p["mlp"], s["mlp"] = L.init_mlp(ks[1], cfg)
    return p, s


def _init_dec_layer(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    p["ln1"], s["ln1"] = L.init_rmsnorm(cfg.d_model, cfg.pdt)
    p["attn"], s["attn"] = L.init_attention(ks[0], cfg)
    p["ln_x"], s["ln_x"] = L.init_rmsnorm(cfg.d_model, cfg.pdt)
    p["xattn"], s["xattn"] = L.init_attention(ks[1], cfg)
    p["ln2"], s["ln2"] = L.init_rmsnorm(cfg.d_model, cfg.pdt)
    p["mlp"], s["mlp"] = L.init_mlp(ks[2], cfg)
    return p, s


def init_encdec(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    ks = jax.random.split(key, 8)
    params: Params = {}
    specs: Params = {}
    params["embed"] = L._dense_init(ks[0], (cfg.padded_vocab, cfg.d_model), cfg.pdt)
    specs["embed"] = ("vocab", "fsdp")
    params["enc_pos"] = L._dense_init(ks[1], (cfg.enc_seq, cfg.d_model), cfg.pdt)
    specs["enc_pos"] = (None, "fsdp")
    params["dec_pos"] = L._dense_init(ks[2], (max(cfg.max_pos, 1), cfg.d_model), cfg.pdt)
    specs["dec_pos"] = (None, "fsdp")
    params["enc"], specs["enc"] = _stack_init(
        lambda k: _init_enc_layer(k, cfg), ks[3], cfg.enc_layers)
    params["dec"], specs["dec"] = _stack_init(
        lambda k: _init_dec_layer(k, cfg), ks[4], cfg.n_layers)
    params["enc_norm"], specs["enc_norm"] = L.init_rmsnorm(cfg.d_model, cfg.pdt)
    params["final_norm"], specs["final_norm"] = L.init_rmsnorm(cfg.d_model, cfg.pdt)
    # whisper ties the unembedding to the token embedding
    return params, specs


def init_encdec_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype
                      ) -> Tuple[Params, Params]:
    kv = lambda s_len: {
        "k": jnp.zeros((batch, s_len, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((batch, s_len, cfg.n_kv_heads, cfg.hd), dtype),
    }
    kv_spec = {"k": ("batch", "kv_seq", None, None), "v": ("batch", "kv_seq", None, None)}
    stack = lambda c: jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), c)
    caches = {"self": stack(kv(max_seq)), "cross": stack(kv(cfg.enc_seq))}
    cspecs = {
        "self": jax.tree.map(lambda sp: (None,) + tuple(sp), kv_spec,
                             is_leaf=lambda x: isinstance(x, tuple)),
        "cross": jax.tree.map(lambda sp: (None,) + tuple(sp), kv_spec,
                              is_leaf=lambda x: isinstance(x, tuple)),
    }
    return caches, cspecs


def encode(params: Params, frames: jax.Array, cfg: ModelConfig,
           remat: bool = False) -> jax.Array:
    """frames: (B, enc_seq, d) precomputed embeddings (stub frontend)."""
    cdt = cfg.cdt
    x = frames.astype(cdt) + params["enc_pos"].astype(cdt)[None]
    x = shard(x, "batch", "seq", None)

    def body(x, lp):
        h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        mix, _ = L.attention(lp["attn"], h, cfg, causal=False)
        x = x + mix
        h2 = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + L.mlp(lp["mlp"], h2, cfg)
        return shard(x, "batch", "seq", None), None

    b = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable
                       ) if remat else body
    x, _ = jax.lax.scan(b, x, params["enc"], unroll=cfg.unroll_groups)
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def decode(
    params: Params,
    tokens: jax.Array,                 # (B, S)
    enc_out: Optional[jax.Array],      # (B, enc_seq, d); None if cross cached
    cfg: ModelConfig,
    caches: Optional[Params] = None,
    cache_index: Optional[jax.Array] = None,
    remat: bool = False,
) -> Tuple[jax.Array, Optional[Params]]:
    cdt = cfg.cdt
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    start = jnp.zeros((), jnp.int32) if cache_index is None else cache_index
    pe = jax.lax.dynamic_slice_in_dim(params["dec_pos"], start, s, 0)
    x = x + pe.astype(cdt)[None]
    x = shard(x, "batch", "seq", None)

    use_cached_cross = caches is not None and enc_out is None

    def body(x, xs):
        lp, lc = xs if caches is not None else (xs, None)
        h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        mix, nself = L.attention(lp["attn"], h, cfg,
                                 cache=None if lc is None else lc["self"],
                                 cache_index=cache_index)
        x = x + mix
        hx = L.rmsnorm(lp["ln_x"], x, cfg.norm_eps)
        if use_cached_cross:
            # cross K/V already cached at prefill: score against them
            mixx, _ = _cross_from_cache(lp["xattn"], hx, lc["cross"], cfg)
            ncross = lc["cross"]
        else:
            mixx, ncross_kv = L.attention(lp["xattn"], hx, cfg, kv_source=enc_out,
                                          cache=None, causal=False)
            # cache cross K/V for subsequent decode steps
            if lc is not None:
                k = jnp.einsum("bsd,dhk->bshk", enc_out.astype(cdt),
                               lp["xattn"]["wk"].astype(cdt))
                v = jnp.einsum("bsd,dhk->bshk", enc_out.astype(cdt),
                               lp["xattn"]["wv"].astype(cdt))
                ncross = {"k": k.astype(lc["cross"]["k"].dtype),
                          "v": v.astype(lc["cross"]["v"].dtype)}
            else:
                ncross = None
        x = x + mixx
        h2 = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + L.mlp(lp["mlp"], h2, cfg)
        x = shard(x, "batch", "seq", None)
        if lc is None:
            return x, None
        return x, {"self": nself, "cross": ncross}

    if caches is None:
        bfn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable
                             ) if remat else body
        x, _ = jax.lax.scan(bfn, x, params["dec"], unroll=cfg.unroll_groups)
        new_caches = None
    else:
        x, new_caches = jax.lax.scan(body, x, (params["dec"], caches),
                                     unroll=cfg.unroll_groups)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(cdt))
    logits = shard(logits, "batch", None, "vocab")
    logits = logits.astype(jnp.dtype(cfg.logit_dtype))
    if cfg.padded_vocab != cfg.vocab:
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad, jnp.asarray(-1e30, logits.dtype), logits)
    return logits, new_caches


def _cross_from_cache(pa: Params, hx: jax.Array, cross: Params, cfg: ModelConfig):
    """Cross-attention against cached encoder K/V (decode steps)."""
    import math
    b, s, _ = hx.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = h // kh
    cdt = cfg.cdt
    q = jnp.einsum("bsd,dhk->bshk", hx, pa["wq"].astype(cdt))
    kf = jnp.repeat(cross["k"].astype(cdt), g, axis=2)
    vf = jnp.repeat(cross["v"].astype(cdt), g, axis=2)
    scores = jnp.einsum("bqhd,bshd->bhqs", q, kf,
                        preferred_element_type=jnp.float32) / math.sqrt(hd)
    pr = jax.nn.softmax(scores, axis=-1).astype(cdt)
    out = jnp.einsum("bhqs,bshd->bqhd", pr, vf)
    y = jnp.einsum("bshk,hkd->bsd", out, pa["wo"].astype(cdt))
    return y, None
