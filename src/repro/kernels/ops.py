"""Jit'd dispatch wrappers for the Pallas kernels.

On TPU the Pallas path compiles natively; on CPU (this container) the
kernels run under ``interpret=True`` (the kernel body executed step-by-
step for correctness) or fall back to the jnp reference for speed.
``mode`` resolution:

- ``"auto"``    — pallas on TPU, reference on CPU (fast tests/benches)
- ``"pallas"``  — force the kernel (interpret=True off-TPU): oracle tests
- ``"ref"``     — force the jnp reference
"""
from __future__ import annotations

import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as _ref
from repro.kernels.fused_cg import fused_cg_update_pallas
from repro.kernels.stencil7 import stencil7_pallas
from repro.nvm import gf256 as _gf256


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(mode: str) -> str:
    if mode == "auto":
        return "pallas" if _on_tpu() else "ref"
    return mode


@functools.partial(jax.jit, static_argnames=("mode", "bz"))
def stencil7(u: jax.Array, mode: str = "auto", bz: int = 8) -> jax.Array:
    """7-point stencil SpMV; drop-in for :func:`repro.kernels.ref.stencil7_ref`."""
    m = _resolve(mode)
    if m == "ref":
        return _ref.stencil7_ref(u)
    return stencil7_pallas(u, bz=bz, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("mode", "bm"))
def fused_cg_update(
    x: jax.Array,
    r: jax.Array,
    p: jax.Array,
    ap: jax.Array,
    alpha: jax.Array,
    inv_diag: jax.Array,
    mode: str = "auto",
    bm: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused PCG vector update; drop-in for the 4-op jnp sequence.
    ``bm=None`` lets the kernel pick the largest legal row tile."""
    m = _resolve(mode)
    if m == "ref":
        return _ref.fused_cg_update_ref(x, r, p, ap, alpha, inv_diag)
    return fused_cg_update_pallas(x, r, p, ap, alpha, inv_diag, bm=bm,
                                  interpret=not _on_tpu())


def rs_encode(data: Sequence[np.ndarray], nparity: int,
              mode: str = "auto") -> List[np.ndarray]:
    """GF(2^8) P/Q parity encode; drop-in for
    :func:`repro.nvm.gf256.rs_encode` and **the registered fused-encode
    toggle**: persistence backends route every parity encode through
    here (repro-lint rule RL204) so one seam decides between the numpy
    reference and the fused Pallas kernel — both bit-identical.

    ``mode="auto"`` keeps numpy off-TPU (the fast host path) and the
    Pallas kernel on TPU; ``"pallas"`` forces the kernel (interpreted
    off-TPU — the oracle-test and fused-persist path); ``"ref"`` forces
    numpy.
    """
    m = _resolve(mode)
    if m == "ref":
        return _gf256.rs_encode(data, nparity)
    from repro.kernels.gf256_encode import gf256_rs_encode_pallas

    return gf256_rs_encode_pallas(data, nparity, interpret=not _on_tpu())
