"""Pallas TPU kernel: fused PCG vector update (lines 4-7a of Algorithm 1).

CG's per-iteration vector work is HBM-bandwidth-bound (arithmetic
intensity < 1 flop/byte).  Executed as separate XLA ops, the update
reads/writes each of ``x, r, z`` plus ``p, ap`` several times:

    x' = x + a p; r' = r - a ap; z' = M^{-1} r'; rz' = <r', z'>
    (>= 9n reads + 3n writes as 4 standalone ops)

This kernel performs all four in **one pass over VMEM tiles**: 5n reads +
3n writes (the theoretical minimum with a fused reduction), a ~1.5x cut
of HBM traffic on the dominant term of the solver roofline.  The dual
reduction is accumulated per-tile into a (grid,)-shaped partials vector
(hierarchical reduction: VREG -> VMEM partial -> tiny jnp.sum epilogue).

Layout: inputs are viewed as ``(m, 128)`` — lane-aligned for the VPU;
``bm`` rows per tile (sublane-multiple).  ``inv_diag`` supports any
diagonal preconditioner (Jacobi); pass ones for plain CG.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128

#: default row-tile cap: tiles never exceed this many (·, 128) rows
DEFAULT_BM = 256


def largest_divisor_bm(m: int, cap: int = DEFAULT_BM) -> int:
    """The largest divisor of ``m`` that is <= ``cap`` (>= 1 always):
    the auto block-rows choice, so every lane-aligned ``n`` gets a
    legal tiling instead of a divisibility error."""
    bm = min(cap, m)
    while m % bm:
        bm -= 1
    return bm


def _fused_cg_kernel(x_ref, r_ref, p_ref, ap_ref, inv_ref, alpha_ref,
                     xo_ref, ro_ref, zo_ref, partial_ref):
    alpha = alpha_ref[0]
    p = p_ref[...]
    ap = ap_ref[...]
    xn = x_ref[...] + alpha * p
    rn = r_ref[...] - alpha * ap
    zn = rn * inv_ref[...]
    xo_ref[...] = xn
    ro_ref[...] = rn
    zo_ref[...] = zn
    # fp32 accumulation for the dual reduction (bf16 partial sums of
    # near-cancelling terms would destroy CG's beta)
    partial_ref[0, 0] = jnp.sum(rn.astype(jnp.float32) * zn.astype(jnp.float32))


def fused_cg_update_pallas(
    x: jax.Array,
    r: jax.Array,
    p: jax.Array,
    ap: jax.Array,
    alpha: jax.Array,
    inv_diag: jax.Array,
    bm: Optional[int] = None,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Single-pass fused CG update; returns (x', r', z', rz').

    ``bm=None`` (the default) picks the largest divisor of the row
    count ``m = n // 128`` not exceeding :data:`DEFAULT_BM`, so any
    lane-aligned ``n`` tiles legally (e.g. ``n = 384*128`` -> bm=192).
    An explicit ``bm`` that does not divide ``m`` still raises — that
    is a caller bug, not a size to silently repair.
    """
    n = x.shape[0]
    if n % LANES != 0:
        raise ValueError(f"n={n} must be a multiple of {LANES}")
    m = n // LANES
    if bm is None:
        bm = largest_divisor_bm(m)
    else:
        bm = min(bm, m)
        if m % bm != 0:
            raise ValueError(
                f"rows m={m} not divisible by block rows bm={bm}")
    grid = m // bm

    def as2d(v):
        return v.reshape(m, LANES)

    vec_spec = pl.BlockSpec((bm, LANES), lambda i: (i, 0))
    alpha_arr = jnp.broadcast_to(jnp.asarray(alpha, x.dtype), (1,))

    xo, ro, zo, partials = pl.pallas_call(
        _fused_cg_kernel,
        grid=(grid,),
        in_specs=[
            vec_spec, vec_spec, vec_spec, vec_spec, vec_spec,
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            vec_spec, vec_spec, vec_spec,
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, LANES), x.dtype),
            jax.ShapeDtypeStruct((m, LANES), x.dtype),
            jax.ShapeDtypeStruct((m, LANES), x.dtype),
            jax.ShapeDtypeStruct((grid, 1), jnp.float32),
        ],
        interpret=interpret,
    )(as2d(x), as2d(r), as2d(p), as2d(ap), as2d(inv_diag), alpha_arr)

    rz = jnp.sum(partials).astype(x.dtype)  # tiny fp32 epilogue
    return xo.reshape(n), ro.reshape(n), zo.reshape(n), rz


# ----------------------------------------------------------------------
# Fused persist staging (DESIGN.md §13): the update pass already holds
# every vector the PCG recovery schema needs (the search direction ``p``
# is one of its five reads), so the erasure stripe's staging work —
# chunking ``p`` block-wise into K shards and deriving the P/Q parity
# bytes — can ride the same tile pass instead of a separate host-side
# numpy pass.  The emitted chunk and parity layouts are byte-identical
# to ``ErasureSession._shards`` + ``gf256.rs_encode``.
# ----------------------------------------------------------------------
def _make_persist_kernel(k_data: int, nparity: int, chunk: int,
                         itemsize: int):
    def kernel(x_ref, r_ref, p_ref, ap_ref, inv_ref, alpha_ref,
               exp_ref, log_ref,
               xo_ref, ro_ref, zo_ref, partial_ref, ch_ref, par_ref):
        alpha = alpha_ref[0]
        p = p_ref[...]
        ap = ap_ref[...]
        xn = x_ref[...] + alpha * p
        rn = r_ref[...] - alpha * ap
        zn = rn * inv_ref[...]
        xo_ref[...] = xn
        ro_ref[...] = rn
        zo_ref[...] = zn
        partial_ref[0, 0] = jnp.sum(rn.astype(jnp.float32)
                                    * zn.astype(jnp.float32))
        # --- staging free rider: this tile IS one partition block of p
        stripe = p.reshape(k_data, chunk)
        ch_ref[0] = stripe
        dbytes = jax.lax.bitcast_convert_type(
            stripe, jnp.uint8).reshape(k_data, chunk * itemsize)
        pp = dbytes[0]
        for j in range(1, k_data):
            pp = pp ^ dbytes[j]
        par_ref[0, 0] = pp
        if nparity == 2:
            exp = exp_ref[...]
            logt = log_ref[...]
            q = None
            for j in range(k_data):
                dj = dbytes[j]
                idx = jnp.take(logt, dj.astype(jnp.int32)) + (j % 255)
                term = jnp.take(exp, idx).astype(jnp.uint8)
                term = jnp.where(dj == jnp.uint8(0), jnp.uint8(0), term)
                q = term if q is None else q ^ term
            par_ref[0, 1] = q

    return kernel


def fused_cg_update_persist_pallas(
    x: jax.Array,
    r: jax.Array,
    p: jax.Array,
    ap: jax.Array,
    alpha: jax.Array,
    inv_diag: jax.Array,
    *,
    nblocks: int,
    k_data: int,
    nparity: int,
    interpret: bool = False,
):
    """Fused CG update + erasure persist staging in one tile pass.

    Returns ``(x', r', z', rz', chunks, parity)`` where ``chunks`` is a
    ``(nblocks, k_data, chunk)`` array of ``p``'s stripe chunks (chunk
    ``j`` of the full vector is ``chunks[:, j, :].reshape(-1)``) and
    ``parity`` a ``(nblocks, nparity, chunk*itemsize)`` uint8 array of
    the P/Q parity bytes, both byte-identical to what
    ``ErasureSession._shards`` computes from the same ``p``.

    The grid runs one partition block per step (tile rows =
    ``block_size // 128``), so the stripe chunking aligns with the
    update tiling; sizes that break that alignment (``128 ∤
    block_size`` or ``k_data ∤ block_size``) raise and callers fall
    back to the unfused path (DESIGN.md §13).
    """
    from repro.nvm import gf256

    n = x.shape[0]
    if n % nblocks != 0:
        raise ValueError(f"n={n} not divisible by nblocks={nblocks}")
    bs = n // nblocks
    if bs % LANES != 0:
        raise ValueError(
            f"block_size={bs} must be a multiple of {LANES} for the "
            f"fused persist pass")
    if bs % k_data != 0:
        raise ValueError(
            f"block_size={bs} not divisible by k_data={k_data}: the "
            f"stripe pads chunks, which the fused pass does not model")
    gf256.vandermonde(nparity, k_data)
    chunk = bs // k_data
    itemsize = jnp.dtype(x.dtype).itemsize
    rb = bs // LANES
    m = n // LANES

    def as2d(v):
        return v.reshape(m, LANES)

    vec_spec = pl.BlockSpec((rb, LANES), lambda i: (i, 0))
    table = lambda size: pl.BlockSpec((size,), lambda i: (0,))  # noqa: E731
    alpha_arr = jnp.broadcast_to(jnp.asarray(alpha, x.dtype), (1,))
    exp = jnp.asarray(gf256.EXP, dtype=jnp.int32)
    logt = jnp.asarray(gf256.LOG, dtype=jnp.int32)

    xo, ro, zo, partials, chunks, parity = pl.pallas_call(
        _make_persist_kernel(k_data, nparity, chunk, itemsize),
        grid=(nblocks,),
        in_specs=[
            vec_spec, vec_spec, vec_spec, vec_spec, vec_spec,
            pl.BlockSpec((1,), lambda i: (0,)),
            table(510), table(256),
        ],
        out_specs=[
            vec_spec, vec_spec, vec_spec,
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, k_data, chunk), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, nparity, chunk * itemsize),
                         lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, LANES), x.dtype),
            jax.ShapeDtypeStruct((m, LANES), x.dtype),
            jax.ShapeDtypeStruct((m, LANES), x.dtype),
            jax.ShapeDtypeStruct((nblocks, 1), jnp.float32),
            jax.ShapeDtypeStruct((nblocks, k_data, chunk), x.dtype),
            jax.ShapeDtypeStruct((nblocks, nparity, chunk * itemsize),
                                 jnp.uint8),
        ],
        interpret=interpret,
    )(as2d(x), as2d(r), as2d(p), as2d(ap), as2d(inv_diag), alpha_arr,
      exp, logt)

    rz = jnp.sum(partials).astype(x.dtype)
    return xo.reshape(n), ro.reshape(n), zo.reshape(n), rz, chunks, parity


def fused_pass_traffic(n: int, itemsize: int, k_data: int,
                       nparity: int) -> dict:
    """HBM traffic accounting of the fused update+staging pass (the
    roofline's persist-bandwidth term): the bare update moves 5n reads
    + 3n writes; fused staging adds the chunk emission (n values) and
    the parity emission (n * P/K values) as extra writes — the encode
    *reads* ride for free on the p read the update already does."""
    update_read = 5 * n * itemsize
    update_write = 3 * n * itemsize
    staged_write = n * itemsize + (n * itemsize * nparity) // k_data
    total = update_read + update_write + staged_write
    return {
        "update_read_bytes": update_read,
        "update_write_bytes": update_write,
        "staged_write_bytes": staged_write,
        "total_bytes": total,
        # share of the fused pass's HBM traffic that is persist staging
        "persist_bw_fraction": staged_write / total,
        # what a standalone staging pass would add: re-read the vector
        # (n) plus the same writes — the traffic the fusion removes
        "unfused_extra_read_bytes": n * itemsize,
    }
