"""Pallas TPU kernel: fused PCG vector update (lines 4-7a of Algorithm 1).

CG's per-iteration vector work is HBM-bandwidth-bound (arithmetic
intensity < 1 flop/byte).  Executed as separate XLA ops, the update
reads/writes each of ``x, r, z`` plus ``p, ap`` several times:

    x' = x + a p; r' = r - a ap; z' = M^{-1} r'; rz' = <r', z'>
    (>= 9n reads + 3n writes as 4 standalone ops)

This kernel performs all four in **one pass over VMEM tiles**: 5n reads +
3n writes (the theoretical minimum with a fused reduction), a ~1.5x cut
of HBM traffic on the dominant term of the solver roofline.  The dual
reduction is accumulated per-tile into a (grid,)-shaped partials vector
(hierarchical reduction: VREG -> VMEM partial -> tiny jnp.sum epilogue).

Layout: inputs are viewed as ``(m, 128)`` — lane-aligned for the VPU;
``bm`` rows per tile (sublane-multiple).  ``inv_diag`` supports any
diagonal preconditioner (Jacobi); pass ones for plain CG.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _fused_cg_kernel(x_ref, r_ref, p_ref, ap_ref, inv_ref, alpha_ref,
                     xo_ref, ro_ref, zo_ref, partial_ref):
    alpha = alpha_ref[0]
    p = p_ref[...]
    ap = ap_ref[...]
    xn = x_ref[...] + alpha * p
    rn = r_ref[...] - alpha * ap
    zn = rn * inv_ref[...]
    xo_ref[...] = xn
    ro_ref[...] = rn
    zo_ref[...] = zn
    # fp32 accumulation for the dual reduction (bf16 partial sums of
    # near-cancelling terms would destroy CG's beta)
    partial_ref[0, 0] = jnp.sum(rn.astype(jnp.float32) * zn.astype(jnp.float32))


def fused_cg_update_pallas(
    x: jax.Array,
    r: jax.Array,
    p: jax.Array,
    ap: jax.Array,
    alpha: jax.Array,
    inv_diag: jax.Array,
    bm: int = 256,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Single-pass fused CG update; returns (x', r', z', rz')."""
    n = x.shape[0]
    if n % LANES != 0:
        raise ValueError(f"n={n} must be a multiple of {LANES}")
    m = n // LANES
    bm = min(bm, m)
    if m % bm != 0:
        raise ValueError(f"rows m={m} not divisible by block rows bm={bm}")
    grid = m // bm

    def as2d(v):
        return v.reshape(m, LANES)

    vec_spec = pl.BlockSpec((bm, LANES), lambda i: (i, 0))
    alpha_arr = jnp.broadcast_to(jnp.asarray(alpha, x.dtype), (1,))

    xo, ro, zo, partials = pl.pallas_call(
        _fused_cg_kernel,
        grid=(grid,),
        in_specs=[
            vec_spec, vec_spec, vec_spec, vec_spec, vec_spec,
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            vec_spec, vec_spec, vec_spec,
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, LANES), x.dtype),
            jax.ShapeDtypeStruct((m, LANES), x.dtype),
            jax.ShapeDtypeStruct((m, LANES), x.dtype),
            jax.ShapeDtypeStruct((grid, 1), jnp.float32),
        ],
        interpret=interpret,
    )(as2d(x), as2d(r), as2d(p), as2d(ap), as2d(inv_diag), alpha_arr)

    rz = jnp.sum(partials).astype(x.dtype)  # tiny fp32 epilogue
    return xo.reshape(n), ro.reshape(n), zo.reshape(n), rz
