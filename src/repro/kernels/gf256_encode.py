"""Pallas TPU kernel: tiled GF(2^8) Reed-Solomon P/Q parity encode.

The erasure backend's stripe write (DESIGN.md §8) splits every slot
vector into K data chunks and derives P parity chunks (P ∈ {1, 2}) with
:func:`repro.nvm.gf256.rs_encode` — a numpy table-lookup pass that runs
entirely outside the compute stream, reading the K chunks once per
parity row.  This kernel fuses both parity rows into **one read of the
data**: each grid step pulls a ``(K, bm, 128)`` byte tile into VMEM and
emits the matching P and Q tiles together —

- P parity is the plain bytewise XOR of the K shards (Vandermonde row 0
  is all ones);
- Q parity weights shard ``j`` by the generator power ``g^j`` before
  XOR-accumulating, computed exactly as ``gf256.gf_mul`` does it:
  ``EXP[LOG[d] + LOG[g^j]]`` with zero operands masked.  The EXP/LOG
  tables ride into the kernel as lane-resident lookup inputs
  (510 + 256 entries, a few KB of VMEM), and ``LOG[g^j] == j % 255`` by
  table construction, so the per-shard coefficient lookup folds into a
  static offset.

Same table, same index arithmetic, same masking — the parity bytes are
**bit-identical** to :func:`repro.nvm.gf256.rs_encode`, which stays the
fallback and the test oracle (``tests/test_gf256_encode.py`` sweeps
K ∈ {2,..,6}, P ∈ {1,2} and ragged tails in interpret mode).

Backends never call this module directly: dispatch goes through
:func:`repro.kernels.ops.rs_encode` (the registered fused-persist
toggle), which repro-lint rule RL204 enforces.
"""
from __future__ import annotations

import functools
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.nvm import gf256

LANES = 128

#: default byte-tile rows per grid step ((bm, 128) = 8 KB per shard)
DEFAULT_BM = 64


def _make_encode_kernel(k_data: int, nparity: int):
    """Build the tile kernel for a static (K, P) stripe shape."""

    def kernel(d_ref, exp_ref, log_ref, *out_refs):
        d = d_ref[...]                       # (K, bm, LANES) uint8
        p = d[0]
        for j in range(1, k_data):
            p = p ^ d[j]
        out_refs[0][...] = p
        if nparity == 2:
            exp = exp_ref[...]               # (510,) int32 values of EXP
            logt = log_ref[...]              # (256,) int32 LOG table
            q = None
            for j in range(k_data):
                dj = d[j]
                # gf_mul(g^j, dj) == EXP[LOG[g^j] + LOG[dj]], zeros
                # masked; LOG[g^j] == j % 255 by table construction.
                idx = jnp.take(logt, dj.astype(jnp.int32)) + (j % 255)
                term = jnp.take(exp, idx).astype(jnp.uint8)
                term = jnp.where(dj == jnp.uint8(0), jnp.uint8(0), term)
                q = term if q is None else q ^ term
            out_refs[1][...] = q

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("nparity", "bm", "interpret"))
def _encode_tiles(arr: jax.Array, exp: jax.Array, logt: jax.Array,
                  nparity: int, bm: int, interpret: bool):
    k_data, m, _ = arr.shape
    grid = m // bm
    tile = pl.BlockSpec((k_data, bm, LANES), lambda i: (0, i, 0))
    table = lambda size: pl.BlockSpec((size,), lambda i: (0,))  # noqa: E731
    out_spec = pl.BlockSpec((bm, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _make_encode_kernel(k_data, nparity),
        grid=(grid,),
        in_specs=[tile, table(510), table(256)],
        out_specs=[out_spec] * nparity,
        out_shape=[jax.ShapeDtypeStruct((m, LANES), jnp.uint8)] * nparity,
        interpret=interpret,
    )(arr, exp, logt)


def gf256_rs_encode_pallas(data: Sequence[np.ndarray], nparity: int,
                           bm: int = DEFAULT_BM,
                           interpret: bool = False) -> List[np.ndarray]:
    """Drop-in for :func:`repro.nvm.gf256.rs_encode`: ``nparity``
    parity shards over equal-length uint8 data shards, both parities
    emitted from a single tiled read of the data.

    Ragged lengths are zero-padded up to the tile grid internally
    (parity of zero bytes is zero on both rows) and sliced back, so the
    returned shards are bit-identical to the numpy reference for any
    length.
    """
    shards = [np.ascontiguousarray(d, dtype=np.uint8).reshape(-1)
              for d in data]
    if len({s.shape for s in shards}) != 1:
        raise ValueError(
            f"data shards must share one shape, got "
            f"{[s.shape for s in shards]}")
    # same arity validation (and error text) as the numpy reference
    gf256.vandermonde(nparity, len(shards))
    n = shards[0].size
    tile_bytes = bm * LANES
    padded = max(tile_bytes, -(-n // tile_bytes) * tile_bytes)
    arr = np.zeros((len(shards), padded // LANES, LANES), dtype=np.uint8)
    for j, s in enumerate(shards):
        arr[j].reshape(-1)[:n] = s
    exp = jnp.asarray(gf256.EXP, dtype=jnp.int32)
    logt = jnp.asarray(gf256.LOG, dtype=jnp.int32)
    out = _encode_tiles(jnp.asarray(arr), exp, logt, nparity=nparity,
                        bm=bm, interpret=interpret)
    return [np.asarray(o).reshape(-1)[:n].copy() for o in out]
