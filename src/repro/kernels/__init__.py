"""Pallas TPU kernels for the solver's compute hot-spots.

- ``stencil7.py`` — 7-point Poisson SpMV (the PCG/HPCG hot loop):
  z-slab VMEM tiling with single-plane halo blocks.
- ``fused_cg.py`` — fused PCG vector update (Alg. 1 lines 4-7a) with an
  fp32 dual-reduction: one HBM pass instead of four ops.
- ``ops.py`` — jit'd dispatch (pallas on TPU / interpret / jnp ref).
- ``ref.py`` — pure-jnp oracles; every kernel is swept against them over
  shapes/dtypes in ``tests/test_kernels.py``.

The NN side intentionally has no custom kernels: the paper's contribution
is solver-level; transformer blocks rely on XLA (chunked attention and
SSD are structured for fusion instead — see DESIGN.md §2).
"""
from repro.kernels import ops, ref  # noqa: F401
