"""Pallas TPU kernel: 7-point Poisson stencil SpMV (the PCG hot spot).

TPU-native design (DESIGN.md §2): the 3-D grid is tiled into **z-slabs**
held in VMEM.  Each program instance owns one slab of shape
``(bz, ny, nx)`` plus the two neighbouring z-planes (the halo), brought in
as separate 1-plane blocks so the slab itself is fetched exactly once
from HBM.  In-slab neighbour access is pure VREG shuffling; the stencil is
a VPU (8x128 vector unit) workload — arithmetic intensity ~1 flop/byte,
so the kernel's job is to reach the HBM bandwidth roofline by avoiding
any re-fetch of ``u``.

Alignment: ``nx`` should be a multiple of 128 (lanes) and ``ny`` a
multiple of 8 (sublanes) for full VPU utilization; other sizes work but
pad internally on the VREG path.

The z-halo planes use *clamped* index maps (block index ``i*bz - 1`` /
``(i+1)*bz`` clamped into range); the kernel masks the contribution at
the physical domain boundary (homogeneous Dirichlet), so the clamp's
duplicated plane is never read into the result.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stencil7_kernel(prev_ref, cur_ref, nxt_ref, out_ref, *, bz: int, nblocks: int):
    i = pl.program_id(0)
    u = cur_ref[...]  # (bz, ny, nx) slab in VMEM

    # z-neighbours: shift within the slab; edge rows take the halo planes.
    prev_plane = prev_ref[...]  # (1, ny, nx): plane i*bz - 1 (clamped)
    nxt_plane = nxt_ref[...]    # (1, ny, nx): plane (i+1)*bz (clamped)
    prev_plane = jnp.where(i == 0, jnp.zeros_like(prev_plane), prev_plane)
    nxt_plane = jnp.where(i == nblocks - 1, jnp.zeros_like(nxt_plane), nxt_plane)
    z_minus = jnp.concatenate([prev_plane, u[:-1]], axis=0)
    z_plus = jnp.concatenate([u[1:], nxt_plane], axis=0)

    # y/x-neighbours: VREG shifts with zero fill (Dirichlet).
    zero_y = jnp.zeros_like(u[:, :1, :])
    y_minus = jnp.concatenate([zero_y, u[:, :-1, :]], axis=1)
    y_plus = jnp.concatenate([u[:, 1:, :], zero_y], axis=1)
    zero_x = jnp.zeros_like(u[:, :, :1])
    x_minus = jnp.concatenate([zero_x, u[:, :, :-1]], axis=2)
    x_plus = jnp.concatenate([u[:, :, 1:], zero_x], axis=2)

    out_ref[...] = 6.0 * u - z_minus - z_plus - y_minus - y_plus - x_minus - x_plus


def stencil7_pallas(u: jax.Array, bz: int = 8, interpret: bool = False) -> jax.Array:
    """``A @ u`` for the 7-point stencil via a z-slab Pallas kernel."""
    nz, ny, nx = u.shape
    if nz % bz != 0:
        raise ValueError(f"nz={nz} not divisible by z-block {bz}")
    nblocks = nz // bz

    def prev_map(i):
        # plane index i*bz - 1, clamped to >= 0 (masked at i == 0)
        return (jnp.maximum(i * bz - 1, 0), 0, 0)

    def next_map(i):
        # plane index (i+1)*bz, clamped to <= nz-1 (masked at last block)
        return (jnp.minimum((i + 1) * bz, nz - 1), 0, 0)

    kernel = functools.partial(_stencil7_kernel, bz=bz, nblocks=nblocks)
    return pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1, ny, nx), prev_map),
            pl.BlockSpec((bz, ny, nx), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, ny, nx), next_map),
        ],
        out_specs=pl.BlockSpec((bz, ny, nx), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(u.shape, u.dtype),
        interpret=interpret,
    )(u, u, u)
