"""Pure-jnp oracles for the Pallas kernels (ground truth for tests)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def stencil7_ref(u: jax.Array) -> jax.Array:
    """7-point Poisson stencil, homogeneous Dirichlet BC. u: (nz, ny, nx)."""
    p = jnp.pad(u, 1)
    return (
        6.0 * u
        - p[:-2, 1:-1, 1:-1]
        - p[2:, 1:-1, 1:-1]
        - p[1:-1, :-2, 1:-1]
        - p[1:-1, 2:, 1:-1]
        - p[1:-1, 1:-1, :-2]
        - p[1:-1, 1:-1, 2:]
    )


def fused_cg_update_ref(
    x: jax.Array,
    r: jax.Array,
    p: jax.Array,
    ap: jax.Array,
    alpha: jax.Array,
    inv_diag: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused PCG lines 4-7a: x', r', z' = P r', and rz' = <r', z'>.

    Reference semantics for the single-pass TPU kernel: one read of each
    input, one write of each output, reduction produced on the fly.
    """
    xn = x + alpha * p
    rn = r - alpha * ap
    zn = rn * inv_diag
    # fp32 accumulation (the kernel contract): bf16 sums of near-
    # cancelling r.z terms would destroy CG's beta
    rz = jnp.sum(rn.astype(jnp.float32) * zn.astype(jnp.float32)).astype(x.dtype)
    return xn, rn, zn, rz
