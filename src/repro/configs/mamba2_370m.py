"""mamba2-370m [arXiv:2405.21060; unverified].

SSM (attention-free): 48L d_model=1024, ssm_state=128, expand=2
(d_inner=2048, 32 heads of 64), vocab=50280.  SSD chunked scan; decode
state is O(1) in context -> long_500k native.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=1,       # attention-free; SSD heads derive from expand*d/head_dim
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    attn_pattern=("ssm",),
    ssm_state=128,
    ssm_head_dim=64,
    expand=2,
    ssm_chunk=256,
    long_ok=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab=512,
    attn_pattern=("ssm",),
    ssm_state=16,
    ssm_head_dim=16,
    expand=2,
    ssm_chunk=16,
)
