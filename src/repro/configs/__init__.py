"""Assigned architecture configs (exact published shapes) + reduced SMOKE
configs of the same family for CPU tests.

Each module exposes ``CONFIG`` and ``SMOKE``.  Sources are cited per file;
verification tier from the assignment is noted in the docstring.
"""
