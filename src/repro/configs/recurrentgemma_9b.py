"""recurrentgemma-9b (Griffin) [arXiv:2402.19427; unverified].

Hybrid: 38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000.
Pattern rec/rec/local (window 2048), RG-LRU width 4096.
38 = 12 scanned groups of 3 + 2 tail layers (rec, rec).
Constant-size recurrent state + O(window) ring caches -> long_500k native.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    attn_pattern=("rec", "rec", "local"),
    window=2048,
    lru_width=4096,
    rope_theta=1e4,
    mlp_act="gelu_gated",
    long_ok=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke",
    family="hybrid",
    n_layers=5,   # 1 group of 3 + tail (rec, rec)
    d_model=48,
    n_heads=4,
    n_kv_heads=1,
    d_ff=96,
    vocab=512,
    attn_pattern=("rec", "rec", "local"),
    window=16,
    lru_width=48,
    mlp_act="gelu_gated",
    attn_chunk=16,
)
