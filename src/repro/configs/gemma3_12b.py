"""gemma3-12b [hf:google/gemma-3-1b-pt (family); unverified].

Dense LM: 48L d_model=3840 16H (GQA kv=8, head_dim=256) d_ff=15360
vocab=262144.  5:1 local:global attention (window 1024); the hybrid
pattern keeps long_500k decodable (local layers use O(window) ring
caches; the 8 global layers hold sequence-sharded 512k caches).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="lm",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab=262144,
    attn_pattern=("local", "local", "local", "local", "local", "global"),
    window=1024,
    rope_theta=1e6,
    mlp_act="gelu_gated",
    long_ok=True,
)

SMOKE = ModelConfig(
    name="gemma3-smoke",
    family="lm",
    n_layers=6,
    d_model=48,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab=512,
    attn_pattern=("local", "local", "local", "local", "local", "global"),
    window=16,
    mlp_act="gelu_gated",
    attn_chunk=16,
)
