"""dbrx-132b [hf:databricks/dbrx-base; unverified].

MoE LM: 40L d_model=6144 48H (GQA kv=8) d_ff=10752/expert vocab=100352,
16 experts top-4 (fine-grained).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="lm",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    n_experts=16,
    top_k=4,
    rope_theta=5e5,
    mlp_act="silu_gated",
    long_ok=False,  # full attention -> long_500k skipped
)

SMOKE = ModelConfig(
    name="dbrx-smoke",
    family="lm",
    n_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    d_ff=64,
    vocab=512,
    n_experts=4,
    top_k=2,
    mlp_act="silu_gated",
    attn_chunk=32,
)
