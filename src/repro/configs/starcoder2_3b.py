"""starcoder2-3b [arXiv:2402.19173; hf].

Dense LM: 30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.
Sliding-window attention (4096) + RoPE -> sub-quadratic, long_500k runs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="lm",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab=49152,
    attn_pattern=("local",),
    window=4096,
    rope_theta=1e5,
    mlp_act="gelu",
    long_ok=True,
)

SMOKE = ModelConfig(
    name="starcoder2-smoke",
    family="lm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab=512,
    attn_pattern=("local",),
    window=32,
    mlp_act="gelu",
    attn_chunk=16,
)
