"""whisper-small [arXiv:2212.04356; unverified].

Encoder-decoder audio backbone: 12L enc + 12L dec, d_model=768 12H (kv=12)
d_ff=3072 vocab=51865.  The conv/log-mel frontend is a STUB: input_specs
provide precomputed frame embeddings (B, 1500, d).  Learned absolute
positions (no RoPE); the decoder position table is extended to the
assigned 32k shapes (original 448 — systems exercise, noted in DESIGN.md).
long_500k skipped (full attention).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,
    enc_layers=12,
    enc_seq=1500,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    use_rope=False,
    max_pos=32768,
    tie_embeddings=True,
    mlp_act="gelu",
    frontend="audio",
    long_ok=False,
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="encdec",
    n_layers=2,
    enc_layers=2,
    enc_seq=24,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=512,
    use_rope=False,
    max_pos=128,
    tie_embeddings=True,
    mlp_act="gelu",
    frontend="audio",
    attn_chunk=16,
)
