"""qwen2-vl-72b [arXiv:2409.12191; hf].

VLM backbone: 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064,
M-RoPE (temporal/height/width sections).  The vision tower is a STUB:
input_specs provide precomputed patch embeddings (B, S, d) plus the
3-stream M-RoPE position ids.  long_500k skipped (full attention).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="lm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    mrope=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    mlp_act="silu_gated",
    frontend="vision",
    long_ok=False,
)

SMOKE = ModelConfig(
    name="qwen2vl-smoke",
    family="lm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    mrope=True,
    mrope_sections=(4, 2, 2),
    mlp_act="silu_gated",
    frontend="vision",
    attn_chunk=32,
)
