"""llama3-8b [arXiv:2407.21783; unverified].

Dense LM: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="lm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    rope_theta=5e5,
    mlp_act="silu_gated",
    long_ok=False,  # full attention -> long_500k skipped
)

SMOKE = ModelConfig(
    name="llama3-smoke",
    family="lm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    mlp_act="silu_gated",
    attn_chunk=32,
)
