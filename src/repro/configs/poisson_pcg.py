"""The paper's own workload: PCG on the 7-point stencil of a 3-D Poisson
equation (HPCG-style), with ESR / NVM-ESR recovery.

``GRIDS`` defines the dry-run problem sizes on the production mesh
(z sharded across all 512 devices) and ``SMOKE`` the CPU test problem.
"""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    name: str
    grid: Tuple[int, int, int]     # (nz, ny, nx)
    nblocks: int                   # process blocks (z-slabs)
    precond: str = "jacobi"
    esr_mode: str = "nvm"          # "none" | "nvm" | "inmemory"
    tol: float = 1e-10
    maxiter: int = 10_000
    persistence_period: int = 1
    persist_mode: str = "sync"     # "sync" | "overlap" (driver pipeline)
    variant: str = "auto"          # "auto" (GSPMD baseline) | "shardmap" (§Perf)

    def solve_config(self):
        """The generic-driver :class:`repro.solvers.SolveConfig` slice of
        this launch config (grid/mesh/precond fields are launch-side)."""
        from repro.solvers import SolveConfig

        return SolveConfig(tol=self.tol, maxiter=self.maxiter,
                           persistence_period=self.persistence_period,
                           persist_mode=self.persist_mode)


# dry-run cells: one pod-scale grid per ESR mode (512-way z sharding)
GRIDS = {
    "pcg_1g": SolverConfig("pcg_1g", (1024, 1024, 1024), 512),
    "pcg_1g_esr": SolverConfig("pcg_1g_esr", (1024, 1024, 1024), 512, esr_mode="inmemory"),
    "pcg_128m": SolverConfig("pcg_128m", (512, 512, 512), 512),
    "pcg_128m_esr": SolverConfig("pcg_128m_esr", (512, 512, 512), 512, esr_mode="inmemory"),
    # §Perf hillclimbed variants: shard_map + single-plane ppermute halos
    # (+ Pallas stencil/fused-update kernels on TPU)
    "pcg_1g_opt": SolverConfig("pcg_1g_opt", (1024, 1024, 1024), 512, variant="shardmap"),
    "pcg_1g_esr_opt": SolverConfig("pcg_1g_esr_opt", (1024, 1024, 1024), 512,
                                   esr_mode="inmemory", variant="shardmap"),
}

SMOKE = SolverConfig("pcg_smoke", (16, 12, 10), 8)
