"""granite-20b (code) [arXiv:2405.04324; hf].

Dense LM: 52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.
GPT-BigCode-style: plain GeLU MLP, multi-query attention.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="lm",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    mlp_act="gelu",
    long_ok=False,  # full attention -> long_500k skipped
)

SMOKE = ModelConfig(
    name="granite-smoke",
    family="lm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=256,
    vocab=512,
    mlp_act="gelu",
    attn_chunk=32,
)
