"""moonshot-v1-16b-a3b (Moonlight-16B-A3B) [hf:moonshotai/Moonlight-16B-A3B; hf].

MoE LM: 48L d_model=2048 16H (kv=16, MHA) d_ff=1408/expert vocab=163840,
64 experts top-6 (fine-grained experts, deepseek-style).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="lm",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163840,
    n_experts=64,
    top_k=6,
    rope_theta=5e4,
    mlp_act="silu_gated",
    long_ok=False,  # pure full attention -> long_500k skipped (DESIGN.md)
)

SMOKE = ModelConfig(
    name="moonshot-smoke",
    family="lm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=32,
    vocab=512,
    n_experts=8,
    top_k=2,
    rope_theta=5e4,
    mlp_act="silu_gated",
    attn_chunk=32,
)
