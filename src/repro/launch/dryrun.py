import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
#   512 placeholder host devices back the (2,16,16) production mesh.

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x applicable input shape) cell — and the paper's
own PCG solver cells — this lowers and compiles the jitted step on the
production mesh (single-pod 16x16 and multi-pod 2x16x16), prints
``memory_analysis()`` (fits/doesn't fit) and ``cost_analysis()`` (FLOPs,
bytes), extracts collective bytes from the partitioned HLO, and appends
one JSON row per cell to ``results/dryrun.jsonl`` for EXPERIMENTS.md.

Usage::

    python -m repro.launch.dryrun                         # all cells
    python -m repro.launch.dryrun --arch llama3_8b --shape train_4k
    python -m repro.launch.dryrun --mesh multi            # 2x16x16 only
    python -m repro.launch.dryrun --solver                # PCG cells only
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax

from repro.distributed.sharding import set_rules, use_rules
from repro.launch.mesh import make_production_mesh
from repro.launch import roofline as RL
from repro.models import registry as R


def _memory_row(compiled) -> dict:
    ma = compiled.memory_analysis()
    try:
        return {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "peak_bytes": int(ma.argument_size_in_bytes + ma.output_size_in_bytes
                              + ma.temp_size_in_bytes),
        }
    except AttributeError:
        return {"raw": str(ma)}


def _compile_cell(cfg, arch, shape_name, rules, mesh):
    cell = R.build_cell(cfg, arch, shape_name, rules)
    with mesh:
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.in_structs)
        return lowered.compile(), cell


def _depth_variant(cfg, groups: int):
    """Same architecture at reduced UNROLLED depth (scan calibration):
    rolled scan bodies are counted once by cost_analysis regardless of
    trip count, so the calibration variants unroll their (short) scans."""
    import dataclasses as dc
    period = cfg.group_size
    kw = {"n_layers": period * groups, "name": f"{cfg.name}@g{groups}",
          "unroll_groups": True}
    if cfg.family == "encdec":
        kw["enc_layers"] = groups
    return dc.replace(cfg, **kw)


OPT_LEVERS = {
    # §Perf hillclimb levers, applied via --opt (see EXPERIMENTS.md §Perf)
    "logit_bf16": {"logit_dtype": "bfloat16"},
    "explicit_sp": {"explicit_sp": True},
    "bf16_gather": {"bf16_gather": True},
    "remat_dots": {"remat_policy": "dots"},
    "serve_resident": {"serve_resident": True},
    "micro2": {"microbatches": 2},
    "micro4": {"microbatches": 4},
}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             results_path: Optional[str] = "results/dryrun.jsonl",
             verbose: bool = True, calibrate: Optional[bool] = None,
             opt: Optional[str] = None) -> dict:
    """Compile one (arch x shape x mesh) cell.

    XLA's ``cost_analysis`` counts a ``scan`` body ONCE regardless of trip
    count, so FLOPs/bytes/collective-bytes are calibrated by compiling
    1-group and 2-group depth variants and extrapolating the per-group
    delta across the full depth.  Memory analysis always comes from the
    full-depth compile.  Calibration runs on the single-pod mesh (the
    roofline table is single-pod); the multi-pod pass proves compilation.
    """
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = len(mesh.devices.reshape(-1))
    rules = set_rules(mesh)
    cfg = R.get_config(arch)
    label = arch
    if opt:
        import dataclasses as dc
        kw = {}
        for lever in opt.split(","):
            kw.update(OPT_LEVERS[lever])
        cfg = dc.replace(cfg, **kw)
        label = f"{arch}+{opt}"
    if calibrate is None:
        calibrate = not multi_pod

    t0 = time.monotonic()
    compiled, cell = _compile_cell(cfg, arch, shape_name, rules, mesh)
    dt = time.monotonic() - t0
    mem = _memory_row(compiled)

    row = {
        "arch": label, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "compile_s": round(dt, 1),
        "memory": mem,
        "ok": True,
    }

    if calibrate:
        c1, _ = _compile_cell(_depth_variant(cfg, 1), arch, shape_name, rules, mesh)
        c2, _ = _compile_cell(_depth_variant(cfg, 2), arch, shape_name, rules, mesh)
        r1 = RL.analyze(c1, chips)
        r2 = RL.analyze(c2, chips)
        period = cfg.group_size
        groups_eff = cfg.n_groups + cfg.n_tail / period
        if cfg.family == "encdec":
            groups_eff = cfg.n_layers  # enc+dec scale together per group

        # the microbatch accumulation loop is ALSO a scan (counted once):
        # per-layer work sits inside it, so totals scale by cfg.microbatches
        mb = cfg.microbatches if shape_name == "train_4k" else 1

        def extrap(a, b):
            return (a + (b - a) * (groups_eff - 1)) * mb

        coll_kinds = set(r1.coll_by_kind) | set(r2.coll_by_kind)
        colls = {k: int(extrap(r1.coll_by_kind.get(k, 0), r2.coll_by_kind.get(k, 0)))
                 for k in coll_kinds}
        roof = RL.Roofline(
            flops=extrap(r1.flops, r2.flops),
            hbm_bytes=extrap(r1.hbm_bytes, r2.hbm_bytes),
            coll_bytes=float(sum(colls.values())),
            coll_by_kind=colls,
            chips=chips,
        )
        mflops = RL.model_flops(cfg, cell.shape, cell.shape.kind)
        row.update({
            "roofline": roof.as_row(),
            "coll_by_kind": roof.coll_by_kind,
            "model_flops_global": mflops,
            "model_flops_per_chip": mflops / chips,
            "useful_flop_ratio": (mflops / chips) / roof.flops if roof.flops else None,
            "calibration": {"groups_eff": groups_eff,
                            "flops_g1": r1.flops, "flops_g2": r2.flops},
        })
        if verbose:
            print(f"[{arch} x {shape_name} x {row['mesh']}] compile {dt:.1f}s | "
                  f"peak {mem.get('peak_bytes', 0)/2**30:.2f} GiB/dev | "
                  f"flops/chip {roof.flops:.3e} (useful {row['useful_flop_ratio']:.2f}) | "
                  f"bottleneck {roof.bottleneck} "
                  f"(c={roof.t_compute*1e3:.1f} m={roof.t_memory*1e3:.1f} "
                  f"x={roof.t_collective*1e3:.1f} ms)")
    elif verbose:
        print(f"[{label} x {shape_name} x {row['mesh']}] compile {dt:.1f}s | "
              f"peak {mem.get('peak_bytes', 0)/2**30:.2f} GiB/dev | multi-pod pass OK")

    if results_path:
        os.makedirs(os.path.dirname(results_path), exist_ok=True)
        with open(results_path, "a") as f:
            f.write(json.dumps(row) + "\n")
    return row


def run_solver_cell(grid_name: str, multi_pod: bool,
                    results_path: Optional[str] = "results/dryrun.jsonl",
                    verbose: bool = True) -> dict:
    from repro.configs.poisson_pcg import GRIDS
    from repro.core.spmv import lower_pcg_step
    sc = GRIDS[grid_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = len(mesh.devices.reshape(-1))
    nz, ny, nx = sc.grid
    t0 = time.monotonic()
    lowered = lower_pcg_step(mesh, nz, ny, nx, esr_mode=sc.esr_mode,
                             variant=sc.variant)
    compiled = lowered.compile()
    dt = time.monotonic() - t0
    mem = _memory_row(compiled)
    roof = RL.analyze(compiled, chips)
    n = nz * ny * nx
    # PCG iteration useful flops: SpMV(7pt: 7 mul+6 add ~ 13/pt... count 2*nnz
    # = 14n) + 2 dots (4n) + 3 axpy (6n) + precond (n)  => ~25n flops global
    useful = 25.0 * n / chips
    row = {
        "arch": "poisson_pcg", "shape": grid_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "esr_mode": sc.esr_mode,
        "compile_s": round(dt, 1),
        "memory": mem,
        "roofline": roof.as_row(),
        "coll_by_kind": roof.coll_by_kind,
        "model_flops_per_chip": useful,
        "useful_flop_ratio": useful / roof.flops if roof.flops else None,
        "ok": True,
    }
    if verbose:
        print(f"[pcg {grid_name} ({sc.esr_mode}) x {row['mesh']}] compile {dt:.1f}s | "
              f"peak {mem.get('peak_bytes',0)/2**30:.3f} GiB/dev | "
              f"bottleneck {roof.bottleneck} colls {roof.coll_by_kind}")
    if results_path:
        os.makedirs(os.path.dirname(results_path), exist_ok=True)
        with open(results_path, "a") as f:
            f.write(json.dumps(row) + "\n")
    return row


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all applicable)")
    ap.add_argument("--mesh", default="both", choices=("single", "multi", "both"))
    ap.add_argument("--solver", action="store_true", help="run PCG solver cells only")
    ap.add_argument("--with-solver", action="store_true", help="include PCG cells")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already present in --out")
    ap.add_argument("--opt", default=None,
                    help="comma-separated §Perf levers (see OPT_LEVERS)")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = []

    done = set()
    if args.resume and os.path.exists(args.out):
        for line in open(args.out):
            try:
                r = json.loads(line)
                if r.get("ok"):
                    done.add((r["arch"], r["shape"], r["mesh"]))
            except json.JSONDecodeError:
                pass

    def _skip(arch, shape, mp):
        return (arch, shape, "2x16x16" if mp else "16x16") in done

    if args.solver or args.with_solver:
        from repro.configs.poisson_pcg import GRIDS
        for g in GRIDS:
            for mp in meshes:
                if _skip("poisson_pcg", g, mp):
                    continue
                try:
                    run_solver_cell(g, mp, args.out)
                except Exception as e:  # noqa: BLE001
                    failures.append((f"pcg/{g}", mp, repr(e)))
                    traceback.print_exc()
        if args.solver:
            _finish(failures)
            return

    archs = [args.arch] if args.arch else R.ARCH_IDS
    for arch in archs:
        cfg = R.get_config(arch)
        shapes = [args.shape] if args.shape else R.cells_for(cfg)
        for shape in shapes:
            for mp in meshes:
                if _skip(arch, shape, mp):
                    continue
                try:
                    run_cell(arch, shape, mp, args.out, opt=args.opt)
                except Exception as e:  # noqa: BLE001
                    failures.append((f"{arch}/{shape}", mp, repr(e)))
                    traceback.print_exc()
    _finish(failures)


def _finish(failures) -> None:
    if failures:
        print(f"\nDRY-RUN FAILURES ({len(failures)}):")
        for name, mp, err in failures:
            print(f"  {name} multi_pod={mp}: {err}")
        raise SystemExit(1)
    print("\nDRY-RUN: all requested cells lowered + compiled successfully.")


if __name__ == "__main__":
    main()
