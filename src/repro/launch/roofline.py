"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (EXPERIMENTS.md §Roofline):

    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

``cost_analysis`` provides FLOPs and bytes-accessed for the whole (SPMD)
program — i.e. per-partition values multiplied by nothing: XLA reports the
per-device program, so we treat them as per-chip and divide by per-chip
peaks.  Collective bytes are NOT in cost_analysis: we parse the
post-partitioning HLO text and sum operand bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[\w\[\],{}]+)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )


def _parse_shape_bytes(type_str: str) -> int:
    """Bytes of one HLO shape string like ``f32[8,128]`` (tuples summed)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes per collective kind from post-SPMD HLO text."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        if not line or "=" not in line:
            continue
        m = re.search(
            r"=\s*(.*?)\s*(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start)?\(", line)
        if m is None:
            continue
        kind = m.group(2)
        # `-done` ops would double-count their `-start` halves
        if f"{kind}-done" in line.split("=")[1][:80]:
            continue
        nbytes = _parse_shape_bytes(m.group(1))
        out[kind] = out.get(kind, 0) + nbytes
    return out


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    coll_by_kind: Dict[str, int]
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        # per-chip collective bytes over one ICI link direction (the
        # bottleneck link on a 2-D torus for ring collectives)
        return self.coll_bytes / ICI_BW_PER_LINK

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time_lb(self) -> float:
        """Lower bound on step time (perfect overlap): max of the terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_row(self) -> Dict[str, float]:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "coll_bytes_per_chip": self.coll_bytes,
        }


def analyze(compiled, chips: int) -> Roofline:
    """Build the roofline terms from a compiled executable.

    ``cost_analysis`` reports the per-device (partitioned) program.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    colls = collective_bytes(compiled.as_text())
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        coll_bytes=float(sum(colls.values())),
        coll_by_kind=colls,
        chips=chips,
    )


def model_flops(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS: 6*N*D for training (N = params, dense; N_active MoE),
    2*N*D for prefill, 2*N per token for decode — global, then per chip."""
    n_total = cfg.param_count()
    if cfg.n_experts > 0:
        # active params: replace expert MLPs with top_k experts
        gates = 3 if "gated" in cfg.mlp_act else 2
        expert_p = cfg.n_experts * gates * cfg.d_model * cfg.d_ff
        active_p = n_total - cfg.n_layers * expert_p \
            + cfg.n_layers * cfg.top_k * gates * cfg.d_model * cfg.d_ff
    else:
        active_p = n_total
    tokens = shape.batch * (shape.seq if kind != "decode" else 1)
    mult = 6.0 if kind == "train" else 2.0
    return mult * active_p * tokens
