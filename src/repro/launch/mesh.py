"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state.  Single-pod:
(data=16, model=16) = 256 chips (one v5e pod); multi-pod adds a leading
pod axis: (pod=2, data=16, model=16) = 512 chips across the DCI.
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the installed jax
    supports them (>= 0.5); older jax has no ``axis_types`` kwarg and
    every mesh axis is implicitly auto-sharded already."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_mesh_for(devices: int, model_parallel: int = None) -> jax.sharding.Mesh:
    """Elastic mesh for whatever device count is actually available."""
    model = model_parallel or min(devices, 16)
    while devices % model:
        model -= 1
    data = devices // model
    return compat_make_mesh((data, model), ("data", "model"))


# Hardware constants for the roofline (TPU v5e per chip).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW_PER_LINK = 50e9        # B/s per link direction
