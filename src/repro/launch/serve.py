"""Production serving driver: batched prefill + decode for any arch.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3_12b --smoke \
        --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.distributed.sharding import set_rules
from repro.launch.mesh import make_mesh_for
from repro.models import registry as R
from repro.serving.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=R.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = R.get_config(args.arch, smoke=args.smoke)
    ndev = len(jax.devices())
    if ndev > 1:
        set_rules(make_mesh_for(ndev))
    params, _ = R.init_params(cfg, jax.random.PRNGKey(0))

    def prefill(p, t, c):
        inputs = {"tokens": t}
        if cfg.family == "encdec":
            inputs["frames"] = jnp.zeros((t.shape[0], cfg.enc_seq, cfg.d_model), cfg.cdt)
        if cfg.frontend == "vision":
            raise SystemExit("vision serving takes patch embeddings; see examples/")
        return R.make_prefill(cfg)(p, inputs, c)

    eng = ServeEngine(prefill_fn=prefill, decode_fn=R.make_decode(cfg),
                      cache_init=lambda b, s: R.init_caches(cfg, b, s)[0])
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.perf_counter()
    out = eng.generate(params, prompt, steps=args.gen)
    wall = time.perf_counter() - t0
    print(f"{cfg.name}: {out.shape} tokens in {wall:.2f}s "
          f"({args.batch*args.gen/wall:.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
