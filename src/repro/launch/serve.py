"""Serving driver.

Default path — the multi-tenant batched **solve service**
(docs/serving.md): replay a seeded request trace through
:class:`repro.serving.SolveService` and print per-tenant outcomes plus
the service's admission/queue statistics::

    PYTHONPATH=src python -m repro.launch.serve --seed 0 --requests 6 \
        --lanes 4 --failures

LM path (kept from the original driver) — batched prefill + decode for
any registered arch::

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3_12b --smoke \
        --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time


def _serve_lm(args) -> None:
    import jax
    import jax.numpy as jnp

    from repro.distributed.sharding import set_rules
    from repro.launch.mesh import make_mesh_for
    from repro.models import registry as R
    from repro.serving.engine import ServeEngine

    cfg = R.get_config(args.arch, smoke=args.smoke)
    ndev = len(jax.devices())
    if ndev > 1:
        set_rules(make_mesh_for(ndev))
    params, _ = R.init_params(cfg, jax.random.PRNGKey(0))

    def prefill(p, t, c):
        inputs = {"tokens": t}
        if cfg.family == "encdec":
            inputs["frames"] = jnp.zeros((t.shape[0], cfg.enc_seq, cfg.d_model), cfg.cdt)
        if cfg.frontend == "vision":
            raise SystemExit("vision serving takes patch embeddings; see examples/")
        return R.make_prefill(cfg)(p, inputs, c)

    eng = ServeEngine(prefill_fn=prefill, decode_fn=R.make_decode(cfg),
                      cache_init=lambda b, s: R.init_caches(cfg, b, s)[0])
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.perf_counter()
    out = eng.generate(params, prompt, steps=args.gen)
    wall = time.perf_counter() - t0
    print(f"{cfg.name}: {out.shape} tokens in {wall:.2f}s "
          f"({args.batch*args.gen/wall:.1f} tok/s incl. compile)")


def _serve_solves(args) -> None:
    from repro import api

    reqs = api.generate_request_trace(
        args.seed, nrequests=args.requests,
        failure_rate=args.failure_rate if args.failures else 0.0,
        survivable_only=True)
    svc = api.SolveService(api.ServiceConfig(lanes=args.lanes,
                                             max_queue=args.max_queue))
    t0 = time.perf_counter()
    tickets = svc.replay(reqs)
    wall = time.perf_counter() - t0

    completed = 0
    for name, ticket in sorted(tickets.items()):
        if not ticket.accepted:
            print(f"  {name}: REJECTED ({ticket.reason})")
            continue
        rep = ticket.result.report
        completed += 1
        print(f"  {name}: {rep.solver:9s} conv={str(rep.converged):5s} "
              f"iters={rep.iterations:4d} recovered={rep.failures_recovered} "
              f"wait={rep.service_queue_wait_steps} "
              f"occupancy={rep.service_batch_occupancy:.2f}")
    waits = svc.metrics.histogram("service.queue_wait_steps")
    print(f"service: {completed}/{len(reqs)} completed in {svc.now} steps "
          f"({wall:.2f}s, {completed / wall:.2f} solves/s); "
          f"queue-wait p50={waits.percentile(50):.0f} "
          f"p99={waits.percentile(99):.0f} steps")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None,
                    help="LM arch id: switches to the prefill/decode "
                         "engine (default: the solve service)")
    # LM path
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    # solve-service path
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--max-queue", type=int, default=8)
    ap.add_argument("--failures", action="store_true",
                    help="inject the trace's per-tenant failure campaigns")
    ap.add_argument("--failure-rate", type=float, default=0.6)
    args = ap.parse_args()

    if args.arch is not None:
        from repro.models import registry as R

        if args.arch not in R.ARCH_IDS:
            raise SystemExit(f"unknown arch {args.arch!r}; "
                             f"one of {sorted(R.ARCH_IDS)}")
        _serve_lm(args)
    else:
        _serve_solves(args)


if __name__ == "__main__":
    main()
