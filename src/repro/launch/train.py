"""Production training driver.

Runs any assigned architecture (SMOKE config on CPU; full config on a
real mesh) under the fault-tolerant runtime: NVM checkpoints
(double-buffered, async-drained), Young/Daly persistence period, elastic
restore on restart, deterministic resumable data.

    PYTHONPATH=src python -m repro.launch.train --arch llama3_8b --smoke \
        --steps 100 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.distributed.sharding import set_rules
from repro.ft.checkpoint import CheckpointConfig, NVMCheckpointManager
from repro.ft.period import PersistencePeriodTuner
from repro.ft.recovery import TrainingRecovery
from repro.ft.straggler import StragglerMonitor
from repro.launch.mesh import make_mesh_for
from repro.models import registry as R
from repro.training.data import SyntheticCorpus
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_step import TrainConfig, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=R.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU); omit on a real TPU mesh")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/nvm_esr_train")
    ap.add_argument("--mtbf", type=float, default=3600.0,
                    help="assumed MTBF seconds for the Young/Daly period")
    args = ap.parse_args()

    cfg = R.get_config(args.arch, smoke=args.smoke)
    ndev = len(jax.devices())
    if ndev > 1:
        set_rules(make_mesh_for(ndev))

    params, _ = R.init_params(cfg, jax.random.PRNGKey(0))
    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch {cfg.name}: {n/1e6:.1f}M params on {ndev} device(s)")

    step_fn = jax.jit(make_train_step(
        R.make_train_forward(cfg), AdamWConfig(lr=args.lr),
        TrainConfig(microbatches=args.microbatches)))
    data = SyntheticCorpus(vocab=cfg.vocab, batch=args.batch, seq=args.seq)
    mgr = NVMCheckpointManager(CheckpointConfig(args.ckpt_dir))
    tuner = PersistencePeriodTuner(mtbf_s=args.mtbf, min_period=5)
    rec = TrainingRecovery(mgr, tuner)
    straggle = StragglerMonitor()

    state = {"params": params, "opt": adamw_init(params)}
    start = 0
    restored = mgr.restore(state)
    if restored is not None:
        state, start, _ = restored
        print(f"elastic restore: resuming from step {start}")

    for s in range(start, args.steps):
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
        if cfg.frontend == "vision":
            b, sq = batch["tokens"].shape
            batch["tokens"] = jax.random.normal(
                jax.random.PRNGKey(s), (b, sq, cfg.d_model), cfg.cdt)
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(sq)[None, None], (3, b, sq)).astype(jnp.int32)
        if cfg.family == "encdec":
            batch["frames"] = jax.random.normal(
                jax.random.PRNGKey(s), (args.batch, cfg.enc_seq, cfg.d_model))
        p, o, m = step_fn(state["params"], state["opt"], batch)
        state = {"params": p, "opt": o}
        dt = time.perf_counter() - t0
        rec.observe_step(dt)
        advice = straggle.observe(dt)
        if advice.suggest_eviction:
            print(f"step {s+1}: persistent straggler detected "
                  f"({dt*1e3:.0f}ms vs median {advice.median_s*1e3:.0f}ms) — "
                  "evict + elastic-restore advised")
        if not advice.defer_persistence:
            rec.maybe_persist(state, s + 1)
        if (s + 1) % 10 == 0:
            print(f"step {s+1:5d} loss {float(m['loss']):.4f} "
                  f"period {tuner.period}")
    mgr.join()
    print("done.")


if __name__ == "__main__":
    main()
