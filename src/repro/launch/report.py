"""Generate the EXPERIMENTS.md roofline tables from dry-run JSONL results.

TPU-corrected collective estimate (documented in EXPERIMENTS.md §Roofline):
the CPU backend promotes bf16 program values to f32 (2x byte inflation on
every collective of a bf16 model) and lacks the all-reduce->reduce-scatter
rewrite the TPU pipeline applies to the activation-psum + slice pattern.
We report RAW (what the compiled CPU HLO does) and a CORRECTED estimate:

    corrected = 0.5 * (AG + AA + CP) + 0.25 * AR     [bf16 models]
    (AR factor: 0.5 dtype x 0.5 scatter-rewrite)

f32 programs (the PCG solver) get no correction.
"""
from __future__ import annotations

import json
import sys
from typing import Dict, Optional

from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

HBM_PER_CHIP = 16 * 2**30  # v5e


def load(path: str) -> Dict:
    rows = {}
    for line in open(path):
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        if r.get("ok"):
            rows[(r["arch"], r["shape"], r["mesh"])] = r
    return rows


def corrected_coll_bytes(r: dict, bf16: bool = True) -> Optional[float]:
    kinds = r.get("coll_by_kind")
    if kinds is None:
        return None
    if not bf16:
        return float(sum(kinds.values()))
    ar = kinds.get("all-reduce", 0)
    rest = sum(v for k, v in kinds.items() if k != "all-reduce")
    return 0.5 * rest + 0.25 * ar


def table(rows: Dict, mesh: str = "16x16", corrected: bool = True) -> str:
    out = []
    hdr = ("| arch | shape | peak GiB/dev | fits | t_comp ms | t_mem ms | "
           "t_coll ms | bottleneck | useful-flop | roofline frac |")
    out.append(hdr)
    out.append("|" + "---|" * 10)
    for (a, s, m), r in sorted(rows.items()):
        if m != mesh or "roofline" not in r:
            continue
        rf = r["roofline"]
        bf16 = a != "poisson_pcg"
        coll = corrected_coll_bytes(r, bf16) if corrected else rf["coll_bytes_per_chip"]
        hbm = rf["hbm_bytes_per_chip"] * (0.5 if (corrected and bf16) else 1.0)
        tc = rf["flops_per_chip"] / PEAK_FLOPS_BF16
        tm = hbm / HBM_BW
        tx = (coll or 0) / ICI_BW_PER_LINK
        terms = {"compute": tc, "memory": tm, "collective": tx}
        bneck = max(terms, key=terms.get)
        peak = r["memory"].get("peak_bytes", 0)
        fits = "Y" if peak <= HBM_PER_CHIP else "n"
        mf = r.get("model_flops_per_chip") or 0
        uf = r.get("useful_flop_ratio")
        t_useful = mf / PEAK_FLOPS_BF16
        frac = t_useful / max(tc, tm, tx) if max(tc, tm, tx) > 0 else 0
        out.append(
            f"| {a} | {s} | {peak/2**30:.2f} | {fits} | {tc*1e3:.1f} | "
            f"{tm*1e3:.1f} | {tx*1e3:.1f} | {bneck} | "
            f"{uf:.2f} | {frac:.3f} |" if uf is not None else
            f"| {a} | {s} | {peak/2**30:.2f} | {fits} | - | - | - | - | - | - |")
    return "\n".join(out)


def multipod_table(rows: Dict) -> str:
    out = ["| arch | shape | mesh | peak GiB/dev | compile s |",
           "|---|---|---|---|---|"]
    for (a, s, m), r in sorted(rows.items()):
        if m != "2x16x16":
            continue
        peak = r["memory"].get("peak_bytes", 0)
        out.append(f"| {a} | {s} | {m} | {peak/2**30:.2f} | {r['compile_s']} |")
    return "\n".join(out)


# ----------------------------------------------------------------------
# Solver-run reporting: every SolveReport field in one table (the report
# dataclass docstring in repro/solvers/driver.py defines the semantics).
# ----------------------------------------------------------------------
def solve_report_rows(r) -> Dict[str, str]:
    """One :class:`repro.solvers.SolveReport` as printable columns,
    including the overlapped-persistence metrics."""
    return {
        "solver": r.solver or "-",
        "mode": r.persist_mode,
        "iters": str(r.iterations),
        "conv": "Y" if r.converged else "n",
        "relres": f"{r.final_relres:.2e}",
        "recovered": str(r.failures_recovered),
        "restarts": str(r.recovery_restarts),
        "prd lost": str(r.storage_failures),
        "wasted": str(r.wasted_iterations),
        "events": str(r.persist_events),
        "persist ms": f"{r.persist_cost_s * 1e3:.3f}",
        "exposed ms": f"{r.persist_exposed_s * 1e3:.3f}",
        "hidden %": f"{r.persist_hidden_fraction * 100:.1f}",
        "stage ms": f"{r.persist_stage_s * 1e3:.3f}",
        "drain ms": f"{r.persist_drain_s * 1e3:.3f}",
        # trailing column (ISSUE 6): the paper's time-overhead quantity
        # normalized per iteration; appended last so the columns before
        # it stay byte-stable for existing tables
        "exposed/iter us": f"{r.persist_exposed_per_iteration * 1e6:.3f}",
        # trailing columns (ISSUE 7): sharded-solve accounting — the
        # device-shard count and the per-shard byte traffic totals the
        # metrics registry meters (DESIGN.md §10); appended after the
        # ISSUE-6 column for the same byte-stable-prefix reason
        "shards": str(getattr(r, "nshards", 1)),
        "persist KiB": f"{getattr(r, 'persist_bytes', 0) / 1024:.1f}",
        "fetch KiB": f"{getattr(r, 'recovery_fetch_bytes', 0) / 1024:.1f}",
    }


def _markdown_table(rows, empty: str) -> str:
    """Render dict rows (shared column order from the first row)."""
    if not rows:
        return empty
    cols = list(rows[0])
    out = ["| " + " | ".join(cols) + " |",
           "|" + "---|" * len(cols)]
    for row in rows:
        out.append("| " + " | ".join(row[c] for c in cols) + " |")
    return "\n".join(out)


def solve_report_table(reports) -> str:
    """Markdown table over solver runs (benchmarks/examples print this)."""
    return _markdown_table([solve_report_rows(r) for r in reports],
                           "(no solver reports)")


# ----------------------------------------------------------------------
# Metrics-registry reporting (DESIGN.md §9): the labeled instruments a
# solve's `report.metrics` carries, as a per-phase summary table.
# ----------------------------------------------------------------------
def metrics_rows(registry):
    """One row per instrument in a :class:`repro.obs.MetricsRegistry`
    (sorted by name then labels, like ``registry.snapshot()``).
    Histograms render their per-phase summary (count/total/mean/p50/
    p95/max); counters and gauges render their value with the summary
    columns dashed."""
    rows = []
    base = set(registry.base_labels)
    for inst in registry:
        labels = ", ".join(f"{k}={v}" for k, v in inst.labels
                           if k not in base)
        row = {"metric": inst.name, "kind": inst.kind,
               "labels": labels or "-"}
        if inst.kind == "histogram":
            s = inst.summary()
            row["count"] = str(s["count"])
            row["total"] = f"{s['total']:.3e}"
            for col in ("mean", "p50", "p95", "max"):
                row[col] = (f"{s[col]:.3e}" if s["count"] else "-")
        else:
            row["count"] = "-"
            row["total"] = (str(inst.value) if inst.kind == "counter"
                            else f"{inst.value:g}")
            for col in ("mean", "p50", "p95", "max"):
                row[col] = "-"
        rows.append(row)
    return rows


def metrics_table(registry) -> str:
    """Markdown table over a solve's metrics registry
    (``result.report.metrics``); empty registries render a placeholder."""
    if registry is None or not len(registry):
        return "(no metrics)"
    return _markdown_table(metrics_rows(registry), "(no metrics)")


# ----------------------------------------------------------------------
# Backend capability reporting (DESIGN.md §7): what each backend in the
# registry *declares* — rendered by examples and the docs surface.
# ----------------------------------------------------------------------
def storage_values(backend) -> int:
    """Total redundancy footprint of a backend in *values* (RAM overhead
    + persistent-tier residency) — the quantity the paper's Fig. 2/8
    memory-overhead argument compares."""
    return backend.memory_overhead_values() + backend.nvm_values()


def capability_rows(name: str, backend,
                    baseline_values: Optional[int] = None) -> Dict[str, str]:
    """One backend's :class:`repro.nvm.backend.BackendCapabilities` as
    printable columns.  ``baseline_values`` (typically a single
    unreplicated backend's :func:`storage_values`) turns the storage
    column into an overhead factor — 2.00x for a mirror pair, 1.25x for
    a 4+p erasure stripe."""
    caps = backend.capabilities
    tol = caps.max_block_failures
    row = {
        "backend": name,
        "durability": caps.durability,
        "node loss": "survives" if caps.survives_node_loss else "fatal",
        "PRD loss": "survives" if caps.survives_prd_loss else "fatal",
        "storage losses": str(caps.max_storage_failures),
        "overlap": caps.overlap,
        "max failures": "unbounded" if tol is None else str(tol),
    }
    values = storage_values(backend)
    if baseline_values:
        row["storage"] = f"{values / baseline_values:.2f}x"
    else:
        row["storage"] = f"{values} values"
    return row


def capability_matrix_table(named_backends,
                            baseline_values: Optional[int] = None) -> str:
    """Markdown capability matrix over ``(name, backend)`` pairs."""
    return _markdown_table(
        [capability_rows(n, b, baseline_values) for n, b in named_backends],
        "(no backends)")


# ----------------------------------------------------------------------
# Advisor reporting (DESIGN.md §8): the cheapest-spec ranking a
# `repro.solvers.driver.SpecAdvice` carries, as a readable table.
# ----------------------------------------------------------------------
def _advice_row(r, chosen: Optional[str],
                baseline_values: Optional[int]) -> Dict[str, str]:
    if r.survivable:
        verdict = "chosen" if r.spec == chosen else "ok"
        why = "-"
    else:
        verdict = "rejected"
        # the planner's reason, compacted to the violating fact
        why = r.reason.replace("campaign rejected before iteration 0: ", "")
        if len(why) > 88:
            why = why[:85] + "..."
    if baseline_values:
        storage = f"{r.storage_values / baseline_values:.2f}x"
    else:
        storage = f"{r.storage_values} values"
    cost = ("-" if r.persist_cost_s != r.persist_cost_s  # NaN: not probed
            else f"{r.persist_cost_s * 1e3:.3f}")
    return {"spec": r.spec, "verdict": verdict, "storage": storage,
            "persist ms/event": cost, "why not": why}


def spec_advice_rows(advice, baseline_values: Optional[int] = None):
    """One row per candidate: survivors cheapest-first (the chosen spec
    marked), then the planner-rejected specs with their reason."""
    return [_advice_row(r, advice.chosen, baseline_values)
            for r in list(advice.ranked) + list(advice.rejected)]


def spec_advice_table(advice, baseline_values: Optional[int] = None) -> str:
    """Markdown table over a :class:`repro.solvers.driver.SpecAdvice`
    (``baseline_values`` turns the storage column into overhead
    factors, like :func:`capability_rows`)."""
    return _markdown_table(spec_advice_rows(advice, baseline_values),
                           "(no candidates)")


if __name__ == "__main__":
    rows = load(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.jsonl")
    print(table(rows))
