"""NVM-tier training checkpoints with the paper's persistence protocol.

Carries the NVM-ESR design into NN training (DESIGN.md §4):

- **minimal-state identification**: only (params, optimizer moments, step,
  data cursor, RNG) persist; activations are *reconstructed* by
  recomputation — the training analogue of ESR's solve-don't-store.
- **double-buffered alternating slots** (Dorożyński et al. [4]): two slot
  directories written alternately; a manifest (step + per-file CRC32) is
  committed *after* the payload is durable, so one valid checkpoint always
  survives a crash mid-persist.
- **PSCW-style overlap**: ``save_async`` snapshots device arrays to host
  (the access epoch), returns immediately, and a drainer thread plays the
  PRD target (exposure epoch) writing + fsync'ing — training overlaps the
  NVM drain exactly like the solver's compute overlaps the PRD flush.
- **elastic restore**: arrays are restored host-side and re-placed with
  ``jax.device_put`` under the *current* mesh/sharding — a checkpoint
  taken on N devices restores onto M devices (elastic scaling).

Tier cost accounting uses the same calibrated models as the solver
backends, so benchmarks can compare DRAM/NVM/SSD persistence for training
exactly as the paper's Fig. 9/10 do for the solver.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
import zlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

from repro.nvm.store import TIER_SPECS, CostModel, Tier


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    directory: str
    tier: Tier = Tier.NVM
    async_drain: bool = True
    keep_fsync: bool = False  # real fsync per file (slow on CI; modeled anyway)


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class NVMCheckpointManager:
    """Double-buffered, asynchronous, tier-modeled checkpoint manager."""

    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        self.spec = TIER_SPECS[cfg.tier]
        self.cost = CostModel()
        os.makedirs(cfg.directory, exist_ok=True)
        self._seq = self._latest_valid()[0] or 0
        self._drainer: Optional[threading.Thread] = None
        self._last_persist_wall = 0.0
        self._last_persist_model = 0.0

    # ------------------------------------------------------------------
    def _slot_dir(self, seq: int) -> str:
        return os.path.join(self.cfg.directory, f"slot{seq % 2}")

    def _manifest_path(self, slot: str) -> str:
        return os.path.join(slot, "MANIFEST.json")

    # ------------------------------------------------------------------
    def save(self, tree: Any, step: int, extra: Optional[Dict[str, Any]] = None) -> float:
        """Synchronous persist; returns modeled seconds."""
        host = self._snapshot(tree)
        return self._drain(host, step, extra or {})

    def save_async(self, tree: Any, step: int,
                   extra: Optional[Dict[str, Any]] = None) -> None:
        """Access epoch: snapshot to host and return; drain overlaps."""
        self.join()
        host = self._snapshot(tree)  # device -> host pull (origin-side cost)

        def _run():
            self._drain(host, step, extra or {})

        if self.cfg.async_drain:
            self._drainer = threading.Thread(target=_run, name="ckpt-drainer")
            self._drainer.start()
        else:
            _run()

    def join(self) -> None:
        if self._drainer is not None:
            self._drainer.join()
            self._drainer = None

    # ------------------------------------------------------------------
    def _snapshot(self, tree: Any) -> Dict[str, np.ndarray]:
        flat = _flatten(jax.device_get(tree))
        return flat

    def _drain(self, flat: Dict[str, np.ndarray], step: int,
               extra: Dict[str, Any]) -> float:
        t0 = time.monotonic()
        seq = self._seq + 1
        slot = self._slot_dir(seq)
        shutil.rmtree(slot, ignore_errors=True)
        os.makedirs(slot, exist_ok=True)
        modeled = 0.0
        entries = {}
        total_bytes = 0
        for key, arr in flat.items():
            fn = key.replace("/", "__") + ".npy"
            path = os.path.join(slot, fn)
            data = arr.tobytes()
            with open(path, "wb") as f:
                np.save(f, arr)
                if self.cfg.keep_fsync:
                    f.flush()
                    os.fsync(f.fileno())
            entries[key] = {"file": fn, "crc": zlib.crc32(data) & 0xFFFFFFFF,
                            "shape": list(arr.shape), "dtype": str(arr.dtype)}
            modeled += self.spec.write_cost(len(data))
            total_bytes += len(data)
        modeled += self.spec.flush_cost(total_bytes)
        # manifest commit AFTER payload is durable (crash-consistent ordering)
        manifest = {"seq": seq, "step": step, "entries": entries, "extra": extra}
        mp = self._manifest_path(slot)
        with open(mp + ".tmp", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(mp + ".tmp", mp)
        self._seq = seq
        self.cost.add("persist", modeled)
        self._last_persist_wall = time.monotonic() - t0
        self._last_persist_model = modeled
        return modeled

    # ------------------------------------------------------------------
    def _read_manifest(self, slot: str) -> Optional[Dict[str, Any]]:
        mp = self._manifest_path(slot)
        if not os.path.exists(mp):
            return None
        try:
            with open(mp) as f:
                return json.load(f)
        except (json.JSONDecodeError, OSError):
            return None

    def _latest_valid(self) -> Tuple[Optional[int], Optional[str]]:
        best_seq, best_slot = None, None
        for i in (0, 1):
            slot = os.path.join(self.cfg.directory, f"slot{i}")
            m = self._read_manifest(slot)
            if m is None:
                continue
            ok = all(
                os.path.exists(os.path.join(slot, e["file"]))
                for e in m["entries"].values()
            )
            if ok and (best_seq is None or m["seq"] > best_seq):
                best_seq, best_slot = m["seq"], slot
        return best_seq, best_slot

    def _try_load_slot(self, slot: str, flat_keys) -> Optional[Tuple[Dict, Dict]]:
        m = self._read_manifest(slot)
        if m is None:
            return None
        restored = {}
        for key in flat_keys:
            e = m["entries"].get(key)
            if e is None:
                return None  # structure mismatch
            try:
                arr = np.load(os.path.join(slot, e["file"]))
            except (ValueError, OSError):
                return None  # torn/corrupt file (even the npy header)
            if (zlib.crc32(arr.tobytes()) & 0xFFFFFFFF) != e["crc"]:
                return None  # torn payload detected by checksum
            restored[key] = arr
        return restored, m

    def restore(self, like: Any, shardings: Optional[Any] = None
                ) -> Optional[Tuple[Any, int, Dict[str, Any]]]:
        """Restore the newest FULLY-VALID checkpoint into the structure of
        ``like`` (a pytree of arrays or ShapeDtypeStructs).  Slots are
        tried newest-first; any torn/corrupt payload (CRC or even a
        mangled npy header) makes the whole slot invalid and the previous
        slot wins — the double-buffer guarantee.  With ``shardings`` the
        arrays are placed onto the *current* mesh — elastic restore onto
        a different device count."""
        self.join()
        flat_keys = list(_flatten(like).keys())
        candidates = []
        for i in (0, 1):
            slot = os.path.join(self.cfg.directory, f"slot{i}")
            m = self._read_manifest(slot)
            if m is not None:
                candidates.append((m["seq"], slot))
        for _, slot in sorted(candidates, reverse=True):
            got = self._try_load_slot(slot, flat_keys)
            if got is None:
                continue
            restored, m = got
            _, treedef = jax.tree_util.tree_flatten(like)
            tree = jax.tree_util.tree_unflatten(
                treedef, [restored[k] for k in flat_keys])
            if shardings is not None:
                tree = jax.tree.map(lambda a, s: jax.device_put(a, s),
                                    tree, shardings)
            return tree, m["step"], m.get("extra", {})
        return None

    # ------------------------------------------------------------------
    @property
    def last_persist_seconds(self) -> Tuple[float, float]:
        """(wall, modeled) duration of the last drain."""
        return self._last_persist_wall, self._last_persist_model
