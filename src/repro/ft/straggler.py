"""Straggler detection and mitigation for the training runtime.

At pod scale, slow hosts (thermal throttling, failing NICs, noisy
neighbours on the storage tier) stretch every synchronous step.  The
monitor keeps a robust running estimate of step time (median + MAD over a
sliding window) and classifies each observation:

- **transient** spike (> ``spike_mad`` MADs once): logged, no action;
- **persistent** straggle (``persist_k`` consecutive spikes): mitigation
  hooks fire —
    * persistence drains are deferred (the NVM checkpoint drain is taken
      off the critical path until the step time recovers), and
    * the runtime is advised to *evict + elastically restore* (shrink the
      mesh by the slow host and continue from the NVM checkpoint — the
      same elastic-restore path as failure recovery, DESIGN.md §2).

The monitor is deliberately runtime-agnostic: it consumes durations and
emits advice; launch/train.py and the recovery wrapper act on it.
"""
from __future__ import annotations

import dataclasses
import statistics
from collections import deque
from typing import Deque, List, Optional


@dataclasses.dataclass
class StragglerAdvice:
    classification: str          # "normal" | "transient" | "persistent"
    defer_persistence: bool
    suggest_eviction: bool
    step_time_s: float
    median_s: float


class StragglerMonitor:
    def __init__(self, window: int = 50, spike_mad: float = 5.0,
                 persist_k: int = 5, warmup: int = 5):
        self.window = window
        self.spike_mad = spike_mad
        self.persist_k = persist_k
        self.warmup = warmup
        self._times: Deque[float] = deque(maxlen=window)
        self._consecutive = 0
        self.history: List[StragglerAdvice] = []

    def observe(self, step_time_s: float) -> StragglerAdvice:
        if len(self._times) < self.warmup:
            self._times.append(step_time_s)
            adv = StragglerAdvice("normal", False, False, step_time_s, step_time_s)
            self.history.append(adv)
            return adv
        med = statistics.median(self._times)
        mad = statistics.median(abs(t - med) for t in self._times) or med * 0.01
        is_spike = step_time_s > med + self.spike_mad * mad
        if is_spike:
            self._consecutive += 1
        else:
            self._consecutive = 0
            self._times.append(step_time_s)  # don't poison the baseline
        if self._consecutive >= self.persist_k:
            cls = "persistent"
        elif is_spike:
            cls = "transient"
        else:
            cls = "normal"
        adv = StragglerAdvice(
            classification=cls,
            defer_persistence=is_spike,
            suggest_eviction=cls == "persistent",
            step_time_s=step_time_s,
            median_s=med,
        )
        self.history.append(adv)
        return adv

    @property
    def median_step_s(self) -> Optional[float]:
        return statistics.median(self._times) if self._times else None
