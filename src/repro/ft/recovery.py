"""Training-side failure handling: detection, restore, elastic reshard.

The runtime loop (launch/train.py) wraps every step with
:class:`TrainingRecovery`.  On a (simulated or real) host failure the
volatile training state is lost; recovery restores the newest valid NVM
checkpoint and resumes — possibly on a *different* device count (elastic
restore: host arrays are re-placed under the current mesh).  Straggler
mitigation: persistently slow persist drains push the Young/Daly period
up via the tuner, and the async drain keeps stragglers off the critical
path entirely.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

from repro.ft.checkpoint import NVMCheckpointManager
from repro.ft.period import PersistencePeriodTuner


def inject_host_failure(tree: Any) -> Any:
    """Simulate loss of volatile state: every leaf becomes garbage."""
    return jax.tree.map(lambda a: jax.numpy.full_like(a, jax.numpy.nan)
                        if jax.numpy.issubdtype(a.dtype, jax.numpy.floating)
                        else jax.numpy.zeros_like(a), tree)


@dataclasses.dataclass
class TrainingRecovery:
    manager: NVMCheckpointManager
    tuner: PersistencePeriodTuner
    state_shardings: Optional[Any] = None
    failures_recovered: int = 0
    steps_wasted: int = 0

    def maybe_persist(self, state: Any, step: int,
                      extra: Optional[Dict[str, Any]] = None) -> bool:
        """Persist iff the adaptive period says so.  Async (PSCW overlap)."""
        if step % self.tuner.period == 0:
            t0 = time.monotonic()
            self.manager.save_async(state, step, extra)
            # origin-visible cost only (snapshot); drain overlaps compute
            self.tuner.observe(max(time.monotonic() - t0, 1e-9),
                               self.tuner._step or 1e-3)
            return True
        return False

    def observe_step(self, step_time_s: float) -> None:
        self.tuner.observe(self.tuner._delta or 1e-9, step_time_s)

    def recover(self, like: Any, failed_step: int
                ) -> Tuple[Any, int, Dict[str, Any]]:
        """Restore newest valid checkpoint; count wasted steps (ESRP cost)."""
        self.manager.join()
        got = self.manager.restore(like, self.state_shardings)
        if got is None:
            raise RuntimeError("no valid checkpoint to recover from")
        state, step, extra = got
        self.failures_recovered += 1
        self.steps_wasted += max(failed_step - step, 0)
        return state, step, extra
