"""Fault-tolerant training substrate (the paper's NVM persistence
machinery as a first-class training feature — DESIGN.md §4)."""
from repro.ft.checkpoint import NVMCheckpointManager, CheckpointConfig  # noqa: F401
from repro.ft.period import optimal_period, PersistencePeriodTuner  # noqa: F401
from repro.ft.recovery import TrainingRecovery, inject_host_failure  # noqa: F401
from repro.ft.straggler import StragglerMonitor, StragglerAdvice  # noqa: F401
