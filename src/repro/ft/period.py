"""Persistence-period selection (the ESRP trade-off, paper §2).

ESRP showed: longer periods cut persistence overhead but waste more
iterations on recovery.  The classical optimum (Young '74 / Daly '06) for
persist cost ``delta`` and mean time between failures ``M`` is::

    T_opt = sqrt(2 * delta * M)   (first order; Daly refines higher order)

expressed here in *steps*: ``T_steps = T_opt / step_time``.  The tuner
tracks EWMA estimates of both delta and step time at runtime, so the
period adapts when e.g. the NVM tier degrades or the model grows —
straggler-aware persistence scheduling.
"""
from __future__ import annotations

import dataclasses
import math


def optimal_period(persist_cost_s: float, mtbf_s: float,
                   step_time_s: float) -> int:
    """Young/Daly optimum converted to whole training steps (>= 1)."""
    if persist_cost_s <= 0 or step_time_s <= 0:
        return 1
    t_opt = math.sqrt(2.0 * persist_cost_s * mtbf_s)
    return max(1, int(round(t_opt / step_time_s)))


@dataclasses.dataclass
class PersistencePeriodTuner:
    mtbf_s: float
    alpha: float = 0.2          # EWMA smoothing
    min_period: int = 1
    max_period: int = 10_000
    _delta: float = 0.0
    _step: float = 0.0

    def observe(self, persist_cost_s: float, step_time_s: float) -> None:
        a = self.alpha
        self._delta = persist_cost_s if self._delta == 0 else (
            (1 - a) * self._delta + a * persist_cost_s)
        self._step = step_time_s if self._step == 0 else (
            (1 - a) * self._step + a * step_time_s)

    @property
    def period(self) -> int:
        if self._delta == 0 or self._step == 0:
            return self.min_period
        p = optimal_period(self._delta, self.mtbf_s, self._step)
        return min(max(p, self.min_period), self.max_period)

    def expected_overhead_fraction(self) -> float:
        """Expected runtime overhead at the current optimum: delta/T + T/(2M)."""
        if self._delta == 0 or self._step == 0:
            return 0.0
        t = self.period * self._step
        return self._delta / t + t / (2 * self.mtbf_s)
