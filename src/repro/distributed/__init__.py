"""Distribution substrate: logical-axis sharding rules and helpers."""
from repro.distributed.sharding import (  # noqa: F401
    AxisRules,
    DEFAULT_RULES,
    current_rules,
    logical_spec,
    set_rules,
    shard,
    use_rules,
)
