"""Logical-axis sharding: model code names *logical* dimensions; a rules
table maps them onto physical mesh axes per deployment.

Parallelism realized through the rules (DESIGN.md §5):

- **DP**   batch        -> ("pod", "data")
- **FSDP** fsdp         -> "data"   (ZeRO-3 parameter/optimizer sharding)
- **TP**   heads/mlp/vocab -> "model" (Megatron tensor parallelism)
- **EP**   experts      -> "model"  (expert parallelism, aligned with TP)
- **SP**   seq          -> "model"  (Megatron sequence parallelism of the
  residual stream between blocks; GSPMD inserts the all-gather /
  reduce-scatter transitions at block boundaries)
- **KV-seq** kv_seq     -> "model"  (sequence-sharded decode caches ->
  flash-decode style distributed softmax)

Model code calls ``shard(x, "batch", "seq", "embed")`` etc.; with no mesh
configured (CPU smoke tests) this is the identity.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]


@dataclass(frozen=True)
class AxisRules:
    """Mapping of logical axis names to physical mesh axes."""

    mesh: Optional[Mesh]
    rules: Dict[str, Axis]

    def physical(self, logical: Optional[str]) -> Axis:
        if logical is None:
            return None
        axis = self.rules.get(logical)
        if axis is None or self.mesh is None:
            return None
        # keep only axes present in this mesh (e.g. no "pod" single-pod)
        if isinstance(axis, tuple):
            kept = tuple(a for a in axis if a in self.mesh.axis_names)
            return kept if kept else None
        return axis if axis in self.mesh.axis_names else None

    def spec(self, *logical: Optional[str]) -> P:
        return P(*(self.physical(l) for l in logical))


DEFAULT_RULES: Dict[str, Axis] = {
    "batch": ("pod", "data"),
    "fsdp": "data",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "seq": "model",      # sequence parallelism of the residual stream
    "kv_seq": "model",   # sequence-sharded decode caches
    "embed": None,
    "layers": None,
    "state": None,       # SSM state dim
}

_ctx = threading.local()


def set_rules(mesh: Optional[Mesh], rules: Optional[Dict[str, Axis]] = None) -> AxisRules:
    r = AxisRules(mesh, dict(DEFAULT_RULES if rules is None else rules))
    _ctx.rules = r
    return r


def current_rules() -> AxisRules:
    r = getattr(_ctx, "rules", None)
    if r is None:
        r = AxisRules(None, dict(DEFAULT_RULES))
        _ctx.rules = r
    return r


@contextlib.contextmanager
def use_rules(mesh: Optional[Mesh], rules: Optional[Dict[str, Axis]] = None):
    prev = getattr(_ctx, "rules", None)
    set_rules(mesh, rules)
    try:
        yield current_rules()
    finally:
        _ctx.rules = prev


def logical_spec(*logical: Optional[str]) -> P:
    return current_rules().spec(*logical)


def spec_for_shape(rules: AxisRules, shape: Sequence[int],
                   logical: Sequence[Optional[str]]) -> P:
    """Physical spec with per-dimension divisibility degradation.

    A logical axis whose mapped mesh extent does not divide the tensor
    dimension is dropped (for tuple mappings, the longest divisible prefix
    is kept) — e.g. kv_heads=8 on a model=16 axis falls back to
    replication while q-heads=32 shard fully.
    """
    phys = []
    mesh = rules.mesh
    for dim, l in zip(shape, logical):
        ax = rules.physical(l)
        if ax is None or mesh is None:
            phys.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        kept = []
        prod = 1
        for a in axes:
            ext = mesh.shape[a]
            if dim % (prod * ext) == 0:
                kept.append(a)
                prod *= ext
        if not kept:
            phys.append(None)
        elif len(kept) == 1:
            phys.append(kept[0])
        else:
            phys.append(tuple(kept))
    return P(*phys)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names (identity w/o mesh)."""
    r = current_rules()
    if r.mesh is None:
        return x
    spec = spec_for_shape(r, x.shape, logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))
