"""Logical-axis sharding: model code names *logical* dimensions; a rules
table maps them onto physical mesh axes per deployment.

Parallelism realized through the rules (DESIGN.md §5):

- **DP**   batch        -> ("pod", "data")
- **FSDP** fsdp         -> "data"   (ZeRO-3 parameter/optimizer sharding)
- **TP**   heads/mlp/vocab -> "model" (Megatron tensor parallelism)
- **EP**   experts      -> "model"  (expert parallelism, aligned with TP)
- **SP**   seq          -> "model"  (Megatron sequence parallelism of the
  residual stream between blocks; GSPMD inserts the all-gather /
  reduce-scatter transitions at block boundaries)
- **KV-seq** kv_seq     -> "model"  (sequence-sharded decode caches ->
  flash-decode style distributed softmax)

Model code calls ``shard(x, "batch", "seq", "embed")`` etc.; with no mesh
configured (CPU smoke tests) this is the identity.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]


@dataclass(frozen=True)
class AxisRules:
    """Mapping of logical axis names to physical mesh axes."""

    mesh: Optional[Mesh]
    rules: Dict[str, Axis]

    def physical(self, logical: Optional[str]) -> Axis:
        if logical is None:
            return None
        axis = self.rules.get(logical)
        if axis is None or self.mesh is None:
            return None
        # keep only axes present in this mesh (e.g. no "pod" single-pod)
        if isinstance(axis, tuple):
            kept = tuple(a for a in axis if a in self.mesh.axis_names)
            return kept if kept else None
        return axis if axis in self.mesh.axis_names else None

    def spec(self, *logical: Optional[str]) -> P:
        return P(*(self.physical(l) for l in logical))


DEFAULT_RULES: Dict[str, Axis] = {
    "batch": ("pod", "data"),
    "fsdp": "data",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "seq": "model",      # sequence parallelism of the residual stream
    "kv_seq": "model",   # sequence-sharded decode caches
    "embed": None,
    "layers": None,
    "state": None,       # SSM state dim
}

_ctx = threading.local()


def set_rules(mesh: Optional[Mesh], rules: Optional[Dict[str, Axis]] = None) -> AxisRules:
    r = AxisRules(mesh, dict(DEFAULT_RULES if rules is None else rules))
    _ctx.rules = r
    return r


def current_rules() -> AxisRules:
    r = getattr(_ctx, "rules", None)
    if r is None:
        r = AxisRules(None, dict(DEFAULT_RULES))
        _ctx.rules = r
    return r


@contextlib.contextmanager
def use_rules(mesh: Optional[Mesh], rules: Optional[Dict[str, Axis]] = None):
    prev = getattr(_ctx, "rules", None)
    set_rules(mesh, rules)
    try:
        yield current_rules()
    finally:
        _ctx.rules = prev


def logical_spec(*logical: Optional[str]) -> P:
    return current_rules().spec(*logical)


def spec_for_shape(rules: AxisRules, shape: Sequence[int],
                   logical: Sequence[Optional[str]]) -> P:
    """Physical spec with per-dimension divisibility degradation.

    A logical axis whose mapped mesh extent does not divide the tensor
    dimension is dropped (for tuple mappings, the longest divisible prefix
    is kept) — e.g. kv_heads=8 on a model=16 axis falls back to
    replication while q-heads=32 shard fully.
    """
    phys = []
    mesh = rules.mesh
    for dim, l in zip(shape, logical):
        ax = rules.physical(l)
        if ax is None or mesh is None:
            phys.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        kept = []
        prod = 1
        for a in axes:
            ext = mesh.shape[a]
            if dim % (prod * ext) == 0:
                kept.append(a)
                prod *= ext
        if not kept:
            phys.append(None)
        elif len(kept) == 1:
            phys.append(kept[0])
        else:
            phys.append(tuple(kept))
    return P(*phys)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names (identity w/o mesh)."""
    r = current_rules()
    if r.mesh is None:
        return x
    spec = spec_for_shape(r, x.shape, logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))


# ======================================================================
# Sharded solves (ISSUE 7): block-rows of a solver problem mapped onto a
# 1-D ``data`` mesh axis.  The paper's failure unit is a *node*: one
# device shard owning a contiguous run of partition blocks.  A
# ``ShardLayout`` is that mapping; ``shard_problem`` wraps an operator /
# rhs pair so the five zoo solvers run with device-sharded vectors and
# ``FailureEvent(shard=...)`` kills exactly one device's blocks
# (DESIGN.md §10).
# ======================================================================
@dataclass(frozen=True)
class ShardLayout:
    """Block-rows -> device shards, contiguously: shard ``s`` owns blocks
    ``[s*bps, (s+1)*bps)`` with ``bps = nblocks // nshards`` (z-slab
    locality: a device's blocks are its slab of the grid)."""

    nblocks: int
    nshards: int

    def __post_init__(self):
        if not (1 <= self.nshards <= self.nblocks):
            raise ValueError(
                f"need 1 <= nshards <= nblocks, got nshards={self.nshards} "
                f"with nblocks={self.nblocks}")
        if self.nblocks % self.nshards != 0:
            raise ValueError(
                f"nblocks={self.nblocks} not divisible by "
                f"nshards={self.nshards}")

    @property
    def blocks_per_shard(self) -> int:
        return self.nblocks // self.nshards

    def blocks_of(self, shard: int) -> Tuple[int, ...]:
        """The partition blocks owned by device shard ``shard``."""
        if not (0 <= shard < self.nshards):
            raise ValueError(
                f"shard {shard} out of range for nshards={self.nshards}")
        bps = self.blocks_per_shard
        return tuple(range(shard * bps, (shard + 1) * bps))

    def shard_of_block(self, block: int) -> int:
        if not (0 <= block < self.nblocks):
            raise ValueError(
                f"block {block} out of range for nblocks={self.nblocks}")
        return block // self.blocks_per_shard

    def shard_of_block_map(self) -> Dict[int, int]:
        """The full block -> owning-shard map (per-shard session
        addressing: :meth:`repro.nvm.backend.PersistSession.bind_shards`)."""
        return {b: self.shard_of_block(b) for b in range(self.nblocks)}


def make_data_mesh(nshards: int) -> Mesh:
    """A 1-D ``data`` mesh of ``nshards`` devices (jax-0.4.37-compatible
    via ``compat_make_mesh``).  Raises ``ValueError`` when the runtime
    has fewer devices — callers (tests) turn that into a clean skip."""
    from repro.launch.mesh import compat_make_mesh

    have = jax.device_count()
    if have < nshards:
        raise ValueError(
            f"cannot build a {nshards}-shard data mesh on {have} "
            f"device(s); fake host devices with "
            f"--xla_force_host_platform_device_count")
    return compat_make_mesh((nshards,), ("data",))


class ShardedOperator:
    """An operator whose vectors live block-sharded on a ``data`` mesh.

    Wraps any block-partitioned operator: ``apply`` keeps outputs pinned
    to the canonical layout (``P("data")`` over the flat index space —
    legal because ``nblocks % nshards == 0``); every other attribute
    (``partition``, ``nblocks``, ``n``, ``diag``, ``inblock_apply``,
    ``offblock_apply``, ...) delegates to the base operator, so
    preconditioners and reconstruction code run unchanged.  The wrapper
    adds ``layout`` and ``mesh`` — the driver and the solvers' deterministic
    reductions key off both (``getattr(op, "mesh", None)``)."""

    def __init__(self, base, layout: ShardLayout, mesh: Mesh):
        if "data" not in mesh.axis_names:
            raise ValueError("ShardedOperator needs a mesh with a 'data' axis")
        if int(mesh.shape["data"]) != layout.nshards:
            raise ValueError(
                f"mesh data axis has {mesh.shape['data']} device(s) but the "
                f"layout declares nshards={layout.nshards}")
        if base.nblocks != layout.nblocks:
            raise ValueError(
                f"operator has {base.nblocks} blocks but the layout "
                f"declares nblocks={layout.nblocks}")
        self.base = base
        self.layout = layout
        self.mesh = mesh
        self.vector_sharding = NamedSharding(mesh, P("data"))

    def __getattr__(self, name):
        return getattr(self.base, name)

    def apply(self, x: jax.Array) -> jax.Array:
        y = self.base.apply(x)
        return jax.lax.with_sharding_constraint(y, self.vector_sharding)

    def device_put(self, x: jax.Array) -> jax.Array:
        """Place a full-length vector into the canonical block sharding."""
        return jax.device_put(x, self.vector_sharding)


def shard_problem(op, b, nshards: int, mesh: Optional[Mesh] = None):
    """Shard a block-partitioned problem across ``nshards`` devices.

    Returns ``(sharded_op, sharded_b)``: the operator wrapped in a
    :class:`ShardedOperator` over a 1-D ``data`` mesh and the rhs placed
    into the canonical block sharding.  ``nshards`` must divide the
    operator's block count (blocks are the failure unit; shards are
    whole groups of them)."""
    layout = ShardLayout(nblocks=op.nblocks, nshards=nshards)
    if mesh is None:
        mesh = make_data_mesh(nshards)
    sharded = ShardedOperator(op, layout, mesh)
    return sharded, sharded.device_put(b)


def place_state(state, mesh: Mesh, vector_fields: Sequence[str]):
    """Re-pin a solver state NamedTuple to the canonical placement:
    vector fields block-sharded on ``data``, everything else replicated.

    The driver applies this after ``init_state``/``reconstruct`` so the
    jitted step always sees one placement — recovery must not silently
    recompile the step for a different layout (a different layout could
    legally reassociate reductions and break bit-exactness)."""
    vspec = NamedSharding(mesh, P("data"))
    rspec = NamedSharding(mesh, P())
    vfields = set(vector_fields)
    placed = {
        f: jax.device_put(getattr(state, f),
                          vspec if f in vfields else rspec)
        for f in state._fields
    }
    return type(state)(**placed)
