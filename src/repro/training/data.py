"""Data pipeline: deterministic, host-sharded, resumable.

Resumability is a single integer cursor (the step), stored inside the
NVM checkpoint's minimal state — the data-pipeline analogue of the
paper's "reconstruct, don't persist" principle: batches are re-derivable
functions of (seed, step), so nothing else needs saving.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticCorpus:
    """Deterministic synthetic LM batches (zipf-ish token distribution)."""

    vocab: int
    batch: int
    seq: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_index]))
        b = self.batch // self.host_count
        z = rng.zipf(1.3, size=(b, self.seq + 1)).astype(np.int64)
        toks = (z % (self.vocab - 1)) + 1
        return {"tokens": toks[:, :-1].astype(np.int32),
                "targets": toks[:, 1:].astype(np.int32)}


@dataclasses.dataclass
class MemmapCorpus:
    """Token-file corpus (np.memmap), strided per host, resumable by step."""

    path: str
    vocab: int
    batch: int
    seq: int
    host_index: int = 0
    host_count: int = 1

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=np.int32, mode="r")
        self._ntok = self._data.shape[0]

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        b = self.batch // self.host_count
        span = self.seq + 1
        out = np.empty((b, span), np.int32)
        for i in range(b):
            # deterministic stride walk; hosts interleave rows
            row = step * self.batch + self.host_index * b + i
            start = (row * span) % max(self._ntok - span, 1)
            out[i] = self._data[start : start + span]
        return {"tokens": out[:, :-1].copy(), "targets": out[:, 1:].copy()}


def write_token_file(path: str, tokens: np.ndarray) -> None:
    np.asarray(tokens, np.int32).tofile(path)
