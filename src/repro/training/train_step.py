"""Train step: loss, gradients, optimizer update, microbatching, remat.

The step is family-agnostic: it consumes a ``forward(params, batch) ->
(logits, aux)`` closure from the registry.  Cross-entropy runs in fp32
against vocab-sharded logits using the fused select-reduce formulation
(no (B,S,V) one-hot buffer materializes after XLA fusion).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.training.optimizer import AdamWConfig, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    aux_weight: float = 1e-2   # MoE load-balance loss weight
    z_weight: float = 1e-4     # z-loss (logit drift regularizer)
    # int8 + error-feedback gradient compression (cross-pod DCI lever:
    # 4x less gradient traffic vs fp32; see training/compression.py)
    compress_grads: bool = False


def token_xent(logits: jax.Array, targets: jax.Array, z_weight: float
               ) -> Tuple[jax.Array, jax.Array]:
    """Mean CE over tokens (+z-loss). logits fp32 (B,S,V); targets (B,S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    v = logits.shape[-1]
    onehot = jax.nn.one_hot(targets, v, dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    ce = jnp.mean(logz - gold)
    zloss = jnp.mean(jnp.square(logz))
    return ce + z_weight * zloss, ce


def make_loss_fn(forward: Callable, tcfg: TrainConfig):
    def loss_fn(params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        logits, aux = forward(params, batch)
        loss, ce = token_xent(logits, batch["targets"], tcfg.z_weight)
        total = loss + tcfg.aux_weight * aux
        return total, {"loss": ce, "aux": aux}
    return loss_fn


def make_train_step(
    forward: Callable,
    opt_cfg: AdamWConfig,
    tcfg: TrainConfig = TrainConfig(),
) -> Callable:
    """Returns ``step(params, opt_state, batch) -> (params, opt, metrics)``."""
    loss_fn = make_loss_fn(forward, tcfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            n = tcfg.microbatches

            def split(key, x):
                # batch dim is axis 0 except M-RoPE positions (3, B, S)
                ax = 1 if key == "positions" else 0
                b = x.shape[ax]
                parts = x.reshape(*x.shape[:ax], n, b // n, *x.shape[ax + 1:])
                return jnp.moveaxis(parts, ax, 0)

            micro = {k: split(k, v) for k, v in batch.items()}

            def accum(carry, mb):
                gacc, lacc = carry
                (l, m), g = grad_fn(params, mb)
                gacc = jax.tree.map(jnp.add, gacc, g)
                return (gacc, lacc + m["loss"]), None

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(accum, (zero_g, 0.0), micro)
            grads = jax.tree.map(lambda g: g / n, gsum)
            metrics = {"loss": lsum / n, "aux": jnp.zeros(())}
        else:
            (l, metrics), grads = grad_fn(params, batch)

        if tcfg.compress_grads:
            from repro.training.compression import GradCompression, apply as _ef
            ef = opt_state.get("ef")
            if ef is None:
                ef = GradCompression.init(params)
            grads, ef = _ef(grads, ef)
            opt_state = dict(opt_state)
            opt_state["ef"] = ef

        ef_keep = opt_state.get("ef") if tcfg.compress_grads else None
        base_opt = {k: v for k, v in opt_state.items() if k != "ef"}
        params, base_opt, opt_metrics = adamw_update(grads, base_opt, params, opt_cfg)
        opt_state = dict(base_opt)
        if ef_keep is not None:
            opt_state["ef"] = ef_keep
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return step
