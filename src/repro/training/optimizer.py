"""Optimizers as pure pytree transforms (no optax in this environment).

Moments inherit the parameters' ZeRO-3 sharding (the spec tree is reused
verbatim), so optimizer state is fully sharded — the distributed-
optimizer half of FSDP.  ``moment_dtype="bfloat16"`` halves optimizer
memory (beyond-paper memory lever, recorded in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    warmup_steps: int = 100


def _schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda dt: jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return {"m": zeros(jnp.float32), "v": zeros(jnp.float32),
            "step": jnp.zeros((), jnp.int32)}


def adamw_init_specs(param_specs) -> Dict[str, Any]:
    """Moment sharding specs mirror the parameter specs."""
    return {"m": param_specs, "v": param_specs, "step": ()}


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(
    grads, opt_state: Dict[str, Any], params, cfg: AdamWConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = opt_state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12)) if cfg.grad_clip > 0 else 1.0
    lr = _schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(mdt), v_new.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
