"""Training substrate: optimizer, train step, data pipeline."""
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update  # noqa: F401
from repro.training.train_step import TrainConfig, make_train_step  # noqa: F401
from repro.training.data import SyntheticCorpus, MemmapCorpus  # noqa: F401
