"""Gradient compression with error feedback (cross-pod sync trick).

On the multi-pod mesh the gradient all-reduce crosses the inter-pod DCI —
the slowest link in the system.  Per-tensor symmetric int8 quantization
cuts that traffic 4x vs fp32 (2x vs bf16); **error feedback** (Seide et
al. '14 / Karimireddy et al. '19) accumulates the quantization residual
locally and re-injects it the next step, preserving convergence
(the compressed-SGD regret bound needs exactly this).

Usage::

    comp = GradCompression.init(params)
    grads_q, comp = comp.compress(grads)     # int8 + scales (+ residual)
    # ... all-reduce the int8 payload across pods ...
    grads = decompress(grads_q)

With pjit-auto the reduce placement belongs to XLA, so ``compressed_update``
wires compression around the optimizer update directly: the quantized
tensors are what a pod-boundary reducer would move (the 4x factor is
recorded in EXPERIMENTS §Perf as a multi-pod lever); numerics are fully
exercised on any backend.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Compressed(NamedTuple):
    q: Any       # int8 pytree
    scale: Any   # fp32 per-tensor scales


class GradCompression(NamedTuple):
    """Error-feedback state: the local quantization residual per tensor."""

    residual: Any

    @classmethod
    def init(cls, params) -> "GradCompression":
        return cls(residual=jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def compress(self, grads) -> Tuple[Compressed, "GradCompression"]:
        def one(g, r):
            corrected = g.astype(jnp.float32) + r
            scale = jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
            new_r = corrected - q.astype(jnp.float32) * scale
            return q, scale, new_r

        out = jax.tree.map(one, grads, self.residual)
        q = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        s = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        r = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return Compressed(q, s), GradCompression(residual=r)


def decompress(c: Compressed):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, c.q, c.scale)


def compressed_bytes(c: Compressed) -> int:
    return sum(q.size for q in jax.tree.leaves(c.q)) \
        + 4 * len(jax.tree.leaves(c.scale))


def apply(grads, ef_state: GradCompression):
    """Quantize -> (conceptual pod-boundary reduce) -> dequantize, with
    error feedback.  Returns (approx_grads, new_ef_state)."""
    c, new_state = ef_state.compress(grads)
    return decompress(c), new_state
