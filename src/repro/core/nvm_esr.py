"""NVM-ESR: persistence of the minimal recovery set to NVRAM (paper §3-4).

Two architectures:

- :class:`NVMESRHomogeneous` — every block persists its shard to **local**
  NVM through a ``libpmemobj``-like pool (paper §4.2, Fig. 5) or, by tier
  choice, to a local SSD (the paper's reference point).  If a block's
  node fails, its pool becomes unreachable until the node recovers
  (Algorithm 5, homogeneous branch) — recovery then reads from the local
  pool, which survived the crash.

- :class:`NVMESRPRD` — all blocks persist to a **remote PRD node** via MPI
  one-sided communication over RDMA with PSCW epochs (paper §4.1, Fig. 4).
  Recovery data stays reachable by every surviving rank even while failed
  nodes are down; reconstruction can start immediately on spare ranks.

Both keep a 4-slot ring per block (pair-level double buffering): slot
``k % 4`` holds ``(k, beta^(k-1), p^(k))``.  The newest *consecutive valid
pair* ``(k-1, k)`` is the recovery point; a crash tearing the in-flight
slot write leaves the previous pair intact (crash-consistency property
tests exercise this).

RAM overhead: **zero** — this is the paper's headline claim; NVM holds
``O(n)`` values total versus ``O(n * proc)`` RAM for in-memory ESR.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.esr import UnrecoverableFailure
from repro.core.state import RecoveryPayload, decode_payload, encode_payload, payload_nbytes
from repro.nvm.pmdk import PmemPool
from repro.nvm.prd import PRDNode
from repro.nvm.store import CostModel, Store, Tier

SLOTS = 4  # pair-level double buffering of (p^(k-1), p^(k))


class NVMESRHomogeneous:
    """Local-NVM persistence (one pool per block / compute node)."""

    name = "nvm-esr-homogeneous"

    def __init__(
        self,
        nblocks: int,
        block_size: int,
        dtype,
        tier: Tier = Tier.NVM,
        pool_dir: Optional[str] = None,
        cost_model: Optional[CostModel] = None,
    ):
        self.nblocks = nblocks
        self.block_size = block_size
        self.dtype = np.dtype(dtype)
        self.cost = cost_model if cost_model is not None else CostModel()
        slot_bytes = payload_nbytes(block_size, self.dtype)
        self.pools: List[PmemPool] = []
        for b in range(nblocks):
            path = None if pool_dir is None else os.path.join(pool_dir, f"pool_{b}.pmem")
            # x2 inside PmemPool (its own double buffer) x SLOTS/2 ring entries
            store = Store((slot_bytes + 64) * SLOTS * 2, tier=tier, path=path,
                          cost_model=self.cost)
            pool = PmemPool(store, layout="nvm-esr")
            for s in range(SLOTS):
                pool.create(f"slot{s}", slot_bytes)
            self.pools.append(pool)
        self._down: set = set()
        self._event = 0  # persistence-event counter (NOT k: ESRP persists
        #                  with gaps, and k % SLOTS would overwrite a slot
        #                  that is still part of the last complete pair)

    # ------------------------------------------------------------------
    def persist(self, k: int, beta: float, p_full: np.ndarray) -> float:
        """Persistence iteration: each block persists its own shard locally.

        Embarrassingly parallel across nodes (paper §5), so the modeled
        wall cost is the **max** over blocks, not the sum.
        """
        p_full = np.asarray(p_full, self.dtype)
        slot = self._event % SLOTS
        self._event += 1
        per_block = []
        for b, pool in enumerate(self.pools):
            shard = p_full[b * self.block_size : (b + 1) * self.block_size]
            per_block.append(pool.persist(f"slot{slot}", encode_payload(k, beta, shard)))
        cost = max(per_block)
        self.cost.add("persist_wall", cost)
        return cost

    # ------------------------------------------------------------------
    def fail(self, failed_blocks: Sequence[int]) -> None:
        """Node crash: local pools survive but are unreachable until the
        node recovers; in-flight (unflushed) writes are torn away."""
        for b in failed_blocks:
            self.pools[b].store.crash()
            self._down.add(b)

    def node_recovered(self, blocks: Sequence[int]) -> None:
        """Algorithm 5 (homogeneous): wait for failed nodes to come back."""
        for b in blocks:
            self.pools[b].recover()
            self._down.discard(b)

    def recover(self, failed_blocks: Sequence[int], k: int) -> Tuple[RecoveryPayload, RecoveryPayload]:
        # Homogeneous recovery requires the failed nodes to be up again.
        self.node_recovered(failed_blocks)
        prev_parts, cur_parts, beta = [], [], None
        for b in failed_blocks:
            pool = self.pools[b]
            # content-matched scan: slots are event-addressed, so find the
            # wanted iterations by the k stored in each valid slot
            found = {}
            for sl in range(SLOTS):
                raw = pool.read(f"slot{sl}")
                if raw is not None:
                    payload = decode_payload(raw, self.dtype)
                    found[payload.k] = payload
            got = {}
            for kk in (k - 1, k):
                if kk not in found:
                    raise UnrecoverableFailure(
                        f"block {b}: no valid slot holds p^({kk}) "
                        f"(have {sorted(found)})")
                got[kk] = found[kk]
            prev_parts.append(got[k - 1].p)
            cur_parts.append(got[k].p)
            beta = got[k].beta
        return (
            RecoveryPayload(k - 1, 0.0, np.concatenate(prev_parts)),
            RecoveryPayload(k, beta, np.concatenate(cur_parts)),
        )

    def latest_pair(self, block: int = 0) -> Optional[int]:
        """Newest k with a valid consecutive (k-1, k) pair on ``block``."""
        pool = self.pools[block]
        ks = []
        for s in range(SLOTS):
            raw = pool.read(f"slot{s}")
            if raw is not None:
                ks.append(decode_payload(raw, self.dtype).k)
        ks = sorted(set(ks))
        best = None
        for k in ks:
            if k - 1 in ks:
                best = k
        return best

    # ------------------------------------------------------------------
    def memory_overhead_values(self) -> int:
        return 0  # the headline claim: zero RAM redundancy

    def nvm_values(self) -> int:
        return SLOTS * self.nblocks * self.block_size


class NVMESRPRD:
    """Remote persistence to a PRD sub-cluster node over MPI OSC / RDMA."""

    name = "nvm-esr-prd"

    def __init__(
        self,
        nblocks: int,
        block_size: int,
        dtype,
        tier: Tier = Tier.NVM,
        network: str = "rdma",
        path: Optional[str] = None,
        cost_model: Optional[CostModel] = None,
        async_drain: bool = True,
    ):
        self.nblocks = nblocks
        self.block_size = block_size
        self.dtype = np.dtype(dtype)
        slot_bytes = payload_nbytes(block_size, self.dtype)
        # PRDNode double-buffers by seq parity (2 slots/rank); a 4-slot ring
        # per block is obtained with two *virtual* ranks per block.
        self.prd = PRDNode(
            nranks=nblocks * 2,
            capacity_per_rank=slot_bytes,
            tier=tier,
            network=network,
            path=path,
            cost_model=cost_model,
            async_drain=async_drain,
        )
        self.cost = self.prd.store.cost
        self._event = 0  # persistence-event counter (see NVMESRHomogeneous)

    # ------------------------------------------------------------------
    def persist(self, k: int, beta: float, p_full: np.ndarray) -> float:
        """One PSCW persistence epoch (paper Fig. 4): all blocks put their
        shard + header, complete, and proceed; the PRD target drains and
        flushes asynchronously.  Returns the origin-visible modeled cost."""
        p_full = np.asarray(p_full, self.dtype)
        e = self._event
        self._event += 1
        vr = (e >> 1) & 1        # 4-ring: (vrank offset, parity) by event
        group = [b * 2 + vr for b in range(self.nblocks)]
        self.prd.begin_epoch(group)
        origin = 0.0
        for b in range(self.nblocks):
            shard = p_full[b * self.block_size : (b + 1) * self.block_size]
            payload = encode_payload(k, beta, shard)
            # header seq carries k+1 (content id); the slot is event-chosen
            origin += self.prd.put_rank(b * 2 + vr, payload, seq=k + 1,
                                        slot=e & 1)
        self.prd.end_epoch()
        self.cost.add("persist_origin", origin)
        return origin

    def drain(self) -> float:
        """Join the PRD exposure epoch (target-side persist)."""
        return self.prd.join()

    # ------------------------------------------------------------------
    def fail(self, failed_blocks: Sequence[int]) -> None:
        """Compute-node failures do NOT touch the PRD node: recovery data
        stays reachable (the PRD architecture's defining property)."""
        self.drain()  # epochs in flight still complete on the PRD side

    def recover(self, failed_blocks: Sequence[int], k: int) -> Tuple[RecoveryPayload, RecoveryPayload]:
        prev_parts, cur_parts, beta = [], [], None
        for b in failed_blocks:
            got = {}
            for kk in (k - 1, k):
                payload = None
                for vr in (0, 1):  # content-matched scan over the 4-ring
                    found = self.prd.read_latest(b * 2 + vr, want_seq=kk + 1)
                    if found is not None:
                        payload = decode_payload(found[1], self.dtype)
                        break
                if payload is None or payload.k != kk:
                    raise UnrecoverableFailure(
                        f"block {b}: no valid PRD slot holds p^({kk})")
                got[kk] = payload
            prev_parts.append(got[k - 1].p)
            cur_parts.append(got[k].p)
            beta = got[k].beta
        return (
            RecoveryPayload(k - 1, 0.0, np.concatenate(prev_parts)),
            RecoveryPayload(k, beta, np.concatenate(cur_parts)),
        )

    # ------------------------------------------------------------------
    def memory_overhead_values(self) -> int:
        return 0

    def nvm_values(self) -> int:
        return SLOTS * self.nblocks * self.block_size


BACKENDS = {
    "esr": "repro.core.esr.InMemoryESR",
    "nvm-homogeneous": NVMESRHomogeneous,
    "nvm-prd": NVMESRPRD,
}
