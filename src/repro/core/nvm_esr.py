"""NVM-ESR: persistence of the minimal recovery set to NVRAM (paper §3-4).

Two architectures:

- :class:`NVMESRHomogeneous` — every block persists its shard to **local**
  NVM through a ``libpmemobj``-like pool (paper §4.2, Fig. 5) or, by tier
  choice, to a local SSD (the paper's reference point).  If a block's
  node fails, its pool becomes unreachable until the node recovers
  (Algorithm 5, homogeneous branch) — recovery then reads from the local
  pool, which survived the crash.

- :class:`NVMESRPRD` — all blocks persist to a **remote PRD node** via MPI
  one-sided communication over RDMA with PSCW epochs (paper §4.1, Fig. 4).
  Recovery data stays reachable by every surviving rank even while failed
  nodes are down; reconstruction can start immediately on spare ranks.

Both are **schema-driven** (solver-zoo generalization): slot payloads are
encoded from any solver's :class:`~repro.core.state.RecoverySchema`
(named vectors + scalars), and the slot ring is sized to the schema's
recovery ``history`` — ``2 * history`` slots give burst-level double
buffering: the newest *consecutive valid run* of ``history`` iterations
is the recovery point, and a crash tearing the in-flight slot write
leaves the previous run intact (crash-consistency property tests
exercise this).  For PCG (history=2) this is exactly the 4-slot
``(k-1, k)`` pair ring of the original implementation.

RAM overhead: **zero** — this is the paper's headline claim; NVM holds
``O(n)`` values total versus ``O(n * proc)`` RAM for in-memory ESR.
"""
from __future__ import annotations

import os
from typing import List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.esr import InMemoryESR, UnrecoverableFailure
from repro.core.state import (
    PCG_SCHEMA,
    RecoveryPayload,
    RecoverySchema,
    RecoverySet,
    concat_sets,
    legacy_pair,
    newest_complete_run,
    peek_k,
    require_pcg_schema,
    shard_vectors,
    typed_vectors,
)
from repro.nvm.backend import (
    OVERLAP_NATIVE,
    BackendCapabilities,
    DeprecatedBackendTable,
    SchemaDrivenBackend,
    register_backend_class,
    warn_legacy_call,
)
from repro.nvm.pmdk import PmemPool
from repro.nvm.prd import PRDNode
from repro.nvm.store import CostModel, PersistStager, Store, Tier

def ring_slots(schema: RecoverySchema) -> int:
    """Slot-ring size: double-buffer the ``history``-long recovery run."""
    return max(2, 2 * schema.history)


class NVMESRHomogeneous(SchemaDrivenBackend):
    """Local-NVM persistence (one pool per block / compute node)."""

    name = "nvm-esr-homogeneous"

    def __init__(
        self,
        nblocks: int,
        block_size: int,
        dtype,
        tier: Tier = Tier.NVM,
        pool_dir: Optional[str] = None,
        cost_model: Optional[CostModel] = None,
        schema: RecoverySchema = PCG_SCHEMA,
    ):
        self.nblocks = nblocks
        self.block_size = block_size
        self.dtype = np.dtype(dtype)
        self.schema = schema
        self.slots = ring_slots(schema)
        self.cost = cost_model if cost_model is not None else CostModel()
        slot_bytes = schema.slot_nbytes(block_size, self.dtype)
        self.pools: List[PmemPool] = []
        for b in range(nblocks):
            path = None if pool_dir is None else os.path.join(pool_dir, f"pool_{b}.pmem")
            # x2 inside PmemPool (its own double buffer) x ring entries
            store = Store((slot_bytes + 64) * self.slots * 2, tier=tier,
                          path=path, cost_model=self.cost)
            pool = PmemPool(store, layout="nvm-esr")
            for s in range(self.slots):
                pool.create(f"slot{s}", slot_bytes)
            self.pools.append(pool)
        self._down: set = set()
        self._event = 0  # persistence-event counter (NOT k: ESRP persists
        #                  with gaps, and k % slots would overwrite a slot
        #                  that is still part of the last complete run)
        self._stager = PersistStager(self.persist_set, cost_model=self.cost)

    @property
    def capabilities(self) -> BackendCapabilities:
        """Local pools survive a node crash (Algorithm 5 waits for the
        node to return), but the pool service itself is the node — a
        persistence-service loss is not survivable without mirroring."""
        return BackendCapabilities(
            durability=self.pools[0].store.tier.value,
            survives_node_loss=True,
            survives_prd_loss=False,
            overlap=OVERLAP_NATIVE,
            max_block_failures=None,
        )

    def storage_crash(self) -> None:
        """Persistence-service loss: every pool's node power-fails at
        once (unflushed writes torn).  Reachability is gone regardless;
        sessions guard fetches with :class:`UnrecoverableFailure`."""
        self._stager.abort()
        for pool in self.pools:
            pool.store.crash()

    # -- overlapped persistence (DESIGN.md §6): stage now, flush later
    def persist_begin(self, k: int, scalars: Mapping[str, float],
                      vectors: Mapping[str, np.ndarray]) -> float:
        """Stage the payload (local DRAM copy); the pmem slot write happens
        at :meth:`persist_commit` and overlaps the next iteration."""
        return self._stager.begin(k, scalars, vectors)

    def persist_commit(self) -> float:
        """Flush the oldest staged payload through the local pools."""
        return self._stager.commit()

    def persist_drain(self) -> float:
        """Drain barrier: commit everything staged.  PmemPool commits are
        synchronous-durable (payload->flush->header->flush), so after this
        returns every committed slot survives a crash."""
        return self._stager.drain()

    # ------------------------------------------------------------------
    def persist_set(self, k: int, scalars: Mapping[str, float],
                    vectors: Mapping[str, np.ndarray]) -> float:
        """Persistence iteration: each block persists its own shard locally.

        Embarrassingly parallel across nodes (paper §5), so the modeled
        wall cost is the **max** over blocks, not the sum.
        """
        slot = self._event % self.slots
        self._event += 1
        typed = typed_vectors(self.schema, vectors, self.dtype)
        per_block = []
        for b, pool in enumerate(self.pools):
            shards = shard_vectors(self.schema, typed, b, self.block_size)
            payload = self.schema.encode(k, scalars, shards)
            per_block.append(pool.persist(f"slot{slot}", payload))
        cost = max(per_block)
        self.cost.add("persist_wall", cost)
        return cost

    def persist(self, k: int, beta: float, p_full: np.ndarray) -> float:
        """Legacy PCG-shaped persist (pre-zoo API; deprecated)."""
        warn_legacy_call(self, "persist")
        require_pcg_schema(self.schema, "persist")
        return self.persist_set(k, {"beta": beta}, {"p": p_full})

    # ------------------------------------------------------------------
    def fail(self, failed_blocks: Sequence[int]) -> None:
        """Node crash: local pools survive but are unreachable until the
        node recovers; in-flight (unflushed) writes are torn away — both
        unflushed store bytes and staged-but-uncommitted payloads."""
        self._stager.abort()
        for b in failed_blocks:
            self.pools[b].store.crash()
            self._down.add(b)

    def node_recovered(self, blocks: Sequence[int]) -> None:
        """Algorithm 5 (homogeneous): wait for failed nodes to come back."""
        for b in blocks:
            self.pools[b].recover()
            self._down.discard(b)

    def recover_set(self, failed_blocks: Sequence[int],
                    ks: Sequence[int]) -> List[RecoverySet]:
        # Homogeneous recovery requires the failed nodes to be up again.
        self.node_recovered(failed_blocks)
        per_k = {kk: [] for kk in ks}
        for b in failed_blocks:
            pool = self.pools[b]
            # content-matched scan: slots are event-addressed, so find the
            # wanted iterations by the k stored in each valid slot (header
            # peek first; only matching slots decode their vectors)
            found = {}
            for sl in range(self.slots):
                raw = pool.read(f"slot{sl}")
                if raw is not None:
                    found[peek_k(raw)] = raw
            for kk in ks:
                if kk not in found:
                    raise UnrecoverableFailure(
                        f"block {b}: no valid slot holds iteration {kk} "
                        f"(have {sorted(found)})")
                per_k[kk].append(self.schema.decode(found[kk], self.dtype))
        return [concat_sets(self.schema, per_k[kk]) for kk in ks]

    def recover(self, failed_blocks: Sequence[int], k: int) -> Tuple[RecoveryPayload, RecoveryPayload]:
        """Legacy PCG-shaped recover (pre-zoo API; deprecated): the
        (k-1, k) pair."""
        warn_legacy_call(self, "recover")
        require_pcg_schema(self.schema, "recover")
        return legacy_pair(self.recover_set(failed_blocks, (k - 1, k)))

    def latest_run(self, block: int = 0) -> Optional[int]:
        """Newest k ending a valid consecutive ``history``-run on ``block``."""
        pool = self.pools[block]
        ks = set()
        for s in range(self.slots):
            raw = pool.read(f"slot{s}")
            if raw is not None:
                ks.add(peek_k(raw))
        return newest_complete_run(ks, self.schema.history)

    # legacy alias (PCG pair semantics)
    latest_pair = latest_run

    # the protocol name (PersistSession.durable_run delegates here)
    durable_run = latest_run

    # ------------------------------------------------------------------
    def memory_overhead_values(self) -> int:
        return 0  # the headline claim: zero RAM redundancy

    def nvm_values(self) -> int:
        return self.slots * len(self.schema.vectors) * self.nblocks * self.block_size


class NVMESRPRD(SchemaDrivenBackend):
    """Remote persistence to a PRD sub-cluster node over MPI OSC / RDMA."""

    name = "nvm-esr-prd"

    def __init__(
        self,
        nblocks: int,
        block_size: int,
        dtype,
        tier: Tier = Tier.NVM,
        network: str = "rdma",
        path: Optional[str] = None,
        cost_model: Optional[CostModel] = None,
        async_drain: bool = True,
        schema: RecoverySchema = PCG_SCHEMA,
    ):
        self.nblocks = nblocks
        self.block_size = block_size
        self.dtype = np.dtype(dtype)
        self.schema = schema
        slot_bytes = schema.slot_nbytes(block_size, self.dtype)
        # PRDNode double-buffers by seq parity (2 slots/rank); a
        # ``ring_slots``-deep ring per block is obtained with
        # ``ring_slots/2`` *virtual* ranks per block.
        self.vranks = ring_slots(schema) // 2
        self.prd = PRDNode(
            nranks=nblocks * self.vranks,
            capacity_per_rank=slot_bytes,
            tier=tier,
            network=network,
            path=path,
            cost_model=cost_model,
            async_drain=async_drain,
        )
        self.cost = self.prd.store.cost
        self._event = 0  # persistence-event counter (see NVMESRHomogeneous)
        self._stager = PersistStager(self.persist_set, cost_model=self.cost)

    @property
    def capabilities(self) -> BackendCapabilities:
        """Recovery data stays reachable through arbitrary compute-node
        failures (the PRD architecture's defining property) but the PRD
        node itself is a single point of failure — the paper scopes the
        RAID fix out; :class:`repro.nvm.backend.ReplicatedBackend`
        composes it back in."""
        return BackendCapabilities(
            durability=self.prd.store.tier.value,
            survives_node_loss=True,
            survives_prd_loss=False,
            overlap=OVERLAP_NATIVE,
            max_block_failures=None,
        )

    def storage_crash(self) -> None:
        """The PRD node power-fails: staged origin-side payloads can
        never be put, and unflushed exposure epochs are torn away."""
        self._stager.abort()
        self.prd.crash()

    # -- overlapped persistence (DESIGN.md §6): stage now, put later
    def persist_begin(self, k: int, scalars: Mapping[str, float],
                      vectors: Mapping[str, np.ndarray]) -> float:
        """Stage the payload (local DRAM copy); the PSCW epoch happens at
        :meth:`persist_commit` and overlaps the next iteration.  This
        stacks with the PRD's own target-side overlap: commit returns at
        origin-completion and the PRD drain proceeds asynchronously."""
        return self._stager.begin(k, scalars, vectors)

    def persist_commit(self) -> float:
        """Run the PSCW epoch for the oldest staged payload."""
        return self._stager.commit()

    def persist_drain(self) -> float:
        """Drain barrier: commit staged payloads AND join the PRD exposure
        epoch, so every committed slot is target-side durable."""
        return self._stager.drain() + self.drain()

    # ------------------------------------------------------------------
    def persist_set(self, k: int, scalars: Mapping[str, float],
                    vectors: Mapping[str, np.ndarray]) -> float:
        """One PSCW persistence epoch (paper Fig. 4): all blocks put their
        shard + header, complete, and proceed; the PRD target drains and
        flushes asynchronously.  Returns the origin-visible modeled cost."""
        e = self._event
        self._event += 1
        vr = (e >> 1) % self.vranks  # ring: (vrank offset, parity) by event
        group = [b * self.vranks + vr for b in range(self.nblocks)]
        self.prd.begin_epoch(group)
        typed = typed_vectors(self.schema, vectors, self.dtype)
        origin = 0.0
        for b in range(self.nblocks):
            shards = shard_vectors(self.schema, typed, b, self.block_size)
            payload = self.schema.encode(k, scalars, shards)
            # header seq carries k+1 (content id); the slot is event-chosen
            origin += self.prd.put_rank(b * self.vranks + vr, payload,
                                        seq=k + 1, slot=e & 1)
        self.prd.end_epoch()
        self.cost.add("persist_origin", origin)
        return origin

    def persist(self, k: int, beta: float, p_full: np.ndarray) -> float:
        """Legacy PCG-shaped persist (pre-zoo API; deprecated)."""
        warn_legacy_call(self, "persist")
        require_pcg_schema(self.schema, "persist")
        return self.persist_set(k, {"beta": beta}, {"p": p_full})

    def drain(self) -> float:
        """Join the PRD exposure epoch (target-side persist)."""
        return self.prd.join()

    # ------------------------------------------------------------------
    def fail(self, failed_blocks: Sequence[int]) -> None:
        """Compute-node failures do NOT touch the PRD node: recovery data
        stays reachable (the PRD architecture's defining property).
        Staged-but-uncommitted payloads die with the compute nodes (their
        puts never started); epochs already in flight still complete on
        the PRD side."""
        self._stager.abort()
        self.drain()

    def recover_set(self, failed_blocks: Sequence[int],
                    ks: Sequence[int]) -> List[RecoverySet]:
        per_k = {kk: [] for kk in ks}
        for b in failed_blocks:
            for kk in ks:
                rset = None
                for vr in range(self.vranks):  # content-matched ring scan
                    found = self.prd.read_latest(b * self.vranks + vr,
                                                 want_seq=kk + 1)
                    if found is not None:
                        rset = self.schema.decode(found[1], self.dtype)
                        break
                if rset is None or rset.k != kk:
                    raise UnrecoverableFailure(
                        f"block {b}: no valid PRD slot holds iteration {kk}")
                per_k[kk].append(rset)
        return [concat_sets(self.schema, per_k[kk]) for kk in ks]

    def recover(self, failed_blocks: Sequence[int], k: int) -> Tuple[RecoveryPayload, RecoveryPayload]:
        """Legacy PCG-shaped recover (pre-zoo API; deprecated): the
        (k-1, k) pair."""
        warn_legacy_call(self, "recover")
        require_pcg_schema(self.schema, "recover")
        return legacy_pair(self.recover_set(failed_blocks, (k - 1, k)))

    def durable_run(self) -> Optional[int]:
        """Newest iteration ending a complete ``history``-run durable on
        the PRD node (block 0's virtual ranks; this is a drain barrier —
        it joins any in-flight exposure epoch before answering)."""
        ks = set()
        for vr in range(self.vranks):
            for seq, _payload in self.prd.scan_rank(vr):
                ks.add(seq - 1)  # header seq carries k+1
        return newest_complete_run(ks, self.schema.history)

    # ------------------------------------------------------------------
    def memory_overhead_values(self) -> int:
        return 0

    def nvm_values(self) -> int:
        return (2 * self.vranks * len(self.schema.vectors)
                * self.nblocks * self.block_size)


# The three core architectures in the single backend registry
# (:mod:`repro.nvm.backend`); composites ("replicated", "tiered")
# register there.  ``repro.solvers.registry.make_backend`` and
# ``repro.api`` size registry backends from an operator.
register_backend_class("esr", InMemoryESR)
register_backend_class("nvm-homogeneous", NVMESRHomogeneous)
register_backend_class("nvm-prd", NVMESRPRD)

# Deprecated table view of the pre-redesign registry: iteration and
# membership stay silent (benchmarks sweep the names), construction via
# ``BACKENDS[name](...)`` warns and routes through the class factory.
BACKENDS = DeprecatedBackendTable({
    "esr": InMemoryESR,
    "nvm-homogeneous": NVMESRHomogeneous,
    "nvm-prd": NVMESRPRD,
})
