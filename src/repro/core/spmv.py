"""Sharded PCG iteration for the production mesh (dry-run / roofline path).

The solver state lives as 3-D grids ``(nz, ny, nx)`` with the z axis
sharded across **all** mesh axes (the paper's row-block distribution: each
device owns a z-slab = one "process" block).  Under ``jit`` the 7-point
stencil's z-neighbour access lowers to a nearest-neighbour halo exchange
(``collective-permute``) and the dot products to ``all-reduce`` — exactly
the communication structure of distributed PCG over MPI.

ESR variants (what the roofline measures):

- ``esr_mode="none"`` / ``"nvm"`` — plain iteration.  NVM-ESR persistence
  happens **off the device graph** (host pull of the local shard; zero
  collectives, zero device RAM), so the compiled HLO is identical to the
  unprotected solver: the paper's headline claim, visible structurally.
- ``esr_mode="inmemory"`` — the iteration additionally materializes the
  peer-RAM redundancy: ``p`` is all-gathered and kept replicated for two
  successive iterations (``O(2n)`` extra bytes *per device*, an
  ``all-gather`` of n values per iteration in the collective schedule).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.poisson import stencil7


def _grid_sharding(mesh: Mesh, shard_axes) -> NamedSharding:
    return NamedSharding(mesh, P(shard_axes, None, None))


# ----------------------------------------------------------------------
# Deterministic block-hierarchical reductions (sharded exactness).
#
# A global ``jnp.vdot`` lets XLA pick a reduction order per compiled
# program, so the same mathematical dot produces different low-order
# bits unsharded vs sharded (and even between two sharded layouts).
# The zoo's bit-exactness contract — a sharded solve reproduces the
# unsharded trajectory exactly — therefore pins the order explicitly:
#
# 1. per-block partial sums (``reshape(nblocks, -1).sum(axis=1)``):
#    each partial is computed entirely within one block, which the
#    ``data``-mesh layout never splits across devices, so the partials
#    are bitwise identical under any 1-D block sharding;
# 2. an explicit replication constraint gathers the partials (the only
#    collective — an all-gather of ``nblocks`` scalars);
# 3. an UNROLLED left-to-right add chain combines them.  ``jnp.sum``
#    over the partials is NOT enough: XLA fuses it context-dependently
#    and reassociates across shardings, which is exactly the
#    nondeterminism being excluded.
# ----------------------------------------------------------------------
def make_det_dot(nblocks: int, mesh: Optional[Mesh] = None):
    """Build ``dot(a, b)``: a block-hierarchical, order-pinned inner
    product that is bitwise identical across device shardings (and
    equal to the unsharded result).  ``mesh`` is the 1-D ``data`` mesh
    of a sharded operator (None for single-device runs)."""
    rep = None if mesh is None else NamedSharding(mesh, P())

    def det_dot(a: jax.Array, b: jax.Array) -> jax.Array:
        partials = (a * b).reshape(nblocks, -1).sum(axis=1)
        if rep is not None:
            partials = jax.lax.with_sharding_constraint(partials, rep)
        acc = partials[0]
        for i in range(1, nblocks):
            acc = acc + partials[i]
        return acc

    return det_dot


def make_det_rowdots(nblocks: int, mesh: Optional[Mesh] = None):
    """Row-batched :func:`make_det_dot`: ``rowdots(M, w)[i] == det_dot(M[i],
    w)`` for an ``(rows, n)`` matrix — the Arnoldi projection shape.  The
    per-row partials use the same block-hierarchical order, so the result
    is bitwise sharding-independent like the scalar form."""
    rep = None if mesh is None else NamedSharding(mesh, P())

    def det_rowdots(m_rows: jax.Array, w: jax.Array) -> jax.Array:
        rows = m_rows.shape[0]
        partials = (m_rows * w[None, :]).reshape(rows, nblocks, -1).sum(axis=2)
        if rep is not None:
            partials = jax.lax.with_sharding_constraint(partials, rep)
        acc = partials[:, 0]
        for i in range(1, nblocks):
            acc = acc + partials[:, i]
        return acc

    return det_rowdots


def make_sharded_pcg_step(
    mesh: Mesh,
    shard_axes=("pod", "data", "model"),
    esr_mode: str = "nvm",
    dtype=jnp.float32,
) -> Tuple[Callable, Callable]:
    """Build (step_fn, spec_fn) for one sharded PCG iteration.

    ``step_fn(state) -> state`` where state is a dict of grids + scalars.
    ``spec_fn(nz, ny, nx) -> (in_shardings, input ShapeDtypeStructs)``.
    """
    axes = tuple(a for a in shard_axes if a in mesh.axis_names)
    gshard = _grid_sharding(mesh, axes)
    rep = NamedSharding(mesh, P())

    def step(state: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        x, r, z, p, rz = state["x"], state["r"], state["z"], state["p"], state["rz"]
        ap = stencil7(p)                                   # halo exchange on z
        # repro-lint: noqa[RL201] -- roofline dry-run path modeling the paper's MPI all-reduce; outside the zoo exactness contract
        pap = jnp.sum(p * ap)                              # all-reduce
        alpha = rz / pap
        x = x + alpha * p
        r = r - alpha * ap
        zn = r * (1.0 / 6.0)                               # Jacobi M^{-1}
        # repro-lint: noqa[RL201] -- roofline dry-run path modeling the paper's MPI all-reduce; outside the zoo exactness contract
        rz_new = jnp.sum(r * zn)                           # all-reduce
        beta = rz_new / rz
        pn = zn + beta * p
        out = dict(x=x, r=r, z=zn, p=pn, rz=rz_new, beta=beta)
        if esr_mode == "inmemory":
            # Algorithm 2 (ASpMV surplus): replicate p into peer RAM for two
            # successive iterations -> all-gather + 2n replicated residency.
            red_cur = jax.lax.with_sharding_constraint(pn, rep)
            out["esr_red_prev"] = state["esr_red_cur"]
            out["esr_red_cur"] = red_cur
        return out

    def spec(nz: int, ny: int, nx: int):
        grid = jax.ShapeDtypeStruct((nz, ny, nx), dtype)
        scalar = jax.ShapeDtypeStruct((), dtype)
        shardings = dict(x=gshard, r=gshard, z=gshard, p=gshard, rz=rep)
        structs = dict(x=grid, r=grid, z=grid, p=grid, rz=scalar)
        if esr_mode == "inmemory":
            shardings["esr_red_cur"] = rep
            structs["esr_red_cur"] = grid
        return shardings, structs

    return step, spec


def nvm_persist_host(state: Dict[str, jax.Array]) -> np.ndarray:
    """NVM-ESR persistence tap: pull the local ``p`` shard to the host.

    In a real pod each host pulls only its addressable shards
    (``jax.Array.addressable_shards``) and hands the bytes to the NVM
    backend (local pool or PRD window).  No collective, no device memory.
    """
    shards = state["p"].addressable_shards
    return np.concatenate([np.asarray(s.data).reshape(-1) for s in shards])


def make_shardmap_pcg_step(
    mesh: Mesh,
    shard_axes=("pod", "data", "model"),
    esr_mode: str = "nvm",
    dtype=jnp.float32,
):
    """Optimized distributed PCG iteration (§Perf hillclimb A1/A2).

    The auto-GSPMD stencil (pad+slice) makes XLA exchange 3-5 z-plane
    slabs per neighbour (~265 MiB/chip on the 1024^3 grid).  This version
    uses ``shard_map`` with explicit single-plane ``ppermute`` halos — the
    information-theoretic minimum (2 planes/chip) — and the fused-update
    algebra of ``kernels/fused_cg.py`` (on TPU the local stencil and the
    fused update ARE the Pallas kernels; the jnp bodies here are their
    ref semantics, which XLA fuses on CPU).

    Boundary devices receive ppermute's zero-fill — exactly homogeneous
    Dirichlet.
    """
    axes = tuple(a for a in shard_axes if a in mesh.axis_names)
    nshards = 1
    for a in axes:
        nshards *= mesh.shape[a]
    up_perm = [(i, i + 1) for i in range(nshards - 1)]    # send last plane up
    down_perm = [(i + 1, i) for i in range(nshards - 1)]  # send first plane down

    def stencil_local(u, lo, hi):
        zm = jnp.concatenate([lo, u[:-1]], axis=0)
        zp = jnp.concatenate([u[1:], hi], axis=0)
        zero_y = jnp.zeros_like(u[:, :1, :])
        ym = jnp.concatenate([zero_y, u[:, :-1, :]], axis=1)
        yp = jnp.concatenate([u[:, 1:, :], zero_y], axis=1)
        zero_x = jnp.zeros_like(u[:, :, :1])
        xm = jnp.concatenate([zero_x, u[:, :, :-1]], axis=2)
        xp = jnp.concatenate([u[:, :, 1:], zero_x], axis=2)
        return 6.0 * u - zm - zp - ym - yp - xm - xp

    def step_local(state):
        x, r, z, p, rz = state["x"], state["r"], state["z"], state["p"], state["rz"]
        lo = jax.lax.ppermute(p[-1:], axes, up_perm)    # plane from below
        hi = jax.lax.ppermute(p[:1], axes, down_perm)   # plane from above
        ap = stencil_local(p, lo, hi)
        # repro-lint: noqa[RL201] -- shard_map roofline kernel: psum-of-partials is the modeled MPI collective itself
        pap = jax.lax.psum(jnp.sum(p * ap, dtype=jnp.float32), axes)
        alpha = (rz / pap).astype(p.dtype)
        # fused update (Pallas fused_cg on TPU): one pass, fp32 partials
        xn = x + alpha * p
        rn = r - alpha * ap
        zn = rn * (1.0 / 6.0)
        # repro-lint: noqa[RL201] -- shard_map roofline kernel: psum-of-partials is the modeled MPI collective itself
        rz_new = jax.lax.psum(jnp.sum(rn.astype(jnp.float32) * zn.astype(jnp.float32)), axes)
        beta = (rz_new / rz).astype(p.dtype)
        pn = zn + beta * p
        out = dict(x=xn, r=rn, z=zn, p=pn, rz=rz_new, beta=beta)
        if esr_mode == "inmemory":
            out["esr_red_prev"] = state["esr_red_cur"]
            out["esr_red_cur"] = jax.lax.all_gather(pn, axes, tiled=True)
        return out

    grid_spec = P(axes, None, None)
    in_specs = dict(x=grid_spec, r=grid_spec, z=grid_spec, p=grid_spec, rz=P())
    out_specs = dict(x=grid_spec, r=grid_spec, z=grid_spec, p=grid_spec,
                     rz=P(), beta=P())
    if esr_mode == "inmemory":
        in_specs["esr_red_cur"] = P()
        out_specs["esr_red_prev"] = P()
        out_specs["esr_red_cur"] = P()

    step = compat.shard_map(step_local, mesh=mesh, in_specs=(in_specs,),
                            out_specs=out_specs)

    def spec(nz: int, ny: int, nx: int):
        grid = jax.ShapeDtypeStruct((nz, ny, nx), dtype)
        scalar = jax.ShapeDtypeStruct((), jnp.float32)
        shardings = {k: NamedSharding(mesh, v) for k, v in in_specs.items()}
        structs = dict(x=grid, r=grid, z=grid, p=grid, rz=scalar)
        if esr_mode == "inmemory":
            structs["esr_red_cur"] = grid
        return shardings, structs

    return step, spec


def lower_pcg_step(
    mesh: Mesh,
    nz: int,
    ny: int,
    nx: int,
    esr_mode: str = "nvm",
    dtype=jnp.float32,
    shard_axes=("pod", "data", "model"),
    variant: str = "auto",
):
    """Lower one sharded PCG iteration on ``mesh`` (dry-run entry point).

    ``variant="auto"`` is the GSPMD baseline; ``"shardmap"`` is the
    hillclimbed explicit-halo version (§Perf).
    """
    if variant == "shardmap":
        step, spec = make_shardmap_pcg_step(mesh, shard_axes, esr_mode, dtype)
    else:
        step, spec = make_sharded_pcg_step(mesh, shard_axes, esr_mode, dtype)
    shardings, structs = spec(nz, ny, nx)
    with mesh:
        jitted = jax.jit(
            step,
            in_shardings=(shardings,),
            out_shardings=None,
        )
        return jitted.lower(structs)
