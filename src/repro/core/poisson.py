"""Problem operators for the PCG solver.

The paper's workload is the 7-point stencil of the 3-D Poisson equation
(the HPCG kernel).  We implement it matrix-free — ``A`` is never
materialized globally; per-block restrictions needed by exact state
reconstruction (``A[f,f]``, ``A[f,~f]``) are derived from the stencil by
masked application (DESIGN.md §1).

Block convention: the flat index space ``I = [0, n)`` is split into
``nblocks`` contiguous equal blocks — block ``b`` owns
``I_b = [b*bs, (b+1)*bs)``.  For the stencil, blocks are z-slabs, exactly
the paper's row-block distribution of ``A``.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def stencil7(u: jax.Array) -> jax.Array:
    """7-point Poisson stencil with homogeneous Dirichlet boundary.

    ``(A u)[i,j,k] = 6 u[i,j,k] - sum of 6 face neighbours`` on a
    ``(nz, ny, nx)`` grid; out-of-domain neighbours are zero.
    """
    p = jnp.pad(u, 1)
    return (
        6.0 * u
        - p[:-2, 1:-1, 1:-1]
        - p[2:, 1:-1, 1:-1]
        - p[1:-1, :-2, 1:-1]
        - p[1:-1, 2:, 1:-1]
        - p[1:-1, 1:-1, :-2]
        - p[1:-1, 1:-1, 2:]
    )


@dataclass(frozen=True)
class BlockPartition:
    """Contiguous equal-size block partition of ``[0, n)``."""

    n: int
    nblocks: int

    def __post_init__(self):
        if self.n % self.nblocks != 0:
            raise ValueError(f"n={self.n} not divisible by nblocks={self.nblocks}")

    @property
    def block_size(self) -> int:
        return self.n // self.nblocks

    def restrict(self, x: jax.Array, blocks: Sequence[int]) -> jax.Array:
        """``x[I_F]`` for the union F of ``blocks`` (concatenated, flat)."""
        xb = x.reshape(self.nblocks, self.block_size)
        return xb[jnp.asarray(blocks)].reshape(-1)

    def zero_blocks(self, x: jax.Array, blocks: Sequence[int]) -> jax.Array:
        """``x`` with ``x[I_F] = 0``."""
        xb = x.reshape(self.nblocks, self.block_size)
        return xb.at[jnp.asarray(blocks)].set(0.0).reshape(-1)

    def embed(self, v: jax.Array, blocks: Sequence[int]) -> jax.Array:
        """Scatter a concatenated union vector back into a zero full vector."""
        xb = jnp.zeros((self.nblocks, self.block_size), v.dtype)
        vb = v.reshape(len(blocks), self.block_size)
        return xb.at[jnp.asarray(blocks)].set(vb).reshape(-1)

    def scatter(self, x: jax.Array, v: jax.Array, blocks: Sequence[int]) -> jax.Array:
        """``x`` with ``x[I_F] <- v``."""
        xb = x.reshape(self.nblocks, self.block_size)
        vb = v.reshape(len(blocks), self.block_size)
        return xb.at[jnp.asarray(blocks)].set(vb).reshape(-1)


class StencilOperator:
    """Matrix-free 7-point stencil operator on a 3-D grid.

    Blocks are z-slabs: ``nblocks`` must divide ``nz``.
    """

    def __init__(self, nz: int, ny: int, nx: int, nblocks: int = 1, dtype=jnp.float64):
        self.grid = (nz, ny, nx)
        self.n = nz * ny * nx
        self.dtype = dtype
        if nz % nblocks != 0:
            raise ValueError(f"nz={nz} not divisible by nblocks={nblocks}")
        self.partition = BlockPartition(self.n, nblocks)

    @property
    def nblocks(self) -> int:
        return self.partition.nblocks

    def apply(self, x: jax.Array) -> jax.Array:
        return stencil7(x.reshape(self.grid)).reshape(-1).astype(x.dtype)

    def diag(self) -> jax.Array:
        return jnp.full((self.n,), 6.0, self.dtype)

    # ------- restrictions used by exact state reconstruction -------
    def offblock_apply(self, x: jax.Array, blocks: Sequence[int]) -> jax.Array:
        """``A[F, ~F] @ x[~F]``: apply with x zeroed on F, restrict to F."""
        xm = self.partition.zero_blocks(x, blocks)
        return self.partition.restrict(self.apply(xm), blocks)

    def inblock_apply(self, v: jax.Array, blocks: Sequence[int]) -> jax.Array:
        """``A[F, F] @ v`` for the (possibly multi-block) union F."""
        xf = self.partition.embed(v, blocks)
        return self.partition.restrict(self.apply(xf), blocks)

    def to_dense(self) -> np.ndarray:
        eye = jnp.eye(self.n, dtype=self.dtype)
        return np.asarray(jax.vmap(self.apply)(eye).T)


class DenseOperator:
    """Explicit SPD matrix operator (used by property tests)."""

    def __init__(self, a: np.ndarray, nblocks: int = 1):
        a = np.asarray(a)
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError("square matrix required")
        self.a = jnp.asarray(a)
        self.n = a.shape[0]
        self.dtype = self.a.dtype
        self.partition = BlockPartition(self.n, nblocks)

    @property
    def nblocks(self) -> int:
        return self.partition.nblocks

    def apply(self, x: jax.Array) -> jax.Array:
        return self.a @ x

    def diag(self) -> jax.Array:
        return jnp.diagonal(self.a)

    def offblock_apply(self, x: jax.Array, blocks: Sequence[int]) -> jax.Array:
        xm = self.partition.zero_blocks(x, blocks)
        return self.partition.restrict(self.apply(xm), blocks)

    def inblock_apply(self, v: jax.Array, blocks: Sequence[int]) -> jax.Array:
        xf = self.partition.embed(v, blocks)
        return self.partition.restrict(self.apply(xf), blocks)

    def to_dense(self) -> np.ndarray:
        return np.asarray(self.a)


def random_spd(n: int, seed: int = 0, cond: float = 50.0) -> np.ndarray:
    """Well-conditioned random SPD matrix for tests."""
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    eigs = np.geomspace(1.0, cond, n)
    return (q * eigs) @ q.T


# ======================================================================
# Preconditioners.  ``apply`` computes z = P r.  Reconstruction needs
# ``block_solve`` (solve P[F,F] r_F = v) and ``offblock_apply``
# (P[F,~F] r[~F]); both are trivial/local for the families below, which
# is precisely why they are the standard choices for ESR-enabled PCG.
# ======================================================================
class IdentityPreconditioner:
    def __init__(self, op):
        self.op = op

    def apply(self, r: jax.Array) -> jax.Array:
        return r

    def block_solve(self, v: jax.Array, blocks: Sequence[int]) -> jax.Array:
        return v

    def offblock_apply(self, r: jax.Array, blocks: Sequence[int]) -> jax.Array:
        return jnp.zeros_like(self.op.partition.restrict(r, blocks))


class JacobiPreconditioner:
    """P = D^{-1}; diagonal, hence P[F,~F] = 0 and block solves are local."""

    def __init__(self, op):
        self.op = op
        self.inv_diag = 1.0 / op.diag()

    def apply(self, r: jax.Array) -> jax.Array:
        return r * self.inv_diag

    def block_solve(self, v: jax.Array, blocks: Sequence[int]) -> jax.Array:
        # P[F,F] r_F = v  =>  r_F = v / inv_diag[F]
        return v / self.op.partition.restrict(self.inv_diag, blocks)

    def offblock_apply(self, r: jax.Array, blocks: Sequence[int]) -> jax.Array:
        return jnp.zeros_like(self.op.partition.restrict(r, blocks))


class BlockJacobiPreconditioner:
    """P = blockdiag(A[s,s]^{-1}) aligned with the process blocks.

    ``apply`` solves the per-block systems with cached dense Cholesky
    factors (test scale) — production would use local CG.  For
    reconstruction, ``P[F,F]^{-1} = blockdiag(A[s,s])``: the *forward*
    local stencil application, so ``block_solve`` is exact and cheap.
    """

    def __init__(self, op):
        self.op = op
        bs = op.partition.block_size
        blocks = []
        for b in range(op.nblocks):
            cols = jax.vmap(lambda v: op.inblock_apply(v, [b]))(jnp.eye(bs, dtype=op.dtype))
            blocks.append(np.asarray(cols.T))
        self._factors = [np.linalg.cholesky(blk) for blk in blocks]
        self._chol = jnp.asarray(np.stack(self._factors))

    def apply(self, r: jax.Array) -> jax.Array:
        part = self.op.partition
        rb = r.reshape(part.nblocks, part.block_size)

        def solve_one(chol, rhs):
            y = jax.scipy.linalg.solve_triangular(chol, rhs, lower=True)
            return jax.scipy.linalg.solve_triangular(chol.T, y, lower=False)

        return jax.vmap(solve_one)(self._chol, rb).reshape(-1)

    def block_solve(self, v: jax.Array, blocks: Sequence[int]) -> jax.Array:
        # P[F,F] r_F = v  =>  r_F = blockdiag(A[s,s]) v : per-block forward apply
        part = self.op.partition
        vb = v.reshape(len(blocks), part.block_size)
        outs = [self.op.inblock_apply(vb[i], [b]) for i, b in enumerate(blocks)]
        return jnp.concatenate(outs)

    def offblock_apply(self, r: jax.Array, blocks: Sequence[int]) -> jax.Array:
        return jnp.zeros_like(self.op.partition.restrict(r, blocks))


PRECONDITIONERS = {
    "identity": IdentityPreconditioner,
    "jacobi": JacobiPreconditioner,
    "block_jacobi": BlockJacobiPreconditioner,
}


def make_poisson_problem(
    nz: int, ny: int, nx: int, nblocks: int, dtype=jnp.float64, seed: int = 0
) -> Tuple[StencilOperator, jax.Array]:
    """Stencil operator + smooth right-hand side (paper's benchmark problem)."""
    op = StencilOperator(nz, ny, nx, nblocks, dtype)
    z, y, x = jnp.meshgrid(
        jnp.linspace(0, 1, nz), jnp.linspace(0, 1, ny), jnp.linspace(0, 1, nx), indexing="ij"
    )
    b = jnp.sin(jnp.pi * x) * jnp.sin(jnp.pi * y) * jnp.sin(jnp.pi * z) + 0.1
    return op, b.reshape(-1).astype(dtype)
