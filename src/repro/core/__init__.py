"""The paper's primary contribution: exact state reconstruction (ESR) for
distributed PCG, with NVM-backed persistence (NVM-ESR).

Public API
----------
- :func:`repro.core.pcg.solve` / :func:`repro.core.pcg.solve_jit`
- operators/preconditioners in :mod:`repro.core.poisson`
- recovery backends: :class:`repro.core.esr.InMemoryESR`,
  :class:`repro.core.nvm_esr.NVMESRHomogeneous`,
  :class:`repro.core.nvm_esr.NVMESRPRD`
- :func:`repro.core.reconstruction.reconstruct` (Algorithm 3/5)
"""
from repro.core.pcg import (  # noqa: F401
    FailureCampaign,
    FailureEvent,
    FailurePlan,
    PCGConfig,
    SolveReport,
    init_state,
    make_step,
    solve,
    solve_jit,
)
from repro.core.poisson import (  # noqa: F401
    BlockJacobiPreconditioner,
    BlockPartition,
    DenseOperator,
    IdentityPreconditioner,
    JacobiPreconditioner,
    PRECONDITIONERS,
    StencilOperator,
    make_poisson_problem,
    random_spd,
    stencil7,
)
from repro.core.esr import InMemoryESR, UnrecoverableFailure  # noqa: F401
from repro.core.nvm_esr import NVMESRHomogeneous, NVMESRPRD  # noqa: F401
from repro.core.reconstruction import reconstruct  # noqa: F401
from repro.core.state import (  # noqa: F401
    PCG_SCHEMA,
    PCGState,
    RecoverySchema,
    RecoverySet,
    minimal_recovery_state,
)
