"""Exact state reconstruction for PCG (paper Algorithm 3 / 5).

Given the persisted minimal set ``(p^(k-1)_F, p^(k)_F, beta^(k-1))`` for
the failed block union F, plus the surviving shards of ``x, r`` and the
static data (A rows, P rows, b — regenerated matrix-free here), the full
failed state is reconstructed *exactly* (to solver precision):

    z_F = p^(k)_F - beta^(k-1) * p^(k-1)_F                      (line 4)
    solve  P[F,F] r_F = z_F - P[F,~F] r_~F                      (lines 5-6)
    solve  A[F,F] x_F = b_F - r_F - A[F,~F] x_~F                (lines 7-8)

The local solves run on the replacement node; ``A[F,F]`` is SPD (principal
submatrix of an SPD matrix), so we solve with a dense Cholesky for small
blocks or matrix-free local CG for large ones.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.state import PCGState


def _local_cg(apply_fn, rhs: jax.Array, tol: float = 1e-14, maxiter: int = 10000) -> jax.Array:
    """Matrix-free CG on the failed-block operator (replacement-node solve)."""

    def body(carry):
        x, r, p, rs, it = carry
        ap = apply_fn(p)
        # repro-lint: noqa[RL201] -- replacement-node local solve: single-block, single-device by construction
        alpha = rs / jnp.vdot(p, ap)
        x = x + alpha * p
        r = r - alpha * ap
        # repro-lint: noqa[RL201] -- replacement-node local solve: single-block, single-device by construction
        rs_new = jnp.vdot(r, r)
        p = r + (rs_new / rs) * p
        return x, r, p, rs_new, it + 1

    def cond(carry):
        _, _, _, rs, it = carry
        return jnp.logical_and(rs > tol * tol * rs0, it < maxiter)

    x0 = jnp.zeros_like(rhs)
    # repro-lint: noqa[RL201] -- replacement-node local solve: single-block, single-device by construction
    rs0 = jnp.vdot(rhs, rhs)
    init = (x0, rhs, rhs, rs0, jnp.asarray(0))
    x, *_ = jax.lax.while_loop(cond, body, init)
    return x


def _local_dense_solve(apply_fn, rhs: jax.Array) -> jax.Array:
    """Materialize A[F,F] column-by-column and Cholesky-solve (small F)."""
    m = rhs.shape[0]
    eye = jnp.eye(m, dtype=rhs.dtype)
    a_ff = jax.vmap(apply_fn)(eye).T
    chol = jnp.linalg.cholesky(a_ff)
    y = jax.scipy.linalg.solve_triangular(chol, rhs, lower=True)
    return jax.scipy.linalg.solve_triangular(chol.T, y, lower=False)


def solve_local(apply_fn, rhs: jax.Array, method: str = "auto") -> jax.Array:
    if method == "auto":
        method = "dense" if rhs.shape[0] <= 1024 else "cg"
    if method == "dense":
        return _local_dense_solve(apply_fn, rhs)
    if method == "cg":
        return _local_cg(apply_fn, rhs)
    raise ValueError(f"unknown local solve method {method!r}")


def solve_x_from_residual(
    op,
    b: jax.Array,
    x_surviving: jax.Array,
    r_f: jax.Array,
    failed: Sequence[int],
    local_method: str = "auto",
) -> jax.Array:
    """Algorithm 3 lines 7-8: solve ``A[F,F] x_F = b_F - r_F - A[F,~F] x_{~F}``
    and return the full ``x`` with the failed union restored."""
    part = op.partition
    x_clean = part.scatter(x_surviving, jnp.zeros_like(r_f), failed)
    w = part.restrict(b, failed) - r_f - op.offblock_apply(x_clean, failed)
    x_f = solve_local(lambda u: op.inblock_apply(u, failed), w, local_method)
    return part.scatter(x_surviving, x_f, failed)


def residual_on_failed(op, b: jax.Array, x: jax.Array,
                       failed: Sequence[int]) -> jax.Array:
    """``r_F = b_F - A[F,F] x_F - A[F,~F] x_{~F}`` — the direct residual
    restriction, used by solvers whose recovery set contains ``x`` itself
    (weighted Jacobi, restarted GMRES)."""
    part = op.partition
    return (part.restrict(b, failed)
            - op.inblock_apply(part.restrict(x, failed), failed)
            - op.offblock_apply(x, failed))


def reconstruct_direction_form(
    op,
    precond,
    b: jax.Array,
    state_surviving,
    failed_blocks: Sequence[int],
    p_prev_f: jax.Array,
    p_cur_f: jax.Array,
    beta: float,
    local_method: str = "auto",
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Algorithm 3 core for any solver with the three-term direction
    structure ``p^(k) = z^(k) + beta^(k) p^(k-1)`` (PCG, Chebyshev).

    ``state_surviving`` carries valid ``x, r, z, p`` on surviving blocks
    (failed shards may be garbage — they are overwritten).
    ``p_prev_f``/``p_cur_f`` are the persisted shards for the failed
    union, concatenated in ``failed_blocks`` order.  Returns the fully
    restored ``(x, r, z, p)``.
    """
    part = op.partition
    failed = list(failed_blocks)

    # Line 4: z_F = p^(k)_F - beta * p^(k-1)_F
    z_f = p_cur_f - beta * p_prev_f

    # Lines 5-6: solve P[F,F] r_F = z_F - P[F,~F] r_{~F}
    r_clean = part.scatter(state_surviving.r, jnp.zeros_like(z_f), failed)
    v = z_f - precond.offblock_apply(r_clean, failed)
    r_f = precond.block_solve(v, failed)

    # Lines 7-8: solve A[F,F] x_F = b_F - r_F - A[F,~F] x_{~F}
    x = solve_x_from_residual(op, b, state_surviving.x, r_f, failed, local_method)

    # Reassemble; p_F comes straight from the redundancy.
    r = part.scatter(state_surviving.r, r_f, failed)
    z = part.scatter(state_surviving.z, z_f, failed)
    p = part.scatter(state_surviving.p, p_cur_f, failed)
    return x, r, z, p


def reconstruct(
    op,
    precond,
    b: jax.Array,
    state_surviving: PCGState,
    failed_blocks: Sequence[int],
    p_prev_f: jax.Array,
    p_cur_f: jax.Array,
    beta: float,
    local_method: str = "auto",
    dot=jnp.vdot,
) -> PCGState:
    """Run Algorithm 3 and return the fully reconstructed PCG state at ``k``.

    ``dot`` must match the solve loop's inner product (the zoo passes the
    order-pinned one) so the restored ``rz`` is bitwise what the unfailed
    trajectory would carry."""
    x, r, z, p = reconstruct_direction_form(
        op, precond, b, state_surviving, failed_blocks,
        p_prev_f, p_cur_f, beta, local_method)
    rz = dot(r, z)  # global reduction (replaces the replicated scalar)
    return PCGState(
        x=x, r=r, z=z, p=p, rz=rz,
        beta_prev=jnp.asarray(beta, x.dtype), k=state_surviving.k,
    )
