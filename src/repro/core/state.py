"""Solver state pytrees and minimal-recovery-set schemas.

Following the generic strategy of Pachajoa et al. [14], an ESR-recoverable
iterative solver persists a *minimal* set of named vectors and scalars per
iteration from which every lost shard is exactly reconstructible.  For PCG
that set is ``{p^(k), p^(k-1), beta^(k-1), k}``; other solvers persist
different payloads (weighted Jacobi: ``{x^(k)}``; BiCGStab:
``{r^(k), p^(k), rho, alpha, omega}``; restarted GMRES: ``{x^(k)}`` at
restart boundaries).

:class:`RecoverySchema` declares a solver's recovery set — which vectors
are block-sharded and persisted, which replicated scalars ride along, and
how many *consecutive* persisted iterations recovery needs (``history``;
2 for the PCG pair, 1 for single-state solvers).  The ESR backends size
their slots and encode/decode payloads purely from the schema, so any
:class:`~repro.solvers.base.RecoverableSolver` persists through any
backend unchanged.

Slot wire format (one block's shard of one iteration)::

    k:int64 | scalars (f64 each, schema order) | vector shards (schema order)
"""
from __future__ import annotations

import dataclasses
import struct
from typing import Dict, Mapping, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_K_HEADER = struct.Struct("<q")


@dataclasses.dataclass(frozen=True)
class RecoverySchema:
    """Declares the minimal recovery set persisted by one solver.

    ``vectors``: names of block-sharded vectors, persisted shard-wise.
    ``scalars``: names of replicated scalars persisted alongside each slot.
    ``history``: number of *consecutive* persisted iterations a recovery
    needs (PCG reconstructs from the pair ``(k-1, k)`` -> 2; solvers whose
    full state is derivable from one persisted iteration -> 1).
    """

    solver: str
    vectors: Tuple[str, ...]
    scalars: Tuple[str, ...] = ()
    history: int = 2

    def __post_init__(self):
        if not self.vectors:
            raise ValueError("a recovery schema needs at least one vector")
        if self.history < 1:
            raise ValueError(f"history must be >= 1, got {self.history}")

    # ------------------------------------------------------------------
    def slot_nbytes(self, block_size: int, dtype) -> int:
        """Payload bytes of one block's slot (excludes backend headers)."""
        return (
            _K_HEADER.size
            + 8 * len(self.scalars)
            + len(self.vectors) * block_size * np.dtype(dtype).itemsize
        )

    def encode(
        self,
        k: int,
        scalars: Mapping[str, float],
        vector_shards: Mapping[str, np.ndarray],
    ) -> bytes:
        """Serialize one block's slot payload (dtype fixed by caller)."""
        parts = [_K_HEADER.pack(int(k))]
        parts.append(struct.pack(f"<{len(self.scalars)}d",
                                 *(float(scalars[s]) for s in self.scalars)))
        for name in self.vectors:
            parts.append(np.ascontiguousarray(vector_shards[name]).tobytes())
        return b"".join(parts)

    def decode(self, raw: bytes, dtype) -> "RecoverySet":
        (k,) = _K_HEADER.unpack(raw[: _K_HEADER.size])
        off = _K_HEADER.size
        ns = len(self.scalars)
        vals = struct.unpack(f"<{ns}d", raw[off : off + 8 * ns])
        off += 8 * ns
        flat = np.frombuffer(raw[off:], dtype=dtype)
        if len(flat) % len(self.vectors):
            raise ValueError(
                f"payload holds {len(flat)} values, not divisible by "
                f"{len(self.vectors)} schema vectors")
        per = len(flat) // len(self.vectors)
        vectors = {
            name: flat[i * per : (i + 1) * per].copy()
            for i, name in enumerate(self.vectors)
        }
        return RecoverySet(k=k, scalars=dict(zip(self.scalars, vals)),
                           vectors=vectors)


def peek_k(raw: bytes) -> int:
    """Read a slot payload's iteration header without decoding the
    vectors — content-matched slot scans probe many slots per recovery
    and only decode the one whose ``k`` matches."""
    return _K_HEADER.unpack(raw[: _K_HEADER.size])[0]


def newest_complete_run(ks, history: int):
    """Newest ``k`` ending a consecutive ``history``-long run within the
    iteration set ``ks`` (the durable-recovery-point scan every backend's
    ``durable_run`` performs), or None if no complete run exists."""
    ks = set(ks)
    best = None
    for k in sorted(ks):
        if all(k - i in ks for i in range(history)):
            best = k
    return best


class RecoverySet(NamedTuple):
    """One iteration's decoded recovery payload.

    ``vectors`` maps names to either a single block shard or the
    concatenated union of failed-block shards (backend ``recover_set``
    returns the latter, in ``failed_blocks`` order).
    """

    k: int
    scalars: Dict[str, float]
    vectors: Dict[str, np.ndarray]


class PCGState(NamedTuple):
    """State after ``k`` completed PCG iterations.

    Invariants (exact arithmetic):
      - ``r = b - A x``
      - ``z = P r``
      - ``p = z + beta_prev * p_prev``  (``p = z`` when k == 0)
      - ``rz = <r, z>``
    """

    x: jax.Array
    r: jax.Array
    z: jax.Array
    p: jax.Array
    rz: jax.Array
    beta_prev: jax.Array
    k: jax.Array


# The paper's PCG recovery set: {p^(k), p^(k-1), beta^(k-1), k}.  The two
# p's come from two consecutive slots (history=2); beta rides in the
# newer slot.
PCG_SCHEMA = RecoverySchema("pcg", vectors=("p",), scalars=("beta",), history=2)


class RecoveryPayload(NamedTuple):
    """Legacy PCG-shaped recovery slot (kept for the Fig. 9/10 benchmark
    paths and any external caller of the pre-zoo backend API)."""

    k: int
    beta: float  # beta^(k-1): the scalar linking p^(k-1) -> p^(k)
    p: np.ndarray  # p^(k), the block shard (or full vector)


def encode_payload(k: int, beta: float, p_block: np.ndarray) -> bytes:
    """Serialize one PCG slot (wire-compatible with the generic codec)."""
    return PCG_SCHEMA.encode(k, {"beta": beta}, {"p": p_block})


def decode_payload(raw: bytes, dtype) -> RecoveryPayload:
    rset = PCG_SCHEMA.decode(raw, dtype)
    return RecoveryPayload(k=rset.k, beta=rset.scalars["beta"],
                           p=rset.vectors["p"])


def payload_nbytes(block_size: int, dtype) -> int:
    return PCG_SCHEMA.slot_nbytes(block_size, dtype)


def minimal_recovery_state(state: PCGState) -> Tuple[int, float, jax.Array]:
    """The paper's minimal persistent set at this iteration: (k, beta, p)."""
    return int(state.k), float(state.beta_prev), state.p


# ----------------------------------------------------------------------
# Schema payload plumbing shared by every persistence backend.
# ----------------------------------------------------------------------
def typed_vectors(
    schema: RecoverySchema,
    vectors: Mapping[str, np.ndarray],
    dtype,
) -> Dict[str, np.ndarray]:
    """Convert every schema vector to the backend dtype ONCE per persist
    event (callers then shard by slicing — converting inside the
    per-block loop would copy each full vector nblocks times)."""
    return {name: np.asarray(vectors[name], dtype) for name in schema.vectors}


def shard_vectors(
    schema: RecoverySchema,
    vectors: Mapping[str, np.ndarray],
    block: int,
    block_size: int,
) -> Dict[str, np.ndarray]:
    """One block's shard of every (already-typed) schema vector."""
    lo, hi = block * block_size, (block + 1) * block_size
    return {name: vectors[name][lo:hi] for name in schema.vectors}


def concat_sets(schema: RecoverySchema, per_block) -> RecoverySet:
    """Merge per-block recovery sets into one union set (block order kept)."""
    first = per_block[0]
    return RecoverySet(
        k=first.k,
        scalars=dict(first.scalars),
        vectors={name: np.concatenate([s.vectors[name] for s in per_block])
                 for name in schema.vectors},
    )


def legacy_pair(sets) -> Tuple["RecoveryPayload", "RecoveryPayload"]:
    """Map a PCG-schema (prev, cur) recovery to the legacy payload pair."""
    prev, cur = sets[-2], sets[-1]
    return (
        RecoveryPayload(prev.k, 0.0, prev.vectors["p"]),
        RecoveryPayload(cur.k, cur.scalars["beta"], cur.vectors["p"]),
    )


def require_pcg_schema(schema: RecoverySchema, api: str) -> None:
    """Guard for the legacy ``persist``/``recover`` backend shims, which
    speak PCG payloads only — fail with a pointer instead of a KeyError
    deep in the codec."""
    if (schema.vectors, schema.scalars, schema.history) != (("p",), ("beta",), 2):
        raise TypeError(
            f"the legacy {api}() API carries PCG payloads only, but this "
            f"backend persists schema {schema.solver!r}; use "
            f"persist_set()/recover_set()")


def wipe_vectors(state, partition, blocks, vector_fields, nan_scalars=()):
    """Simulate failure of ``blocks`` on any NamedTuple solver state: the
    failed shards of every volatile vector become garbage (NaN), as their
    VM is lost (paper §3 model); non-replicated reduction scalars are
    NaN'd too (they are recomputed during reconstruction)."""
    nan = float("nan")
    idx = jnp.asarray(list(blocks))

    def wipe(v):
        vb = v.reshape(partition.nblocks, partition.block_size)
        return vb.at[idx].set(nan).reshape(-1)

    repl = {f: wipe(getattr(state, f)) for f in vector_fields}
    for f in nan_scalars:
        repl[f] = jnp.asarray(nan, getattr(state, f).dtype)
    return state._replace(**repl)


def wipe_blocks(state: PCGState, partition, blocks) -> PCGState:
    """PCG-shaped :func:`wipe_vectors` (legacy entry point)."""
    return wipe_vectors(state, partition, blocks, ("x", "r", "z", "p"),
                        nan_scalars=("rz",))
