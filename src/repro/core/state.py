"""Solver state pytrees and minimal-state identification.

Following the generic strategy of Pachajoa et al. [14], the *minimal*
persistent set for PCG is ``{p^(k), p^(k-1), beta^(k-1), k}`` — every other
state variable (x, r, z, and the scalars) is reconstructible from it plus
surviving shards and static data.  This module defines the state pytree
and the extraction of the minimal set.
"""
from __future__ import annotations

import struct
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class PCGState(NamedTuple):
    """State after ``k`` completed PCG iterations.

    Invariants (exact arithmetic):
      - ``r = b - A x``
      - ``z = P r``
      - ``p = z + beta_prev * p_prev``  (``p = z`` when k == 0)
      - ``rz = <r, z>``
    """

    x: jax.Array
    r: jax.Array
    z: jax.Array
    p: jax.Array
    rz: jax.Array
    beta_prev: jax.Array
    k: jax.Array


class RecoveryPayload(NamedTuple):
    """Minimal recovery data persisted at iteration ``k`` (one slot)."""

    k: int
    beta: float  # beta^(k-1): the scalar linking p^(k-1) -> p^(k)
    p: np.ndarray  # p^(k), the block shard (or full vector)


_SCALARS = struct.Struct("<qd")  # k, beta


def encode_payload(k: int, beta: float, p_block: np.ndarray) -> bytes:
    """Serialize one slot's recovery payload (dtype fixed by caller)."""
    return _SCALARS.pack(int(k), float(beta)) + np.ascontiguousarray(p_block).tobytes()


def decode_payload(raw: bytes, dtype) -> RecoveryPayload:
    k, beta = _SCALARS.unpack(raw[: _SCALARS.size])
    p = np.frombuffer(raw[_SCALARS.size :], dtype=dtype).copy()
    return RecoveryPayload(k=k, beta=beta, p=p)


def payload_nbytes(block_size: int, dtype) -> int:
    return _SCALARS.size + block_size * np.dtype(dtype).itemsize


def minimal_recovery_state(state: PCGState) -> Tuple[int, float, jax.Array]:
    """The paper's minimal persistent set at this iteration: (k, beta, p)."""
    return int(state.k), float(state.beta_prev), state.p


def wipe_blocks(state: PCGState, partition, blocks) -> PCGState:
    """Simulate failure of ``blocks``: their shards of every volatile
    vector become garbage (NaN), as their VM is lost (paper §3 model)."""
    nan = float("nan")

    def wipe(v):
        vb = v.reshape(partition.nblocks, partition.block_size)
        return vb.at[jnp.asarray(list(blocks))].set(nan).reshape(-1)

    return state._replace(
        x=wipe(state.x), r=wipe(state.r), z=wipe(state.z), p=wipe(state.p),
        rz=jnp.asarray(nan, state.rz.dtype),
    )
