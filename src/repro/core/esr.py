"""In-memory ESR (the paper's baseline; Chen '11 / Pachajoa et al.).

Redundancy of the recovery set is piggybacked on the SpMV transition
(ASpMV, Algorithm 2) and replicated into the **volatile RAM of peer
processes**.  To tolerate ``c`` simultaneous failures, ``c+1`` copies are
placed; full fault tolerance places a copy at every process —
``O(n * proc)`` values of RAM and an all-to-all every persistence
iteration (paper §2 and §3.1).

Since the solver-zoo generalization the payload is schema-driven
(:class:`repro.core.state.RecoverySchema`): any solver's named
multi-vector/multi-scalar recovery set replicates through the same copy
placement; slot sizes and the wire format derive from the schema.

Copy placement: copy ``i`` of block ``b`` lives in the RAM of rank
``(b + i + 1) mod nblocks``.  A failure of block set ``F`` wipes every
copy hosted on ranks in ``F``; recovery succeeds iff each failed block
still has a surviving copy — which the placement guarantees whenever
``copies > |F|``.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.state import (  # noqa: F401  (payload helpers re-exported)
    PCG_SCHEMA,
    RecoveryPayload,
    RecoverySchema,
    RecoverySet,
    concat_sets,
    legacy_pair,
    newest_complete_run,
    peek_k,
    require_pcg_schema,
    shard_vectors,
    typed_vectors,
)
from repro.nvm.backend import (  # noqa: F401  (UnrecoverableFailure re-exported)
    OVERLAP_NATIVE,
    BackendCapabilities,
    SchemaDrivenBackend,
    UnrecoverableFailure,
    warn_legacy_call,
)
from repro.nvm.store import (
    NETWORK_SPECS,
    TIER_SPECS,
    CostModel,
    PersistStager,
    Tier,
)


class InMemoryESR(SchemaDrivenBackend):
    """Peer-RAM redundancy backend with explicit copy placement."""

    name = "esr-inmemory"

    def __init__(self, nblocks: int, block_size: int, dtype,
                 copies: Optional[int] = None, slots: Optional[int] = None,
                 schema: RecoverySchema = PCG_SCHEMA):
        self.nblocks = nblocks
        self.block_size = block_size
        self.dtype = np.dtype(dtype)
        self.schema = schema
        # full fault tolerance by default: a copy at every other process
        self.copies = nblocks - 1 if copies is None else copies
        if not (1 <= self.copies <= nblocks - 1):
            raise ValueError(f"copies must be in [1, nblocks-1], got {self.copies}")
        # Ring size 2h-1 (h = history) is the provable minimum that keeps
        # the previous recovery run intact through an in-flight ESRP
        # burst: with event-addressed slots mod (2h-1), burst writes
        # 1..h-1 can never land on the old run's h slots (j - i + h is in
        # [1, 2h-2], never 0 mod 2h-1); only the h-th write may, and at
        # that moment the NEW run is complete.  Floor of 2 keeps a
        # staging slot for single-state schemas (peer-RAM stores are not
        # atomic in reality).  h=2 gives the paper's 3-slot layout.
        self.slots = max(2, 2 * schema.history - 1) if slots is None else slots
        # ram[host_rank][(owner_block, slot)] -> payload bytes
        self.ram: List[Dict[Tuple[int, int], bytes]] = [dict() for _ in range(nblocks)]
        self._event = 0  # event-addressed slots (ESRP persists with gaps)
        self.cost = CostModel()
        self._dram = TIER_SPECS[Tier.DRAM]
        self._net = NETWORK_SPECS["rdma"]
        self._stager = PersistStager(self.persist_set, cost_model=self.cost)

    # ------------------------------------------------------------------
    @property
    def capabilities(self) -> BackendCapabilities:
        """Peer RAM is volatile and dies with its hosts: data survives
        node loss only while ``|failures| <= copies`` (the failed block
        occupies one slot of the failed set, so at most ``copies - 1``
        of its ``copies`` peer hosts can be among the casualties)."""
        return BackendCapabilities(
            durability="ram",
            survives_node_loss=True,
            survives_prd_loss=False,
            overlap=OVERLAP_NATIVE,
            max_block_failures=self.copies,
        )

    # ------------------------------------------------------------------
    def _hosts(self, block: int) -> List[int]:
        return [(block + i + 1) % self.nblocks for i in range(self.copies)]

    # -- overlapped persistence (DESIGN.md §6): stage now, replicate later
    def persist_begin(self, k: int, scalars: Mapping[str, float],
                      vectors: Mapping[str, np.ndarray]) -> float:
        """Stage the payload (local DRAM copy); the peer all-to-all happens
        at :meth:`persist_commit` and overlaps the next iteration."""
        return self._stager.begin(k, scalars, vectors)

    def persist_commit(self) -> float:
        """Replicate the oldest staged payload to the peer hosts."""
        return self._stager.commit()

    def persist_drain(self) -> float:
        """Drain barrier: commit everything staged (nothing else is in
        flight — peer-RAM replication is synchronous once committed)."""
        return self._stager.drain()

    def persist_set(self, k: int, scalars: Mapping[str, float],
                    vectors: Mapping[str, np.ndarray]) -> float:
        """One redundancy iteration: every block's slot payload is sent to
        its ``copies`` peer hosts (modeled as the ASpMV all-to-all surplus)."""
        slot = self._event % self.slots
        self._event += 1
        typed = typed_vectors(self.schema, vectors, self.dtype)
        cost = 0.0
        for b in range(self.nblocks):
            shards = shard_vectors(self.schema, typed, b, self.block_size)
            payload = self.schema.encode(k, scalars, shards)
            for host in self._hosts(b):
                self.ram[host][(b, slot)] = payload
                # network transfer + peer DRAM write (per copy)
                cost += self._net.transfer_cost(len(payload))
                cost += self._dram.write_cost(len(payload))
        self.cost.add("persist", cost)
        return cost

    def persist(self, k: int, beta: float, p_full: np.ndarray) -> float:
        """Legacy PCG-shaped persist (pre-zoo API; deprecated)."""
        warn_legacy_call(self, "persist")
        require_pcg_schema(self.schema, "persist")
        return self.persist_set(k, {"beta": beta}, {"p": p_full})

    # ------------------------------------------------------------------
    def fail(self, failed_blocks: Sequence[int]) -> None:
        """Process crash: the peer-RAM copies hosted on failed ranks die
        too, and any staged-but-uncommitted persist is torn away (the
        failed ranks' contributions to the all-to-all never happened)."""
        self._stager.abort()
        for b in failed_blocks:
            self.ram[b] = {}

    def _find_block_set(self, block: int, kk: int,
                        failed_blocks: Sequence[int]) -> RecoverySet:
        for host in self._hosts(block):
            if host in failed_blocks:
                continue
            # content-matched scan over the host's slots (header peek
            # first: only the matching slot's vectors are decoded)
            for sl in range(self.slots):
                cand = self.ram[host].get((block, sl))
                if cand is None or peek_k(cand) != kk:
                    continue
                self.cost.add("recover", self._net.transfer_cost(len(cand)))
                return self.schema.decode(cand, self.dtype)
        raise UnrecoverableFailure(
            f"block {block}: no surviving copy of iteration {kk} — "
            f"{len(failed_blocks)} failures exceed tolerance c={self.copies} "
            f"(capabilities.max_block_failures)"
        )

    def recover_set(self, failed_blocks: Sequence[int],
                    ks: Sequence[int]) -> List[RecoverySet]:
        """Fetch the recovery sets for iterations ``ks`` over the failed
        union from surviving peer RAM (vectors concatenated in
        ``failed_blocks`` order)."""
        out = []
        for kk in ks:
            per_block = [self._find_block_set(b, kk, failed_blocks)
                         for b in failed_blocks]
            out.append(concat_sets(self.schema, per_block))
        return out

    def recover(self, failed_blocks: Sequence[int], k: int) -> Tuple[RecoveryPayload, RecoveryPayload]:
        """Legacy PCG-shaped recover (pre-zoo API; deprecated): the
        (k-1, k) pair."""
        warn_legacy_call(self, "recover")
        require_pcg_schema(self.schema, "recover")
        return legacy_pair(self.recover_set(failed_blocks, (k - 1, k)))

    def durable_run(self) -> Optional[int]:
        """Newest iteration ending a complete ``history``-run still held
        by block 0's surviving peer copies (peer-RAM writes are durable
        the moment they land — there is no flush pipeline)."""
        ks = set()
        for host in self._hosts(0):
            for (owner, _slot), payload in self.ram[host].items():
                if owner == 0:
                    ks.add(peek_k(payload))
        return newest_complete_run(ks, self.schema.history)

    # ------------------------------------------------------------------
    def memory_overhead_values(self) -> int:
        """Redundancy values resident in system RAM.  Paper §3.1 models
        ~history*copies*n (the live slots); steady state here is
        slots*copies*n — the extra n*copies is the ESRP mid-burst staging
        slot."""
        return sum(len(v) for host in self.ram for v in host.values()) // self.dtype.itemsize

    def nvm_values(self) -> int:
        return 0
