"""In-memory ESR (the paper's baseline; Chen '11 / Pachajoa et al.).

Redundancy of the search direction ``p`` is piggybacked on the SpMV
transition (ASpMV, Algorithm 2) and replicated into the **volatile RAM of
peer processes**.  To tolerate ``c`` simultaneous failures, ``c+1`` copies
are placed; full fault tolerance places a copy at every process —
``O(n * proc)`` values of RAM and an all-to-all every persistence
iteration (paper §2 and §3.1).

Copy placement: copy ``i`` of block ``b`` lives in the RAM of rank
``(b + i + 1) mod nblocks``.  A failure of block set ``F`` wipes every
copy hosted on ranks in ``F``; recovery succeeds iff each failed block
still has a surviving copy — which the placement guarantees whenever
``copies > |F|``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.state import RecoveryPayload, decode_payload, encode_payload
from repro.nvm.store import TIER_SPECS, NETWORK_SPECS, CostModel, Tier


class UnrecoverableFailure(RuntimeError):
    """All redundancy copies of some failed block were lost with it."""


class InMemoryESR:
    """Peer-RAM redundancy backend with explicit copy placement."""

    name = "esr-inmemory"

    def __init__(self, nblocks: int, block_size: int, dtype, copies: Optional[int] = None,
                 slots: int = 3):
        # 3 slots: the paper's logical minimum is 2 (two successive p's),
        # plus one staging slot so a failure BETWEEN the two writes of an
        # ESRP burst still leaves the previous pair intact.
        self.nblocks = nblocks
        self.block_size = block_size
        self.dtype = np.dtype(dtype)
        # full fault tolerance by default: a copy at every other process
        self.copies = nblocks - 1 if copies is None else copies
        if not (1 <= self.copies <= nblocks - 1):
            raise ValueError(f"copies must be in [1, nblocks-1], got {self.copies}")
        self.slots = slots
        # ram[host_rank][(owner_block, slot)] -> payload bytes
        self.ram: List[Dict[Tuple[int, int], bytes]] = [dict() for _ in range(nblocks)]
        self._event = 0  # event-addressed slots (ESRP persists with gaps)
        self.cost = CostModel()
        self._dram = TIER_SPECS[Tier.DRAM]
        self._net = NETWORK_SPECS["rdma"]

    # ------------------------------------------------------------------
    def _hosts(self, block: int) -> List[int]:
        return [(block + i + 1) % self.nblocks for i in range(self.copies)]

    def persist(self, k: int, beta: float, p_full: np.ndarray) -> float:
        """One redundancy iteration: every block's shard is sent to its
        ``copies`` peer hosts (modeled as the ASpMV all-to-all surplus)."""
        p_full = np.asarray(p_full, self.dtype)
        slot = self._event % self.slots
        self._event += 1
        cost = 0.0
        for b in range(self.nblocks):
            shard = p_full[b * self.block_size : (b + 1) * self.block_size]
            payload = encode_payload(k, beta, shard)
            for host in self._hosts(b):
                self.ram[host][(b, slot)] = payload
                # network transfer + peer DRAM write (per copy)
                cost += self._net.transfer_cost(len(payload))
                cost += self._dram.write_cost(len(payload))
        self.cost.add("persist", cost)
        return cost

    # ------------------------------------------------------------------
    def fail(self, failed_blocks: Sequence[int]) -> None:
        """Process crash: the peer-RAM copies hosted on failed ranks die too."""
        for b in failed_blocks:
            self.ram[b] = {}

    def recover(self, failed_blocks: Sequence[int], k: int) -> Tuple[RecoveryPayload, RecoveryPayload]:
        """Fetch (p^(k-1), p^(k), beta^(k-1)) for the failed union from
        surviving peer RAM. Returns concatenated payloads (prev, cur)."""
        prev_parts, cur_parts = [], []
        beta = None
        for b in failed_blocks:
            got = {}
            for kk in (k - 1, k):
                payload = None
                for host in self._hosts(b):
                    if host in failed_blocks:
                        continue
                    # content-matched scan over the host's slots
                    for sl in range(self.slots):
                        cand = self.ram[host].get((b, sl))
                        if cand is not None and decode_payload(cand, self.dtype).k == kk:
                            payload = cand
                            break
                    if payload is not None:
                        self.cost.add("recover", self._net.transfer_cost(len(payload)))
                        break
                if payload is None:
                    raise UnrecoverableFailure(
                        f"block {b}: no surviving copy of p^({kk}) — "
                        f"{len(failed_blocks)} failures exceed tolerance c={self.copies - 1}"
                    )
                got[kk] = decode_payload(payload, self.dtype)
            prev_parts.append(got[k - 1].p)
            cur_parts.append(got[k].p)
            beta = got[k].beta
        return (
            RecoveryPayload(k - 1, 0.0, np.concatenate(prev_parts)),
            RecoveryPayload(k, beta, np.concatenate(cur_parts)),
        )

    # ------------------------------------------------------------------
    def memory_overhead_values(self) -> int:
        """Redundancy values resident in system RAM.  Paper §3.1 models
        ~2*copies*n (the two live p's); steady state here is slots(=3)*
        copies*n — the extra n*copies is the ESRP mid-burst staging slot."""
        return sum(len(v) for host in self.ram for v in host.values()) // self.dtype.itemsize

    def nvm_values(self) -> int:
        return 0
