"""Distributed Preconditioned Conjugate Gradient with ESR recovery.

Implements paper Algorithm 1 (PCG) and drives Algorithm 2/4 (redundancy /
persistence iterations) and Algorithm 3/5 (reconstruction) through the
generic solver driver (:mod:`repro.solvers.driver`) and pluggable
recovery backends (:mod:`repro.core.esr`, :mod:`repro.core.nvm_esr`).

Two execution paths:

- :func:`solve` — Python driver around a jitted iteration.  Supports the
  persistence schedule (classic ESR: every iteration; ESRP: period ``T``),
  failure injection, recovery, and convergence monitoring.  This is the
  paper-faithful path used by tests/benchmarks.  Since the solver-zoo
  generalization it is a thin shim over ``repro.solvers.driver.solve``
  with the PCG solver adapter — kept because PCG is the paper's subject
  and the most convenient entry point.
- :func:`solve_jit` — fully fused ``lax.while_loop`` solver (no recovery
  hooks) used for performance baselines and the dry-run lowering.

Note on Algorithm 1 line 3: the paper writes ``alpha = r'z / r'Ap``; we use
the standard ``alpha = r'z / p'Ap``, which is identical in exact arithmetic
(``r = p - beta p_prev`` and ``p_prev'Ap = 0`` by conjugacy) and is the
numerically conventional choice.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.state import PCGState
from repro.solvers.driver import (  # noqa: F401  (re-exported public API)
    FailureCampaign,
    FailureEvent,
    FailurePlan,
    SolveConfig,
    SolveReport,
)
from repro.solvers import driver as _driver

# The historical name: PCG predates the zoo; its config IS the generic one.
PCGConfig = SolveConfig


def init_state(op, precond, b: jax.Array, x0: Optional[jax.Array] = None,
               dot: Callable = jnp.vdot) -> PCGState:
    x0 = jnp.zeros_like(b) if x0 is None else x0
    r0 = b - op.apply(x0)
    z0 = precond.apply(r0)
    return PCGState(
        x=x0, r=r0, z=z0, p=z0, rz=dot(r0, z0),
        beta_prev=jnp.zeros((), b.dtype), k=jnp.zeros((), jnp.int32),
    )


def make_step(op_apply: Callable, precond_apply: Callable,
              dot: Callable = jnp.vdot) -> Callable[[PCGState], PCGState]:
    """One PCG iteration (Algorithm 1 lines 3-8) as a jittable pure fn.

    ``dot`` is the inner product; the zoo path passes the order-pinned
    block-hierarchical one (:func:`repro.core.spmv.make_det_dot`) so the
    trajectory is bitwise sharding-independent, while the fused perf path
    keeps ``jnp.vdot``."""

    def step(state: PCGState) -> PCGState:
        ap = op_apply(state.p)                       # (A)SpMV
        alpha = state.rz / dot(state.p, ap)          # line 3
        x = state.x + alpha * state.p                # line 4
        r = state.r - alpha * ap                     # line 5
        z = precond_apply(r)                         # line 6
        rz_new = dot(r, z)
        beta = rz_new / state.rz                     # line 7
        p = z + beta * state.p                       # line 8
        return PCGState(x=x, r=r, z=z, p=p, rz=rz_new, beta_prev=beta, k=state.k + 1)

    return step


def should_persist(k: int, period: int) -> bool:
    """PCG persistence schedule (pair bursts); see the generic
    :func:`repro.solvers.driver.should_persist`."""
    return _driver.should_persist(k, period, history=2)


def solve(
    op,
    b: jax.Array,
    precond,
    config: PCGConfig = PCGConfig(),
    backend=None,
    failures: Sequence[FailurePlan] = (),
    x0: Optional[jax.Array] = None,
    capture_states_at: Sequence[int] = (),
) -> Tuple[PCGState, SolveReport, Dict[int, PCGState]]:
    """PCG with optional ESR/NVM-ESR fault tolerance.

    ``backend`` is an in-memory-ESR or NVM-ESR recovery backend (or None
    for plain PCG).  ``failures`` injects block crashes.  Returns the
    final state, a report, and any states captured for verification.
    """
    from repro.solvers.pcg import PCGSolver  # local: solvers.pcg imports us

    return _driver.solve(
        PCGSolver(), op, b, precond, config=config, backend=backend,
        failures=failures, x0=x0, capture_states_at=capture_states_at,
    )


def solve_jit(
    op_apply: Callable,
    precond_apply: Callable,
    b: jax.Array,
    tol: float = 1e-10,
    maxiter: int = 10_000,
) -> Tuple[jax.Array, jax.Array]:
    """Fused while-loop PCG (no recovery hooks): perf/dry-run path."""
    step = make_step(op_apply, precond_apply)
    # repro-lint: noqa[RL201] -- fused single-device perf path; the recoverable zoo path pins order via solver_dot
    bnorm2 = jnp.vdot(b, b)

    def cond(state: PCGState):
        # repro-lint: noqa[RL201] -- fused single-device perf path; never sharded, never persisted
        rr = jnp.vdot(state.r, state.r)
        return jnp.logical_and(rr > (tol * tol) * bnorm2, state.k < maxiter)

    x0 = jnp.zeros_like(b)
    r0 = b
    z0 = precond_apply(r0)
    # repro-lint: noqa[RL201] -- fused single-device perf path; never sharded, never persisted
    init = PCGState(x=x0, r=r0, z=z0, p=z0, rz=jnp.vdot(r0, z0),
                    beta_prev=jnp.zeros((), b.dtype), k=jnp.zeros((), jnp.int32))
    final = jax.lax.while_loop(cond, lambda s: step(s), init)
    return final.x, final.k
