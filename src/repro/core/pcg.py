"""Distributed Preconditioned Conjugate Gradient with ESR recovery.

Implements paper Algorithm 1 (PCG), Algorithm 2/4 (redundancy /
persistence iterations) and drives Algorithm 3/5 (reconstruction) through
pluggable recovery backends (:mod:`repro.core.esr`,
:mod:`repro.core.nvm_esr`).

Two execution paths:

- :func:`solve` — Python driver around a jitted iteration.  Supports the
  persistence schedule (classic ESR: every iteration; ESRP: period ``T``),
  failure injection, recovery, and convergence monitoring.  This is the
  paper-faithful path used by tests/benchmarks.
- :func:`solve_jit` — fully fused ``lax.while_loop`` solver (no recovery
  hooks) used for performance baselines and the dry-run lowering.

Note on Algorithm 1 line 3: the paper writes ``alpha = r'z / r'Ap``; we use
the standard ``alpha = r'z / p'Ap``, which is identical in exact arithmetic
(``r = p - beta p_prev`` and ``p_prev'Ap = 0`` by conjugacy) and is the
numerically conventional choice.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import reconstruction
from repro.core.state import PCGState, wipe_blocks


@dataclasses.dataclass(frozen=True)
class PCGConfig:
    tol: float = 1e-10            # relative residual tolerance ||r|| / ||b||
    maxiter: int = 10_000
    persistence_period: int = 1   # T=1: classic ESR; T>1: ESRP bursts
    local_solve: str = "auto"     # reconstruction local solver


@dataclasses.dataclass(frozen=True)
class FailurePlan:
    """Inject a failure of ``blocks`` right after iteration ``at_iteration``."""

    at_iteration: int
    blocks: Tuple[int, ...]


@dataclasses.dataclass
class SolveReport:
    iterations: int = 0
    wasted_iterations: int = 0
    failures_recovered: int = 0
    converged: bool = False
    final_relres: float = float("nan")
    persist_cost_s: float = 0.0
    persist_events: int = 0
    residual_history: List[float] = dataclasses.field(default_factory=list)


def init_state(op, precond, b: jax.Array, x0: Optional[jax.Array] = None) -> PCGState:
    x0 = jnp.zeros_like(b) if x0 is None else x0
    r0 = b - op.apply(x0)
    z0 = precond.apply(r0)
    return PCGState(
        x=x0, r=r0, z=z0, p=z0, rz=jnp.vdot(r0, z0),
        beta_prev=jnp.zeros((), b.dtype), k=jnp.zeros((), jnp.int32),
    )


def make_step(op_apply: Callable, precond_apply: Callable) -> Callable[[PCGState], PCGState]:
    """One PCG iteration (Algorithm 1 lines 3-8) as a jittable pure fn."""

    def step(state: PCGState) -> PCGState:
        ap = op_apply(state.p)                       # (A)SpMV
        alpha = state.rz / jnp.vdot(state.p, ap)     # line 3
        x = state.x + alpha * state.p                # line 4
        r = state.r - alpha * ap                     # line 5
        z = precond_apply(r)                         # line 6
        rz_new = jnp.vdot(r, z)
        beta = rz_new / state.rz                     # line 7
        p = z + beta * state.p                       # line 8
        return PCGState(x=x, r=r, z=z, p=p, rz=rz_new, beta_prev=beta, k=state.k + 1)

    return step


def should_persist(k: int, period: int) -> bool:
    """Persistence schedule: classic ESR persists every iteration; ESRP
    persists bursts of two successive iterations every ``period``."""
    if period <= 1:
        return True
    return k % period in (0, 1)


def solve(
    op,
    b: jax.Array,
    precond,
    config: PCGConfig = PCGConfig(),
    backend=None,
    failures: Sequence[FailurePlan] = (),
    x0: Optional[jax.Array] = None,
    capture_states_at: Sequence[int] = (),
) -> Tuple[PCGState, SolveReport, Dict[int, PCGState]]:
    """PCG with optional ESR/NVM-ESR fault tolerance.

    ``backend`` is an in-memory-ESR or NVM-ESR recovery backend (or None
    for plain PCG).  ``failures`` injects block crashes.  Returns the
    final state, a report, and any states captured for verification.
    """
    step = jax.jit(make_step(op.apply, precond.apply))
    state = init_state(op, precond, b, x0)
    bnorm = float(jnp.linalg.norm(b))
    report = SolveReport()
    captured: Dict[int, PCGState] = {}
    pending = sorted(failures, key=lambda f: f.at_iteration)
    pending_idx = 0

    # Survivor-side snapshot at the last completed persistence pair: the
    # surviving processes' own state copy kept in their local RAM (cheap,
    # one shard each).  Needed to roll back to the recovery point when
    # persistence is periodic (ESRP trade-off, paper §2).
    snapshot: Optional[PCGState] = None
    last_persisted_k = -10

    def persist_now(st: PCGState) -> None:
        nonlocal snapshot, last_persisted_k
        if backend is None:
            return
        k = int(st.k)
        cost = backend.persist(k, float(st.beta_prev), np.asarray(st.p))
        report.persist_cost_s += cost
        report.persist_events += 1
        if last_persisted_k == k - 1 or k == 0:
            # pair (k-1, k) now durable (or initial state) -> new recovery point
            snapshot = st
        last_persisted_k = k

    # Iteration 0 state counts as persisted so the first pair completes at k=1.
    persist_now(state)

    while int(state.k) < config.maxiter:
        k = int(state.k)
        if k in capture_states_at:
            captured[k] = state

        relres = float(jnp.linalg.norm(state.r)) / bnorm
        report.residual_history.append(relres)
        if relres < config.tol:
            report.converged = True
            break

        # ---- failure injection + recovery ----
        if pending_idx < len(pending) and k == pending[pending_idx].at_iteration and k > 0:
            plan = pending[pending_idx]
            pending_idx += 1
            if backend is None:
                raise RuntimeError("failure injected but no recovery backend configured")
            state = wipe_blocks(state, op.partition, plan.blocks)  # VM lost
            backend.fail(plan.blocks)
            assert snapshot is not None, "no completed persistence pair before failure"
            k_rec = int(snapshot.k)
            report.wasted_iterations += k - k_rec  # ESRP discard cost
            prev, cur = backend.recover(plan.blocks, k_rec)
            state = reconstruction.reconstruct(
                op, precond, b,
                state_surviving=snapshot,
                failed_blocks=list(plan.blocks),
                p_prev_f=jnp.asarray(prev.p, b.dtype),
                p_cur_f=jnp.asarray(cur.p, b.dtype),
                beta=cur.beta,
                local_method=config.local_solve,
            )
            report.failures_recovered += 1
            if int(state.k) in capture_states_at:
                captured[int(state.k)] = state
            continue

        state = step(state)
        if backend is not None and should_persist(int(state.k), config.persistence_period):
            persist_now(state)

    report.iterations = int(state.k)
    report.final_relres = float(jnp.linalg.norm(state.r)) / bnorm
    report.converged = report.converged or report.final_relres < config.tol
    return state, report, captured


def solve_jit(
    op_apply: Callable,
    precond_apply: Callable,
    b: jax.Array,
    tol: float = 1e-10,
    maxiter: int = 10_000,
) -> Tuple[jax.Array, jax.Array]:
    """Fused while-loop PCG (no recovery hooks): perf/dry-run path."""
    step = make_step(op_apply, precond_apply)
    bnorm2 = jnp.vdot(b, b)

    def cond(state: PCGState):
        rr = jnp.vdot(state.r, state.r)
        return jnp.logical_and(rr > (tol * tol) * bnorm2, state.k < maxiter)

    x0 = jnp.zeros_like(b)
    r0 = b
    z0 = precond_apply(r0)
    init = PCGState(x=x0, r=r0, z=z0, p=z0, rz=jnp.vdot(r0, z0),
                    beta_prev=jnp.zeros((), b.dtype), k=jnp.zeros((), jnp.int32))
    final = jax.lax.while_loop(cond, lambda s: step(s), init)
    return final.x, final.k
