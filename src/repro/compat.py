"""Version shims for the installed jax.

The repo targets the modern public API (``jax.shard_map`` with
``check_vma``); older jax (< 0.5) ships the same primitive as
``jax.experimental.shard_map.shard_map`` with ``check_rep``.  Route all
call sites through :func:`shard_map` so both work.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking disabled, on any jax."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
