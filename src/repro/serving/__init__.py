"""Serving substrate.

Two independent engines live here:

- :class:`SolveService` — the multi-tenant batched *solve* service
  (docs/serving.md, DESIGN.md §12): size-bucketed tenant lanes through
  one vmapped recoverable driver step, with per-tenant persistence,
  failure isolation, and bounded admission.
- :class:`ServeEngine` — the LM prefill/decode engine over sharded KV
  caches (the ``launch/serve.py --arch ...`` path).
"""
from repro.serving.engine import ServeEngine  # noqa: F401
from repro.serving.solve_service import (  # noqa: F401
    ServiceConfig,
    ServiceError,
    ServiceTicket,
    SolveService,
)
from repro.serving.trace import (  # noqa: F401
    ServiceRequest,
    generate_request_trace,
)
