"""Serving substrate: batched prefill/decode engine with sharded KV caches."""
from repro.serving.engine import ServeEngine  # noqa: F401
