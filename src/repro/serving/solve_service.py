"""Multi-tenant batched solve service with failure-isolated tenants.

DESIGN.md §12.  Many concurrent :class:`~repro.api.Problem` requests
share one process: the service buckets them by padded size (the lm1b
input-pipeline idiom — pad each grid dimension to the next power of two,
min 4, so a handful of compiled shapes serve arbitrary tenant sizes),
embeds each tenant in one *lane* of a fixed-width bucket, and advances
every bucket with a single jitted, vmapped recoverable driver step
(:func:`repro.solvers.driver.make_batched_step`).

**Masked lane embedding.**  A tenant grid sits in the corner of the
bucket grid behind a boolean mask ``m``; the lane operator is::

    A_lane(x) = where(m, stencil7(where(m, x, 0)), x)

— the tenant's own 7-point Dirichlet stencil on tenant cells (masked
neighbours contribute exactly the 0.0 the tenant's own zero padding
would), and the *identity* on padding cells, which keeps the lane
operator SPD.  With ``b`` zero-embedded and ``x0 = 0``, padding entries
stay exactly 0.0 through every batchable solver family, so unpadding is
a pure gather.  Preconditioning is per-lane *data*, not code: a
diagonal ``pdiag`` vector (1 on padding), which is why lanes carry
identity/Jacobi preconditioners only.

**Failure isolation.**  Each admitted tenant owns a full
:class:`~repro.solvers.driver.PersistencePipeline` — its own backend,
session, campaign planner, and metrics registry — with the tenant's
*declared logical* :class:`~repro.distributed.sharding.ShardLayout`, so
``shard=`` kills resolve to block sets without any device mesh.  A
:class:`~repro.solvers.driver.FailureEvent` (block, shard, or PRD kill)
addresses one tenant inside a live batch: the victim's lane state is
unpadded, recovered through the standard engine (wipe → drain → fetch →
reconstruct → rollback), re-embedded, and written back to its lane;
every persisted payload comes from *unpadded lane states*, so recovery
is self-consistent with the lane trajectory.  Cohabitant lanes are
untouched — lane ``i``'s vmapped output depends only on lane ``i``'s
inputs, so a cohabitant's trajectory is bit-identical to its solo
no-failure run through the same bucket shape.

**Admission.**  :meth:`SolveService.submit` validates the request,
resolves the resilience spec via the PR-5 advisor
(:meth:`repro.api.ResilienceSpec.advise`) when none is given, and
plans the campaign at submission — an unsurvivable campaign raises
:class:`~repro.solvers.driver.UnsurvivableCampaignError` naming the
violating event.  The admission queue is bounded: a full queue returns
a ``ServiceTicket(accepted=False)`` (counted, not raised).  Queue wait
is measured in deterministic service *steps*, so the benchmark's
queue-depth/wait/occupancy statistics survive the BENCH determinism
gate; they land in each tenant's :class:`SolveReport`
(``service_queue_wait_steps`` / ``service_lane_steps`` /
``service_batch_occupancy``) and in the service-labeled
:class:`~repro.obs.metrics.MetricsRegistry`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.poisson import (
    IdentityPreconditioner,
    JacobiPreconditioner,
    StencilOperator,
    stencil7,
)
from repro.core.spmv import make_det_dot
from repro.distributed.sharding import ShardLayout
from repro.obs.metrics import MetricsRegistry
from repro.serving.trace import ServiceRequest
from repro.solvers.base import base_operator
from repro.solvers.driver import (
    PersistencePipeline,
    SolveConfig,
    SolveReport,
    make_batched_step,
    resolve_shard_events,
    should_persist,
)
from repro.solvers.registry import SOLVERS

__all__ = [
    "ServiceConfig",
    "ServiceError",
    "ServiceTicket",
    "SolveService",
]


class ServiceError(ValueError):
    """A request the service cannot host (wrong operator family,
    non-diagonal preconditioner, non-batchable solver, device-sharded
    problem).  Distinct from admission-control rejection, which is a
    ``ServiceTicket(accepted=False)``, and from campaign planning,
    which raises UnsurvivableCampaignError naming the violating event."""


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Service-wide knobs.

    ``lanes`` is the *fixed* lane width of every bucket — fixed so the
    compiled vmapped step for a bucket shape never changes, which is
    what scopes the cohabitant bit-identity contract (docs/serving.md).
    ``max_queue`` bounds the admission queue; a submit against a full
    queue is rejected with a ticket, not an exception.  ``tracer``
    feeds the service spans/events and every tenant pipeline."""

    lanes: int = 4
    max_queue: int = 8
    tracer: Optional[object] = None


@dataclasses.dataclass
class ServiceTicket:
    """Admission-control outcome for one submitted request.  After the
    tenant completes (``SolveService.step``/``drain``/``replay``),
    ``result`` holds its :class:`~repro.api.SolveResult`."""

    tenant: str
    accepted: bool
    reason: str = ""
    submitted_step: int = 0
    result: Optional[object] = None


def _bucket_dim(d: int) -> int:
    """Next power of two >= max(d, 4) — the bucket edge for a tenant
    grid edge (lm1b-style size bucketing: few shapes, bounded waste)."""
    p = 4
    while p < d:
        p *= 2
    return p


class _LaneOperator:
    """One tenant's masked view of a bucket grid (module docstring):
    the tenant stencil on masked-in cells, identity on padding.  Used
    solo for ``init_state`` only; the vmapped step rebuilds the same
    arithmetic from the stacked lane data, so init and step agree bit
    for bit."""

    def __init__(self, grid: Tuple[int, int, int], mask, dtype):
        self.grid = tuple(grid)
        self.n = int(np.prod(grid))
        self.dtype = dtype
        self.mask = mask
        self.nblocks = 1  # lane dot = make_det_dot(1): plain full sum

    def apply(self, x):
        xin = jnp.where(self.mask, x, 0.0).reshape(self.grid)
        return jnp.where(self.mask, stencil7(xin).reshape(-1), x)


class _LanePreconditioner:
    """Diagonal preconditioner as lane data (1.0 on padding)."""

    def __init__(self, pdiag):
        self.pdiag = pdiag

    def apply(self, r):
        return r * self.pdiag


class _Tenant:
    """One admitted request: the real problem (for persistence and
    recovery, which run in tenant space) plus its lane embedding (for
    the batched step, which runs in bucket space)."""

    def __init__(self, name: str, problem, solver, config: SolveConfig,
                 backend, campaign, layout: ShardLayout, ticket: ServiceTicket,
                 capture_at: Sequence[int] = ()):
        self.name = name
        self.op = problem.op
        self.precond = problem.precond
        self.b = problem.b
        self.solver = solver
        self.tol = config.tol
        self.maxiter = config.maxiter
        self.period = config.persistence_period
        self.capture_at = frozenset(int(k) for k in capture_at)
        self.captured: Dict[int, object] = {}
        self.bnorm = float(np.linalg.norm(np.asarray(self.b)))
        self.backend = backend
        self.ticket = ticket

        grid = tuple(base_operator(self.op).grid)
        self.grid = grid
        self.bucket_grid = tuple(_bucket_dim(d) for d in grid)
        self.bucket_n = int(np.prod(self.bucket_grid))
        self.n_t = int(self.op.n)
        dtype = self.op.dtype
        self.dtype = np.dtype(dtype).name

        mask_np = np.zeros(self.bucket_grid, bool)
        mask_np[:grid[0], :grid[1], :grid[2]] = True
        flat = mask_np.reshape(-1)
        idx_np = np.flatnonzero(flat)
        self.idx = jnp.asarray(idx_np)
        self.lane_mask = jnp.asarray(flat)

        pd = np.ones(self.bucket_n)
        if isinstance(self.precond, JacobiPreconditioner):
            pd[idx_np] = np.asarray(self.precond.inv_diag)
        self.lane_pdiag = jnp.asarray(pd, dtype)
        bp = np.zeros(self.bucket_n)
        bp[idx_np] = np.asarray(self.b)
        b_pad = jnp.asarray(bp, dtype)

        # Lane-space init BEFORE the pipeline: solvers that derive lane
        # params in init_state (BiCGStab's rhat0) must see the lane b.
        lane_op = _LaneOperator(self.bucket_grid, self.lane_mask, dtype)
        self.lane_init = solver.init_state(lane_op,
                                           _LanePreconditioner(self.lane_pdiag),
                                           b_pad)
        self.lane_params = solver.lane_params()

        # The tenant's own persistence/recovery engine, in TENANT space:
        # real operator, real preconditioner, declared logical layout.
        # plan_campaign fires here — at submission — so an unsurvivable
        # campaign raises before the tenant ever reaches the queue.
        self.pipe = PersistencePipeline(solver, self.op, self.precond, self.b,
                                        config, backend, campaign,
                                        layout=layout)
        self.report = SolveReport(solver=solver.name,
                                  persist_mode=config.persist_mode,
                                  metrics=self.pipe.metrics)
        self.wait_steps = 0
        self.lane_steps = 0
        self.occupancy_sum = 0.0

    @property
    def bucket_key(self) -> Tuple[str, Tuple[int, int, int], str]:
        return (self.solver.name, self.bucket_grid, self.dtype)

    def unpad(self, lane_state):
        """Lane -> tenant space: gather vector fields at the masked-in
        indices (a pure gather — padding is exactly 0 by invariant);
        scalars and k pass through."""
        idx, n_pad = self.idx, self.bucket_n

        def take(a):
            if getattr(a, "ndim", None) == 1 and a.shape[0] == n_pad:
                return a[idx]
            return a

        return type(lane_state)(*[take(v) for v in lane_state])

    def pad(self, state):
        """Tenant -> lane space: scatter vector fields into a zeroed
        bucket vector (re-establishing the padding-is-0 invariant after
        a recovery rewrites the tenant state)."""
        idx, n_pad, n_t = self.idx, self.bucket_n, self.n_t

        def put(a):
            a = jnp.asarray(a)
            if a.ndim == 1 and a.shape[0] == n_t:
                return jnp.zeros(n_pad, a.dtype).at[idx].set(a)
            return a

        return type(state)(*[put(v) for v in state])


class _Bucket:
    """One compiled shape: (solver family, bucket grid, dtype) with a
    fixed number of lanes.  Stacked lane data (mask, pdiag, per-lane
    solver params) and stacked states advance together through one
    jitted vmapped step; free lanes carry inert dummy data (mask all
    False, pdiag/params 1) whose arithmetic never feeds a live lane."""

    def __init__(self, solver_cls, grid: Tuple[int, int, int], lanes: int,
                 dtype):
        self.grid = tuple(grid)
        self.n = int(np.prod(grid))
        self.lanes = lanes
        self.tenants: List[Optional[_Tenant]] = [None] * lanes
        self.masks = jnp.zeros((lanes, self.n), bool)
        self.pdiags = jnp.ones((lanes, self.n), dtype)
        self.params = None
        self.states = None
        self.occupancy = 0.0

        grid_t = self.grid
        det = make_det_dot(1)

        def make_lane_ops(lane):
            mask = lane["mask"]

            def op_apply(x):
                xin = jnp.where(mask, x, 0.0).reshape(grid_t)
                return jnp.where(mask, stencil7(xin).reshape(-1), x)

            def precond_apply(r):
                return r * lane["pdiag"]

            return op_apply, precond_apply, det, lane["params"]

        self.step = make_batched_step(solver_cls, make_lane_ops)

    def free_lane_count(self) -> int:
        return sum(1 for t in self.tenants if t is None)

    def live(self) -> List["_Tenant"]:
        return [t for t in self.tenants if t is not None]

    def lane_data(self) -> Dict[str, object]:
        return {"mask": self.masks, "pdiag": self.pdiags,
                "params": self.params}

    def lane_state(self, i: int):
        return jax.tree_util.tree_map(lambda a: a[i], self.states)

    def set_lane_state(self, i: int, state) -> None:
        self.states = jax.tree_util.tree_map(
            lambda a, v: a.at[i].set(v), self.states, state)

    def admit(self, tenant: _Tenant) -> int:
        i = self.tenants.index(None)
        self.tenants[i] = tenant
        self.masks = self.masks.at[i].set(tenant.lane_mask)
        self.pdiags = self.pdiags.at[i].set(tenant.lane_pdiag)
        init = tenant.lane_init
        params = jax.tree_util.tree_map(jnp.asarray, tenant.lane_params)
        if self.states is None:
            self.states = jax.tree_util.tree_map(
                lambda a: jnp.zeros((self.lanes,) + jnp.shape(a), a.dtype),
                init)
            self.params = jax.tree_util.tree_map(
                lambda a: jnp.ones((self.lanes,) + jnp.shape(a), a.dtype),
                params)
        self.set_lane_state(i, init)
        self.params = jax.tree_util.tree_map(
            lambda stack, v: stack.at[i].set(v), self.params, params)
        return i

    def free(self, i: int) -> None:
        self.tenants[i] = None
        self.masks = self.masks.at[i].set(False)
        self.pdiags = self.pdiags.at[i].set(1.0)
        self.states = jax.tree_util.tree_map(
            lambda a: a.at[i].set(jnp.zeros(a.shape[1:], a.dtype)),
            self.states)
        self.params = jax.tree_util.tree_map(
            lambda a: a.at[i].set(jnp.ones(a.shape[1:], a.dtype)),
            self.params)


class SolveService:
    """The multi-tenant batched solve service (module docstring).

    Drive it with :meth:`submit` + :meth:`step`/:meth:`drain`, or
    replay a declarative :class:`~repro.serving.trace.ServiceRequest`
    trace with :meth:`replay`.  ``service.metrics`` is the
    service-labeled registry (counters ``service.submitted`` /
    ``service.rejected`` / ``service.admitted`` / ``service.completed``,
    gauge ``service.queue_depth``, histograms
    ``service.queue_wait_steps`` / ``service.batch_occupancy``)."""

    def __init__(self, config: ServiceConfig = ServiceConfig()):
        self.config = config
        # RL301: normalize the tracer once; every site identity-guards.
        self._trace = config.tracer or None
        self.metrics = MetricsRegistry(service="solve")
        self._queue: List[_Tenant] = []
        self._buckets: Dict[tuple, _Bucket] = {}
        self._now = 0
        self._nsubmitted = 0

    @property
    def now(self) -> int:
        """Completed service steps (the deterministic service clock)."""
        return self._now

    @property
    def active(self) -> int:
        return sum(len(b.live()) for b in self._buckets.values())

    @property
    def queued(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------ admission
    def submit(self, problem, solver=None, resilience=None, failures=(),
               *, tenant: Optional[str] = None, nshards: int = 1,
               capture_states_at: Sequence[int] = ()) -> ServiceTicket:
        """Submit one tenant request.

        ``solver``/``resilience`` accept specs or registry name strings;
        ``resilience=None`` asks the PR-5 advisor for the cheapest spec
        that carries ``failures``.  ``nshards`` declares the tenant's
        *logical* shard layout (``shard=`` events resolve against it; it
        also becomes the report's ``nshards`` and the per-shard traffic
        labels).  Raises :class:`ServiceError` for requests the service
        cannot host and UnsurvivableCampaignError (from the submission-
        time campaign plan or the advisor) naming the violating event;
        returns a rejected ticket — no exception — when the bounded
        queue is full."""
        from repro import api

        self.metrics.counter("service.submitted").inc()
        name = tenant if tenant is not None else f"tenant{self._nsubmitted}"
        self._nsubmitted += 1
        trace = self._trace
        if trace is not None:
            trace.event("service.submit", tenant=name, step=self._now)

        if solver is None:
            solver = api.SolverSpec()
        elif isinstance(solver, str):
            solver = api.SolverSpec(solver)
        if isinstance(resilience, str):
            resilience = api.ResilienceSpec(resilience)

        op = problem.op
        if getattr(op, "layout", None) is not None or getattr(
                op, "mesh", None) is not None:
            raise ServiceError(
                "service tenants declare shard layouts logically "
                "(nshards=...); pass an unsharded problem — device "
                "placement is the solo api.solve path")
        if not isinstance(base_operator(op), StencilOperator):
            raise ServiceError(
                f"service buckets embed 7-point stencil operators only, "
                f"got {type(base_operator(op)).__name__}")
        if not isinstance(problem.precond,
                          (IdentityPreconditioner, JacobiPreconditioner)):
            raise ServiceError(
                f"service lanes carry diagonal (identity/Jacobi) "
                f"preconditioners only, got "
                f"{type(problem.precond).__name__}")
        solver_cls = SOLVERS.get(solver.name)
        if solver_cls is None:
            from repro.nvm.backend import unknown_name_error

            raise unknown_name_error("solver", solver.name, SOLVERS)
        if not getattr(solver_cls, "batchable", False):
            raise ServiceError(
                f"solver {solver.name!r} has no batched lane step; run "
                f"it through api.solve")

        layout = ShardLayout(op.nblocks, nshards)
        campaign = resolve_shard_events(failures, layout)
        if resilience is None:
            resilience = api.ResilienceSpec.advise(problem, campaign,
                                                   solver=solver)

        # Bounded admission queue: backpressure before any build work.
        if len(self._queue) >= self.config.max_queue:
            self.metrics.counter("service.rejected").inc()
            if trace is not None:
                trace.event("service.reject", tenant=name,
                            reason="queue full")
            return ServiceTicket(tenant=name, accepted=False,
                                 reason="queue full",
                                 submitted_step=self._now)

        built = solver.build(problem)
        backend = resilience.build(problem, built)
        cfg = SolveConfig(tol=solver.tol, maxiter=solver.maxiter,
                          persistence_period=resilience.period,
                          persist_mode=resilience.persist_mode,
                          plan_campaign=resilience.plan_campaigns,
                          tracer=self._trace)
        ticket = ServiceTicket(tenant=name, accepted=True,
                               submitted_step=self._now)
        t = _Tenant(name, problem, built, cfg, backend, campaign, layout,
                    ticket, capture_states_at)
        self._queue.append(t)
        self.metrics.gauge("service.queue_depth").set(len(self._queue))
        return ticket

    def submit_request(self, req: ServiceRequest) -> ServiceTicket:
        """Submit a declarative trace request (repro.serving.trace)."""
        return self.submit(req.problem(), solver=req.solver_spec(),
                           resilience=req.resilience_spec(),
                           failures=req.failures, tenant=req.tenant,
                           nshards=req.nshards,
                           capture_states_at=req.capture_states_at)

    def _admit(self) -> None:
        """Order-preserving first-fit: walk the queue once, seating every
        request whose bucket has a free lane (later requests may seat
        past a blocked head — deterministic, and keeps unrelated bucket
        shapes from head-of-line blocking each other)."""
        trace = self._trace
        still: List[_Tenant] = []
        for t in self._queue:
            bucket = self._buckets.get(t.bucket_key)
            if bucket is None:
                bucket = _Bucket(SOLVERS[t.solver.name], t.bucket_grid,
                                 self.config.lanes, t.lane_pdiag.dtype)
                self._buckets[t.bucket_key] = bucket
            if bucket.free_lane_count() == 0:
                still.append(t)
                continue
            lane = bucket.admit(t)
            t.wait_steps = self._now - t.ticket.submitted_step
            self.metrics.counter("service.admitted").inc()
            if trace is not None:
                trace.event("service.admit", tenant=t.name, lane=lane,
                            waited=t.wait_steps)
                trace.event("solve.begin", solver=t.solver.name,
                            mode=t.report.persist_mode, maxiter=t.maxiter)
            # Iteration 0 counts as persisted (driver contract) — from
            # the UNPADDED lane init, like every later persist point.
            if t.pipe.session is not None:
                t.pipe.persist_point(t.unpad(t.lane_init))
        self._queue = still
        self.metrics.gauge("service.queue_depth").set(len(self._queue))

    # ------------------------------------------------------------ stepping
    def step(self) -> None:
        """One deterministic service step: admit from the queue, then for
        every bucket run the driver loop-top per live lane (capture /
        convergence / failure injection+recovery), one batched vmapped
        step, and the post-step persistence schedule."""
        self._admit()
        trace = self._trace
        if trace is None:
            self._step_buckets()
        else:
            with trace.span("service.step", step=self._now,
                            active=self.active, queued=len(self._queue)):
                self._step_buckets()
        self._now += 1

    def _step_buckets(self) -> None:
        for key in sorted(self._buckets):
            bucket = self._buckets[key]
            for i, t in enumerate(list(bucket.tenants)):
                if t is not None:
                    self._pre_step(bucket, i, t)
            live = bucket.live()
            if not live:
                continue
            bucket.occupancy = len(live) / bucket.lanes
            t0 = time.perf_counter()
            bucket.states = bucket.step(bucket.states, bucket.lane_data())
            jax.block_until_ready(bucket.states)
            window = time.perf_counter() - t0
            for i, t in enumerate(list(bucket.tenants)):
                if t is not None:
                    self._post_step(bucket, i, t, window)

    def _pre_step(self, bucket: _Bucket, i: int, t: _Tenant) -> None:
        """The driver loop-top for one lane, iterated exactly like the
        solo loop's ``continue``: capture, residual append, convergence,
        then at most one pending failure event per pass — a recovery
        rolls k back and the loop re-checks at the recovered k."""
        while True:
            st = bucket.lane_state(i)
            k = int(st.k)
            if k >= t.maxiter:
                self._finalize(bucket, i, t, st)
                return
            st_t = t.unpad(st)
            if k in t.capture_at:
                t.captured[k] = st_t
            relres = t.solver.residual_norm(st_t) / t.bnorm
            t.report.residual_history.append(relres)
            if relres < t.tol:
                t.report.converged = True
                self._finalize(bucket, i, t, st)
                return
            ev = t.pipe.pop_event(k)
            if ev is None:
                return
            st_rec = t.pipe.inject(ev, st_t, k)
            if st_rec is not st_t:
                # Block/shard recovery: re-embed the reconstructed
                # tenant state into the lane (padding back to exact 0).
                bucket.set_lane_state(i, t.pad(st_rec))
            # storage-only kills leave the lane untouched; either way
            # the loop re-runs at the (possibly rolled-back) k.

    def _post_step(self, bucket: _Bucket, i: int, t: _Tenant,
                   window: float) -> None:
        st = bucket.lane_state(i)
        t.lane_steps += 1
        t.occupancy_sum += bucket.occupancy
        pipe = t.pipe
        if pipe.session is None:
            return
        if pipe.staged_state is not None:
            # Overlap window: the staged commit rides behind this
            # step's batched compute (the bucket's measured wall).
            pipe.persist_commit(window)
        if should_persist(int(st.k), t.period, pipe.history):
            pipe.persist_point(t.unpad(st))

    def _finalize(self, bucket: _Bucket, i: int, t: _Tenant,
                  lane_state) -> None:
        st_t = t.unpad(lane_state)
        tm = t.pipe.metrics
        tm.counter("service.wait_steps").inc(t.wait_steps)
        tm.counter("service.lane_steps").inc(t.lane_steps)
        t.pipe.finalize(t.report, st_t, t.bnorm)
        rep = t.report
        # Derived views (DESIGN.md §9): read the service fields back OUT
        # of the tenant registry, like every other report counter.
        rep.service_queue_wait_steps = tm.counter_value("service.wait_steps")
        rep.service_lane_steps = tm.counter_value("service.lane_steps")
        rep.service_batch_occupancy = (
            t.occupancy_sum / t.lane_steps if t.lane_steps else 0.0)
        self.metrics.counter("service.completed").inc()
        self.metrics.histogram("service.queue_wait_steps").observe(
            float(t.wait_steps))
        self.metrics.histogram("service.batch_occupancy").observe(
            rep.service_batch_occupancy)
        trace = self._trace
        if trace is not None:
            trace.event("service.complete", tenant=t.name,
                        iterations=rep.iterations, converged=rep.converged)
        from repro import api

        t.ticket.result = api.SolveResult(state=st_t, report=rep,
                                          captured=t.captured,
                                          backend=t.backend)
        bucket.free(i)

    # ------------------------------------------------------------ driving
    def drain(self, max_steps: int = 100_000) -> None:
        """Step until the queue and every lane are empty."""
        steps = 0
        while self._queue or self.active:
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError(
                    f"service did not drain within {max_steps} steps "
                    f"({self.active} active, {len(self._queue)} queued)")

    def replay(self, requests: Sequence[ServiceRequest],
               max_steps: int = 100_000) -> Dict[str, ServiceTicket]:
        """Replay a declarative request trace against the service clock:
        each request is submitted when its ``at_step`` arrives, the
        service steps while work is live, and idle gaps fast-forward to
        the next arrival.  Returns tenant -> ticket (rejected tickets
        included; their ``result`` stays None)."""
        pending = sorted(requests, key=lambda r: (r.at_step, r.tenant))
        tickets: Dict[str, ServiceTicket] = {}
        i = 0
        steps = 0
        while i < len(pending) or self._queue or self.active:
            while i < len(pending) and pending[i].at_step <= self._now:
                tickets[pending[i].tenant] = self.submit_request(pending[i])
                i += 1
            if self._queue or self.active:
                self.step()
            else:
                self._now += 1  # idle tick toward the next arrival
            steps += 1
            if steps > max_steps:
                raise RuntimeError(
                    f"service replay did not finish within {max_steps} "
                    f"steps ({self.active} active, {len(self._queue)} "
                    f"queued, {len(pending) - i} pending)")
        return tickets
