"""Deterministic multi-tenant request traces (ISSUE 9).

One seeded generator feeds the whole service surface — the
tenant-isolation tests (``tests/test_solve_service.py``), the service
leg of the campaign-fuzz harness, and the ``service`` subtree of the
benchmark trajectory — so bench and tests replay the *same* traces.
Uses :class:`random.Random` (not numpy) so the module stays importable
without the runtime and the draw sequence is pinned by seed alone.

A :class:`ServiceRequest` is declarative: grid / solver / spec /
failure choices, no built objects.  ``SolveService.submit_request``
materializes the :class:`~repro.api.Problem` and specs at submission,
which keeps traces cheap to generate, hash, and embed in BENCH JSON.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Optional, Sequence, Tuple

from repro.solvers.driver import FailureEvent

#: tenant grids mixed by the generator: (grid, nblocks) with nblocks
#: dividing nz (the z-slab partition constraint).  Sizes straddle the
#: power-of-two bucket boundaries so traces exercise both padded and
#: exact-fit lanes: (3,4,4)/(4,4,4) share bucket (4,4,4); (4,6,6),
#: (6,6,6), (5,8,8) and (8,8,8) share bucket (8,8,8).
GRID_CHOICES: Tuple[Tuple[Tuple[int, int, int], int], ...] = (
    ((3, 4, 4), 3),
    ((4, 4, 4), 4),
    ((4, 6, 6), 4),
    ((6, 6, 6), 6),
    ((5, 8, 8), 5),
    ((8, 8, 8), 8),
)

#: (solver family, tol, maxiter) — tolerances matched to the family's
#: convergence rate on the small trace grids (Jacobi is a smoother, not
#: a Krylov method, so it gets the loose target).
SOLVER_CHOICES: Tuple[Tuple[str, float, int], ...] = (
    ("pcg", 1e-9, 500),
    ("bicgstab", 1e-9, 500),
    ("chebyshev", 1e-8, 1500),
    ("jacobi", 1e-6, 3000),
)

#: resilience spec mix: registry spec strings plus None, which asks the
#: service to pick via the advisor (repro.api.ResilienceSpec.advise).
SPEC_CHOICES: Tuple[Optional[str], ...] = (
    "nvm-prd",
    "replicated(nvm-prd x2)",
    "erasure(nvm-prd x4+p)",
    None,
)

#: specs whose declared capabilities survive a PRD (persistence-node)
#: loss — the survivable_only generator upgrades a PRD victim to one
PRD_SAFE_SPECS: Tuple[str, ...] = (
    "replicated(nvm-prd x2)",
    "erasure(nvm-prd x4+p)",
)


@dataclasses.dataclass(frozen=True)
class ServiceRequest:
    """One declarative tenant request in a service trace."""

    tenant: str
    at_step: int                      # service step at which it arrives
    grid: Tuple[int, int, int]
    nblocks: int
    preconditioner: str = "jacobi"
    solver: str = "pcg"
    tol: float = 1e-9
    maxiter: int = 500
    backend: Optional[str] = None     # spec string; None = advisor picks
    persist_mode: str = "sync"
    period: int = 1
    nshards: int = 1                  # declared logical shard layout
    failures: Tuple[FailureEvent, ...] = ()
    capture_states_at: Tuple[int, ...] = ()

    def problem(self):
        """Materialize the Poisson problem this request describes."""
        from repro import api

        return api.Problem.poisson(*self.grid, nblocks=self.nblocks,
                                   preconditioner=self.preconditioner)

    def solver_spec(self):
        from repro import api

        return api.SolverSpec(self.solver, tol=self.tol,
                              maxiter=self.maxiter)

    def resilience_spec(self):
        """The request's ResilienceSpec, or None for advisor choice."""
        from repro import api

        if self.backend is None:
            return None
        return api.ResilienceSpec(self.backend,
                                  persist_mode=self.persist_mode,
                                  period=self.period)


def _divisor_shards(rng: random.Random, nblocks: int) -> int:
    """A shard count > 1 dividing nblocks (logical layout for shard=
    events), falling back to nblocks itself for prime block counts."""
    divs = [d for d in range(2, nblocks + 1) if nblocks % d == 0]
    return rng.choice(divs) if divs else nblocks


def _failure(rng: random.Random, nblocks: int, nshards: int,
             kind: str) -> FailureEvent:
    at = rng.randrange(3, 9)
    if kind == "shard":
        return FailureEvent(shard=rng.randrange(nshards), at_iteration=at)
    if kind == "prd":
        return FailureEvent(blocks=(rng.randrange(nblocks),),
                            at_iteration=at, prd=True)
    return FailureEvent(blocks=(rng.randrange(nblocks),), at_iteration=at)


def generate_request_trace(
    seed: int,
    nrequests: int = 6,
    failure_rate: float = 0.5,
    survivable_only: bool = False,
    max_arrival_step: int = 4,
    solvers: Sequence[Tuple[str, float, int]] = SOLVER_CHOICES,
    specs: Sequence[Optional[str]] = SPEC_CHOICES,
) -> Tuple[ServiceRequest, ...]:
    """The shared deterministic request trace.

    Draws ``nrequests`` tenants with seeded sizes, arrival steps, solver
    families, spec families, and (with probability ``failure_rate``) one
    block / PRD / shard failure event each.  ``survivable_only=True``
    upgrades every PRD victim to a PRD-safe spec so the whole trace is
    plan-acceptable — the benchmark's sustained-load mode; the fuzz leg
    keeps it False and asserts the planner names the violating event at
    submission instead.
    """
    # repro-lint: noqa[RL203] -- explicitly seeded Random instance (not the process-global stream); stdlib keeps traces importable by runtime-free tooling
    rng = random.Random(seed)
    requests = []
    for i in range(nrequests):
        grid, nblocks = rng.choice(GRID_CHOICES)
        solver, tol, maxiter = rng.choice(list(solvers))
        spec = rng.choice(list(specs))
        persist_mode = rng.choice(("sync", "overlap"))
        period = rng.choice((1, 3))
        precond = rng.choice(("jacobi", "identity"))
        nshards = 1
        failures: Tuple[FailureEvent, ...] = ()
        if rng.random() < failure_rate:
            kind = rng.choice(("block", "prd", "shard"))
            if kind == "shard":
                nshards = _divisor_shards(rng, nblocks)
            failures = (_failure(rng, nblocks, nshards, kind),)
            if survivable_only and failures[0].prd and (
                    spec is not None and spec not in PRD_SAFE_SPECS):
                spec = PRD_SAFE_SPECS[i % len(PRD_SAFE_SPECS)]
        requests.append(ServiceRequest(
            tenant=f"t{i}",
            at_step=rng.randrange(0, max_arrival_step + 1),
            grid=grid, nblocks=nblocks, preconditioner=precond,
            solver=solver, tol=tol, maxiter=maxiter,
            backend=spec, persist_mode=persist_mode, period=period,
            nshards=nshards, failures=failures,
        ))
    return tuple(requests)
