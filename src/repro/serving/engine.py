"""Batched serving engine: prefill + decode over sharded KV caches.

``prefill`` consumes the prompt and fills the caches (global layers:
full-length seq-sharded caches; local layers: O(window) ring buffers;
SSM/RG-LRU layers: constant-size recurrent state — which is why those
families run the 500k-context cell).  ``decode_step`` appends one token.
Greedy sampling; batch-synchronous (all requests share a position),
matching the assigned decode shapes.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


class ServeEngine:
    def __init__(self, prefill_fn: Callable, decode_fn: Callable,
                 cache_init: Callable):
        """All three callables come from the arch registry:
        - prefill_fn(params, tokens_or_embeds, caches) -> (logits, caches)
        - decode_fn(params, last_tokens (B,1), caches, index) -> (logits, caches)
        - cache_init(batch, max_seq) -> caches pytree
        """
        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn)
        self._cache_init = cache_init

    def generate(self, params, prompt: jax.Array, steps: int,
                 max_seq: Optional[int] = None) -> jax.Array:
        b, s = prompt.shape[0], prompt.shape[1]
        max_seq = max_seq if max_seq is not None else s + steps
        caches = self._cache_init(b, max_seq)
        logits, caches = self._prefill(params, prompt, caches)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(prompt.dtype)
        out = [tok]
        idx = jnp.asarray(s, jnp.int32)
        for _ in range(steps - 1):
            logits, caches = self._decode(params, tok, caches, idx)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(prompt.dtype)
            out.append(tok)
            idx = idx + 1
        return jnp.concatenate(out, axis=1)
