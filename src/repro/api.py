"""``repro.api`` — the front door: problem, solver, resilience, solve.

The rest of the package is deliberately explicit (operators, schemas,
sessions, registries); this façade wires it for the common case so a
recoverable solve is three declarations and one call::

    from repro import api

    result = api.solve(
        api.Problem.poisson(8, nblocks=4),
        api.SolverSpec("pcg"),
        api.ResilienceSpec("replicated(nvm-prd x2)", persist_mode="overlap"),
    )
    assert result.converged

Everything is still the same machinery underneath — `SolverSpec.build`
returns a registry solver, `ResilienceSpec.build` a registry
:class:`~repro.nvm.backend.PersistenceBackend` (spec strings compose:
``"replicated(nvm-prd x2)"``, ``"tiered(nvm-homogeneous)"``), and
:func:`solve` drives :func:`repro.solvers.driver.solve` — so anything
built here interoperates with hand-wired code.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.poisson import PRECONDITIONERS, make_poisson_problem
from repro.nvm.backend import (
    BackendCapabilities,
    PersistenceBackend,
    UnrecoverableFailure,
    backend_names,
)
from repro.solvers import driver as _driver
from repro.solvers.driver import (
    CampaignPlan,
    FailureCampaign,
    FailureEvent,
    FailurePlan,
    SolveConfig,
    SolveReport,
    SpecAdvice,
    SpecRanking,
    UnsurvivableCampaignError,
    advise_spec,
    plan_campaign,
)
from repro.solvers.registry import SOLVERS, make_backend, make_solver
from repro.serving.solve_service import (
    ServiceConfig,
    ServiceError,
    ServiceTicket,
    SolveService,
)
from repro.serving.trace import ServiceRequest, generate_request_trace

__all__ = [
    "Problem",
    "SolverSpec",
    "ResilienceSpec",
    "SolveResult",
    "solve",
    "advise",
    "default_candidate_specs",
    "solver_names",
    "backend_names",
    "BackendCapabilities",
    "PersistenceBackend",
    "UnrecoverableFailure",
    "CampaignPlan",
    "UnsurvivableCampaignError",
    "plan_campaign",
    "advise_spec",
    "SpecAdvice",
    "SpecRanking",
    "FailureCampaign",
    "FailureEvent",
    "FailurePlan",
    "SolveConfig",
    "SolveReport",
    "SolveService",
    "ServiceConfig",
    "ServiceError",
    "ServiceTicket",
    "ServiceRequest",
    "generate_request_trace",
    "serve",
]

#: the composite spec families — they take arguments, so the default
#: candidate list names one canonical instantiation of each
_COMPOSITE_FAMILIES = ("replicated", "tiered", "erasure")


def default_candidate_specs() -> Tuple[str, ...]:
    """The advisor's default candidate list: every non-composite
    registered backend by name, plus canonical instantiations of each
    composite family across the footprint/distance trade-off."""
    base = tuple(n for n in backend_names() if n not in _COMPOSITE_FAMILIES)
    return base + (
        "tiered(nvm-prd)",
        "replicated(nvm-prd x2)",
        "replicated(nvm-prd x3)",
        "erasure(nvm-prd x4+p)",
        "erasure(nvm-prd x6+2p)",
    )


def advise(
    problem: Problem,
    campaign,
    candidates: Optional[Sequence[str]] = None,
    solver: Union["SolverSpec", str] = "pcg",
    dtype: Any = np.float64,
    tracer=None,
) -> SpecAdvice:
    """Rank candidate resilience specs against a campaign for this
    problem: each spec is built (sized for the problem, persisting the
    solver's schema), filtered through
    :func:`~repro.solvers.driver.plan_campaign`, and the survivors
    ranked by storage footprint with modeled persist cost as
    tie-breaker (:func:`~repro.solvers.driver.advise_spec`).  The
    returned :class:`~repro.solvers.driver.SpecAdvice` renders as a
    table via :func:`repro.launch.report.spec_advice_table`.  A
    ``tracer`` (repro.obs) records per-candidate ``advise.candidate``
    events and the ``advise.chosen`` verdict."""
    if isinstance(solver, str):
        solver = SolverSpec(solver)
    built_solver = solver.build(problem)
    if candidates is None:
        candidates = default_candidate_specs()
    built = [(spec, make_backend(spec, problem.op, dtype=dtype,
                                 solver=built_solver))
             for spec in candidates]
    return advise_spec(campaign, built, probe_values=problem.op.n,
                       tracer=tracer)


def solver_names() -> list:
    """All registered solver names."""
    return sorted(SOLVERS)


@dataclasses.dataclass(frozen=True)
class Problem:
    """A linear system ``A x = b`` with a preconditioner: the operator is
    matrix-free and block-partitioned (the failure/recovery unit)."""

    op: Any
    b: Any
    precond: Any

    @property
    def nshards(self) -> int:
        """Device shards the operator is laid out over (1 = unsharded;
        >1 when the operator is a
        :class:`~repro.distributed.sharding.ShardedOperator`)."""
        layout = getattr(self.op, "layout", None)
        return 1 if layout is None else layout.nshards

    def with_shards(self, nshards: int, mesh=None) -> "Problem":
        """Lay this problem out over ``nshards`` devices on a 1-D
        ``data`` mesh (:func:`repro.distributed.sharding.shard_problem`):
        block-rows map contiguously onto shards, and the driver's
        fail/persist/recover path becomes per-shard addressable
        (``FailureEvent(shard=...)``).  The sharded solve is
        bit-identical to the unsharded one (DESIGN.md §10).  Raises if
        the problem is already sharded or fewer than ``nshards``
        devices are visible."""
        if getattr(self.op, "layout", None) is not None:
            raise ValueError(
                "problem is already sharded; shard the unsharded "
                "problem instead of re-sharding")
        from repro.distributed.sharding import shard_problem

        sop, sb = shard_problem(self.op, self.b, nshards, mesh=mesh)
        return dataclasses.replace(self, op=sop, b=sb)

    @classmethod
    def poisson(cls, nz: int, ny: Optional[int] = None,
                nx: Optional[int] = None, nblocks: int = 4,
                preconditioner: str = "jacobi",
                nshards: int = 1) -> "Problem":
        """The paper's benchmark: a 7-point 3-D Poisson stencil with a
        smooth right-hand side, split into ``nblocks`` z-slabs.  ``ny``
        and ``nx`` default to ``nz`` (a cubic grid).  ``nshards > 1``
        device-shards the problem (see :meth:`with_shards`)."""
        op, b = make_poisson_problem(nz, ny if ny is not None else nz,
                                     nx if nx is not None else nz,
                                     nblocks=nblocks)
        try:
            pre_cls = PRECONDITIONERS[preconditioner]
        except KeyError:
            from repro.nvm.backend import unknown_name_error

            raise unknown_name_error("preconditioner", preconditioner,
                                     PRECONDITIONERS) from None
        problem = cls(op=op, b=b, precond=pre_cls(op))
        if nshards != 1:
            problem = problem.with_shards(nshards)
        return problem

    @classmethod
    def from_parts(cls, op, b, precond=None) -> "Problem":
        """Wrap an existing operator / rhs / preconditioner triple."""
        if precond is None:
            precond = PRECONDITIONERS["identity"](op)
        return cls(op=op, b=b, precond=precond)


@dataclasses.dataclass(frozen=True)
class SolverSpec:
    """Which solver, to what accuracy.

    ``options`` are forwarded to the solver factory (e.g. ``{"m": 8}``
    for restarted GMRES)."""

    name: str = "pcg"
    tol: float = 1e-10
    maxiter: int = 10_000
    options: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def build(self, problem: Problem):
        return make_solver(self.name, problem.op, problem.precond,
                           **dict(self.options))


@dataclasses.dataclass(frozen=True)
class ResilienceSpec:
    """Which persistence backend, and how persistence is scheduled.

    ``backend`` is a registry name or composable spec string
    (``"nvm-prd"``, ``"replicated(nvm-prd x2)"``,
    ``"erasure(nvm-prd x4+p)"``, ``"tiered(nvm-homogeneous)"``), an
    already-built :class:`~repro.nvm.backend.PersistenceBackend`, or
    None for an unprotected run.  ``persist_mode`` picks the pipeline
    ("sync" or "overlap", DESIGN.md §6); ``period`` the ESRP
    persistence period.  ``plan_campaigns`` keeps the pre-flight
    campaign planner on (:func:`plan_campaign`, DESIGN.md §8): a
    campaign the backend's capabilities provably cannot survive is
    rejected with :class:`UnsurvivableCampaignError` before iteration
    0.  ``nshards`` pins the expected device-shard count of the
    problem: ``None`` accepts any layout, an integer makes
    :func:`solve` refuse a problem whose shard axis disagrees (the
    spec was sized/planned for that layout).  ``fused_persist``
    selects the fused persist path (DESIGN.md §13): stripe parity
    encodes run through the Pallas GF(256) kernel and, in overlap
    mode, staging defers into the compute window — slot bytes and
    solve trajectories are bit-identical to the numpy path.
    ``options`` are forwarded to the backend factory."""

    backend: Union[str, PersistenceBackend, None] = "nvm-prd"
    persist_mode: str = "sync"
    period: int = 1
    plan_campaigns: bool = True
    nshards: Optional[int] = None
    fused_persist: bool = False
    dtype: Any = np.float64
    options: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def build(self, problem: Problem, solver) -> Optional[PersistenceBackend]:
        if self.backend is None or isinstance(self.backend, PersistenceBackend):
            return self.backend
        return make_backend(self.backend, problem.op, dtype=self.dtype,
                            solver=solver, **dict(self.options))

    @classmethod
    def advise(cls, problem: Problem, campaign,
               candidates: Optional[Sequence[str]] = None,
               solver: Union["SolverSpec", str] = "pcg",
               **spec_kwargs) -> "ResilienceSpec":
        """The cheapest-spec advisor (DESIGN.md §8): return a
        :class:`ResilienceSpec` for the cheapest candidate whose
        declared capabilities carry ``campaign`` — e.g. a
        double-storage-loss campaign picks ``erasure(nvm-prd x6+2p)``
        (1.33x storage) over ``replicated(nvm-prd x3)`` (3x) on
        footprint grounds.  ``spec_kwargs`` (``persist_mode``,
        ``period``, ...) are forwarded to the spec.  Raises
        :class:`UnsurvivableCampaignError` when no candidate survives;
        use :func:`advise` for the full ranking table."""
        advice = advise(problem, campaign, candidates, solver=solver,
                        dtype=spec_kwargs.get("dtype", np.float64))
        if advice.chosen is None:
            raise UnsurvivableCampaignError(
                "no candidate spec survives the campaign: "
                + "; ".join(f"[{r.spec}] {r.reason}"
                            for r in advice.rejected))
        return cls(advice.chosen, **spec_kwargs)


@dataclasses.dataclass(frozen=True)
class SolveResult:
    """Outcome of :func:`solve`: the final solver state, the full
    :class:`~repro.solvers.driver.SolveReport`, any captured states, and
    the backend (for capability / footprint inspection)."""

    state: Any
    report: SolveReport
    captured: Dict[int, Any]
    backend: Optional[PersistenceBackend]

    @property
    def x(self) -> np.ndarray:
        """The solution iterate as a host array."""
        return np.asarray(self.state.x)

    @property
    def converged(self) -> bool:
        return self.report.converged

    @property
    def relres(self) -> float:
        return self.report.final_relres

    @property
    def iterations(self) -> int:
        return self.report.iterations

    @property
    def capabilities(self) -> Optional[BackendCapabilities]:
        return None if self.backend is None else self.backend.capabilities


def solve(
    problem: Problem,
    solver: Union[SolverSpec, str] = SolverSpec(),
    resilience: Union[ResilienceSpec, str, None] = None,
    failures: Union[FailureCampaign, Sequence, Tuple] = (),
    x0=None,
    capture_states_at: Sequence[int] = (),
    tracer=None,
) -> SolveResult:
    """Build the solver and backend from their specs and run the
    recoverable solve.

    ``solver`` and ``resilience`` accept bare name strings as shorthand
    for default specs (``"pcg"`` == ``SolverSpec("pcg")``,
    ``"replicated(nvm-prd x2)"`` ==
    ``ResilienceSpec("replicated(nvm-prd x2)")``); ``resilience=None``
    runs unprotected (and refuses injected failures, like the driver).
    ``tracer`` (a :class:`repro.obs.Tracer`) records spans and events
    through the driver, the persistence sessions, and the stager —
    export with ``tracer.to_chrome(...)`` for Perfetto
    (docs/observability.md); omitted, the hot path stays a strict no-op.
    """
    if isinstance(solver, str):
        solver = SolverSpec(solver)
    if isinstance(resilience, str):
        resilience = ResilienceSpec(resilience)
    if resilience is None:
        resilience = ResilienceSpec(backend=None)
    if (resilience.nshards is not None
            and resilience.nshards != problem.nshards):
        raise ValueError(
            f"ResilienceSpec.nshards={resilience.nshards} but the "
            f"problem is laid out over nshards={problem.nshards}; "
            f"re-shard with Problem.with_shards({resilience.nshards}) "
            f"or drop the spec's shard pin")

    built_solver = solver.build(problem)
    backend = resilience.build(problem, built_solver)
    config = SolveConfig(
        tol=solver.tol,
        maxiter=solver.maxiter,
        persistence_period=resilience.period,
        persist_mode=resilience.persist_mode,
        plan_campaign=resilience.plan_campaigns,
        fused_persist=resilience.fused_persist,
        tracer=tracer,
    )
    state, report, captured = _driver.solve(
        built_solver, problem.op, problem.b, problem.precond,
        config=config, backend=backend, failures=failures, x0=x0,
        capture_states_at=capture_states_at,
    )
    return SolveResult(state=state, report=report, captured=captured,
                       backend=backend)


def serve(
    requests: Sequence[ServiceRequest],
    lanes: int = 4,
    max_queue: int = 8,
    tracer=None,
) -> Dict[str, ServiceTicket]:
    """Replay a multi-tenant request trace through a fresh
    :class:`SolveService` (docs/serving.md) and return tenant ->
    ticket; each accepted ticket carries its :class:`SolveResult`.
    For incremental submission use the service object directly::

        svc = api.SolveService(api.ServiceConfig(lanes=8))
        ticket = svc.submit(problem, "pcg", failures=campaign)
        svc.drain()
    """
    svc = SolveService(ServiceConfig(lanes=lanes, max_queue=max_queue,
                                     tracer=tracer))
    return svc.replay(requests)
