"""The formal persistence-backend API (DESIGN.md §7).

Before this module, the three recovery backends were duck-typed classes
with an informal, grafted-on protocol (``persist_begin/commit/drain``,
``persist_set``, legacy ``persist``, ``fail``, ``recover_set``) and the
resilience guarantee you actually got was implied by which concrete
class you happened to construct.  Following the composition lesson of
Pachajoa et al. (arXiv:1907.13077) and EasyCrash (arXiv:1906.10081),
this module makes the contract explicit:

- :class:`PersistenceBackend` — the ABC every backend implements.  A
  backend *declares* what it guarantees through a
  :class:`BackendCapabilities` record and *opens* a
  :class:`PersistSession` for each solve.
- :class:`PersistSession` — the per-solve lifecycle the driver speaks:
  ``begin/commit/drain/abort`` (the overlapped pipeline of DESIGN.md
  §6), ``persist`` (synchronous write-through), ``fetch`` (recovery
  reads), ``durable_run`` (the newest durable recovery point), and the
  failure injection points ``fail`` (compute blocks) / ``fail_storage``
  (the PRD / persistence-service node itself).
- composite backends: :class:`ReplicatedBackend` (RAID-1-style
  mirroring across N children with quorum fetch — PRD redundancy as a
  *composition*, not a fourth hand-written backend),
  :class:`ErasureCodedBackend` (RAID-4/5-style XOR parity striping
  across K data children + 1 parity child — the same single-node-loss
  guarantee as a 2x mirror at ~(1+1/K)x footprint, DESIGN.md §8), and
  :class:`TieredBackend` (a volatile RAM front staging into any child;
  this tier is also what gives non-pipelined backends overlap support,
  absorbing the old driver-side staging path).
- the single backend registry (:func:`register_backend`,
  :func:`create_backend`, :func:`backend_names`) with composable spec
  strings — ``"replicated(nvm-prd x2)"``, ``"erasure(nvm-prd x4+p)"``
  — replacing the ``core.nvm_esr.BACKENDS`` dict and the registry
  special-casing.
- shims that route the two legacy entry points through the new
  protocol with a :class:`DeprecationWarning`: pre-zoo duck-typed
  backends (``persist(k, beta, p)`` / ``recover(blocks, k)``) and
  schema-duck-typed externals (``persist_set`` without sessions).

The slot wire format is untouched: sessions delegate to the same
schema codecs (docs/recovery-format.md stays valid byte for byte).
"""
from __future__ import annotations

import abc
import collections.abc
import dataclasses
import difflib
import re
import warnings
from dataclasses import dataclass
from typing import (
    TYPE_CHECKING,
    Callable,
    Dict,
    List,
    Mapping,
    Optional,
    Sequence,
    Tuple,
)

import numpy as np

from repro.nvm import gf256
from repro.nvm.store import TIER_SPECS, CostModel, PersistStager, Tier

if TYPE_CHECKING:
    from repro.core.state import RecoverySchema, RecoverySet  # noqa: F401

# NOTE: repro.core.* is imported lazily throughout this module.  The
# core package's __init__ pulls in the solver driver, which imports this
# module — a top-level core import here would make ``import repro.nvm``
# order-dependent.


class UnrecoverableFailure(RuntimeError):
    """The recovery data needed to reconstruct a failed block is gone —
    every redundancy copy died with the failure, or the persistence
    service itself (PRD node, local pools, peer RAM) was lost and the
    backend's :class:`BackendCapabilities` do not cover that loss."""


OVERLAP_NATIVE = "native"
OVERLAP_DRIVER_STAGED = "driver-staged"


@dataclass
class SessionTraffic:
    """Per-device-shard byte accounting at the driver/session boundary
    (DESIGN.md §10).

    Counts *logical* slot-payload bytes as the driver sees them — what a
    node's NIC moves to (persist) or from (recovery fetch) the
    persistence service for the blocks a shard owns.  Composites meter
    once at the top of the storage tree: a replicated quorum read serves
    from ONE mirror, an erasure fetch reassembles K chunks that sum to
    one slot, so in both cases a recovery moves exactly the lost shard's
    slot bytes.  Keys are shard indices (everything is shard 0 for an
    unsharded solve)."""

    persist_bytes: Dict[int, int] = dataclasses.field(default_factory=dict)
    fetch_bytes: Dict[int, int] = dataclasses.field(default_factory=dict)

    def note_persist(self, shard: int, nbytes: int) -> None:
        self.persist_bytes[shard] = self.persist_bytes.get(shard, 0) + nbytes

    def note_fetch(self, shard: int, nbytes: int) -> None:
        self.fetch_bytes[shard] = self.fetch_bytes.get(shard, 0) + nbytes


@dataclass(frozen=True)
class BackendCapabilities:
    """What a backend *guarantees*, declared instead of implied.

    - ``durability`` — the tier recovery data rests on once committed:
      ``"ram"`` (volatile peer memory), ``"nvm"``, or ``"ssd"``.
      Composites join their children's tiers (``"ram+nvm"``).
    - ``survives_node_loss`` — committed recovery data remains usable
      after compute-node failures (possibly after the node returns, as
      in the homogeneous architecture).
    - ``survives_prd_loss`` — committed recovery data remains usable
      after the persistence-service node itself (the PRD node, the
      local pool service, the peer-RAM fabric) crashes.  Only
      redundant compositions can honestly declare this.
    - ``overlap`` — ``"native"`` when the backend pipelines
      ``begin/commit`` itself; ``"driver-staged"`` when overlap is
      provided by fronting it with a volatile staging tier.
    - ``max_block_failures`` — largest set of concurrently failed
      blocks a fetch can serve; ``None`` means unbounded (any number
      of compute blocks may fail simultaneously).
    - ``max_storage_failures`` — how many persistence-service (PRD /
      pool / storage) node losses committed data remains fetchable
      through: 0 for the base architectures, ``N-1`` for an N-way
      mirror, 1 for a K+parity erasure stripe.  Must agree with
      ``survives_prd_loss`` (which is this field viewed as a boolean);
      the campaign planner (:func:`repro.solvers.driver.plan_campaign`)
      budgets ``FailureEvent(prd=True)`` events against it.
    """

    durability: str
    survives_node_loss: bool
    survives_prd_loss: bool
    overlap: str
    max_block_failures: Optional[int] = None
    max_storage_failures: int = 0

    def __post_init__(self):
        if self.overlap not in (OVERLAP_NATIVE, OVERLAP_DRIVER_STAGED):
            raise ValueError(
                f"overlap must be {OVERLAP_NATIVE!r} or "
                f"{OVERLAP_DRIVER_STAGED!r}, got {self.overlap!r}")
        if not self.durability:
            raise ValueError("durability tier must be a non-empty string")
        if not isinstance(self.max_storage_failures, int) \
                or self.max_storage_failures < 0:
            raise ValueError(
                f"max_storage_failures must be an int >= 0, got "
                f"{self.max_storage_failures!r}")
        if self.survives_prd_loss != (self.max_storage_failures > 0):
            raise ValueError(
                f"incoherent capabilities: survives_prd_loss="
                f"{self.survives_prd_loss} but max_storage_failures="
                f"{self.max_storage_failures}; a backend survives PRD "
                f"loss exactly when it tolerates >= 1 storage failure")

    def max_shard_failures(self, blocks_per_shard: int) -> Optional[int]:
        """The shard-axis view of ``max_block_failures``: how many
        whole device shards (of ``blocks_per_shard`` contiguous blocks
        each, DESIGN.md §10) a fetch can serve concurrently.  ``None``
        passes through from an unbounded block budget; otherwise the
        declared block budget is divided — killing a shard kills every
        block it owns, so a backend that serves ``B`` block failures
        serves exactly ``B // blocks_per_shard`` shard failures."""
        if blocks_per_shard < 1:
            raise ValueError(
                f"blocks_per_shard must be >= 1, got {blocks_per_shard}")
        if self.max_block_failures is None:
            return None
        return self.max_block_failures // blocks_per_shard


class PersistSession(abc.ABC):
    """One solve's persistence stream on an open backend.

    The driver speaks only this interface; costs are modeled seconds
    (the simulation contract of ``nvm/store.py``).  Lifecycle::

        session = backend.open_session(schema)
        session.persist(k, scalars, vectors)      # sync write-through
        session.begin(...); session.commit()      # overlapped pipeline
        session.fail(blocks); session.drain()     # failure + barrier
        sets = session.fetch(failed_blocks, ks)   # recovery reads
    """

    def __init__(self, schema: RecoverySchema):
        self.schema = schema
        self._storage_down = False
        self._trace = None
        self.traffic = SessionTraffic()
        self._shard_of_block: Optional[Dict[int, int]] = None
        self._slot_nbytes: Optional[int] = None

    # -- per-shard addressing (DESIGN.md §10) ---------------------------
    def bind_shards(self, shard_of_block: Optional[Mapping[int, int]] = None,
                    slot_nbytes: Optional[int] = None) -> None:
        """Bind the block -> owning-device-shard map (and the per-block
        slot payload size) so the session can address and meter traffic
        per shard.  The driver calls this once per solve with the
        operator's :class:`~repro.distributed.sharding.ShardLayout` map
        (all blocks -> shard 0 when the solve is unsharded); composite
        sessions propagate the *map* to their children like
        :meth:`set_tracer`, but only the driver-bound top session gets
        ``slot_nbytes`` — metering happens once, at the driver boundary."""
        if shard_of_block is not None:
            self._shard_of_block = {int(b): int(s)
                                    for b, s in shard_of_block.items()}
        if slot_nbytes is not None:
            self._slot_nbytes = int(slot_nbytes)

    def _note_persist_traffic(self) -> None:
        """Meter one persisted event: every block's slot chunk leaves its
        owning shard.  No-op until the driver binds both the shard map
        and the slot size."""
        if self._slot_nbytes is None or self._shard_of_block is None:
            return
        for shard in self._shard_of_block.values():
            self.traffic.note_persist(shard, self._slot_nbytes)

    def _note_fetch_traffic(self, blocks: Sequence[int], nruns: int) -> None:
        """Meter one served recovery fetch: only the failed blocks' slot
        chunks move, ``nruns`` (= ``schema.history``) slots per block —
        the recovery-traffic-proportional-to-the-lost-shard claim."""
        if self._slot_nbytes is None or self._shard_of_block is None:
            return
        for blk in blocks:
            self.traffic.note_fetch(self._shard_of_block.get(int(blk), 0),
                                    nruns * self._slot_nbytes)

    # -- observability (DESIGN.md §9) -----------------------------------
    def set_tracer(self, tracer) -> None:
        """Attach a ``repro.obs`` tracer (detach with None or any falsy
        tracer).  The driver calls this once per solve when tracing is
        enabled; composite sessions propagate it to their children, so
        one call instruments the whole storage tree.  Sessions guard
        every record site with ``if self._trace is not None`` — with no
        tracer attached the session runs zero tracer callables."""
        self._trace = tracer or None

    # -- fused persist staging (DESIGN.md §13) --------------------------
    def set_encode_mode(self, mode: str) -> None:
        """Select the parity-encode route for this session's stripe
        writes: ``"ref"`` (numpy, the default), ``"pallas"`` (the fused
        GF(256) kernel through :func:`repro.kernels.ops.rs_encode`) or
        ``"auto"``.  Only stripe sessions encode anything, so the base
        is a no-op; composite sessions propagate to their children like
        :meth:`set_tracer`, so the driver's one call (made when
        ``SolveConfig.fused_persist`` is set) reaches every stripe in
        the storage tree.  The emitted bytes are identical either way —
        this toggles *where* the encode runs, never *what* it writes."""

    # -- overlapped pipeline (DESIGN.md §6) -----------------------------
    @abc.abstractmethod
    def begin(self, k: int, scalars: Mapping[str, float],
              vectors: Mapping[str, np.ndarray]) -> float:
        """Stage a persistence event; returns the critical-path cost."""

    @abc.abstractmethod
    def commit(self) -> float:
        """Flush the oldest staged event; returns the overlappable cost."""

    @abc.abstractmethod
    def drain(self) -> float:
        """Barrier: commit everything staged and settle in-flight epochs
        so every committed event is durable."""

    @abc.abstractmethod
    def abort(self) -> None:
        """Discard staged-but-uncommitted events (they died with their
        origin nodes; an aborted event must never surface later)."""

    # -- synchronous path ----------------------------------------------
    @abc.abstractmethod
    def persist(self, k: int, scalars: Mapping[str, float],
                vectors: Mapping[str, np.ndarray]) -> float:
        """Write one event straight through (the paper's host-pull
        baseline); the whole cost is on the critical path."""

    # -- failure + recovery --------------------------------------------
    @abc.abstractmethod
    def fail(self, blocks: Sequence[int]) -> None:
        """Compute blocks crashed: tear away their in-flight writes and
        whatever recovery copies lived in their volatile memory."""

    def fail_storage(self) -> None:
        """The persistence-service node itself crashed (the ROADMAP's
        'campaign event that kills the PRD node').  The base behavior is
        honest non-survival: committed data becomes unreachable and a
        later :meth:`fetch` raises :class:`UnrecoverableFailure` instead
        of serving data that no longer exists.  Redundant composites
        override this to absorb the loss."""
        self._storage_down = True
        self.abort()

    @abc.abstractmethod
    def fetch(self, failed_blocks: Sequence[int],
              ks: Sequence[int]) -> List[RecoverySet]:
        """Read the recovery sets for iterations ``ks`` over the failed
        union (vectors concatenated in ``failed_blocks`` order).  Must
        raise :class:`UnrecoverableFailure` — never return stale or
        partial data — when the request cannot be served exactly."""

    @abc.abstractmethod
    def durable_run(self) -> Optional[int]:
        """Newest iteration ending a durable consecutive
        ``schema.history``-run, or None before the first complete run."""

    # -- guards ---------------------------------------------------------
    def _check_storage(self) -> None:
        if self._storage_down:
            raise UnrecoverableFailure(
                "persistence-service (PRD) node was lost and this backend "
                "does not declare survives_prd_loss; recovery data is "
                "unreachable — compose a ReplicatedBackend for PRD "
                "redundancy")


class PersistenceBackend(abc.ABC):
    """A persistence backend: declared capabilities + session factory.

    Concrete backends also keep whatever storage-level surface they
    need (pools, PRD node, accounting); the driver only ever touches
    the session returned by :meth:`open_session`.
    """

    #: registry name ("esr", "nvm-prd", "replicated", ...)
    name: str = ""

    @property
    @abc.abstractmethod
    def capabilities(self) -> BackendCapabilities:
        """The declared guarantee record (instance-level: e.g. the
        in-memory backend's failure tolerance depends on ``copies``)."""

    @abc.abstractmethod
    def open_session(self, schema: Optional[RecoverySchema] = None,
                     partition=None) -> PersistSession:
        """Open the per-solve lifecycle.  ``schema`` (when given) must
        match the schema the backend was sized for; ``partition`` is
        accepted for future unbound backends and validated when the
        backend knows its own geometry."""

    # -- accounting (paper Fig. 2/8 benchmarks) -------------------------
    def memory_overhead_values(self) -> int:
        """Redundancy values resident in volatile RAM."""
        return 0

    def nvm_values(self) -> int:
        """Values resident on persistent tiers."""
        return 0


def _validate_schema(backend, schema: Optional[RecoverySchema]):
    bound = getattr(backend, "schema", None)
    if schema is not None and bound is not None and bound != schema:
        raise ValueError(
            f"backend persists schema {bound.solver!r} but the session "
            f"was opened for {schema.solver!r}; construct the backend "
            f"with the solver's schema (see repro.solvers.registry."
            f"make_backend)")
    if schema is None and bound is None:
        raise ValueError("open_session needs a schema for an unbound backend")
    return bound if schema is None else schema


class SchemaDrivenBackend(PersistenceBackend):
    """Shared base for the schema-driven storage backends (the three
    core architectures): session opening with schema/partition
    validation, and the stager-abort hook sessions use on storage loss.
    Concrete classes declare their own :class:`BackendCapabilities`."""

    nblocks: int

    def open_session(self, schema: Optional[RecoverySchema] = None,
                     partition=None) -> "CoreBackendSession":
        schema = _validate_schema(self, schema)
        if (partition is not None
                and getattr(partition, "nblocks", self.nblocks) != self.nblocks):
            raise ValueError(
                f"backend sized for {self.nblocks} blocks but the "
                f"partition has {partition.nblocks}")
        return CoreBackendSession(self, schema)

    def persist_abort(self) -> None:
        """Abort staged-but-uncommitted payloads (storage-loss teardown;
        ``fail()`` also aborts as part of the failure model)."""
        self._stager.abort()


def warn_legacy_call(obj, api: str) -> None:
    """DeprecationWarning for the pre-zoo PCG-only entry points."""
    warnings.warn(
        f"{type(obj).__name__}.{api}() is the deprecated PCG-only API; "
        f"use persist_set/recover_set or a PersistSession "
        f"(repro.nvm.backend)",
        DeprecationWarning, stacklevel=3)


# ----------------------------------------------------------------------
# The RAM staging front: a volatile tier that buys overlap for any
# backend whose own pipeline is synchronous.  This is the component the
# old driver-side staging path (and the `_LegacyBackendAdapter`) turned
# into; `TieredBackend` is its first-class composition.
# ----------------------------------------------------------------------
class RAMFront:
    """Double-buffered volatile staging buffer with tier-modeled cost."""

    def __init__(self, flush: Callable[..., float], tier: Tier = Tier.DRAM,
                 cost_model: Optional[CostModel] = None):
        self.tier = tier
        self._stager = PersistStager(flush, cost_model=cost_model)
        # PersistStager models its staging copy as a DRAM write; other
        # front tiers scale by the tier's write cost ratio on commit-path
        # accounting (kept simple: DRAM is the only front used today).
        if tier is not Tier.DRAM:
            raise ValueError("only a DRAM front is calibrated; see §7")

    @property
    def pending(self) -> int:
        return self._stager.pending

    def begin(self, k, scalars, vectors) -> float:
        return self._stager.begin(k, scalars, vectors)

    def commit(self) -> float:
        return self._stager.commit()

    def drain(self) -> float:
        return self._stager.drain()

    def abort(self) -> int:
        return self._stager.abort()


# ----------------------------------------------------------------------
# Sessions over the schema-driven core backends (InMemoryESR,
# NVMESRHomogeneous, NVMESRPRD — and any external object speaking
# persist_set/recover_set/fail).
# ----------------------------------------------------------------------
class CoreBackendSession(PersistSession):
    """Session over a schema-driven backend.

    Backends with a native ``persist_begin/commit/drain`` pipeline are
    delegated to directly; backends exposing only ``persist_set`` are
    fronted by a :class:`RAMFront`, which is exactly the overlap
    behavior the driver used to hand-roll for them.
    """

    def __init__(self, backend, schema: RecoverySchema):
        super().__init__(schema)
        self._backend = backend
        self._native = hasattr(backend, "persist_begin")
        self._front = None if self._native else RAMFront(backend.persist_set)

    def set_tracer(self, tracer) -> None:
        super().set_tracer(tracer)
        # stage/drain attribution comes from the stager itself — the
        # driver-side front's, or the native backend's internal one
        if self._front is not None:
            self._front._stager.tracer = self._trace
        stager = getattr(self._backend, "_stager", None)
        if stager is not None:
            stager.tracer = self._trace

    # -- pipeline -------------------------------------------------------
    def begin(self, k, scalars, vectors) -> float:
        if self._storage_down:
            return 0.0  # the put target is gone; the event is lost
        self._note_persist_traffic()
        if self._native:
            return self._backend.persist_begin(k, scalars, vectors)
        return self._front.begin(k, scalars, vectors)

    def commit(self) -> float:
        if self._storage_down:
            self.abort()
            return 0.0
        if self._native:
            return self._backend.persist_commit()
        return self._front.commit()

    def drain(self) -> float:
        if self._storage_down:
            self.abort()
            return 0.0
        if self._native:
            return self._backend.persist_drain()
        return self._front.drain()

    def abort(self) -> None:
        if self._native:
            # core backends abort their stager inside fail(); expose it
            # directly where available for storage-loss teardown
            aborter = getattr(self._backend, "persist_abort", None)
            if aborter is not None:
                aborter()
        else:
            self._front.abort()

    # -- sync path ------------------------------------------------------
    def persist(self, k, scalars, vectors) -> float:
        if self._storage_down:
            return 0.0
        self._note_persist_traffic()
        cost = self._backend.persist_set(k, scalars, vectors)
        if self._trace is not None:
            self._trace.event("backend.write", k=k, cost_s=cost,
                              backend=type(self._backend).__name__)
        return cost

    # -- failure + recovery ---------------------------------------------
    def fail(self, blocks: Sequence[int]) -> None:
        self._backend.fail(tuple(blocks))
        if not self._native:
            self._front.abort()

    def fail_storage(self) -> None:
        super().fail_storage()
        crash = getattr(self._backend, "storage_crash", None)
        if crash is not None:
            crash()

    def fetch(self, failed_blocks, ks) -> List[RecoverySet]:
        self._check_storage()
        sets = self._backend.recover_set(tuple(failed_blocks), tuple(ks))
        self._note_fetch_traffic(failed_blocks, len(ks))
        return sets

    def durable_run(self) -> Optional[int]:
        if self._storage_down:
            return None
        runner = getattr(self._backend, "durable_run", None)
        return None if runner is None else runner()


class LegacyBackendSession(PersistSession):
    """Session over a pre-zoo duck-typed backend (``persist(k, beta,
    p_full)`` / ``recover(blocks, k)``, PCG payloads only).

    Replaces the old ``driver._LegacyBackendAdapter``: overlap comes
    from the :class:`RAMFront` tier, and the untrusted external
    ``recover`` contract is still refused loudly on a stale pair.
    """

    def __init__(self, backend, schema: RecoverySchema):
        from repro.core.state import require_pcg_schema

        try:
            require_pcg_schema(schema, "persist/recover")
        except TypeError as e:
            raise ValueError(
                f"backend {type(backend).__name__} implements only the "
                f"legacy API: {e}") from None
        super().__init__(schema)
        self._backend = backend
        self._front = RAMFront(self._flush)

    def set_tracer(self, tracer) -> None:
        super().set_tracer(tracer)
        self._front._stager.tracer = self._trace

    def _flush(self, k, scalars, vectors) -> float:
        return self._backend.persist(k, scalars["beta"], vectors["p"])

    def begin(self, k, scalars, vectors) -> float:
        if self._storage_down:
            return 0.0  # the flush target is gone; the event is lost
        self._note_persist_traffic()
        return self._front.begin(k, scalars, vectors)

    def commit(self) -> float:
        if self._storage_down:
            self.abort()
            return 0.0
        return self._front.commit()

    def drain(self) -> float:
        if self._storage_down:
            self.abort()
            return 0.0
        return self._front.drain()

    def abort(self) -> None:
        self._front.abort()

    def persist(self, k, scalars, vectors) -> float:
        if self._storage_down:
            return 0.0
        self._note_persist_traffic()
        return self._flush(k, scalars, vectors)

    def fail(self, blocks: Sequence[int]) -> None:
        self._front.abort()
        self._backend.fail(tuple(blocks))

    def fetch(self, failed_blocks, ks) -> List[RecoverySet]:
        from repro.core.state import RecoverySet

        self._check_storage()
        prev, cur = self._backend.recover(tuple(failed_blocks), ks[-1])
        if (prev.k, cur.k) != (ks[0], ks[-1]):
            # external, untrusted contract: refuse loudly rather than
            # reconstruct from a stale pair
            raise RuntimeError(
                f"legacy backend {type(self._backend).__name__}.recover "
                f"returned iterations {(prev.k, cur.k)}, wanted {tuple(ks)}")
        self._note_fetch_traffic(failed_blocks, len(ks))
        return [RecoverySet(prev.k, {"beta": prev.beta}, {"p": prev.p}),
                RecoverySet(cur.k, {"beta": cur.beta}, {"p": cur.p})]

    def durable_run(self) -> Optional[int]:
        return None


def open_persist_session(backend, schema: RecoverySchema,
                         partition=None) -> PersistSession:
    """Normalize any backend object into a :class:`PersistSession`.

    - a :class:`PersistenceBackend` opens its own session;
    - a schema-duck-typed object (``persist_set``/``recover_set``) is
      wrapped in a :class:`CoreBackendSession`;
    - a pre-zoo duck-typed object (``persist``/``recover``) routes
      through :class:`LegacyBackendSession` with a
      :class:`DeprecationWarning`.
    """
    if isinstance(backend, PersistenceBackend) or hasattr(backend, "open_session"):
        return backend.open_session(schema, partition)
    if hasattr(backend, "persist_set"):
        return CoreBackendSession(backend, _validate_schema(backend, schema))
    if hasattr(backend, "persist"):
        warnings.warn(
            f"duck-typed legacy backend {type(backend).__name__} "
            f"(persist/recover, PCG payloads only) is deprecated; "
            f"implement repro.nvm.backend.PersistenceBackend",
            DeprecationWarning, stacklevel=3)
        return LegacyBackendSession(backend, schema)
    raise TypeError(
        f"{type(backend).__name__} is not a persistence backend: expected "
        f"a PersistenceBackend, a persist_set/recover_set object, or a "
        f"legacy persist/recover object")


# ----------------------------------------------------------------------
# Composite backends
# ----------------------------------------------------------------------
def _join_tiers(children) -> str:
    tiers = []
    for c in children:
        t = c.capabilities.durability
        if t not in tiers:
            tiers.append(t)
    return "+".join(tiers)


class ReplicatedSession(PersistSession):
    """Mirror every event to all live children; fetch by quorum.

    Quorum rule (DESIGN.md §7): mirrors are written in lockstep, every
    slot is content-addressed (``k``) and CRC-validated by the child,
    so **any single mirror that serves the complete requested run is
    authoritative**.  A mirror whose storage died, or that cannot
    produce the full run, is skipped; only when *no* mirror can serve
    the run does the fetch raise :class:`UnrecoverableFailure`.
    """

    def __init__(self, backend: "ReplicatedBackend", schema, partition):
        super().__init__(schema)
        self._backend = backend
        self._children = [open_persist_session(c, schema, partition)
                          for c in backend.children]

    def set_tracer(self, tracer) -> None:
        super().set_tracer(tracer)
        for s in self._children:
            s.set_tracer(tracer)

    def set_encode_mode(self, mode: str) -> None:
        for s in self._children:
            s.set_encode_mode(mode)

    def bind_shards(self, shard_of_block=None, slot_nbytes=None) -> None:
        # children get the addressing map but not the meter (slot size):
        # replicated traffic is counted once at the top of the tree
        super().bind_shards(shard_of_block, slot_nbytes)
        for s in self._children:
            s.bind_shards(shard_of_block=shard_of_block)

    def _live(self) -> List[PersistSession]:
        return [s for s in self._children if not s._storage_down]

    # Mirror puts leave the same origin NIC back to back, so the
    # origin-visible cost of a replicated event is the SUM over mirrors
    # (the mirroring overhead the benchmarks report), while staging is
    # still a single local copy per child pipeline.
    def begin(self, k, scalars, vectors) -> float:
        if self._live():
            self._note_persist_traffic()
        return sum(s.begin(k, scalars, vectors) for s in self._live())

    def commit(self) -> float:
        if self._trace is None:
            return sum(s.commit() for s in self._live())
        cost = 0.0
        for i, s in enumerate(self._children):
            if s._storage_down:
                continue
            c = s.commit()
            self._trace.event("mirror.commit", mirror=i, cost_s=c)
            cost += c
        return cost

    def drain(self) -> float:
        return sum(s.drain() for s in self._live())

    def abort(self) -> None:
        for s in self._children:
            s.abort()

    def persist(self, k, scalars, vectors) -> float:
        if self._live():
            self._note_persist_traffic()
        return sum(s.persist(k, scalars, vectors) for s in self._live())

    def fail(self, blocks: Sequence[int]) -> None:
        for s in self._children:
            s.fail(blocks)

    def fail_storage(self) -> None:
        """One mirror's storage node crashes (mirrors die in order:
        the first storage-loss event takes mirror 0, the next mirror 1,
        ...).  The composite itself stays up while any mirror lives."""
        for s in self._children:
            if not s._storage_down:
                s.fail_storage()
                break
        if not self._live():
            self._storage_down = True

    def fetch(self, failed_blocks, ks) -> List[RecoverySet]:
        errors = []
        for i, s in enumerate(self._children):
            if s._storage_down:
                errors.append(f"mirror {i}: storage lost")
                continue
            try:
                sets = s.fetch(failed_blocks, ks)
            except (UnrecoverableFailure, RuntimeError) as e:
                errors.append(f"mirror {i}: {e}")
                if self._trace is not None:
                    self._trace.event("mirror.fetch", mirror=i, served=False,
                                      skipped=len(errors) - 1)
                continue
            if self._trace is not None:
                self._trace.event("mirror.fetch", mirror=i, served=True,
                                  skipped=len(errors))
            # quorum semantics: ONE mirror served the whole request, so
            # the recovery moved exactly one copy of the lost slots
            self._note_fetch_traffic(failed_blocks, len(ks))
            return sets
        raise UnrecoverableFailure(
            f"no mirror of {len(self._children)} can serve iterations "
            f"{tuple(ks)} for blocks {tuple(failed_blocks)}: "
            + "; ".join(errors))

    def durable_run(self) -> Optional[int]:
        runs = [s.durable_run() for s in self._live()]
        runs = [r for r in runs if r is not None]
        return max(runs) if runs else None


class ReplicatedBackend(PersistenceBackend):
    """RAID-1-style mirroring across N child backends.

    In particular ``ReplicatedBackend`` over two ``nvm-prd`` children
    realizes the ROADMAP's "RAID-style PRD redundancy": two PRD nodes,
    each receiving every persistence epoch, so a campaign event that
    crashes one PRD node is absorbed and recovery proceeds from the
    surviving mirror — exactly.
    """

    name = "replicated"

    def __init__(self, children: Sequence[PersistenceBackend]):
        if len(children) < 2:
            raise ValueError(
                f"replication needs >= 2 children, got {len(children)} — "
                f"a single child adds cost without redundancy")
        schemas = {getattr(c, "schema", None) for c in children}
        if len(schemas) != 1:
            raise ValueError("all mirrors must persist the same schema")
        self.children = list(children)
        self.schema = self.children[0].schema

    @property
    def capabilities(self) -> BackendCapabilities:
        caps = [c.capabilities for c in self.children]
        maxes = [c.max_block_failures for c in caps]
        return BackendCapabilities(
            durability=_join_tiers(self.children),
            survives_node_loss=all(c.survives_node_loss for c in caps),
            # the defining property: one full mirror may die
            survives_prd_loss=True,
            overlap=(OVERLAP_NATIVE
                     if all(c.overlap == OVERLAP_NATIVE for c in caps)
                     else OVERLAP_DRIVER_STAGED),
            max_block_failures=(None if all(m is None for m in maxes)
                                else min(m for m in maxes if m is not None)),
            # every mirror may absorb its own tolerance and then die;
            # only the last surviving mirror must stay reachable
            max_storage_failures=(
                sum(c.max_storage_failures + 1 for c in caps) - 1),
        )

    def open_session(self, schema=None, partition=None) -> PersistSession:
        return ReplicatedSession(self, _validate_schema(self, schema),
                                 partition)

    def memory_overhead_values(self) -> int:
        return sum(c.memory_overhead_values() for c in self.children)

    def nvm_values(self) -> int:
        return sum(c.nvm_values() for c in self.children)


class TieredSession(PersistSession):
    """RAM-front staging into a single child session."""

    def __init__(self, backend: "TieredBackend", schema, partition):
        super().__init__(schema)
        self._child = open_persist_session(backend.child, schema, partition)
        self._front = RAMFront(self._child.persist, tier=backend.front_tier)

    def set_tracer(self, tracer) -> None:
        super().set_tracer(tracer)
        self._front._stager.tracer = self._trace
        self._child.set_tracer(tracer)

    def set_encode_mode(self, mode: str) -> None:
        self._child.set_encode_mode(mode)

    def bind_shards(self, shard_of_block=None, slot_nbytes=None) -> None:
        super().bind_shards(shard_of_block, slot_nbytes)
        self._child.bind_shards(shard_of_block=shard_of_block)

    def begin(self, k, scalars, vectors) -> float:
        self._note_persist_traffic()
        return self._front.begin(k, scalars, vectors)

    def commit(self) -> float:
        return self._front.commit()

    def drain(self) -> float:
        return self._front.drain() + self._child.drain()

    def abort(self) -> None:
        self._front.abort()
        self._child.abort()

    def persist(self, k, scalars, vectors) -> float:
        self._note_persist_traffic()
        return self._child.persist(k, scalars, vectors)

    def fail(self, blocks: Sequence[int]) -> None:
        self._front.abort()  # the staged front is volatile — it dies
        self._child.fail(blocks)

    def fail_storage(self) -> None:
        self._front.abort()
        self._child.fail_storage()
        self._storage_down = self._child._storage_down

    def fetch(self, failed_blocks, ks) -> List[RecoverySet]:
        sets = self._child.fetch(failed_blocks, ks)
        self._note_fetch_traffic(failed_blocks, len(ks))
        return sets

    def durable_run(self) -> Optional[int]:
        return self._child.durable_run()


class TieredBackend(PersistenceBackend):
    """A volatile RAM front staging into any child backend.

    The front gives *every* child an overlapped ``begin/commit``
    pipeline (capability ``overlap="native"`` from the driver's point
    of view) while durability, node-loss and PRD-loss guarantees remain
    the child's.  This is the first-class form of the staging path the
    driver used to improvise for non-pipelined backends.
    """

    name = "tiered"

    def __init__(self, child: PersistenceBackend,
                 front_tier: Tier = Tier.DRAM):
        if front_tier is not Tier.DRAM:
            # fail at composition time, not mid-solve in open_session
            raise ValueError("only a DRAM front is calibrated; see §7")
        self.child = child
        self.front_tier = front_tier
        self.schema = getattr(child, "schema", None)

    @property
    def capabilities(self) -> BackendCapabilities:
        c = self.child.capabilities
        return BackendCapabilities(
            durability=c.durability,
            survives_node_loss=c.survives_node_loss,
            survives_prd_loss=c.survives_prd_loss,
            overlap=OVERLAP_NATIVE,
            max_block_failures=c.max_block_failures,
            max_storage_failures=c.max_storage_failures,
        )

    def open_session(self, schema=None, partition=None) -> PersistSession:
        return TieredSession(self, _validate_schema(self, schema), partition)

    def memory_overhead_values(self) -> int:
        return self.child.memory_overhead_values()

    def nvm_values(self) -> int:
        return self.child.nvm_values()


# ----------------------------------------------------------------------
# Erasure-coded composition (RAID-5/6-style rotating parity, DESIGN.md §8)
# ----------------------------------------------------------------------
#: reserved scalar every stripe child persists alongside the solver's
#: scalars: the stripe's parity-rotation offset, recorded durably so a
#: degraded fetch can undo the rotation from any surviving child.
STRIPE_ROT_SCALAR = "_stripe_rot"

#: legal parity-encode routes for the stripe write path (DESIGN.md §13)
ENCODE_MODES = frozenset({"ref", "pallas", "auto"})


def stripe_child_schema(schema):
    """The schema stripe children are bound to: the solver's schema plus
    the reserved :data:`STRIPE_ROT_SCALAR` rotation scalar (appended
    last, so the wire layout of the solver's own fields is unchanged).
    Idempotent — a schema already carrying the scalar passes through."""
    scalars = tuple(schema.scalars)
    if scalars and scalars[-1] == STRIPE_ROT_SCALAR:
        return schema
    if STRIPE_ROT_SCALAR in scalars:
        raise ValueError(
            f"schema {schema.solver!r} already uses the reserved scalar "
            f"{STRIPE_ROT_SCALAR!r} in a non-final position")
    return dataclasses.replace(schema, scalars=scalars + (STRIPE_ROT_SCALAR,))


class ErasureSession(PersistSession):
    """Stripe every event across K data shards + P parity shards
    (P ∈ {1, 2}) spread over K+P children with **rotating placement**.

    Write path: each slot vector is split block-wise into K equal chunks
    (zero-padded when K does not divide the block size); the P parity
    shards are Reed-Solomon combinations of the K chunks computed on
    the *stored bytes* (:mod:`repro.nvm.gf256`; P=1 degenerates to the
    old XOR parity).  Shard-to-child placement rotates per stripe
    (RAID-5/6 proper): for stripe sequence number ``s`` the rotation
    offset ``r = (P·s) mod (K+P)`` maps logical shard ``j`` onto
    physical child ``(j + r) mod (K+P)``, so parity writes round-robin
    and no child is a write hot-spot.  ``r`` is recorded durably in
    every child's slot (the :data:`STRIPE_ROT_SCALAR` scalar of the
    stripe schema) — it is stripe *metadata*, not re-derived at read
    time.  Chunks and parity are computed from the same staged payload
    and handed to the children in one lockstep ``begin`` (committed in
    one lockstep ``commit``), so a failure between driver calls can
    never leave a stripe whose parity disagrees with its data.  The
    solver's scalars are tiny and ride replicated in every child.

    Read path: ``fetch`` reads every live child, recovers the recorded
    rotation from any surviving slot, un-rotates the shards, and — in
    **degraded mode**, with up to P children lost — reconstructs the
    missing data chunks through the surviving parity
    (:func:`repro.nvm.gf256.rs_reconstruct`), bit-exactly.  More than P
    lost children exceed the code's distance and raise
    :class:`UnrecoverableFailure` with a per-child diagnosis.

    Degraded *writes* keep working too: shards are computed from the
    full payload the session holds, so events persisted after a child
    loss remain exactly reconstructible while losses stay within P.
    """

    def __init__(self, backend: "ErasureCodedBackend", schema, partition):
        super().__init__(schema)
        self._backend = backend
        self._children = [open_persist_session(c, backend.child_schema, None)
                          for c in backend.children]
        self._stripe_seq = 0
        #: parity-encode route (DESIGN.md §13): "ref" = numpy reference,
        #: "pallas" = the fused GF(256) kernel, "auto" = per-platform;
        #: seeded from the backend, switchable per solve by the driver
        self._encode_mode = backend.encode_mode
        #: per-child count of parity-shard writes (the hot-spot metric:
        #: rotation keeps max-min <= 1 over any write sequence)
        self.parity_writes = [0] * len(self._children)

    def set_tracer(self, tracer) -> None:
        super().set_tracer(tracer)
        for s in self._children:
            s.set_tracer(tracer)

    def set_encode_mode(self, mode: str) -> None:
        if mode not in ENCODE_MODES:
            raise ValueError(
                f"unknown parity encode mode {mode!r}; expected one of "
                f"{sorted(ENCODE_MODES)}")
        self._encode_mode = mode
        for s in self._children:  # nested stripes follow the same route
            s.set_encode_mode(mode)

    def bind_shards(self, shard_of_block=None, slot_nbytes=None) -> None:
        super().bind_shards(shard_of_block, slot_nbytes)
        for s in self._children:
            s.bind_shards(shard_of_block=shard_of_block)

    # -- stripe geometry ------------------------------------------------
    def _rotation(self) -> int:
        """Allocate the next stripe's rotation offset.  Stepping by P
        (not 1) tiles the parity role over the children so per-child
        parity-write counts never differ by more than one stripe, even
        mid-cycle and for odd K+P."""
        be = self._backend
        r = (be.nparity * self._stripe_seq) % len(self._children)
        self._stripe_seq += 1
        return r

    def _shards(self, vectors) -> List[Dict[str, np.ndarray]]:
        """Split full vectors into K logical chunk vectors + P parity
        shards.  Chunking happens on the *stored* dtype so the parity
        covers exactly the bits the data children persist."""
        be = self._backend
        k_data, nb, bs, chunk = be.k_data, be.nblocks, be.block_size, be.chunk
        nshards = k_data + be.nparity
        out: List[Dict[str, np.ndarray]] = [dict() for _ in range(nshards)]
        for name in self.schema.vectors:
            v = np.asarray(vectors[name], be.dtype).reshape(nb, bs)
            padded = np.zeros((nb, k_data * chunk), be.dtype)
            padded[:, :bs] = v
            chunks = [np.ascontiguousarray(padded[:, j * chunk:(j + 1) * chunk]
                                           ).reshape(-1)
                      for j in range(k_data)]
            # Every non-"ref" encode routes through the registered
            # toggle (repro.kernels.ops.rs_encode — lint rule RL204),
            # imported lazily: repro.nvm must import without the
            # kernels package (ops pulls in jax), so the default "ref"
            # route stays numpy-only end to end.
            if self._encode_mode == "ref":
                def encode(shards):
                    return gf256.rs_encode(shards, be.nparity)
            else:
                from repro.kernels.ops import rs_encode

                def encode(shards):
                    return rs_encode(shards, be.nparity,
                                     mode=self._encode_mode)
            if self._trace is None:
                parity = encode([c.view(np.uint8) for c in chunks])
            else:
                with self._trace.span("gf256.rs_encode", vector=name,
                                      k_data=k_data, nparity=be.nparity,
                                      encoder=self._encode_mode):
                    parity = encode([c.view(np.uint8) for c in chunks])
            for j in range(k_data):
                out[j][name] = chunks[j]
            for i in range(be.nparity):
                out[k_data + i][name] = parity[i].view(be.dtype)
        return out

    def _live(self) -> List[PersistSession]:
        return [s for s in self._children if not s._storage_down]

    def _fan_out(self, method: str, k, scalars, vectors) -> float:
        """One lockstep stripe write (begin or persist): data chunks and
        parity leave the same origin NIC back to back, so the modeled
        origin-visible cost is the sum over children — each carrying
        ~1/K of the payload bytes."""
        be = self._backend
        shards = self._shards(vectors)
        rot = self._rotation()
        scalars = dict(scalars)
        scalars[STRIPE_ROT_SCALAR] = float(rot)
        nchildren = len(self._children)
        cost = 0.0
        for j in range(nchildren):
            child = (j + rot) % nchildren
            if j >= be.k_data:
                self.parity_writes[child] += 1
            c = getattr(self._children[child], method)(k, scalars, shards[j])
            if self._trace is not None:
                self._trace.event("stripe.write", child=child, shard=j,
                                  parity=j >= be.k_data, rot=rot, cost_s=c)
            cost += c
        return cost

    # -- pipeline -------------------------------------------------------
    def begin(self, k, scalars, vectors) -> float:
        if self._storage_down:
            return 0.0  # the stripe is gone; the event is lost
        self._note_persist_traffic()
        return self._fan_out("begin", k, scalars, vectors)

    def commit(self) -> float:
        return sum(s.commit() for s in self._children)

    def drain(self) -> float:
        return sum(s.drain() for s in self._children)

    def abort(self) -> None:
        for s in self._children:
            s.abort()

    def persist(self, k, scalars, vectors) -> float:
        if self._storage_down:
            return 0.0
        self._note_persist_traffic()
        return self._fan_out("persist", k, scalars, vectors)

    # -- failure + recovery ---------------------------------------------
    def fail(self, blocks: Sequence[int]) -> None:
        for s in self._children:
            s.fail(blocks)

    def fail_storage(self) -> None:
        """One stripe node crashes (ordered, like mirrors: the first
        storage-loss event takes child 0, the next child 1, ...).  The
        stripe serves degraded fetches while at most P children are
        lost."""
        for s in self._children:
            if not s._storage_down:
                s.fail_storage()
                break
        if len(self._live()) < self._backend.k_data:
            self._storage_down = True  # > P losses: beyond the code distance

    def fetch(self, failed_blocks, ks) -> List[RecoverySet]:
        be = self._backend
        nchildren = len(self._children)
        per_child: List[Optional[List[RecoverySet]]] = []
        errors: List[str] = []
        for j, s in enumerate(self._children):
            if s._storage_down:
                per_child.append(None)
                errors.append(f"child {j}: storage lost")
                continue
            try:
                per_child.append(s.fetch(failed_blocks, ks))
            except (UnrecoverableFailure, RuntimeError) as e:
                per_child.append(None)
                errors.append(f"child {j}: {e}")
        missing = [j for j, r in enumerate(per_child) if r is None]
        if missing and len(missing) <= be.nparity and self._trace is not None:
            self._trace.event("stripe.degraded", missing=tuple(missing),
                              nparity=be.nparity)
        if len(missing) > be.nparity:
            raise UnrecoverableFailure(
                f"erasure stripe lost {len(missing)} of {nchildren} "
                f"children — {be.nparity}-parity Reed-Solomon "
                f"reconstructs at most {be.nparity} — for iterations "
                f"{tuple(ks)} over blocks {tuple(failed_blocks)}: "
                + "; ".join(errors))
        sets = [self._assemble(per_child, i, kk, tuple(failed_blocks))
                for i, kk in enumerate(ks)]
        # the K data chunks (or their parity reconstruction) reassemble
        # into exactly one slot copy per failed block per run
        self._note_fetch_traffic(failed_blocks, len(ks))
        return sets

    def _assemble(self, per_child, i: int, kk: int,
                  failed: Tuple[int, ...]) -> RecoverySet:
        """Reassemble one iteration's union set from the stripe shards:
        recover the recorded rotation, un-rotate physical children back
        to logical shard order, and rebuild up to P missing data chunks
        through the surviving parity."""
        from repro.core.state import RecoverySet

        be = self._backend
        k_data, chunk, bs = be.k_data, be.chunk, be.block_size
        nchildren = len(self._children)
        nf = len(failed)
        sets = [None if r is None else r[i] for r in per_child]
        donor = next(s for s in sets if s is not None)
        if any(s is not None and s.k != kk for s in sets):
            raise UnrecoverableFailure(
                f"erasure stripe children disagree on iteration {kk}")
        # The rotation is stripe metadata, persisted in every child's
        # slot — read it back rather than re-deriving it, and insist the
        # survivors agree (a disagreement means mixed stripes).
        rots = {s.scalars[STRIPE_ROT_SCALAR] for s in sets if s is not None}
        if len(rots) != 1:
            raise UnrecoverableFailure(
                f"erasure stripe children disagree on the parity rotation "
                f"of iteration {kk}: {sorted(rots)}")
        rot = int(rots.pop())
        logical = [sets[(j + rot) % nchildren] for j in range(nchildren)]
        vectors = {}
        for name in self.schema.vectors:
            shards = [None if s is None else np.ascontiguousarray(
                          np.asarray(s.vectors[name], be.dtype)
                      ).view(np.uint8)
                      for s in logical]
            try:
                if self._trace is None:
                    data = gf256.rs_reconstruct(shards, k_data)
                else:
                    with self._trace.span("gf256.rs_decode", vector=name,
                                          k=kk, missing=tuple(
                                              j for j, s in enumerate(shards)
                                              if s is None)):
                        data = gf256.rs_reconstruct(shards, k_data)
            except ValueError as e:
                raise UnrecoverableFailure(
                    f"erasure stripe cannot reconstruct iteration {kk}: "
                    f"{e}") from None
            data = [d.view(be.dtype) for d in data]
            stacked = np.stack([d.reshape(nf, chunk) for d in data], axis=1)
            vectors[name] = np.ascontiguousarray(
                stacked.reshape(nf, k_data * chunk)[:, :bs]).reshape(-1)
        scalars = {n: v for n, v in donor.scalars.items()
                   if n != STRIPE_ROT_SCALAR}
        return RecoverySet(kk, scalars, vectors)

    def durable_run(self) -> Optional[int]:
        if self._storage_down:
            return None
        runs = [s.durable_run() for s in self._live()]
        if not runs or any(r is None for r in runs):
            return None
        # live children write in lockstep; min is the conservative join
        return min(runs)


class ErasureCodedBackend(PersistenceBackend):
    """K+P erasure coding (Reed-Solomon over GF(2^8), P ∈ {1, 2}) with
    rotating parity placement over K+P children.

    The footprint counterpart of :class:`ReplicatedBackend`: surviving
    P simultaneous storage-node losses costs a (P+1)x mirror (P+1)x
    storage, but the stripe only (K+P)/K — the paper's memory-footprint
    argument applied to the redundancy layer itself (cf. Pachajoa et
    al. on multi-node-failure PCG and EasyCrash on NVM crash
    consistency).  Spec strings: ``"erasure(nvm-prd x4+p)"`` (4 data +
    1 XOR parity, distance 2) and ``"erasure(nvm-prd x6+2p)"`` (6 data
    + P/Q parity, distance 3, **any two** children may die).

    Children are *roles rotated per stripe* (RAID-5/6), so no child is
    a dedicated parity node; the ``data_children``/``parity_children``
    split only sizes the pool.  All children must be bound to the
    stripe schema (:func:`stripe_child_schema` — the solver's schema
    plus the rotation-metadata scalar); the registry factory does this
    automatically.
    """

    name = "erasure"

    def __init__(self, data_children: Sequence[PersistenceBackend],
                 parity_children, block_size: int, encode: str = "ref"):
        if isinstance(parity_children, PersistenceBackend):
            parity_children = [parity_children]
        if encode not in ENCODE_MODES:
            raise ValueError(
                f"unknown parity encode mode {encode!r}; expected one of "
                f"{sorted(ENCODE_MODES)}")
        #: default parity-encode route sessions inherit (DESIGN.md §13)
        self.encode_mode = encode
        if len(data_children) < 2:
            raise ValueError(
                f"erasure coding needs >= 2 data children, got "
                f"{len(data_children)} — with one data child the parity "
                f"is a mirror; use replicated(...)")
        if not 1 <= len(parity_children) <= gf256.MAX_PARITY:
            raise ValueError(
                f"erasure coding supports 1 (xK+p) or 2 (xK+2p) parity "
                f"children, got {len(parity_children)} — for more "
                f"distance use replicated(...)")
        self.data_children = list(data_children)
        self.parity_children = list(parity_children)
        self.children = self.data_children + self.parity_children
        if len({id(c) for c in self.children}) != len(self.children):
            # An aliased child is one storage node wearing two stripe
            # hats: its second (e.g. parity) write silently lands on the
            # first's slots, and a "survivable" single loss then serves
            # corrupted degraded fetches.  Refuse at composition time.
            raise ValueError(
                "stripe children must be distinct backend instances — "
                "the same object appears twice (pass distinct backends, "
                "or spec strings so the factory builds one per role)")
        schemas = {getattr(c, "schema", None) for c in self.children}
        if len(schemas) != 1:
            raise ValueError("all stripe children must persist the same schema")
        nblocks = {c.nblocks for c in self.children}
        if len(nblocks) != 1:
            raise ValueError("all stripe children must cover the same blocks")
        self.nblocks = nblocks.pop()
        self.k_data = len(self.data_children)
        self.nparity = len(self.parity_children)
        self.block_size = int(block_size)
        self.chunk = -(-self.block_size // self.k_data)  # ceil
        self.dtype = np.dtype(getattr(self.children[0], "dtype", np.float64))
        bad = [c.block_size for c in self.children
               if getattr(c, "block_size", self.chunk) != self.chunk]
        if bad:
            raise ValueError(
                f"stripe children must be sized for chunk {self.chunk} "
                f"(= ceil({self.block_size}/{self.k_data})), got {bad}")
        self.child_schema = self.children[0].schema
        child_scalars = tuple(self.child_schema.scalars)
        if not child_scalars or child_scalars[-1] != STRIPE_ROT_SCALAR:
            raise ValueError(
                f"stripe children must persist the stripe schema — the "
                f"solver's schema plus the trailing {STRIPE_ROT_SCALAR!r} "
                f"rotation scalar; bind them with "
                f"schema=stripe_child_schema(schema), or build the stripe "
                f"through create_backend('erasure(...)') which does so")
        # what the driver sees: the solver's own schema, rotation hidden
        self.schema = dataclasses.replace(self.child_schema,
                                          scalars=child_scalars[:-1])

    @property
    def capabilities(self) -> BackendCapabilities:
        caps = [c.capabilities for c in self.children]
        maxes = [c.max_block_failures for c in caps]
        return BackendCapabilities(
            durability=_join_tiers(self.children),
            survives_node_loss=all(c.survives_node_loss for c in caps),
            # the stripe's guarantee: any P children (whatever role the
            # current rotation gives them) may be lost and every
            # committed event remains exact
            survives_prd_loss=True,
            overlap=(OVERLAP_NATIVE
                     if all(c.overlap == OVERLAP_NATIVE for c in caps)
                     else OVERLAP_DRIVER_STAGED),
            max_block_failures=(None if all(m is None for m in maxes)
                                else min(m for m in maxes if m is not None)),
            max_storage_failures=self.nparity,  # P+Q: distance P+1
        )

    def open_session(self, schema=None, partition=None) -> PersistSession:
        schema = _validate_schema(self, schema)
        if partition is not None:
            if getattr(partition, "nblocks", self.nblocks) != self.nblocks:
                raise ValueError(
                    f"stripe sized for {self.nblocks} blocks but the "
                    f"partition has {partition.nblocks}")
            if getattr(partition, "block_size",
                       self.block_size) != self.block_size:
                raise ValueError(
                    f"stripe sized for block_size {self.block_size} but "
                    f"the partition has {partition.block_size}")
        return ErasureSession(self, schema, partition)

    def memory_overhead_values(self) -> int:
        return sum(c.memory_overhead_values() for c in self.children)

    def nvm_values(self) -> int:
        return sum(c.nvm_values() for c in self.children)


# ----------------------------------------------------------------------
# The single backend registry
# ----------------------------------------------------------------------
# name -> factory(nblocks, block_size, dtype, schema=..., **opts)
_REGISTRY: Dict[str, Callable] = {}
_SPEC_RE = re.compile(r"^(?P<name>[\w.-]+)\s*(?:\((?P<args>[^()]*)\))?$")
_CHILD_RE = re.compile(r"^(?P<child>[\w.-]+)\s*[x×]\s*(?P<n>\d+)$")
_STRIPE_RE = re.compile(
    r"^(?P<child>[\w.-]+)\s*[x×]\s*(?P<n>\d+)\s*\+\s*(?P<p>\d+)?p$")


def register_backend(name: str, factory: Callable) -> None:
    """Register a backend factory under ``name``.  The factory signature
    is ``factory(nblocks, block_size, dtype, schema=..., **opts) ->
    PersistenceBackend``."""
    _REGISTRY[name] = factory


def register_backend_class(name: str, cls) -> None:
    """Register a backend class whose constructor is ``cls(nblocks,
    block_size, dtype, **opts)`` with a ``schema`` keyword defaulting
    internally (``schema=None`` from a composite factory is dropped so
    the class default applies)."""

    def build(nblocks, block_size, dtype, schema=None, **opts):
        if schema is not None:
            opts["schema"] = schema
        return cls(nblocks, block_size, dtype, **opts)

    build.__name__ = f"make_{cls.__name__}"
    register_backend(name, build)


def _ensure_builtin() -> None:
    # The three core backends register themselves at import; import them
    # lazily here to avoid a core <-> nvm module cycle.
    if "esr" not in _REGISTRY:
        import repro.core.esr  # noqa: F401
        import repro.core.nvm_esr  # noqa: F401


def backend_names() -> List[str]:
    """All registered backend names (the composable registry view)."""
    _ensure_builtin()
    return sorted(_REGISTRY)


def unknown_name_error(kind: str, name: str, have) -> KeyError:
    """A registry miss with a did-you-mean hint (closest match)."""
    have = sorted(have)
    msg = f"unknown {kind} {name!r}"
    close = difflib.get_close_matches(str(name), have, n=1, cutoff=0.5)
    if close:
        msg += f" — did you mean {close[0]!r}?"
    return KeyError(f"{msg}; have {have}")


def parse_backend_spec(spec: str) -> Tuple[str, dict]:
    """Parse a composable backend spec string into ``(name, opts)``.

    Grammar::

        "nvm-prd"                      -> ("nvm-prd", {})
        "replicated(nvm-prd x2)"       -> ("replicated", {"children": ("nvm-prd",)*2})
        "replicated(nvm-prd,nvm-homogeneous)"
        "tiered(nvm-homogeneous)"      -> ("tiered", {"child": "nvm-homogeneous"})
        "erasure(nvm-prd x4+p)"        -> ("erasure", {"data": ("nvm-prd",)*4,
                                                       "nparity": 1})
        "erasure(nvm-prd x6+2p)"       -> ("erasure", {"data": ("nvm-prd",)*6,
                                                       "nparity": 2})
    """
    m = _SPEC_RE.match(spec.strip())
    if m is None:
        raise ValueError(f"malformed backend spec {spec!r}")
    name, args = m.group("name"), m.group("args")
    if args is None:
        return name, {}
    args = args.strip()
    if name == "erasure":
        stripe = _STRIPE_RE.match(args)
        if stripe is None:
            raise ValueError(
                f"malformed erasure spec {spec!r}: expected "
                f"'erasure(<child> xK+Pp)' (K data nodes + P parity, "
                f"P in {{1, 2}}), e.g. 'erasure(nvm-prd x4+p)' or "
                f"'erasure(nvm-prd x6+2p)'")
        return name, {"data": (stripe.group("child"),) * int(stripe.group("n")),
                      "nparity": int(stripe.group("p") or 1)}
    if name == "replicated":
        xn = _CHILD_RE.match(args)
        if xn is not None:
            return name, {"children": (xn.group("child"),) * int(xn.group("n"))}
        return name, {"children": tuple(a.strip() for a in args.split(",") if a.strip())}
    if name == "tiered":
        return name, {"child": args}
    # Parsing is purely syntactic; whether the name exists (and whether
    # it takes arguments) is judged by create_backend, so misspelled
    # composites still get a did-you-mean hint.
    return name, {"spec_args": args}


def create_backend(spec: str, nblocks: int, block_size: int,
                   dtype=np.float64, **opts) -> PersistenceBackend:
    """Build a backend from a registry name or composable spec string.

    This is the single constructor path: ``repro.solvers.registry.
    make_backend`` sizes it from an operator; ``repro.api`` sizes it
    from a :class:`~repro.api.Problem`.
    """
    _ensure_builtin()
    name, spec_opts = parse_backend_spec(spec)
    if name not in _REGISTRY:
        raise unknown_name_error("backend", name, _REGISTRY)
    if "spec_args" in spec_opts:
        raise ValueError(
            f"backend {name!r} takes no spec arguments, got {spec!r}")
    merged = {**spec_opts, **opts}
    return _REGISTRY[name](nblocks, block_size, dtype, **merged)


def _replicated_factory(nblocks, block_size, dtype,
                        children: Sequence = ("nvm-prd", "nvm-prd"),
                        schema=None, **opts) -> ReplicatedBackend:
    built = [c if isinstance(c, PersistenceBackend)
             else create_backend(c, nblocks, block_size, dtype,
                                 schema=schema, **opts)
             for c in children]
    return ReplicatedBackend(built)


def _tiered_factory(nblocks, block_size, dtype, child="nvm-homogeneous",
                    schema=None, **opts) -> TieredBackend:
    built = (child if isinstance(child, PersistenceBackend)
             else create_backend(child, nblocks, block_size, dtype,
                                 schema=schema, **opts))
    return TieredBackend(built)


def _erasure_factory(nblocks, block_size, dtype,
                     data: Sequence = ("nvm-prd",) * 4,
                     parity: Optional[str] = None,
                     nparity: int = 1,
                     schema=None, encode: str = "ref",
                     **opts) -> ErasureCodedBackend:
    """Build the stripe: children are sized for the chunk (1/K of the
    block, zero-padded) and bound to the stripe schema (the solver's
    schema + the rotation scalar), so the stripe's total footprint is
    ~(K+P)/K of a single backend's — the measured storage-overhead
    claim."""
    k_data = len(data)
    if k_data < 2:
        raise ValueError(
            f"erasure coding needs >= 2 data children, got {k_data}")
    if not 1 <= int(nparity) <= gf256.MAX_PARITY:
        raise ValueError(
            f"erasure coding supports 1 (xK+p) or 2 (xK+2p) parity "
            f"children, got nparity={nparity} — for more distance use "
            f"replicated(...)")
    chunk = -(-int(block_size) // k_data)  # ceil
    if schema is None:
        from repro.core.state import PCG_SCHEMA

        schema = PCG_SCHEMA  # the pre-zoo default every factory shares
    child_schema = stripe_child_schema(schema)

    def build(spec):
        if isinstance(spec, PersistenceBackend):
            return spec
        return create_backend(spec, nblocks, chunk, dtype,
                              schema=child_schema, **opts)

    children = [build(c) for c in data]
    parity_spec = parity if parity is not None else data[0]
    parity_children = [build(parity_spec) for _ in range(int(nparity))]
    return ErasureCodedBackend(children, parity_children, block_size,
                               encode=encode)


register_backend("replicated", _replicated_factory)
register_backend("tiered", _tiered_factory)
register_backend("erasure", _erasure_factory)


# ----------------------------------------------------------------------
# Deprecated table view: ``BACKENDS[name](...)`` construction.
# ----------------------------------------------------------------------
class DeprecatedBackendTable(collections.abc.Mapping):
    """Mapping façade over the legacy ``core.nvm_esr.BACKENDS`` dict.

    Iteration and membership are silent (benchmarks sweep the names);
    *constructing* through ``BACKENDS[name](...)`` warns and routes the
    construction through the registry factory, so the resulting object
    is the same first-class :class:`PersistenceBackend` the registry
    would build."""

    def __init__(self, names_to_ctor: Dict[str, Callable]):
        self._table = dict(names_to_ctor)

    def __iter__(self):
        return iter(self._table)

    def __len__(self):
        return len(self._table)

    def __getitem__(self, name: str) -> Callable:
        ctor = self._table[name]

        def construct(*args, **kwargs):
            warnings.warn(
                f"constructing backends through BACKENDS[{name!r}](...) is "
                f"deprecated; use repro.solvers.registry.make_backend or "
                f"repro.nvm.backend.create_backend",
                DeprecationWarning, stacklevel=2)
            return ctor(*args, **kwargs)

        construct.__name__ = getattr(ctor, "__name__", name)
        construct.__wrapped__ = ctor
        return construct
