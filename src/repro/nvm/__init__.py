"""Simulated NVRAM substrate.

The execution environment has no Intel Optane DCPMM, so this package
simulates the *semantics* of byte-addressable persistent memory (explicit
flush boundaries, data survival across process crashes, torn writes on
crash-during-write) with real file-backed storage, and the *performance*
with calibrated tier cost models (DRAM / Optane-NVM / SATA-SSD / remote
RDMA) taken from the paper's experimental cluster (Fig. 6).

Layers
------
- :mod:`repro.nvm.store`   — tiered byte-addressable stores + cost models
- :mod:`repro.nvm.pmdk`    — ``libpmemobj``-like persistent object pools
- :mod:`repro.nvm.windows` — MPI one-sided-communication windows (PSCW /
  fence / passive-target epochs) with ``*_persist`` variants
- :mod:`repro.nvm.prd`     — persistent-recovery-data (PRD) sub-cluster node
- :mod:`repro.nvm.gf256`   — GF(2^8) tables + Reed-Solomon P/Q parity
  (the byte-exact math under the erasure stripe) — DESIGN.md §8
- :mod:`repro.nvm.backend` — the formal persistence-backend API
  (capability protocol, sessions, composite replicated/tiered/erasure
  backends, the single backend registry) — DESIGN.md §7/§8
"""
from repro.nvm.store import (  # noqa: F401
    Tier,
    TierSpec,
    TIER_SPECS,
    NETWORK_SPECS,
    Store,
    CostModel,
)
from repro.nvm.pmdk import PmemPool  # noqa: F401
from repro.nvm.windows import Window, EpochError  # noqa: F401
from repro.nvm.prd import PRDNode  # noqa: F401
from repro.nvm.backend import (  # noqa: F401
    BackendCapabilities,
    ErasureCodedBackend,
    PersistenceBackend,
    PersistSession,
    ReplicatedBackend,
    STRIPE_ROT_SCALAR,
    TieredBackend,
    UnrecoverableFailure,
    backend_names,
    create_backend,
    open_persist_session,
    register_backend,
    stripe_child_schema,
)
