"""``libpmemobj``-like persistent object pools over a simulated NVM store.

Mirrors the PMDK usage in the paper (§4.2): each process calls
``pmemobj_create`` once, then ``pmemobj_persist`` at every persistence
iteration.  Crash consistency for whole-object updates is provided by
**double-buffered alternating slots** (Dorożyński et al. [4]): an object is
written to the inactive slot, flushed, and only then is the slot header
(sequence number + CRC32) committed — so one valid copy always survives a
crash that interrupts persistence.

Layout of a named object with two slots::

    [slot0: header | payload][slot1: header | payload]
    header := seq:u64 | size:u64 | crc32:u32 | pad:u32   (24 bytes)
"""
from __future__ import annotations

import struct
from typing import Dict, Optional, Tuple

import numpy as np

from repro.nvm.store import Store, checksum

_HEADER = struct.Struct("<QQII")  # seq, size, crc32, pad
HEADER_SIZE = _HEADER.size
_META = struct.Struct("<QQ")


def slot_crc(payload: bytes, seq: int) -> int:
    """CRC binding payload AND header fields (seq, size): a torn write
    cannot forge a header that self-validates (e.g. seq=1/size=0/crc=0
    would match crc32(b'') if the CRC covered only the payload)."""
    return checksum(payload + _META.pack(seq, len(payload)))


class PmemPool:
    """A persistent memory pool holding named, double-buffered objects."""

    def __init__(self, store: Store, layout: str = "nvm-esr"):
        self.store = store
        self.layout = layout
        self._objects: Dict[str, Tuple[int, int]] = {}  # name -> (offset, capacity)
        self._cursor = 0
        self._seq: Dict[str, int] = {}

    # ------------------------------------------------------------------
    def create(self, name: str, capacity: int) -> None:
        """Reserve space for an object of up to ``capacity`` payload bytes."""
        if name in self._objects:
            raise ValueError(f"object {name!r} already exists")
        slot = HEADER_SIZE + capacity
        need = 2 * slot
        if self._cursor + need > self.store.size:
            raise MemoryError(
                f"pool exhausted: need {need} bytes for {name!r}, "
                f"{self.store.size - self._cursor} free"
            )
        self._objects[name] = (self._cursor, capacity)
        self._seq[name] = 0
        self._cursor += need

    def has(self, name: str) -> bool:
        return name in self._objects

    def _slot_offsets(self, name: str) -> Tuple[int, int, int]:
        base, capacity = self._objects[name]
        slot = HEADER_SIZE + capacity
        return base, base + slot, capacity

    # ------------------------------------------------------------------
    def persist(self, name: str, payload: bytes) -> float:
        """``pmemobj_persist``: durably commit ``payload`` under ``name``.

        Returns the modeled cost (seconds).  Write ordering is the
        crash-safe one: payload -> flush -> header -> flush.
        """
        if isinstance(payload, np.ndarray):
            payload = payload.tobytes()
        off0, off1, capacity = self._slot_offsets(name)
        if len(payload) > capacity:
            raise ValueError(f"payload {len(payload)}B > capacity {capacity}B")
        seq = self._seq[name] + 1
        target = off0 if seq % 2 == 0 else off1
        cost = 0.0
        cost += self.store.write(target + HEADER_SIZE, payload)
        cost += self.store.flush()
        header = _HEADER.pack(seq, len(payload), slot_crc(payload, seq), 0)
        cost += self.store.write(target, header)
        cost += self.store.flush()
        self._seq[name] = seq
        return cost

    def persist_array(self, name: str, arr: np.ndarray) -> float:
        return self.persist(name, np.ascontiguousarray(arr).tobytes())

    # ------------------------------------------------------------------
    def _read_slot(self, off: int, capacity: int) -> Optional[Tuple[int, bytes]]:
        raw, _ = self.store.read(off, HEADER_SIZE)
        seq, size, crc, _pad = _HEADER.unpack(raw)
        if seq == 0 or size > capacity:
            return None
        payload, _ = self.store.read(off + HEADER_SIZE, size)
        if slot_crc(payload, seq) != crc:
            return None  # torn write — slot invalid
        return seq, payload

    def read(self, name: str) -> Optional[bytes]:
        """Return the newest *valid* committed copy (None if never persisted)."""
        off0, off1, capacity = self._slot_offsets(name)
        best: Optional[Tuple[int, bytes]] = None
        for off in (off0, off1):
            got = self._read_slot(off, capacity)
            if got is not None and (best is None or got[0] > best[0]):
                best = got
        return None if best is None else best[1]

    def read_array(self, name: str, dtype, shape) -> Optional[np.ndarray]:
        raw = self.read(name)
        if raw is None:
            return None
        return np.frombuffer(raw, dtype=dtype).reshape(shape).copy()

    # ------------------------------------------------------------------
    def recover(self) -> None:
        """Re-open after a crash: re-derive per-object sequence numbers."""
        for name in self._objects:
            off0, off1, capacity = self._slot_offsets(name)
            seqs = []
            for off in (off0, off1):
                got = self._read_slot(off, capacity)
                if got is not None:
                    seqs.append(got[0])
            self._seq[name] = max(seqs) if seqs else 0
