"""GF(2^8) arithmetic and Reed-Solomon P/Q parity (DESIGN.md §8).

The erasure backend's distance-2 code was plain XOR: one parity child,
one survivable storage loss.  Lifting ``max_storage_failures`` to 2
without full mirroring needs a second, *independent* parity — the
classic RAID-6 construction: parity row P is the bytewise XOR of the K
data shards, parity row Q weights shard ``j`` by the generator power
``g^j`` in GF(2^8) before XOR-accumulating.  Both rows together form a
2xK Vandermonde matrix over the field, every square submatrix of which
is invertible, so *any* two erased shards (data or parity) are exactly
recoverable.

Everything here operates on **raw bytes** (``uint8`` views of the
stored payload), never on float values: reconstruction returns the
identical bit pattern the data children persisted, which is the same
bit-exact degraded-fetch invariant the XOR path already guaranteed.

Field: GF(2^8) with the primitive polynomial ``x^8+x^4+x^3+x^2+1``
(0x11D, the AES-adjacent polynomial every RS tutorial uses) and
generator ``g = 2``.  Tables are built once at import: ``EXP[i] = g^i``
(doubled to 510 entries so products skip one modulo), ``LOG[g^i] = i``.

Scope: the Vandermonde rows ``g^(i·j)`` are guaranteed MDS only for
``nparity <= 2`` (rows ``1...1`` and ``g^0..g^(K-1)``); the module
refuses wider codes rather than silently emitting a non-MDS matrix.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

#: primitive polynomial x^8 + x^4 + x^3 + x^2 + 1
PRIMITIVE_POLY = 0x11D
#: generator of the multiplicative group under :data:`PRIMITIVE_POLY`
GENERATOR = 2
#: widest parity the g^(i·j) Vandermonde rows are provably MDS for
MAX_PARITY = 2

# ---------------------------------------------------------------- tables
EXP = np.zeros(510, dtype=np.uint8)   # EXP[i] = g^i, doubled for mul
LOG = np.zeros(256, dtype=np.int64)   # LOG[g^i] = i; LOG[0] is unused


def _build_tables() -> None:
    x = 1
    for i in range(255):
        EXP[i] = x
        LOG[x] = i
        x <<= 1
        if x & 0x100:
            x ^= PRIMITIVE_POLY
    EXP[255:510] = EXP[0:255]


_build_tables()


# ------------------------------------------------------------ arithmetic
def gf_mul(a, b) -> np.ndarray:
    """Elementwise GF(2^8) product of ``a`` and ``b`` (scalars or uint8
    arrays, broadcast like numpy)."""
    a = np.asarray(a, dtype=np.uint8)
    b = np.asarray(b, dtype=np.uint8)
    out = EXP[LOG[a] + LOG[b]]
    return np.where((a == 0) | (b == 0), np.uint8(0), out).astype(np.uint8)


def gf_div(a, b) -> np.ndarray:
    """Elementwise GF(2^8) quotient ``a / b``; division by zero raises."""
    a = np.asarray(a, dtype=np.uint8)
    b = np.asarray(b, dtype=np.uint8)
    if np.any(b == 0):
        raise ZeroDivisionError("division by zero in GF(2^8)")
    out = EXP[(LOG[a] - LOG[b]) % 255]
    return np.where(a == 0, np.uint8(0), out).astype(np.uint8)


def gf_pow(a: int, n: int) -> int:
    """Scalar GF(2^8) power ``a^n`` (``0^0 == 1`` by convention)."""
    if n == 0:
        return 1
    if a == 0:
        return 0
    return int(EXP[(int(LOG[a]) * n) % 255])


def gf_inv(a: int) -> int:
    """Scalar multiplicative inverse; ``gf_inv(0)`` raises."""
    if a == 0:
        raise ZeroDivisionError("0 has no inverse in GF(2^8)")
    return int(EXP[255 - int(LOG[a])])


# --------------------------------------------------------- Reed-Solomon
def vandermonde(nparity: int, k_data: int) -> np.ndarray:
    """The ``nparity x k_data`` encode matrix ``V[i, j] = g^(i·j)``.

    Row 0 is all ones (P parity == plain XOR, which keeps the wire
    format of the old distance-2 stripe); row 1 weights shard ``j`` by
    ``g^j`` (Q parity).  MDS is only guaranteed up to
    :data:`MAX_PARITY` rows — see the module docstring.
    """
    if not 1 <= nparity <= MAX_PARITY:
        raise ValueError(
            f"nparity must be in [1, {MAX_PARITY}] (the g^(i*j) rows are "
            f"only provably MDS up to {MAX_PARITY} parities), got {nparity}")
    if not 1 <= k_data <= 255:
        raise ValueError(f"k_data must be in [1, 255], got {k_data}")
    return np.array([[gf_pow(GENERATOR, i * j) for j in range(k_data)]
                     for i in range(nparity)], dtype=np.uint8)


def _scaled(coeff: int, shard: np.ndarray) -> np.ndarray:
    """``coeff * shard`` with the cheap cases short-circuited (row 0 of
    the Vandermonde is all ones, so P parity never pays table lookups)."""
    if coeff == 0:
        return np.zeros_like(shard)
    if coeff == 1:
        return shard
    return gf_mul(coeff, shard)


def rs_encode(data: Sequence[np.ndarray], nparity: int) -> List[np.ndarray]:
    """Encode ``nparity`` parity shards over equal-length uint8 data
    shards: ``parity[i] = XOR_j  V[i, j] * data[j]``."""
    shards = [np.ascontiguousarray(d, dtype=np.uint8) for d in data]
    if len({s.shape for s in shards}) != 1:
        raise ValueError(
            f"data shards must share one shape, got "
            f"{[s.shape for s in shards]}")
    v = vandermonde(nparity, len(shards))
    out = []
    for i in range(nparity):
        acc = np.zeros_like(shards[0])
        for j, d in enumerate(shards):
            acc ^= _scaled(int(v[i, j]), d)
        out.append(acc)
    return out


def _solve(a: np.ndarray, rhs: List[np.ndarray]) -> List[np.ndarray]:
    """Solve ``a @ x = rhs`` over GF(2^8): ``a`` is a small square uint8
    coefficient matrix, each RHS entry a byte array.  Plain Gaussian
    elimination — the systems here are at most MAX_PARITY x MAX_PARITY,
    but the loop is written generically."""
    m = len(rhs)
    a = a.astype(np.uint8).copy()
    rhs = [r.copy() for r in rhs]
    for col in range(m):
        pivot = next((r for r in range(col, m) if a[r, col] != 0), None)
        if pivot is None:
            raise ValueError("singular reconstruction system in GF(2^8)")
        if pivot != col:
            a[[col, pivot]] = a[[pivot, col]]
            rhs[col], rhs[pivot] = rhs[pivot], rhs[col]
        inv = gf_inv(int(a[col, col]))
        a[col] = gf_mul(inv, a[col])
        rhs[col] = _scaled(inv, rhs[col])
        for r in range(m):
            if r != col and a[r, col] != 0:
                factor = int(a[r, col])
                a[r] ^= gf_mul(factor, a[col])
                rhs[r] = rhs[r] ^ _scaled(factor, rhs[col])
    return rhs


def rs_reconstruct(shards: Sequence[Optional[np.ndarray]],
                   k_data: int) -> List[np.ndarray]:
    """Recover the ``k_data`` data shards from a partially erased stripe.

    ``shards`` lists the logical stripe — ``k_data`` data shards
    followed by the parity shards of :func:`rs_encode` — with ``None``
    marking an erased shard.  Returns the complete data shards,
    byte-identical to what was encoded; raises ``ValueError`` when the
    erasures exceed what the surviving parity can solve.
    """
    nparity = len(shards) - k_data
    if nparity < 1:
        raise ValueError(
            f"stripe of {len(shards)} shards with k_data={k_data} leaves "
            f"no parity")
    missing = [j for j in range(k_data) if shards[j] is None]
    if not missing:
        return [np.asarray(s, dtype=np.uint8) for s in shards[:k_data]]
    alive_parity = [i for i in range(nparity)
                    if shards[k_data + i] is not None]
    if len(missing) > len(alive_parity):
        raise ValueError(
            f"{len(missing)} data shard(s) erased but only "
            f"{len(alive_parity)} parity shard(s) survive — beyond the "
            f"code's remaining distance")
    v = vandermonde(nparity, k_data)
    rows = alive_parity[:len(missing)]
    # RHS per chosen row: parity_i minus (XOR) the surviving data terms.
    rhs = []
    for i in rows:
        acc = np.asarray(shards[k_data + i], dtype=np.uint8).copy()
        for j in range(k_data):
            if shards[j] is not None:
                acc ^= _scaled(int(v[i, j]), np.asarray(shards[j], np.uint8))
        rhs.append(acc)
    a = v[np.ix_(rows, missing)]
    solved = _solve(a, rhs)
    out: List[np.ndarray] = []
    for j in range(k_data):
        if shards[j] is None:
            out.append(solved[missing.index(j)])
        else:
            out.append(np.asarray(shards[j], dtype=np.uint8))
    return out
