"""Persistent-recovery-data (PRD) sub-cluster node (paper §3, Fig. 1c).

A PRD node owns an NVRAM store exposed to all compute ranks through an MPI
one-sided window (over simulated RDMA).  Recovery data is persisted with
the **PSCW** protocol exactly as in the paper's Fig. 4:

  target:  post(group) ............................ wait_persist()
  origin:  start() -> put_pmem(payload, header) -> complete() -> [compute!]

``complete()`` returns before the target finishes persisting, so compute
ranks overlap the next solver iterations with the PRD flush — the paper's
central latency optimization.  The drain runs on a worker thread here to
preserve that overlap in simulation.

Slot layout per rank (double-buffered, crash consistent)::

    rank_base = rank * 2 * (HEADER_SIZE + capacity)
    slot(seq) = rank_base + (seq % 2) * (HEADER_SIZE + capacity)

Cost model: the PRD NIC serializes incoming puts (one IB FDR link), so the
modeled epoch time grows linearly with total put bytes — reproducing the
Fig. 10 trend of overhead vs. process count.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.nvm.pmdk import HEADER_SIZE, _HEADER, slot_crc
from repro.nvm.store import CostModel, Store, Tier, checksum
from repro.nvm.windows import Window


class PRDNode:
    """One PRD storage node serving ``nranks`` compute ranks."""

    def __init__(
        self,
        nranks: int,
        capacity_per_rank: int,
        tier: Tier = Tier.NVM,
        network: str = "rdma",
        path: Optional[str] = None,
        cost_model: Optional[CostModel] = None,
        async_drain: bool = True,
    ):
        self.nranks = nranks
        self.capacity = int(capacity_per_rank)
        self._slot = HEADER_SIZE + self.capacity
        size = nranks * 2 * self._slot
        self.store = Store(size, tier=tier, path=path, cost_model=cost_model)
        self.window = Window(self.store, network=network, name="prd")
        self.async_drain = async_drain
        self._drainer: Optional[threading.Thread] = None
        self._drain_cost = 0.0

    # ------------------------------------------------------------------
    def _slot_offset(self, rank: int, seq: int) -> int:
        return rank * 2 * self._slot + (seq % 2) * self._slot

    # ---------------------- persistence iteration ----------------------
    def join(self) -> float:
        """Block until the previous exposure epoch finished persisting.

        The epoch's target-side cost is consumed on read: a second join
        with no epoch in between returns 0, so callers accumulating drain
        cost (driver recovery barriers) never double-count."""
        if self._drainer is not None:
            self._drainer.join()
            self._drainer = None
        cost, self._drain_cost = self._drain_cost, 0.0
        return cost

    def begin_epoch(self, group=None) -> None:
        """Target side: open the exposure epoch for ``group`` (default all)."""
        self.join()
        self.window.post(range(self.nranks) if group is None else group)

    def put_rank(self, rank: int, payload: bytes, seq: int,
                 slot: Optional[int] = None) -> float:
        """Origin side: start -> put payload+header -> complete.

        ``slot`` overrides the parity choice (callers doing *periodic*
        persistence pick slots by event count, not by seq — seq gaps would
        otherwise overwrite a slot that is still the recovery point).
        Returns the modeled origin-visible cost; the origin is free to
        compute immediately after this returns.
        """
        if isinstance(payload, np.ndarray):
            payload = np.ascontiguousarray(payload).tobytes()
        if len(payload) > self.capacity:
            raise ValueError(f"payload {len(payload)}B > slot capacity {self.capacity}B")
        off = self._slot_offset(rank, seq if slot is None else slot)
        header = _HEADER.pack(seq, len(payload), slot_crc(payload, seq), 0)
        self.window.start(rank)
        cost = self.window.put(rank, off + HEADER_SIZE, payload)
        cost += self.window.put(rank, off, header)
        self.window.complete(rank)
        return cost

    def end_epoch(self) -> float:
        """Target side: wait_persist.  Async when ``async_drain`` is set."""
        if not self.async_drain:
            self._drain_cost = self.window.wait(persist=True)
            return self._drain_cost

        def _drain() -> None:
            self._drain_cost = self.window.wait(persist=True)

        self._drainer = threading.Thread(target=_drain, name="prd-drainer")
        self._drainer.start()
        return 0.0

    def persist_all(self, payloads: List[bytes], seq: int) -> Dict[str, float]:
        """One full persistence iteration for every rank (paper Fig. 4).

        Returns modeled costs: ``origin`` is what compute ranks observe
        (NIC-serialized puts), ``target`` is the PRD-side flush that
        overlaps subsequent compute.
        """
        if len(payloads) != self.nranks:
            raise ValueError("one payload per rank required")
        self.begin_epoch()
        origin = 0.0
        for rank, payload in enumerate(payloads):
            origin += self.put_rank(rank, payload, seq)
        self.end_epoch()
        return {"origin": origin, "target": self._drain_cost}

    # ----------------------------- recovery -----------------------------
    def read_latest(
        self,
        rank: int,
        reader_rank: Optional[int] = None,
        want_seq: Optional[int] = None,
    ) -> Optional[Tuple[int, bytes]]:
        """Passive-target read of a valid slot of ``rank``.

        Returns the newest valid slot, or — when ``want_seq`` is given —
        only a slot carrying exactly that sequence number.  Any
        surviving/spare rank may call this: the PRD store remains
        accessible after arbitrary compute-node failures (paper §3 model).
        """
        self.join()
        reader = self.nranks if reader_rank is None else reader_rank
        self.window.lock(reader)
        best: Optional[Tuple[int, bytes]] = None
        try:
            for parity in (0, 1):
                off = rank * 2 * self._slot + parity * self._slot
                raw, _ = self.window.get(reader, off, HEADER_SIZE)
                seq, size, crc, _pad = _HEADER.unpack(raw)
                if seq == 0 or size > self.capacity:
                    continue
                if want_seq is not None and seq != want_seq:
                    continue
                payload, _ = self.window.get(reader, off + HEADER_SIZE, size)
                if slot_crc(payload, seq) != crc:
                    continue
                if best is None or seq > best[0]:
                    best = (seq, payload)
        finally:
            self.window.unlock(reader, persist=False)
        return best

    def scan_rank(self, rank: int,
                  reader_rank: Optional[int] = None) -> List[Tuple[int, bytes]]:
        """All valid slots of ``rank`` (both parities), newest first.

        Backend ``durable_run`` scans use this: unlike
        :meth:`read_latest` it returns every CRC-valid slot, so the
        caller can check run completeness across the whole ring."""
        self.join()
        reader = self.nranks if reader_rank is None else reader_rank
        self.window.lock(reader)
        found: List[Tuple[int, bytes]] = []
        try:
            for parity in (0, 1):
                off = rank * 2 * self._slot + parity * self._slot
                raw, _ = self.window.get(reader, off, HEADER_SIZE)
                seq, size, crc, _pad = _HEADER.unpack(raw)
                if seq == 0 or size > self.capacity:
                    continue
                payload, _ = self.window.get(reader, off + HEADER_SIZE, size)
                if slot_crc(payload, seq) != crc:
                    continue
                found.append((seq, payload))
        finally:
            self.window.unlock(reader, persist=False)
        return sorted(found, key=lambda sp: -sp[0])

    def crash(self) -> None:
        """PRD node power-fail; unflushed epochs are lost.  A single
        PRD node is a single point of failure — the paper scopes the
        RAID fix out; this repo composes it back in at the backend
        layer: ``replicated(nvm-prd xN)`` mirrors whole nodes,
        ``erasure(nvm-prd xK+p)`` stripes them with XOR parity
        (DESIGN.md §7/§8)."""
        if self._drainer is not None:
            # the drainer dies with the node; whatever was not flushed is gone
            self._drainer = None
        self.store.crash()
