"""Tiered byte-addressable stores with calibrated cost models.

Simulation contract
-------------------
*Semantics* are real: ``Store`` is byte-addressable; writes become durable
only at ``flush()`` boundaries; ``crash()`` discards everything that was not
flushed (volatile tiers lose everything).  This is exactly the programming
model of Optane DCPMM in App-Direct mode (CLWB + SFENCE ≙ ``flush``).

*Performance* is modeled: every operation returns a modeled cost in seconds
derived from per-tier latency/bandwidth constants calibrated to the paper's
cluster (Fig. 6: DDR4-2933 DRAM, Optane DCPMM 2666 MT/s "Apache Pass",
SATA-SSD 6 Gb/s, Mellanox IB FDR 56 Gb/s).  Benchmarks report both the
modeled time (used for the Fig. 9/10 reproductions) and the measured wall
time of the simulation itself.
"""
from __future__ import annotations

import enum
import os
import threading
import zlib
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, Mapping, Optional, Tuple

import numpy as np


class Tier(enum.Enum):
    DRAM = "dram"
    NVM = "nvm"
    SSD = "ssd"


@dataclass(frozen=True)
class TierSpec:
    """Latency/bandwidth model of one persistence tier (per process)."""

    name: str
    write_latency_s: float
    write_bw_Bps: float
    read_latency_s: float
    read_bw_Bps: float
    flush_latency_s: float
    persistent: bool

    def write_cost(self, nbytes: int) -> float:
        return self.write_latency_s + nbytes / self.write_bw_Bps

    def read_cost(self, nbytes: int) -> float:
        return self.read_latency_s + nbytes / self.read_bw_Bps

    def flush_cost(self, nbytes: int) -> float:
        # Draining write-pending-queues scales with dirty bytes.
        return self.flush_latency_s + nbytes / self.write_bw_Bps


# Calibration constants (see DESIGN.md §2).  Sources: paper Fig. 6 cluster,
# Izraelevitz et al. '19 Optane characterization, vendor SATA-SSD specs.
TIER_SPECS: Dict[Tier, TierSpec] = {
    # DDR4-2933, single-process slice of socket bandwidth.
    Tier.DRAM: TierSpec("dram", 90e-9, 12.0e9, 80e-9, 14.0e9, 0.0, False),
    # 4 interleaved 256GB DCPMMs (2 sockets x 2 channels): ~2.3 GB/s write
    # per DIMM sustained, ~6.8 GB/s read per DIMM.
    Tier.NVM: TierSpec("nvm", 170e-9, 9.2e9, 300e-9, 27.0e9, 600e-9, True),
    # 240GB SATA 6Gb/s SSD; fsync forces block I/O + barrier.
    Tier.SSD: TierSpec("ssd", 60e-6, 0.48e9, 90e-6, 0.52e9, 250e-6, True),
}


@dataclass(frozen=True)
class NetworkSpec:
    """One-sided transport model (origin -> target NIC -> target memory)."""

    name: str
    latency_s: float
    bw_Bps: float

    def transfer_cost(self, nbytes: int) -> float:
        return self.latency_s + nbytes / self.bw_Bps


NETWORK_SPECS: Dict[str, NetworkSpec] = {
    # IB FDR 4x = 56 Gb/s; RDMA put/get bypasses the remote CPU.
    "rdma": NetworkSpec("rdma", 1.5e-6, 6.8e9),
    # SSH-FS style remote file access (paper's remote-SSD reference).
    "sshfs": NetworkSpec("sshfs", 120e-6, 1.1e9),
    # local loop-back (homogeneous architecture: no network).
    "local": NetworkSpec("local", 0.0, float("inf")),
}


@dataclass
class CostModel:
    """Accumulates modeled seconds per category; thread-safe."""

    seconds: Dict[str, float] = field(default_factory=dict)
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    def add(self, category: str, cost_s: float) -> float:
        with self._lock:
            self.seconds[category] = self.seconds.get(category, 0.0) + cost_s
        return cost_s

    def total(self) -> float:
        with self._lock:
            return sum(self.seconds.values())

    def reset(self) -> None:
        with self._lock:
            self.seconds.clear()


class Store:
    """A byte-addressable region on one tier with crash-faithful durability.

    Writes land in the working image immediately (byte-addressable stores
    are CPU-visible before persistence, like DCPMM behind the cache
    hierarchy).  ``flush(lo, hi)`` makes a range durable.  ``crash()``
    rewinds the working image to the last durable state — unflushed bytes
    are torn away, which is what a power failure does to cache lines that
    never reached the DIMM's write-pending queue.
    """

    def __init__(
        self,
        size: int,
        tier: Tier = Tier.NVM,
        path: Optional[str] = None,
        cost_model: Optional[CostModel] = None,
    ):
        self.size = int(size)
        self.tier = tier
        self.spec = TIER_SPECS[tier]
        self.cost = cost_model if cost_model is not None else CostModel()
        self._working = bytearray(self.size)
        self._durable = bytearray(self.size) if self.spec.persistent else None
        self._dirty_lo: Optional[int] = None
        self._dirty_hi: Optional[int] = None
        self._lock = threading.RLock()
        self._path = path
        if path is not None and self.spec.persistent:
            self._load_backing(path)

    # -- backing file (lets a *new* Store instance play a rebooted node) --
    def _load_backing(self, path: str) -> None:
        if os.path.exists(path):
            with open(path, "rb") as f:
                data = f.read(self.size)
            self._durable[: len(data)] = data
            self._working[: len(data)] = data

    def _sync_backing(self) -> None:
        if self._path is not None and self._durable is not None:
            with open(self._path, "wb") as f:
                f.write(self._durable)
                f.flush()
                os.fsync(f.fileno())

    # ------------------------------- ops -------------------------------
    def write(self, offset: int, data: bytes) -> float:
        """Store bytes into the working image; NOT yet durable."""
        end = offset + len(data)
        if end > self.size:
            raise ValueError(f"write [{offset}:{end}) beyond store size {self.size}")
        with self._lock:
            self._working[offset:end] = data
            self._dirty_lo = offset if self._dirty_lo is None else min(self._dirty_lo, offset)
            self._dirty_hi = end if self._dirty_hi is None else max(self._dirty_hi, end)
        return self.cost.add("write", self.spec.write_cost(len(data)))

    def read(self, offset: int, nbytes: int) -> Tuple[bytes, float]:
        end = offset + nbytes
        if end > self.size:
            raise ValueError(f"read [{offset}:{end}) beyond store size {self.size}")
        with self._lock:
            data = bytes(self._working[offset:end])
        return data, self.cost.add("read", self.spec.read_cost(nbytes))

    def flush(self) -> float:
        """Persist all dirty bytes (CLWB+SFENCE / msync / fsync analogue)."""
        with self._lock:
            if self._dirty_lo is None:
                return self.cost.add("flush", self.spec.flush_cost(0))
            lo, hi = self._dirty_lo, self._dirty_hi
            if self._durable is not None:
                self._durable[lo:hi] = self._working[lo:hi]
            self._dirty_lo = self._dirty_hi = None
        return self.cost.add("flush", self.spec.flush_cost(hi - lo))

    def crash(self, torn_write: Optional[Tuple[int, bytes]] = None) -> None:
        """Power-fail: lose unflushed bytes; volatile tiers lose all.

        ``torn_write`` optionally lands a partial write *after* the rewind,
        modeling a crash that interrupts an in-flight store sequence (used
        by crash-consistency property tests).
        """
        with self._lock:
            if self._durable is None:
                self._working = bytearray(self.size)
            else:
                self._working = bytearray(self._durable)
                if torn_write is not None:
                    off, frag = torn_write
                    self._working[off : off + len(frag)] = frag
                    self._durable[off : off + len(frag)] = frag
            self._dirty_lo = self._dirty_hi = None
            self._sync_backing()

    def durable_snapshot(self) -> bytes:
        with self._lock:
            if self._durable is None:
                return b"\x00" * self.size
            return bytes(self._durable)


def checksum(data: bytes) -> int:
    return zlib.crc32(data) & 0xFFFFFFFF


class PersistStager:
    """Double-buffered staging area for overlapped persistence.

    Splits a persistence event into the part the solver must wait for and
    the part it can hide behind compute (DESIGN.md §6):

    - ``begin(k, scalars, vectors)`` captures the recovery payload into a
      staging buffer.  The device->host pull already happened in
      ``RecoverableSolver.recovery_set``; what remains on the critical
      path is a local DRAM copy of the slot bytes, whose modeled cost is
      returned.  Nothing is durable yet.
    - ``commit()`` runs the backend's flush function on the *oldest*
      staged payload — the expensive tier/network write — and returns its
      modeled cost.  The driver calls this while the next iteration's
      compute is in flight, so the cost overlaps.
    - ``drain()`` commits everything still staged: the barrier a backend
      must pass before a recovery point may be declared durable.
    - ``abort()`` discards staged payloads.  A failure tears in-flight
      persistence away; backends call this from ``fail()`` so an aborted
      slot write can never be committed later as if it had survived.

    Depth is 2 (double buffering): one payload may be committing while
    the next is being staged — enough for an ESRP burst to stay one event
    ahead.  A third ``begin`` without an intervening ``commit`` is a
    driver bug and raises.
    """

    DEPTH = 2

    def __init__(self, flush_fn: Callable[..., float],
                 cost_model: Optional[CostModel] = None):
        self._flush = flush_fn
        self._staged: deque = deque()
        self.cost = cost_model if cost_model is not None else CostModel()
        self._dram = TIER_SPECS[Tier.DRAM]
        #: a repro.obs tracer (set through PersistSession.set_tracer);
        #: None keeps every stager operation tracer-callable-free
        self.tracer = None

    @property
    def pending(self) -> int:
        """Number of staged-but-uncommitted payloads."""
        return len(self._staged)

    def begin(self, k: int, scalars: Mapping[str, float],
              vectors: Mapping[str, "np.ndarray"]) -> float:
        if len(self._staged) >= self.DEPTH:
            raise RuntimeError(
                f"persist staging depth {self.DEPTH} exceeded: commit or "
                f"drain before staging iteration {k}")
        # A real copy, not a view: the caller may reuse its buffers while
        # the staged payload waits for commit (the cost charged below IS
        # this copy).
        vecs = {name: np.array(v) for name, v in vectors.items()}
        nbytes = 8 + 8 * len(scalars) + sum(v.nbytes for v in vecs.values())
        self._staged.append((int(k), dict(scalars), vecs))
        cost = self.cost.add("stage", self._dram.write_cost(nbytes))
        if self.tracer is not None:
            # The staging copy is the exposed part of an overlapped
            # event; the flush below is the hidden part (DESIGN.md §6).
            self.tracer.event("stage.copy", k=int(k), nbytes=nbytes,
                              cost_s=cost, exposed=True)
        return cost

    def commit(self) -> float:
        if not self._staged:
            return 0.0
        k, scalars, vectors = self._staged.popleft()
        cost = self._flush(k, scalars, vectors)
        if self.tracer is not None:
            self.tracer.event("stage.flush", k=int(k), cost_s=cost,
                              exposed=False)
        return cost

    def drain(self) -> float:
        total = 0.0
        drained = len(self._staged)
        while self._staged:
            total += self.commit()
        if self.tracer is not None and drained:
            self.tracer.event("stage.drain", events=drained, cost_s=total)
        return total

    def abort(self) -> int:
        n = len(self._staged)
        if n and self.tracer is not None:
            # The discard is observable: SolveReport.persist_aborts
            # counts the driver-side event, and this closes the stager
            # leg of the trace triangle — every stage.copy is matched by
            # a stage.flush or accounted for by a stage.abort (the
            # conservation law check_trace_report verifies).
            self.tracer.event("stage.abort", count=n,
                              ks=tuple(int(k) for k, _, _ in self._staged))
        self._staged.clear()
        return n
