"""MPI one-sided-communication (OSC) windows with persistence extensions.

Implements the epoch discipline of MPI-3 RMA (paper §4.1) over a simulated
NVM/DRAM store, including the ``*_persist`` extensions of Dorożyński et
al. [4, 5]:

- **fence**  — collective active-target sync; ``fence_persist`` flushes the
  window to NVM before the epoch closes.
- **PSCW**   — generalized active-target sync (Post-Start-Complete-Wait).
  Origins ``start``/``complete`` an *access epoch*; the target
  ``post``/``wait``s an *exposure epoch*.  ``wait_persist`` drains and
  flushes.  The key NVM-ESR optimization: origins exit their access epoch
  (``complete``) and continue computing while the target is still
  persisting inside its exposure epoch.
- **passive target** — ``lock``/``unlock`` (+ ``unlock_persist``).

Epoch misuse raises :class:`EpochError`, mirroring MPI's erroneous-program
semantics; tests assert the discipline.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, List, Optional, Set, Tuple

import numpy as np

from repro.nvm.store import NETWORK_SPECS, NetworkSpec, Store


class EpochError(RuntimeError):
    """RMA call outside the required epoch (erroneous MPI program)."""


class Window:
    """One window: a region of a target store exposed to origin ranks.

    A single ``Window`` object plays the whole communicator's view: rank-
    indexed epoch state is tracked per origin, and the target side is the
    store owner.  ``disp_unit`` follows MPI (byte displacements here).
    """

    def __init__(
        self,
        store: Store,
        size: Optional[int] = None,
        base: int = 0,
        network: str = "rdma",
        name: str = "win",
    ):
        self.store = store
        self.base = base
        self.size = store.size - base if size is None else size
        self.net: NetworkSpec = NETWORK_SPECS[network]
        self.name = name
        self._lock = threading.RLock()
        # target-side epoch state
        self._exposed_to: Optional[Set[int]] = None
        self._completed: Set[int] = set()
        # origin-side epoch state
        self._access: Set[int] = set()
        # passive target
        self._locked_by: Optional[int] = None
        # pending (unflushed) put bytes for cost accounting
        self._pending_bytes = 0

    # ----------------------------- PSCW: target -----------------------------
    def post(self, group: Iterable[int]) -> None:
        """MPI_Win_post: open an exposure epoch for ``group`` origins."""
        with self._lock:
            if self._exposed_to is not None:
                raise EpochError("post inside an open exposure epoch")
            self._exposed_to = set(group)
            self._completed = set()

    def wait(self, persist: bool = True) -> float:
        """MPI_Win_wait / MPI_Win_Wait_persist: close the exposure epoch.

        Blocks (logically) until every origin in the posted group has
        completed; with ``persist`` the window range is flushed to the
        backing tier before returning, guaranteeing recovery data reached
        NVM (paper Fig. 4).
        """
        with self._lock:
            if self._exposed_to is None:
                raise EpochError("wait without a posted exposure epoch")
            missing = self._exposed_to - self._completed
            if missing:
                raise EpochError(f"wait before origins {sorted(missing)} completed")
            self._exposed_to = None
            self._completed = set()
            cost = self.store.flush() if persist else 0.0
            self._pending_bytes = 0
            return cost

    def test(self) -> bool:
        """MPI_Win_test: non-blocking wait probe."""
        with self._lock:
            if self._exposed_to is None:
                raise EpochError("test without a posted exposure epoch")
            return not (self._exposed_to - self._completed)

    # ----------------------------- PSCW: origin -----------------------------
    def start(self, rank: int) -> None:
        """MPI_Win_start: open this origin's access epoch."""
        with self._lock:
            if rank in self._access:
                raise EpochError(f"rank {rank}: start inside an open access epoch")
            self._access.add(rank)

    def complete(self, rank: int) -> None:
        """MPI_Win_complete: origin exits; target may still be persisting."""
        with self._lock:
            if rank not in self._access:
                raise EpochError(f"rank {rank}: complete without start")
            self._access.discard(rank)
            self._completed.add(rank)

    # ----------------------------- RMA ops -----------------------------
    def _check_rma(self, rank: int) -> None:
        if self._locked_by == rank:
            return  # passive-target epoch
        if rank not in self._access:
            raise EpochError(f"rank {rank}: RMA op outside any epoch")
        if self._exposed_to is not None and rank not in self._exposed_to:
            raise EpochError(f"rank {rank}: not in the posted group")

    def put(self, rank: int, offset: int, data: bytes) -> float:
        """MPI_Win_Put_pmem: one-sided write into the window."""
        if isinstance(data, np.ndarray):
            data = np.ascontiguousarray(data).tobytes()
        with self._lock:
            self._check_rma(rank)
            cost = self.net.transfer_cost(len(data))
            cost += self.store.write(self.base + offset, data)
            self._pending_bytes += len(data)
            self.store.cost.add("network", self.net.transfer_cost(len(data)))
            return cost

    def get(self, rank: int, offset: int, nbytes: int) -> Tuple[bytes, float]:
        """MPI_Win_Get_pmem: one-sided read from the window."""
        with self._lock:
            self._check_rma(rank)
            data, cost = self.store.read(self.base + offset, nbytes)
            cost += self.net.transfer_cost(nbytes)
            self.store.cost.add("network", self.net.transfer_cost(nbytes))
            return data, cost

    # ----------------------------- fence -----------------------------
    def fence(self, persist: bool = False) -> float:
        """MPI_Win_fence / MPI_Win_Fence_persist (collective sync)."""
        with self._lock:
            self._access.clear()
            self._completed = set(self._exposed_to) if self._exposed_to else set()
            cost = self.store.flush() if persist else 0.0
            if self._exposed_to is not None:
                self._exposed_to = None
            self._pending_bytes = 0
            return cost

    # ----------------------------- passive target -----------------------------
    def lock(self, rank: int) -> None:
        with self._lock:
            if self._locked_by is not None:
                raise EpochError(f"window already locked by {self._locked_by}")
            self._locked_by = rank

    def unlock(self, rank: int, persist: bool = True) -> float:
        with self._lock:
            if self._locked_by != rank:
                raise EpochError(f"unlock by {rank} but locked by {self._locked_by}")
            self._locked_by = None
            return self.store.flush() if persist else 0.0
