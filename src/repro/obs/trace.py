"""Structured solve-pipeline tracing (DESIGN.md §9).

A :class:`Tracer` records **nestable spans** (timed regions: an
iteration's step, a recovery fetch, an RS decode) and **instant
events** (a persist commit with its hidden/exposed attribution, a
failure injection) with monotonic timestamps and JSON-safe labels.
Export targets:

- JSONL (:meth:`Tracer.to_jsonl` / :func:`from_jsonl`) — one record per
  line, lossless round-trip, the machine-diffable form;
- Chrome trace-event JSON (:meth:`Tracer.to_chrome`) — loadable in
  Perfetto / ``chrome://tracing`` (complete ``"X"`` events for spans,
  instant ``"i"`` events; see docs/observability.md §5).

The **disabled path is a guaranteed no-op**: :data:`NULL_TRACER` is
falsy, every method does nothing, and :meth:`NullTracer.span` returns a
cached singleton context manager — so instrumented code that guards
with ``tracer = maybe_tracer or None`` / ``if trace is not None`` (the
driver's pattern) executes **zero tracer callables and zero
allocations** on the hot path.  The guard contract is enforced by
``tests/test_obs_pipeline.py``.

Span/event *names are string literals at every call site* — the docs
freshness gate (``tools/check_docs.py``) scans ``src/`` textually for
``.span("...")`` / ``.event("...")`` and requires every name to appear
in the docs/observability.md taxonomy table.
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable, Dict, Iterable, List, Optional

__all__ = ["Tracer", "NullTracer", "NULL_TRACER", "from_jsonl"]

_SCALARS = (str, int, bool, type(None))


def _clean(value: Any) -> Any:
    """JSON-safe label values: scalars pass through, containers are
    cleaned recursively, non-finite floats and arbitrary objects become
    repr strings (json string escaping then handles quotes, newlines,
    unicode — the label-escaping contract tested in test_obs.py)."""
    if isinstance(value, _SCALARS):
        return value
    if isinstance(value, float):
        # NaN/Inf are not valid strict JSON; Perfetto rejects them.
        return value if value == value and abs(value) != float("inf") \
            else repr(value)
    if isinstance(value, (list, tuple)):
        return [_clean(v) for v in value]
    if isinstance(value, dict):
        return {str(k): _clean(v) for k, v in value.items()}
    return repr(value)


class _Span:
    """An open span: a reusable context manager bound to one tracer.

    Records the span *at close* (so the event list orders children
    before their parent — reconstructible through ``depth``/``ts``)."""

    __slots__ = ("_tracer", "name", "args", "_start", "_depth")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.args = args
        self._start = 0.0
        self._depth = 0

    def __enter__(self) -> "_Span":
        self._depth = self._tracer._depth
        self._tracer._depth += 1
        self._start = self._tracer._clock()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        end = self._tracer._clock()
        self._tracer._depth -= 1
        self._tracer._record({
            "type": "span",
            "name": self.name,
            "ts": self._start - self._tracer._t0,
            "dur": end - self._start,
            "depth": self._depth,
            "args": self.args,
        })


class Tracer:
    """Span/event recorder with monotonic timestamps.

    Single-threaded by design (the driver is); timestamps come from a
    monotonic ``clock`` (``time.perf_counter`` by default — injectable
    for deterministic tests).  ``ts``/``dur`` are seconds relative to
    the tracer's construction.
    """

    enabled = True

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self._t0 = clock()
        self._depth = 0
        self.records: List[Dict[str, Any]] = []

    def __bool__(self) -> bool:
        return True

    # -- recording ------------------------------------------------------
    def _record(self, rec: Dict[str, Any]) -> None:
        self.records.append(rec)

    def span(self, name: str, **labels: Any) -> _Span:
        """A nestable timed region: ``with tracer.span("recovery.fetch",
        blocks=(1, 2)): ...``."""
        return _Span(self, name, {k: _clean(v) for k, v in labels.items()})

    def event(self, name: str, **labels: Any) -> None:
        """An instant event at the current time and nesting depth."""
        self._record({
            "type": "event",
            "name": name,
            "ts": self._clock() - self._t0,
            "depth": self._depth,
            "args": {k: _clean(v) for k, v in labels.items()},
        })

    # -- queries --------------------------------------------------------
    def counts(self) -> Dict[str, int]:
        """Occurrences per record name (spans and events alike) — the
        quantity the trace/report cross-check compares."""
        out: Dict[str, int] = {}
        for rec in self.records:
            out[rec["name"]] = out.get(rec["name"], 0) + 1
        return out

    def names(self) -> List[str]:
        """Distinct record names, first-seen order."""
        seen: List[str] = []
        for rec in self.records:
            if rec["name"] not in seen:
                seen.append(rec["name"])
        return seen

    # -- exports --------------------------------------------------------
    def to_jsonl(self, path) -> int:
        """One JSON object per line; lossless (:func:`from_jsonl`).
        Returns the number of records written."""
        with open(path, "w") as f:
            for rec in self.records:
                f.write(json.dumps(rec, allow_nan=False) + "\n")
        return len(self.records)

    def to_chrome(self, path) -> int:
        """Chrome trace-event JSON (Perfetto / ``chrome://tracing``).

        Spans become complete (``"ph": "X"``) events, instants become
        ``"ph": "i"`` thread-scoped events; timestamps are microseconds
        as the format requires.  Returns the number of trace events."""
        events = []
        for rec in self.records:
            ev = {
                "name": rec["name"],
                "cat": "repro",
                "ts": rec["ts"] * 1e6,
                "pid": 0,
                "tid": 0,
                "args": rec["args"],
            }
            if rec["type"] == "span":
                ev["ph"] = "X"
                ev["dur"] = rec["dur"] * 1e6
            else:
                ev["ph"] = "i"
                ev["s"] = "t"
            events.append(ev)
        doc = {"traceEvents": events, "displayTimeUnit": "ms",
               "otherData": {"producer": "repro.obs.trace"}}
        with open(path, "w") as f:
            json.dump(doc, f, allow_nan=False)
        return len(events)


def from_jsonl(path) -> List[Dict[str, Any]]:
    """Load records written by :meth:`Tracer.to_jsonl` (round-trip
    inverse; the export tests compare both directions)."""
    records = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records


class _NullSpan:
    """The cached no-op context manager :meth:`NullTracer.span` returns —
    one shared instance, so the disabled path allocates nothing."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        return None


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The disabled tracer: falsy, allocation-free, method-free on the
    hot path.  Instrumented code normalizes ``tracer or None`` once and
    guards with an identity check, so with tracing disabled no tracer
    method is ever called per iteration (the guard test's contract);
    these no-op methods exist only for callers that skip the guard."""

    enabled = False

    def __bool__(self) -> bool:
        return False

    def span(self, name: str, **labels: Any) -> _NullSpan:
        return _NULL_SPAN

    def event(self, name: str, **labels: Any) -> None:
        return None

    def counts(self) -> Dict[str, int]:
        return {}

    def names(self) -> List[str]:
        return []

    @property
    def records(self) -> List[Dict[str, Any]]:
        return []


#: the shared disabled tracer (``SolveConfig.tracer``'s conceptual
#: default — the driver treats None and any falsy tracer identically)
NULL_TRACER = NullTracer()
