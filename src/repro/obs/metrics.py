"""Labeled metrics registry for the solve pipeline (DESIGN.md §9).

Three instrument kinds, Prometheus-shaped but in-process and
allocation-light:

- :class:`Counter` — monotone integer (``recovery.absorbed``,
  ``persist.commit``);
- :class:`Gauge` — last-write-wins value (``solve.iterations``);
- :class:`Histogram` — streaming observations with exact
  count/total/min/max and percentile queries (``persist.commit_s``).

Instruments live in a :class:`MetricsRegistry`, keyed by ``(kind, name,
labels)``; registry-level *base labels* (solver, persist mode) are
joined onto every instrument, and per-instrument labels add dimensions
such as ``phase`` — the per-phase histogram table in
``repro.launch.report.metrics_table`` groups on that label.

The driver's :class:`~repro.solvers.driver.SolveReport` counters are
**derived views** of this registry: the solve loop increments the
registry at each site and the report's numeric fields are read back
out of it at exit, so the two cannot drift.
:func:`check_report_consistency` re-verifies the derivation and
:func:`check_trace_report` closes the triangle against the tracer's
event counts (the campaign-fuzz harness runs it for every accepted
campaign).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Iterator, List, Mapping, Optional, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "check_report_consistency",
    "check_trace_report",
    "TRACE_REPORT_PAIRS",
    "SHARD_BYTE_PAIRS",
    "SERVICE_REPORT_PAIRS",
]


LabelItems = Tuple[Tuple[str, Any], ...]


def _label_key(labels: Mapping[str, Any]) -> LabelItems:
    return tuple(sorted((str(k), v) for k, v in labels.items()))


class Counter:
    """Monotone event counter."""

    kind = "counter"
    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: LabelItems):
        self.name = name
        self.labels = labels
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease (n={n})")
        self.value += n


class Gauge:
    """Last-write-wins value."""

    kind = "gauge"
    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: LabelItems):
        self.name = name
        self.labels = labels
        self.value: float = 0.0

    def set(self, value: float) -> None:
        self.value = value


class Histogram:
    """Streaming observations with exact summary statistics.

    Observations are kept (the pipelines observed here produce at most
    thousands of events per solve), so ``total`` accumulates in
    observation order — bit-identical to the ``+=`` accumulation the
    pre-registry report used — and percentiles are exact.
    """

    kind = "histogram"
    __slots__ = ("name", "labels", "values", "total")

    def __init__(self, name: str, labels: LabelItems):
        self.name = name
        self.labels = labels
        self.values: List[float] = []
        self.total = 0.0

    def observe(self, value: float) -> None:
        self.values.append(float(value))
        self.total += float(value)

    @property
    def count(self) -> int:
        return len(self.values)

    @property
    def mean(self) -> float:
        return self.total / len(self.values) if self.values else float("nan")

    def percentile(self, q: float) -> float:
        """Exact q-th percentile (nearest-rank), q in [0, 100]."""
        if not self.values:
            return float("nan")
        ordered = sorted(self.values)
        rank = max(0, math.ceil(q / 100.0 * len(ordered)) - 1)
        return ordered[min(rank, len(ordered) - 1)]

    def summary(self) -> Dict[str, float]:
        if not self.values:
            return {"count": 0, "total": 0.0}
        return {
            "count": len(self.values),
            "total": self.total,
            "mean": self.mean,
            "min": min(self.values),
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "max": max(self.values),
        }


class MetricsRegistry:
    """Get-or-create registry of labeled instruments.

    ``base_labels`` (e.g. ``solver="pcg", mode="overlap"``) are joined
    onto every instrument so a sweep can merge registries without
    collisions; per-call labels add dimensions.  Asking for an existing
    ``(kind, name, labels)`` returns the same instrument; asking for an
    existing name with a *different kind* is refused (one name, one
    semantic).
    """

    def __init__(self, **base_labels: Any):
        self.base_labels = dict(base_labels)
        self._instruments: Dict[Tuple[str, LabelItems], Any] = {}
        self._kinds: Dict[str, str] = {}

    # -- instrument factories ------------------------------------------
    def _get(self, cls, name: str, labels: Mapping[str, Any]):
        known = self._kinds.get(name)
        if known is not None and known != cls.kind:
            raise ValueError(
                f"metric {name!r} is already registered as a {known}, "
                f"cannot re-register as a {cls.kind}")
        merged = dict(self.base_labels)
        merged.update(labels)
        key = (name, _label_key(merged))
        inst = self._instruments.get(key)
        if inst is None:
            inst = cls(name, _label_key(merged))
            self._instruments[key] = inst
            self._kinds[name] = cls.kind
        return inst

    def counter(self, name: str, **labels: Any) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels: Any) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels: Any) -> Histogram:
        return self._get(Histogram, name, labels)

    # -- views ----------------------------------------------------------
    def __iter__(self) -> Iterator[Any]:
        return iter(sorted(self._instruments.values(),
                           key=lambda i: (i.name, i.labels)))

    def __len__(self) -> int:
        return len(self._instruments)

    def counter_value(self, name: str, **labels: Any) -> int:
        """The counter's value, 0 when it was never incremented (the
        derived-view read the driver uses at exit)."""
        merged = dict(self.base_labels)
        merged.update(labels)
        inst = self._instruments.get((name, _label_key(merged)))
        return 0 if inst is None else int(inst.value)

    def counter_total(self, name: str) -> int:
        """Sum of a counter over every label set it was incremented
        under — the whole-solve view of a per-shard counter such as
        ``recovery.fetch_bytes`` (0 when the name is unknown)."""
        return sum(int(inst.value) for (n, _), inst
                   in self._instruments.items()
                   if n == name and inst.kind == "counter")

    def counter_by_label(self, name: str, label: str) -> Dict[Any, int]:
        """Per-label-value breakdown of a counter, e.g.
        ``counter_by_label("persist.bytes", "shard") -> {0: ..., 1: ...}``
        (the derived view behind ``SolveReport.*_by_shard``)."""
        out: Dict[Any, int] = {}
        for (n, _), inst in self._instruments.items():
            if n != name or inst.kind != "counter":
                continue
            labels = dict(inst.labels)
            if label in labels:
                key = labels[label]
                out[key] = out.get(key, 0) + int(inst.value)
        return out

    def histogram_total(self, name: str, **labels: Any) -> float:
        merged = dict(self.base_labels)
        merged.update(labels)
        inst = self._instruments.get((name, _label_key(merged)))
        return 0.0 if inst is None else float(inst.total)

    def snapshot(self) -> List[Dict[str, Any]]:
        """Plain-data view (JSON-ready), sorted by (name, labels)."""
        out = []
        for inst in self:
            entry: Dict[str, Any] = {
                "name": inst.name,
                "kind": inst.kind,
                "labels": dict(inst.labels),
            }
            if inst.kind == "histogram":
                entry.update(inst.summary())
            else:
                entry["value"] = inst.value
            out.append(entry)
        return out


# ----------------------------------------------------------------------
# Cross-checks (DESIGN.md §9): report == registry == trace.
# ----------------------------------------------------------------------
#: trace/metrics record name -> SolveReport field.  The fuzz harness
#: asserts these counts agree for every accepted campaign; the names
#: are the driver's literal span/event names (docs/observability.md).
TRACE_REPORT_PAIRS: Tuple[Tuple[str, str], ...] = (
    ("recovery.absorbed", "failures_recovered"),
    ("recovery.restart", "recovery_restarts"),
    ("storage.kill", "storage_failures"),
    ("persist.commit", "persist_events"),
    ("persist.abort", "persist_aborts"),
)


#: per-shard byte counter -> (total field, by-shard dict field).  The
#: counters carry a ``shard=N`` label per device shard; the report's
#: totals and breakdowns are both derived views of them.
SHARD_BYTE_PAIRS: Tuple[Tuple[str, str, str], ...] = (
    ("persist.bytes", "persist_bytes", "persist_bytes_by_shard"),
    ("recovery.fetch_bytes", "recovery_fetch_bytes",
     "recovery_fetch_bytes_by_shard"),
)


#: service-residency counter -> SolveReport field (docs/serving.md §5).
#: Recorded in the *tenant's* registry by the solve service; zero on
#: solo driver runs, where the counters simply never increment — the
#: derived-view rule holds on both paths.
SERVICE_REPORT_PAIRS: Tuple[Tuple[str, str], ...] = (
    ("service.wait_steps", "service_queue_wait_steps"),
    ("service.lane_steps", "service_lane_steps"),
)


def check_report_consistency(report) -> None:
    """Verify the report's counters really are views of its attached
    registry (``report.metrics``); raises ``ValueError`` naming the
    first disagreeing pair.  A report without metrics passes vacuously
    (nothing to check — e.g. a hand-built report)."""
    registry = getattr(report, "metrics", None)
    if registry is None:
        return
    for metric, field in TRACE_REPORT_PAIRS:
        got = registry.counter_value(metric)
        want = getattr(report, field)
        if got != want:
            raise ValueError(
                f"metrics/report disagreement: registry counter "
                f"{metric!r} = {got} but SolveReport.{field} = {want}")
    for metric, field in SERVICE_REPORT_PAIRS:
        got = registry.counter_value(metric)
        want = getattr(report, field, 0)
        if got != want:
            raise ValueError(
                f"metrics/report disagreement: registry counter "
                f"{metric!r} = {got} but SolveReport.{field} = {want}")
    for metric, total_field, by_shard_field in SHARD_BYTE_PAIRS:
        got_total = registry.counter_total(metric)
        want_total = getattr(report, total_field, 0)
        if got_total != want_total:
            raise ValueError(
                f"metrics/report disagreement: registry counter "
                f"{metric!r} totals {got_total} but "
                f"SolveReport.{total_field} = {want_total}")
        got_by = registry.counter_by_label(metric, "shard")
        want_by = getattr(report, by_shard_field, {})
        if got_by != want_by:
            raise ValueError(
                f"metrics/report disagreement: registry counter "
                f"{metric!r} per shard is {got_by} but "
                f"SolveReport.{by_shard_field} = {want_by}")


def check_trace_report(tracer, report) -> Dict[str, int]:
    """Verify the tracer's event counts equal the report's counters
    (and, transitively, the registry's — :func:`check_report_consistency`
    runs first).  Returns the compared ``{field: count}`` mapping;
    raises ``ValueError`` naming the first disagreement.
    """
    check_report_consistency(report)
    counts = tracer.counts()
    compared = {}
    for metric, field in TRACE_REPORT_PAIRS:
        got = counts.get(metric, 0)
        want = getattr(report, field)
        if got != want:
            raise ValueError(
                f"trace/report disagreement: {got} {metric!r} trace "
                f"events but SolveReport.{field} = {want}")
        compared[field] = got
    # Staging conservation (the stager leg of the triangle): every
    # payload a stager copied in (``stage.copy``) must leave it either
    # flushed (``stage.flush``, including drain-time flushes) or
    # explicitly discarded (``stage.abort`` carries the dropped payload
    # count) — a silent discard would make persist_aborts uncheckable
    # against the trace.
    copies = counts.get("stage.copy", 0)
    flushes = counts.get("stage.flush", 0)
    dropped = sum(
        int(rec.get("args", {}).get("count", 0))
        for rec in getattr(tracer, "records", ())
        if rec.get("type") == "event" and rec.get("name") == "stage.abort")
    if copies != flushes + dropped:
        raise ValueError(
            f"trace staging leak: {copies} stage.copy events but "
            f"{flushes} stage.flush + {dropped} payloads dropped by "
            f"stage.abort — staged payloads vanished untraced")
    compared["stage_dropped"] = dropped
    return compared
