"""``repro.obs`` — solve-pipeline observability (DESIGN.md §9).

- :mod:`repro.obs.trace` — nestable span/event tracer with a
  guaranteed no-op disabled path, JSONL + Chrome-trace (Perfetto)
  export.
- :mod:`repro.obs.metrics` — labeled counters/gauges/histograms; the
  registry :class:`~repro.solvers.driver.SolveReport` counters are
  derived from, plus the report/trace cross-checks.

Span and event names are documented in docs/observability.md; the docs
CI gate (``tools/check_docs.py``) keeps that taxonomy complete.
"""
from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    SERVICE_REPORT_PAIRS,
    SHARD_BYTE_PAIRS,
    TRACE_REPORT_PAIRS,
    check_report_consistency,
    check_trace_report,
)
from repro.obs.trace import (  # noqa: F401
    NULL_TRACER,
    NullTracer,
    Tracer,
    from_jsonl,
)
