"""Execute the documentation's python snippets (ISSUE 2 satellite).

The CI docs job syntax-checks every fenced block without a runtime
(`tools/check_docs.py`); here, with jax available, the snippets *run* —
so the README example and the wire-format round-trip cannot rot.
Blocks are executed per-file in one shared namespace, in order, like a
doctest session.
"""
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

from check_docs import python_blocks  # noqa: E402

DOC_FILES = ["README.md", "docs/recovery-format.md", "docs/backend-api.md",
             "docs/erasure-coding.md", "docs/observability.md",
             "docs/static-analysis.md", "docs/serving.md"]


@pytest.mark.parametrize("doc", DOC_FILES)
def test_doc_snippets_execute(doc):
    text = (REPO / doc).read_text()
    blocks = list(python_blocks(text))
    assert blocks, f"{doc} has no python examples to run"
    namespace = {}
    for line_no, src in blocks:
        code = compile(src, f"{doc}:{line_no}", "exec")
        exec(code, namespace)  # noqa: S102 — executing our own docs


def test_check_docs_cli_passes_on_repo_docs():
    """The docs CI job's exact invocation succeeds against the tree."""
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py"),
         "README.md", "DESIGN.md", "docs/recovery-format.md",
         "docs/backend-api.md", "docs/erasure-coding.md",
         "docs/observability.md", "docs/static-analysis.md",
         "docs/serving.md"],
        cwd=REPO, capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "backend matrix covers" in out.stdout
    assert "span taxonomy covers" in out.stdout
    assert "rule catalog covers" in out.stdout
    assert "service metric table covers" in out.stdout


def test_check_api_cli_passes_on_repo():
    """The docs CI job's API gate succeeds against the tree: repro.api
    imports cleanly and every registered backend declares complete
    BackendCapabilities."""
    import os

    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_api.py")],
        cwd=REPO, capture_output=True, text=True, env=env)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "public names resolve" in out.stdout


def test_check_docs_cli_flags_rot(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text(
        "see [missing](nope.md)\n\n```python\ndef broken(:\n```\n")
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py"), str(bad)],
        capture_output=True, text=True)
    assert out.returncode == 1
    assert "does not compile" in out.stderr
    assert "broken relative link" in out.stderr


def test_check_docs_flags_undocumented_backend_family(tmp_path):
    """The freshness gate (ISSUE 4 satellite): a README whose backend
    matrix misses a registered spec family fails the docs job, so a
    future backend cannot land undocumented."""
    from check_docs import registered_backend_families

    families = registered_backend_families(REPO / "src")
    assert {"esr", "nvm-homogeneous", "nvm-prd", "replicated", "tiered",
            "erasure"} <= families

    stale = tmp_path / "README.md"
    keep = sorted(families - {"erasure"})
    stale.write_text("backends: " + " ".join(f"`{n}`" for n in keep) + "\n")
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py"), str(stale)],
        capture_output=True, text=True)
    assert out.returncode == 1
    assert "'erasure' is missing" in out.stderr

    fresh = tmp_path / "ok" / "README.md"
    fresh.parent.mkdir()
    fresh.write_text("backends: "
                     + " ".join(f"`{n}`" for n in sorted(families))
                     + " `erasure(c x4+p)` `erasure(c x6+2p)`\n")
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py"), str(fresh)],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr


def test_check_docs_flags_undocumented_span_name(tmp_path):
    """The ISSUE 6 freshness gate: an observability doc missing an
    emitted span/event name fails the docs job, so new instrumentation
    cannot land undocumented (names are string literals at call sites,
    which is what makes the textual scan complete)."""
    from check_docs import emitted_span_names

    names = emitted_span_names(REPO / "src")
    assert {"iteration.step", "persist.commit", "recovery.fetch",
            "stripe.degraded", "gf256.rs_decode"} <= names

    stale = tmp_path / "observability.md"
    keep = sorted(names - {"stripe.degraded"})
    stale.write_text("spans: " + " ".join(f"`{n}`" for n in keep) + "\n")
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py"), str(stale)],
        capture_output=True, text=True)
    assert out.returncode == 1
    assert "'stripe.degraded' is missing" in out.stderr

    fresh = tmp_path / "ok" / "observability.md"
    fresh.parent.mkdir()
    fresh.write_text("spans: " + " ".join(f"`{n}`" for n in sorted(names))
                     + "\n")
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py"), str(fresh)],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr


def test_check_docs_flags_rule_catalog_drift(tmp_path):
    """The ISSUE 8 freshness gate, both directions: a static-analysis
    doc missing a registered rule id fails the docs job, and so does a
    doc naming a rule the registry no longer ships."""
    from repro_lint.registry import ALL_RULES, META_RULES

    known = sorted(set(ALL_RULES) | set(META_RULES))
    assert {"RL101", "RL201", "RL301", "RL401", "RL501",
            "RL001"} <= set(known)

    stale = tmp_path / "static-analysis.md"
    stale.write_text("rules: " + " ".join(known[1:]) + "\n")
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py"), str(stale)],
        capture_output=True, text=True)
    assert out.returncode == 1
    assert f"{known[0]!r} is missing" in out.stderr

    ghost = tmp_path / "g" / "static-analysis.md"
    ghost.parent.mkdir()
    ghost.write_text("rules: " + " ".join(known) + " RL999\n")
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py"), str(ghost)],
        capture_output=True, text=True)
    assert out.returncode == 1
    assert "'RL999'" in out.stderr and "no longer exists" in out.stderr

    fresh = tmp_path / "ok" / "static-analysis.md"
    fresh.parent.mkdir()
    fresh.write_text("rules: " + " ".join(known) + "\n")
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py"), str(fresh)],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr


def test_check_docs_flags_undocumented_erasure_arity(tmp_path):
    """The ISSUE 5 freshness extension: naming the erasure family is
    not enough — every supported parity arity (+p, +2p) needs a row,
    so a wider code cannot land with only distance 2 documented."""
    from check_docs import registered_backend_families, \
        supported_erasure_arities

    families = registered_backend_families(REPO / "src")
    assert supported_erasure_arities(REPO / "src") == ["+p", "+2p"]

    stale = tmp_path / "README.md"
    stale.write_text("backends: "
                     + " ".join(f"`{n}`" for n in sorted(families))
                     + " `erasure(c x4+p)`\n")       # +2p row missing
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py"), str(stale)],
        capture_output=True, text=True)
    assert out.returncode == 1
    assert "'+2p' missing" in out.stderr


def test_check_docs_flags_undocumented_service_metric(tmp_path):
    """The ISSUE 9 freshness gate: a serving doc missing a metric name
    emitted under serving/ fails the docs job, so new service
    instrumentation cannot land undocumented."""
    sys.path.insert(0, str(REPO / "tools"))
    from repro_lint import facts

    names = set(facts.collect_facts_from_root(
        REPO / "src")["service_metric_names"])
    assert {"service.submitted", "service.rejected", "service.admitted",
            "service.completed", "service.queue_depth",
            "service.queue_wait_steps", "service.batch_occupancy",
            "service.wait_steps", "service.lane_steps"} <= names

    stale = tmp_path / "serving.md"
    keep = sorted(names - {"service.queue_wait_steps"})
    stale.write_text("metrics: " + " ".join(f"`{n}`" for n in keep) + "\n")
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py"), str(stale)],
        capture_output=True, text=True)
    assert out.returncode == 1
    assert "'service.queue_wait_steps' is missing" in out.stderr

    fresh = tmp_path / "ok" / "serving.md"
    fresh.parent.mkdir()
    fresh.write_text("metrics: " + " ".join(f"`{n}`" for n in sorted(names))
                     + "\n")
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py"), str(fresh)],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr
