"""PCG solver correctness: convergence, preconditioners, jit-path parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BlockJacobiPreconditioner,
    DenseOperator,
    IdentityPreconditioner,
    JacobiPreconditioner,
    PCGConfig,
    make_poisson_problem,
    random_spd,
    solve,
    solve_jit,
)


@pytest.mark.parametrize("precond", ["identity", "jacobi", "block_jacobi"])
def test_pcg_converges_poisson(precond):
    op, b = make_poisson_problem(8, 8, 8, nblocks=4)
    pre = {"identity": IdentityPreconditioner, "jacobi": JacobiPreconditioner,
           "block_jacobi": BlockJacobiPreconditioner}[precond](op)
    state, report, _ = solve(op, b, pre, PCGConfig(tol=1e-10))
    assert report.converged
    res = float(jnp.linalg.norm(b - op.apply(state.x)) / jnp.linalg.norm(b))
    assert res < 1e-9


def test_pcg_matches_numpy_direct():
    a = random_spd(64, seed=3)
    op = DenseOperator(a, nblocks=4)
    b = jnp.asarray(np.random.default_rng(0).standard_normal(64))
    pre = JacobiPreconditioner(op)
    state, report, _ = solve(op, b, pre, PCGConfig(tol=1e-12))
    x_np = np.linalg.solve(a, np.asarray(b))
    np.testing.assert_allclose(np.asarray(state.x), x_np, rtol=1e-8, atol=1e-8)


def test_solve_jit_matches_driver():
    op, b = make_poisson_problem(8, 8, 8, nblocks=4)
    pre = JacobiPreconditioner(op)
    state, report, _ = solve(op, b, pre, PCGConfig(tol=1e-10))
    x_jit, iters = jax.jit(
        lambda bb: solve_jit(op.apply, pre.apply, bb, tol=1e-10))(b)
    assert abs(int(iters) - report.iterations) <= 1
    np.testing.assert_allclose(np.asarray(x_jit), np.asarray(state.x),
                               rtol=1e-8, atol=1e-10)


def test_block_partition_roundtrip():
    op, _ = make_poisson_problem(8, 4, 4, nblocks=4)
    part = op.partition
    x = jnp.arange(op.n, dtype=jnp.float64)
    v = part.restrict(x, [1, 3])
    y = part.embed(v, [1, 3])
    assert float(jnp.sum(jnp.abs(part.restrict(y, [1, 3]) - v))) == 0.0
    z = part.zero_blocks(x, [0, 2])
    assert float(jnp.sum(jnp.abs(part.restrict(z, [0, 2])))) == 0.0
    np.testing.assert_array_equal(np.asarray(part.restrict(z, [1, 3])),
                                  np.asarray(part.restrict(x, [1, 3])))


def test_offblock_inblock_decomposition():
    """A x restricted to F == A[F,F] x_F + A[F,~F] x_{~F} (the identity
    the reconstruction relies on)."""
    op, _ = make_poisson_problem(8, 6, 5, nblocks=8)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(op.n))
    for blocks in ([2], [0, 7], [3, 4]):
        full = op.partition.restrict(op.apply(x), blocks)
        dec = op.inblock_apply(op.partition.restrict(x, blocks), blocks) \
            + op.offblock_apply(x, blocks)
        np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                                   rtol=1e-12, atol=1e-12)
