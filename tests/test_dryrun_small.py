"""Dry-run machinery regression test on a small (2,2,2) host-device mesh.

Runs in a SUBPROCESS (the ``multi_device`` fixture) so the 8-device XLA
flag never touches this test process (smoke tests must keep seeing 1
device).
"""
import pytest

_SUB = r"""
import json, jax
import dataclasses as dc
from repro.distributed.sharding import set_rules
from repro.models import registry as R
from repro.launch.mesh import compat_make_mesh
from repro.launch.roofline import analyze

mesh = compat_make_mesh((2, 2, 2), ("pod", "data", "model"))
rules = set_rules(mesh)
out = {}

# one SMOKE arch cell per kind through the full build_cell -> compile path
for arch, shape in (("llama3_8b", "train_4k"), ("llama3_8b", "decode_32k")):
    cfg = dc.replace(R.get_config(arch, smoke=True), name=f"{arch}-dry")
    # shrink the shape for test speed
    sh = dc.replace(R.SHAPES[shape], seq=128, batch=8)
    R.SHAPES["_test"] = sh
    cell = R.build_cell(cfg, arch, "_test", rules)
    with mesh:
        compiled = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                           donate_argnums=cell.donate).lower(*cell.in_structs).compile()
    r = analyze(compiled, 8)
    ma = compiled.memory_analysis()
    out[f"{arch}/{shape}"] = {
        "flops": r.flops,
        "peak": int(ma.argument_size_in_bytes + ma.output_size_in_bytes
                    + ma.temp_size_in_bytes),
        "colls": r.coll_by_kind,
    }

# the solver step, both variants
from repro.core.spmv import lower_pcg_step
for variant in ("auto", "shardmap"):
    c = lower_pcg_step(mesh, 64, 32, 32, esr_mode="nvm", variant=variant).compile()
    out[f"pcg/{variant}"] = {"colls": analyze(c, 8).coll_by_kind}

print(json.dumps(out))
"""


@pytest.mark.multi_device
def test_dryrun_small_mesh(multi_device):
    out = multi_device.run(_SUB, ndevices=8, timeout=480)
    # train cell compiled, has compute and collectives
    tr = out["llama3_8b/train_4k"]
    assert tr["flops"] > 0 and tr["peak"] > 0
    assert any(k in tr["colls"] for k in ("all-reduce", "all-gather"))
    # decode cell compiled
    assert out["llama3_8b/decode_32k"]["peak"] > 0
    # the hillclimbed solver variant moves (far) fewer halo bytes
    auto_cp = out["pcg/auto"]["colls"].get("collective-permute", 0)
    opt_cp = out["pcg/shardmap"]["colls"].get("collective-permute", 0)
    assert 0 < opt_cp < auto_cp
