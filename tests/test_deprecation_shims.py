"""The consolidated deprecation-shim suite (ISSUE 3 satellite).

Two legacy entry points survive the backend-API redesign as warning
shims that route through the new protocol:

1. the PCG-only ``backend.persist(k, beta, p_full)`` /
   ``backend.recover(blocks, k)`` methods on the three core backends,
2. direct ``BACKENDS[name](...)`` construction from the pre-redesign
   registry table,

plus the driver-level shim for *external* pre-zoo duck-typed backends,
which now routes through :class:`repro.nvm.backend.LegacyBackendSession`
(the RAM-front staging tier) instead of the deleted
``driver._LegacyBackendAdapter``.  This file absorbs the old
``test_legacy_adapter.py`` coverage: round-trip fidelity, the stale-pair
refusal for untrusted external contracts, and the non-PCG rejection.
"""
import numpy as np
import pytest

from repro.core import JacobiPreconditioner, make_poisson_problem
from repro.core.nvm_esr import BACKENDS, NVMESRHomogeneous
from repro.core.state import PCG_SCHEMA, RecoveryPayload
from repro.nvm.backend import LegacyBackendSession, open_persist_session
from repro.solvers import FailurePlan, SolveConfig, make_solver, solve
from repro.solvers.gmres import GMRES_SCHEMA


class _OldStyle:
    """Minimal pre-zoo external backend: full-vector slots keyed by
    iteration, PCG payloads only."""

    def __init__(self, block_size=8):
        self.block_size = block_size
        self.slots = {}
        self.failed = []

    def persist(self, k, beta, p_full):
        self.slots[k] = (beta, np.asarray(p_full).copy())
        return 0.125

    def fail(self, blocks):
        self.failed.append(tuple(blocks))

    def recover(self, blocks, k):
        def payload(kk):
            beta, p = self.slots[kk]
            shards = [p[b * self.block_size:(b + 1) * self.block_size]
                      for b in blocks]
            return RecoveryPayload(kk, beta, np.concatenate(shards))
        return payload(k - 1), payload(k)


# ---------------------------------------------------------------- shim 1
def test_legacy_persist_recover_warn_and_stay_wire_compatible():
    """The pre-zoo persist/recover entry points (used by old external
    callers) warn, route through the schema codec, and stay
    byte-compatible with persist_set/recover_set slots."""
    be = NVMESRHomogeneous(4, 8, np.float64)
    p0 = np.arange(32, dtype=np.float64)
    p1 = p0 + 1.0
    with pytest.warns(DeprecationWarning, match="deprecated PCG-only"):
        be.persist(0, 0.0, p0)
    be.persist_set(1, {"beta": 0.25}, {"p": p1})  # modern path, no warning
    with pytest.warns(DeprecationWarning, match="deprecated PCG-only"):
        prev, cur = be.recover([1, 2], 1)
    assert prev.k == 0 and cur.k == 1 and cur.beta == 0.25
    np.testing.assert_array_equal(prev.p, p0[8:24])
    np.testing.assert_array_equal(cur.p, p1[8:24])
    # the same slots serve the modern protocol: one ring, one format
    sets = be.recover_set([1, 2], (0, 1))
    assert [s.k for s in sets] == [0, 1]
    np.testing.assert_array_equal(sets[-1].vectors["p"], p1[8:24])


# ---------------------------------------------------------------- shim 2
def test_backends_table_construction_warns_and_routes():
    """``BACKENDS[name](...)`` still constructs a working first-class
    backend — with a DeprecationWarning on the construction call, while
    iteration/membership (the benchmark sweeps) stay silent."""
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error")  # sweeping must NOT warn
        names = sorted(BACKENDS)
        assert names == ["esr", "nvm-homogeneous", "nvm-prd"]
        assert "nvm-prd" in BACKENDS and len(BACKENDS) == 3
        ctor = BACKENDS["nvm-homogeneous"]  # lookup alone must not warn

    with pytest.warns(DeprecationWarning, match="BACKENDS\\['nvm-homogeneous'\\]"):
        be = ctor(4, 8, np.float64)
    assert isinstance(be, NVMESRHomogeneous)
    assert be.capabilities.durability == "nvm"  # the new protocol surface
    be.persist_set(0, {"beta": 0.0}, {"p": np.zeros(32)})
    be.persist_set(1, {"beta": 0.5}, {"p": np.ones(32)})
    (got,) = be.recover_set([0], (1,))
    np.testing.assert_array_equal(got.vectors["p"], np.ones(8))


# ------------------------------------------------- external duck-typed
def test_external_legacy_backend_round_trip_through_session():
    be = _OldStyle()
    with pytest.warns(DeprecationWarning, match="duck-typed legacy"):
        session = open_persist_session(be, PCG_SCHEMA)
    assert isinstance(session, LegacyBackendSession)

    p0 = np.arange(32, dtype=np.float64)
    p1 = p0 + 100.0
    assert session.persist(0, {"beta": 0.0}, {"p": p0}) == 0.125
    assert session.persist(1, {"beta": 0.25}, {"p": p1}) == 0.125

    sets = session.fetch([1, 2], (0, 1))
    assert [s.k for s in sets] == [0, 1]
    assert sets[-1].scalars["beta"] == 0.25
    np.testing.assert_array_equal(sets[0].vectors["p"], p0[8:24])
    np.testing.assert_array_equal(sets[-1].vectors["p"], p1[8:24])

    session.fail((1, 2))
    assert be.failed == [(1, 2)]


def test_external_legacy_backend_overlap_via_ram_front():
    """Overlap staging for legacy backends now lives in the session's
    RAM front (the TieredBackend component), not in the driver."""
    be = _OldStyle()
    with pytest.warns(DeprecationWarning):
        session = open_persist_session(be, PCG_SCHEMA)
    c = session.begin(0, {"beta": 0.5}, {"p": np.arange(32.0)})
    assert c > 0.0 and 0 not in be.slots      # staged, not yet durable
    assert session.commit() == 0.125 and 0 in be.slots
    session.begin(1, {"beta": 0.25}, {"p": np.arange(32.0) + 1})
    session.abort()
    assert session.drain() == 0.0 and 1 not in be.slots  # aborted event died


def test_legacy_session_goes_dark_after_storage_loss():
    """After fail_storage() the legacy pipeline must stop flushing to
    the dead backend in BOTH pipelines (sync persist and overlapped
    begin/commit/drain) and refuse fetches — same model as the core
    sessions."""
    be = _OldStyle()
    with pytest.warns(DeprecationWarning):
        session = open_persist_session(be, PCG_SCHEMA)
    session.persist(0, {"beta": 0.0}, {"p": np.zeros(32)})
    session.fail_storage()
    assert session.persist(1, {"beta": 0.1}, {"p": np.ones(32)}) == 0.0
    assert session.begin(2, {"beta": 0.2}, {"p": np.ones(32)}) == 0.0
    assert session.commit() == 0.0 and session.drain() == 0.0
    assert set(be.slots) == {0}  # nothing reached the dead backend
    with pytest.raises(Exception, match="PRD"):
        session.fetch([1], (0, 1))


def test_stale_pair_refused():
    """An external backend returning the wrong iteration pair must not be
    silently reconstructed from — the session refuses loudly."""

    class StaleBackend(_OldStyle):
        def recover(self, blocks, k):
            prev, cur = super().recover(blocks, k)
            return prev._replace(k=prev.k - 1), cur  # off-by-one pair

    with pytest.warns(DeprecationWarning):
        session = open_persist_session(StaleBackend(), PCG_SCHEMA)
    session.persist(4, {"beta": 0.0}, {"p": np.zeros(32)})
    session.persist(5, {"beta": 0.5}, {"p": np.ones(32)})
    with pytest.raises(RuntimeError, match="legacy backend .* returned"):
        session.fetch([0], (4, 5))


def test_non_pcg_schema_rejected():
    """The legacy wire format carries PCG payloads only; adapting a
    backend for any other schema is a loud, early error."""
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="legacy"):
            open_persist_session(_OldStyle(), GMRES_SCHEMA)


def test_driver_routes_legacy_backend_end_to_end():
    """solve() normalizes external legacy backends through the session
    shim: persistence, failure, and recovery all work — with exactly the
    deprecation warning, once, at wrap time."""
    op, b = make_poisson_problem(8, 8, 8, nblocks=4)
    pre = JacobiPreconditioner(op)
    be = _OldStyle(op.partition.block_size)
    solver = make_solver("pcg", op, pre)
    with pytest.warns(DeprecationWarning, match="duck-typed legacy"):
        _, rep, _ = solve(solver, op, b, pre, SolveConfig(tol=1e-10),
                          backend=be, failures=[FailurePlan(10, (1, 2))])
    assert rep.converged and rep.failures_recovered == 1
    assert be.slots  # persisted through the shim
