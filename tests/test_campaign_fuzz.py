"""Campaign-fuzz harness (ISSUE 5 satellite): planner == runtime.

A deterministic, seeded generator produces random
:class:`FailureCampaign` s — block kills, PRD/storage kills, overlapping
events landing mid-recovery, repeated kills of the same block — and
runs each against **every registered backend spec family**, asserting
the campaign planner's verdict matches runtime reality in both
directions:

- ``plan_campaign`` **accepts** ⇒ the solve recovers onto the
  no-failure trajectory (state captured past the last event matches
  the reference run to machine precision) and the report's recovery /
  restart / storage-loss counts equal the plan's.
- ``plan_campaign`` **rejects** ⇒ the rejection names a campaign event,
  the planned solve raises :class:`UnsurvivableCampaignError` before
  iteration 0, and the *unplanned* solve (``plan_campaign=False``)
  raises a runtime :class:`UnrecoverableFailure` — the planner is
  neither optimistic nor pessimistic.

The sweep is deterministic (fixed seeds) per the ROADMAP's
no-hypothesis baseline; a property-test variant rides along through
``tests/_hypothesis_compat.py`` and runs when hypothesis is installed.

The advisor acceptance (ISSUE 5): for a double-storage-loss campaign,
``advise_spec`` picks ``erasure(nvm-prd x6+2p)`` over
``replicated(nvm-prd x3)`` on footprint grounds.
"""
import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st  # noqa: F401
from repro.core import JacobiPreconditioner, make_poisson_problem
from repro.nvm.backend import UnrecoverableFailure, backend_names
from repro.obs import Tracer, check_trace_report
from repro.solvers import (
    FailureCampaign,
    FailureEvent,
    SolveConfig,
    UnsurvivableCampaignError,
    advise_spec,
    make_backend,
    make_solver,
    plan_campaign,
    solve,
)

# Every registered spec family, in at least one canonical composition.
SPECS = (
    "esr",
    "nvm-homogeneous",
    "nvm-prd",
    "tiered(nvm-homogeneous)",
    "replicated(nvm-prd x2)",
    "replicated(nvm-prd x3)",
    "erasure(nvm-prd x4+p)",
    "erasure(nvm-prd x6+2p)",
)
SEEDS = (0, 1, 2, 3)
NBLOCKS = 4
CHECK_K = 14          # capture point past every generated event
MAX_AT = 12           # latest trigger — well before convergence (~30)


def test_specs_cover_every_registered_family():
    """The harness's 'every registered spec' claim, enforced: a new
    backend family must be added to SPECS (or this fails)."""
    families = {spec.split("(")[0] for spec in SPECS}
    assert families == set(backend_names())


def _problem():
    op, b = make_poisson_problem(8, 8, 8, nblocks=NBLOCKS)
    return op, b, JacobiPreconditioner(op)


def random_campaign(seed: int) -> FailureCampaign:
    """Deterministic random campaign: 1-2 iteration-triggered events
    (block kills and/or PRD kills, possibly blockless storage-only
    losses), each block-bearing event optionally shadowed by an
    overlapping event that lands during its recovery (which may repeat
    already-failed blocks and may itself kill storage)."""
    rng = np.random.default_rng(seed)
    events = []
    n_at = int(rng.integers(1, 3))
    # at_iteration >= 3 keeps every trigger past the first durable
    # persistence run in both persist modes and ESRP periods
    ats = sorted(rng.choice(np.arange(3, MAX_AT + 1), size=n_at,
                            replace=False))
    for at in ats:
        nb = int(rng.integers(0, 3))
        blocks = tuple(sorted(
            int(x) for x in rng.choice(NBLOCKS, size=nb, replace=False)))
        prd = bool(rng.random() < 0.45)
        if not blocks and not prd:
            blocks = (int(rng.integers(NBLOCKS)),)
        events.append(FailureEvent(blocks=blocks, at_iteration=int(at),
                                   prd=prd))
        if blocks and rng.random() < 0.4:
            nb2 = int(rng.integers(1, 3))
            blocks2 = tuple(sorted(       # may repeat already-dead blocks
                int(x) for x in rng.choice(NBLOCKS, size=nb2, replace=False)))
            events.append(FailureEvent(blocks=blocks2,
                                       during_recovery_at=int(at),
                                       prd=bool(rng.random() < 0.35)))
    return FailureCampaign(tuple(events))


def random_config(seed: int) -> SolveConfig:
    rng = np.random.default_rng(10_000 + seed)
    return SolveConfig(
        tol=1e-10, maxiter=5000,
        persist_mode=str(rng.choice(["sync", "overlap"])),
        persistence_period=int(rng.choice([1, 3])),
    )


_REF = {}


def _reference():
    """The no-failure trajectory: captured state at CHECK_K, final x."""
    if not _REF:
        op, b, pre = _problem()
        solver = make_solver("pcg", op, pre)
        state, rep, cap = solve(solver, op, b, pre,
                                SolveConfig(tol=1e-10, maxiter=5000),
                                capture_states_at=[CHECK_K])
        assert rep.converged and rep.iterations > MAX_AT + 5
        _REF["cap"] = cap[CHECK_K]
        _REF["x"] = np.asarray(state.x)
    return _REF


def _state_fields_close(got, want, rtol=1e-9, atol=1e-9):
    for field in got._fields:
        a, c = getattr(got, field), getattr(want, field)
        if hasattr(a, "shape"):
            np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                       rtol=rtol, atol=atol, err_msg=field)


def check_verdict_matches_runtime(spec: str, seed: int) -> str:
    """The harness core: one (spec, campaign) pair, verdict asserted
    against runtime reality both ways.  Returns "accepted"/"rejected"
    for coverage accounting."""
    op, b, pre = _problem()
    campaign = random_campaign(seed)
    config = random_config(seed)
    solver = make_solver("pcg", op, pre)
    backend = make_backend(spec, op, solver=solver)

    try:
        plan = plan_campaign(campaign, backend.capabilities)
    except UnsurvivableCampaignError as e:
        # --- rejected: the error names an event of THIS campaign ...
        assert any(repr(ev) in str(e) for ev in campaign.events), \
            f"rejection does not name a campaign event: {e}"
        # ... the planned solve refuses before iteration 0 ...
        with pytest.raises(UnsurvivableCampaignError):
            solve(solver, op, b, pre, config, backend=backend,
                  failures=campaign)
        # ... and runtime reality agrees: unplanned, the same campaign
        # dies with a *runtime* UnrecoverableFailure.
        backend2 = make_backend(spec, op, solver=solver)
        with pytest.raises(UnrecoverableFailure) as exc:
            solve(solver, op, b, pre,
                  dataclasses_replace(config, plan_campaign=False),
                  backend=backend2, failures=campaign)
        assert not isinstance(exc.value, UnsurvivableCampaignError)
        return "rejected"

    # --- accepted: the solve must recover onto the reference trajectory
    # (traced: the obs cross-check below locks trace == report == plan
    # for every accepted campaign in the sweep)
    ref = _reference()
    tracer = Tracer()
    state, rep, cap = solve(solver, op, b, pre,
                            dataclasses_replace(config, tracer=tracer),
                            backend=backend, failures=campaign,
                            capture_states_at=[CHECK_K])
    assert rep.converged, (spec, seed)
    assert rep.failures_recovered == sum(1 + r.restarts
                                         for r in plan.recoveries)
    assert rep.recovery_restarts == sum(r.restarts for r in plan.recoveries)
    assert rep.storage_failures == plan.storage_losses
    # trace-event counts == report counters == registry (ISSUE 6): the
    # tracer saw every failure, recovery, restart, commit, and abort
    # the report claims, for this spec family too.
    check_trace_report(tracer, rep)
    _state_fields_close(cap[CHECK_K], ref["cap"])
    x = np.asarray(state.x)
    assert float(np.linalg.norm(x - ref["x"])
                 / np.linalg.norm(ref["x"])) < 1e-8
    res = float(np.linalg.norm(np.asarray(b - op.apply(state.x)))
                / np.linalg.norm(np.asarray(b)))
    assert res < 1e-9
    return "accepted"


def dataclasses_replace(config, **kw):
    import dataclasses

    return dataclasses.replace(config, **kw)


@pytest.mark.parametrize("spec", SPECS)
def test_campaign_fuzz_deterministic_sweep(spec):
    verdicts = {check_verdict_matches_runtime(spec, seed) for seed in SEEDS}
    # the seed set is chosen so every spec sees at least one accepted
    # campaign (recovery really exercised), and the weaker specs at
    # least one rejection — drift in the generator shows up here
    assert "accepted" in verdicts, f"{spec}: no accepted campaign in sweep"


def test_sweep_exercises_both_verdicts_overall():
    """Across the sweep, both planner verdicts occur for the fixed
    seeds (the generator produces both survivable and unsurvivable
    campaigns)."""
    verdicts = [check_verdict_matches_runtime(spec, seed)
                for spec in ("nvm-prd", "erasure(nvm-prd x6+2p)")
                for seed in SEEDS]
    assert "accepted" in verdicts and "rejected" in verdicts


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=100, max_value=10**6))
def test_campaign_fuzz_property(seed):
    """Property variant (ROADMAP: keep deterministic sweeps alongside);
    one spec per example keeps hypothesis runtime sane."""
    check_verdict_matches_runtime("erasure(nvm-prd x6+2p)", seed)


# ------------------------------------------------ the sharded fuzz leg
# (ISSUE 7): seeded campaigns also draw sharded configurations — a
# device-shard count in {1, 2, 4, 8} and shard-addressed events mixed
# with block events — against every registered spec family.  Runs in a
# subprocess (the multi_device fixture) because the faked devices must
# exist before jax imports.  Verdicts, both ways:
#
# - accept => the sharded solve is BITWISE identical to the unsharded
#   solve of the shard-resolved campaign (the DESIGN.md §10 invariant),
#   and lands on the no-failure trajectory to the sweep's tolerance;
# - reject => the planner's error names a violating campaign event.
_SHARDED_SUB = r"""
import json
import numpy as np
import jax
jax.config.update("jax_enable_x64", True)
from repro.core.poisson import make_poisson_problem, PRECONDITIONERS
from repro.distributed.sharding import shard_problem
from repro.solvers import driver as drv
from repro.solvers.driver import (FailureCampaign, FailureEvent,
                                  SolveConfig, UnsurvivableCampaignError,
                                  plan_campaign, resolve_shard_events)
from repro.solvers.registry import make_solver, make_backend

NBLOCKS = 8
SPECS = ("esr", "nvm-homogeneous", "nvm-prd", "tiered(nvm-homogeneous)",
         "replicated(nvm-prd x2)", "replicated(nvm-prd x3)",
         "erasure(nvm-prd x4+p)", "erasure(nvm-prd x6+2p)")
SEEDS = (0, 1, 2, 3)

op, b = make_poisson_problem(8, 8, 8, nblocks=NBLOCKS)
pre = PRECONDITIONERS["jacobi"](op)


def random_sharded_campaign(seed, nshards):
    rng = np.random.default_rng(seed)
    events = []
    n_at = int(rng.integers(1, 3))
    ats = sorted(rng.choice(np.arange(3, 13), size=n_at, replace=False))
    for at in ats:
        prd = bool(rng.random() < 0.45)
        if rng.random() < 0.5:   # device-addressed kill
            ev = FailureEvent(shard=int(rng.integers(nshards)),
                              at_iteration=int(at), prd=prd)
        else:                    # block-addressed kill
            nb = int(rng.integers(1, 3))
            blocks = tuple(sorted(int(x) for x in
                                  rng.choice(NBLOCKS, nb, replace=False)))
            ev = FailureEvent(blocks=blocks, at_iteration=int(at), prd=prd)
        events.append(ev)
    return FailureCampaign(tuple(events))


def random_config(seed):
    rng = np.random.default_rng(10_000 + seed)
    return SolveConfig(
        tol=1e-10, maxiter=5000,
        persist_mode=str(rng.choice(["sync", "overlap"])),
        persistence_period=int(rng.choice([1, 3])))


# the no-failure reference trajectory (unsharded)
_s = make_solver("pcg", op, pre)
ref_state, ref_rep, _ = drv.solve(
    _s, op, b, pre, config=SolveConfig(tol=1e-10, maxiter=5000))
assert ref_rep.converged
ref_x = np.asarray(ref_state.x)

cases = []
unsharded = {}
for seed in SEEDS:
    rng = np.random.default_rng(20_000 + seed)
    nshards = int(rng.choice([1, 2, 4, 8]))
    sop, sb = shard_problem(op, b, nshards)
    campaign = random_sharded_campaign(seed, nshards)
    config = random_config(seed)
    resolved = resolve_shard_events(campaign, sop.layout)
    for spec in SPECS:
        solver = make_solver("pcg", sop, pre)
        backend = make_backend(spec, op, solver=solver)
        rec = {"spec": spec, "seed": seed, "nshards": nshards}
        try:
            plan_campaign(campaign, backend.capabilities,
                          layout=sop.layout)
        except UnsurvivableCampaignError as e:
            rec["verdict"] = "rejected"
            rec["names_event"] = any(repr(ev) in str(e)
                                     for ev in resolved.events)
            cases.append(rec)
            continue
        st, rep, _ = drv.solve(solver, sop, sb, pre, config=config,
                               backend=backend, failures=campaign)
        key = (seed, spec)
        if key not in unsharded:
            s0 = make_solver("pcg", op, pre)
            b0 = make_backend(spec, op, solver=s0)
            st0, _, _ = drv.solve(s0, op, b, pre, config=config,
                                  backend=b0, failures=resolved)
            unsharded[key] = np.asarray(st0.x).tobytes()
        x = np.asarray(st.x)
        rec["verdict"] = "accepted"
        rec["converged"] = bool(rep.converged)
        rec["bit_identical"] = x.tobytes() == unsharded[key]
        rec["close_to_ref"] = bool(
            np.linalg.norm(x - ref_x) / np.linalg.norm(ref_x) < 1e-8)
        cases.append(rec)

print(json.dumps({"cases": cases}))
"""


@pytest.mark.multi_device
def test_campaign_fuzz_sharded_leg(multi_device):
    out = multi_device.run(_SHARDED_SUB, ndevices=8, timeout=1800)
    cases = out["cases"]
    assert len(cases) == len(SPECS) * len(SEEDS)
    verdicts = {c["verdict"] for c in cases}
    assert verdicts == {"accepted", "rejected"}, \
        "seed set must exercise both planner verdicts"
    for c in cases:
        ctx = (c["spec"], c["seed"], c["nshards"])
        if c["verdict"] == "accepted":
            assert c["converged"], ctx
            assert c["bit_identical"], ctx
            assert c["close_to_ref"], ctx
        else:
            assert c["names_event"], ctx


# ------------------------------------------------ the advisor acceptance
def test_advisor_picks_k2p_over_mirror_for_double_storage_loss():
    """ISSUE 5 acceptance: for a campaign whose recovery fetches after
    two storage losses, the advisor picks the x6+2p stripe over the
    triple mirror on footprint grounds (1.33x vs 3x), and the advised
    spec actually carries the campaign."""
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro import api

    problem = api.Problem.poisson(8, nblocks=NBLOCKS)
    campaign = FailureCampaign((
        FailureEvent(blocks=(1,), at_iteration=6, prd=True),
        FailureEvent(blocks=(2,), at_iteration=10, prd=True),
    ))
    advice = api.advise(problem, campaign)
    assert advice.chosen == "erasure(nvm-prd x6+2p)"
    by_spec = {r.spec: r for r in advice.ranked}
    assert "replicated(nvm-prd x3)" in by_spec
    assert (by_spec["erasure(nvm-prd x6+2p)"].storage_values
            < by_spec["replicated(nvm-prd x3)"].storage_values)
    assert {r.spec for r in advice.rejected} == {
        "esr", "nvm-homogeneous", "nvm-prd", "tiered(nvm-prd)",
        "replicated(nvm-prd x2)", "erasure(nvm-prd x4+p)"}
    # the advised spec carries the campaign end to end
    spec = api.ResilienceSpec.advise(problem, campaign,
                                     persist_mode="overlap")
    assert spec.backend == "erasure(nvm-prd x6+2p)"
    result = api.solve(problem, "pcg", spec, failures=campaign)
    assert result.converged and result.report.storage_failures == 2


def test_advise_spec_driver_level_and_no_survivor():
    """The driver-level surface: mapping candidates, rejection reasons,
    and the no-survivor verdict (chosen=None, never an exception at
    this level)."""
    op, _, _ = _problem()
    solver = make_solver("pcg", op, JacobiPreconditioner(op))
    campaign = FailureCampaign((
        FailureEvent(blocks=(1,), at_iteration=5, prd=True),))
    candidates = {
        "nvm-prd": make_backend("nvm-prd", op, solver=solver),
        "erasure(nvm-prd x4+p)": make_backend("erasure(nvm-prd x4+p)", op,
                                              solver=solver),
    }
    advice = advise_spec(campaign, candidates, probe_values=op.n)
    assert advice.chosen == "erasure(nvm-prd x4+p)"
    assert advice.rejected[0].spec == "nvm-prd"
    assert "persistence-service" in advice.rejected[0].reason
    # an unsatisfiable campaign: nothing survives three storage losses
    triple = FailureCampaign(tuple(
        FailureEvent(blocks=(1,), at_iteration=k, prd=True)
        for k in (4, 6, 8)))
    advice = advise_spec(
        triple,
        [("erasure(nvm-prd x6+2p)",
          make_backend("erasure(nvm-prd x6+2p)", op, solver=solver))])
    assert advice.chosen is None and advice.ranked == ()

    from repro import api

    with pytest.raises(UnsurvivableCampaignError, match="no candidate"):
        api.ResilienceSpec.advise(api.Problem.poisson(8, nblocks=NBLOCKS),
                                  triple)


# ------------------------------------------------ the service leg (ISSUE 9)
# Seeded multi-tenant traces replayed through the batched SolveService
# (docs/serving.md): the same planner == runtime contract, lifted to the
# service boundary.  Accepted tenants must match their solo api.solve
# trajectory; unsurvivable requests must be refused at submission with
# the planner naming the violating event.  Seeds picked so the leg
# exercises block, PRD, and shard kills plus one unsurvivable request
# (seed 28's t3: a PRD kill against bare nvm-prd).
SERVICE_TRACE_SEEDS = (0, 6, 28)


def _expect_unsurvivable(req) -> bool:
    """The oracle, derived from the declarative request alone: only a
    PRD kill against a spec with no storage redundancy is refusable —
    every other single-event campaign has a surviving candidate (the
    advisor path never fails on these traces)."""
    return bool(req.failures and req.failures[0].prd
                and req.backend == "nvm-prd")


def _solo_service_reference(req):
    """The tenant's solo trajectory: same declarative request through
    ``api.solve``, with shard events resolved against the same logical
    layout the service uses and the same advisor fallback."""
    from repro import api
    from repro.distributed.sharding import ShardLayout
    from repro.solvers.driver import resolve_shard_events

    problem = req.problem()
    campaign = resolve_shard_events(
        req.failures, ShardLayout(req.nblocks, req.nshards))
    resilience = req.resilience_spec()
    if resilience is None:
        resilience = api.ResilienceSpec.advise(problem, campaign,
                                               solver=req.solver_spec())
    return api.solve(problem, req.solver_spec(), resilience,
                     failures=campaign)


@pytest.mark.parametrize("seed", SERVICE_TRACE_SEEDS)
def test_campaign_fuzz_service_leg(seed, request_trace):
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.serving import ServiceConfig, SolveService

    reqs = request_trace(seed, nrequests=5, failure_rate=0.6)
    svc = SolveService(ServiceConfig(lanes=4, max_queue=16))
    tickets = {}
    refused = {}
    for req in sorted(reqs, key=lambda r: (r.at_step, r.tenant)):
        try:
            tickets[req.tenant] = svc.submit_request(req)
        except UnsurvivableCampaignError as e:
            refused[req.tenant] = str(e)
    svc.drain()

    for req in reqs:
        if _expect_unsurvivable(req):
            # refused at submission, naming the violating event
            assert req.tenant in refused, (seed, req.tenant)
            msg = refused[req.tenant]
            assert "prd" in msg, msg
            assert str(req.failures[0].at_iteration) in msg, msg
            continue
        ticket = tickets[req.tenant]
        assert ticket.accepted, (seed, req.tenant)
        rep = ticket.result.report
        solo = _solo_service_reference(req)
        ctx = (seed, req.tenant, req.solver, req.backend)
        # per-tenant exactness against the solo trajectory
        assert rep.converged == solo.converged, ctx
        assert rep.iterations == solo.iterations, ctx
        np.testing.assert_allclose(np.asarray(ticket.result.x),
                                   np.asarray(solo.x),
                                   rtol=1e-8, atol=1e-10, err_msg=str(ctx))
        assert rep.failures_recovered == solo.report.failures_recovered, ctx
        assert rep.storage_failures == solo.report.storage_failures, ctx


def test_service_trace_seeds_cover_both_verdicts(request_trace):
    """The seed set must keep exercising both submission verdicts — the
    analogue of the solo harness's accepted+rejected coverage check."""
    verdicts = set()
    for seed in SERVICE_TRACE_SEEDS:
        for req in request_trace(seed, nrequests=5, failure_rate=0.6):
            verdicts.add("refused" if _expect_unsurvivable(req)
                         else "accepted")
    assert verdicts == {"accepted", "refused"}
