"""Service-level test layer for the multi-tenant batched solve service
(ISSUE 9): tenant isolation, bucketing determinism, admission control.

The contracts locked down here (docs/serving.md):

- **Tenant isolation** — killing one tenant mid-batch (block, PRD, and
  shard variants, across >= 3 solver families x >= 3 spec families)
  rolls only the victim back; the victim reconverges onto its solo
  trajectory, and every cohabitant lane's iterates — final x, captured
  mid-trajectory states, the full residual history — stay
  **bit-identical** to a solo run of the same tenant through the same
  service (same bucket shape, same compiled vmapped step: the
  bit-identity scope).
- **Bucketing determinism** — a padded, vmapped lane solve agrees with
  the per-problem ``api.solve`` answer to machine precision for every
  batchable solver family (the dot products regroup across the padded
  length, so agreement is to tolerance, not bits).
- **Admission control** — the bounded queue rejects with a ticket (not
  an exception), waits are measured in deterministic service steps, and
  the queue/occupancy statistics land in both SolveReport and the
  service registry.
"""
import numpy as np
import pytest

from repro import api
from repro.obs import check_report_consistency, Tracer
from repro.serving.solve_service import ServiceError
from repro.solvers import FailureEvent, UnsurvivableCampaignError

CAPTURE_K = 5  # mid-trajectory capture: past iteration 0, before any
#                family converges on the sweep grids


def _bitwise_state_equal(got, want):
    return all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(got, want))


def _service(lanes=4, max_queue=8, tracer=None):
    return api.SolveService(api.ServiceConfig(lanes=lanes,
                                              max_queue=max_queue,
                                              tracer=tracer))


# ---------------------------------------------------------------------------
# Tenant-isolation acceptance sweep: >= 3 solver families x >= 3 spec
# families, one kill variant each (block / PRD / shard).
# ---------------------------------------------------------------------------

ISOLATION_CASES = (
    # (solver, tol, spec, victim kill, victim nshards)
    ("pcg", 1e-9, "replicated(nvm-prd x2)",
     FailureEvent(blocks=(1,), at_iteration=4, prd=True), 1),
    ("bicgstab", 1e-9, "nvm-prd",
     FailureEvent(blocks=(0, 1), at_iteration=3), 1),
    ("chebyshev", 1e-8, "erasure(nvm-prd x4+p)",
     FailureEvent(shard=1, at_iteration=6), 3),
)


@pytest.mark.parametrize("solver,tol,spec,kill,nshards", ISOLATION_CASES,
                         ids=[c[0] for c in ISOLATION_CASES])
def test_tenant_isolation_kill_mid_batch(solver, tol, spec, kill, nshards):
    victim_p = api.Problem.poisson(6, nblocks=6)
    cohab_ps = {
        "c1": api.Problem.poisson(5, 8, 8, nblocks=5),   # bucket (8,8,8)
        "c2": api.Problem.poisson(8, nblocks=8),          # exact-fit lane
    }
    sspec = api.SolverSpec(solver, tol=tol, maxiter=2000)

    svc = _service()
    tv = svc.submit(victim_p, sspec, spec, failures=(kill,), tenant="victim",
                    nshards=nshards, capture_states_at=(CAPTURE_K,))
    tc = {name: svc.submit(p, sspec, "nvm-prd", tenant=name,
                           capture_states_at=(CAPTURE_K,))
          for name, p in cohab_ps.items()}
    svc.drain()

    # Victim: recovered mid-batch and reconverged onto its solo
    # trajectory (recovery reconstructs in tenant space, so exactness is
    # to solver tolerance, not bits).
    vrep = tv.result.report
    assert vrep.converged
    assert vrep.failures_recovered >= 1
    assert vrep.nshards == nshards
    solo = api.solve(victim_p, sspec)
    assert solo.iterations == vrep.iterations
    np.testing.assert_allclose(tv.result.x, solo.x, rtol=1e-8, atol=1e-10)
    check_report_consistency(vrep)

    # Cohabitants: bit-identical to their solo no-failure runs through
    # the same service (same bucket shape + lane width = same compiled
    # step), regardless of which lane each run seated them in.
    for name, p in cohab_ps.items():
        ref_svc = _service()
        ref = ref_svc.submit(p, sspec, "nvm-prd", tenant=name,
                             capture_states_at=(CAPTURE_K,))
        ref_svc.drain()
        got, want = tc[name].result, ref.result
        assert np.array_equal(got.x, want.x), f"{name}: final x drifted"
        assert _bitwise_state_equal(got.captured[CAPTURE_K],
                                    want.captured[CAPTURE_K]), \
            f"{name}: captured state at k={CAPTURE_K} drifted"
        assert (got.report.residual_history
                == want.report.residual_history), \
            f"{name}: residual history drifted"
        assert got.report.failures_recovered == 0
        check_report_consistency(got.report)


def test_storage_only_kill_is_isolated_and_survivable():
    """A PRD kill with no compute-block loss: the victim's persistence
    service dies but its lanes keep stepping; cohabitants unaffected."""
    p1 = api.Problem.poisson(4, nblocks=4)
    p2 = api.Problem.poisson(3, 4, 4, nblocks=3)
    svc = _service()
    t1 = svc.submit(p1, api.SolverSpec("pcg", tol=1e-9), "nvm-prd",
                    failures=(FailureEvent(blocks=(), at_iteration=2,
                                           prd=True),),
                    tenant="t1")
    t2 = svc.submit(p2, api.SolverSpec("pcg", tol=1e-9), "nvm-prd",
                    tenant="t2")
    svc.drain()
    assert t1.result.report.converged
    assert t1.result.report.storage_failures == 1
    assert t1.result.report.failures_recovered == 0
    assert t2.result.report.converged
    assert t2.result.report.storage_failures == 0


# ---------------------------------------------------------------------------
# Bucketing determinism: padded + vmapped == per-problem api.solve.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("solver,tol", [("pcg", 1e-9), ("bicgstab", 1e-9),
                                        ("chebyshev", 1e-8),
                                        ("jacobi", 1e-6)])
def test_bucketed_solve_matches_solo_api_solve(solver, tol):
    """Mixed tenant sizes share buckets; every tenant's service answer
    equals its solo api.solve answer to machine precision, with the
    same convergence verdict."""
    problems = [api.Problem.poisson(3, 4, 4, nblocks=3),
                api.Problem.poisson(4, nblocks=4),
                api.Problem.poisson(6, nblocks=6)]
    sspec = api.SolverSpec(solver, tol=tol, maxiter=3000)
    svc = _service()
    tickets = [svc.submit(p, sspec, "nvm-prd", tenant=f"t{i}")
               for i, p in enumerate(problems)]
    svc.drain()
    for p, tk in zip(problems, tickets):
        solo = api.solve(p, sspec)
        assert tk.result.report.converged and solo.converged
        assert tk.result.report.final_relres < tol
        np.testing.assert_allclose(tk.result.x, solo.x,
                                   rtol=1e-8, atol=1e-10)


def test_replay_is_deterministic(request_trace):
    """Two replays of the same seeded trace produce bit-identical
    iterates and identical service clocks."""
    reqs = request_trace(1, nrequests=4, failure_rate=0.5,
                         survivable_only=True)
    a, b = _service(), _service()
    ta, tb = a.replay(reqs), b.replay(reqs)
    assert a.now == b.now
    assert sorted(ta) == sorted(tb)
    for name in ta:
        assert ta[name].accepted == tb[name].accepted
        if ta[name].accepted:
            assert np.array_equal(ta[name].result.x, tb[name].result.x)
            assert (ta[name].result.report.residual_history
                    == tb[name].result.report.residual_history)


# ---------------------------------------------------------------------------
# Admission control: bounded queue, waits, occupancy.
# ---------------------------------------------------------------------------

def test_bounded_queue_rejects_and_counts():
    p = api.Problem.poisson(4, nblocks=4)
    sspec = api.SolverSpec("pcg", tol=1e-9)
    tr = Tracer()
    svc = _service(lanes=1, max_queue=2, tracer=tr)
    tickets = [svc.submit(p, sspec, "nvm-prd", tenant=f"t{i}")
               for i in range(4)]
    accepted = [t for t in tickets if t.accepted]
    rejected = [t for t in tickets if not t.accepted]
    assert len(accepted) == 2 and len(rejected) == 2
    assert all(t.reason == "queue full" for t in rejected)
    assert svc.metrics.counter_value("service.submitted") == 4
    assert svc.metrics.counter_value("service.rejected") == 2
    svc.drain()
    assert svc.metrics.counter_value("service.admitted") == 2
    assert svc.metrics.counter_value("service.completed") == 2
    assert tr.counts().get("service.reject", 0) == 2
    # rejected tickets never produce results
    assert all(t.result is None for t in rejected)
    assert all(t.result.report.converged for t in accepted)


def test_queue_wait_and_occupancy_stats():
    """With one lane, the second tenant must wait for the first to
    finish; its wait (in service steps) lands in the report, the
    service histograms, and the tenant registry (derived-view rule)."""
    p = api.Problem.poisson(4, nblocks=4)
    sspec = api.SolverSpec("pcg", tol=1e-9)
    svc = _service(lanes=1, max_queue=4)
    t1 = svc.submit(p, sspec, "nvm-prd", tenant="first")
    t2 = svc.submit(p, sspec, "nvm-prd", tenant="second")
    svc.drain()
    r1, r2 = t1.result.report, t2.result.report
    assert r1.service_queue_wait_steps == 0
    # the second tenant waits exactly the first one's residency plus the
    # admission step: the lane frees mid-step, after that step's
    # admission pass already ran, so the successor boards next step
    assert r2.service_queue_wait_steps == r1.service_lane_steps + 1
    assert r2.service_queue_wait_steps > 0
    for rep in (r1, r2):
        assert rep.service_lane_steps > 0
        assert rep.service_batch_occupancy == 1.0  # single-lane bucket
        # derived view: the report field reads back out of the registry
        assert (rep.metrics.counter_value("service.wait_steps")
                == rep.service_queue_wait_steps)
        assert (rep.metrics.counter_value("service.lane_steps")
                == rep.service_lane_steps)
    hist = svc.metrics.histogram("service.queue_wait_steps")
    assert hist.count == 2
    assert hist.percentile(99) == r2.service_queue_wait_steps


def test_occupancy_reflects_shared_bucket():
    """Two same-bucket tenants in a 4-lane bucket see occupancy 0.5
    while both are live."""
    sspec = api.SolverSpec("chebyshev", tol=1e-8, maxiter=2000)
    svc = _service(lanes=4)
    t1 = svc.submit(api.Problem.poisson(6, nblocks=6), sspec, "nvm-prd",
                    tenant="a")
    t2 = svc.submit(api.Problem.poisson(6, nblocks=6), sspec, "nvm-prd",
                    tenant="b")
    svc.drain()
    # identical problems retire at the same step: occupancy 0.5 for both
    assert t1.result.report.service_batch_occupancy == pytest.approx(0.5)
    assert t2.result.report.service_batch_occupancy == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# Admission validation and advisor integration.
# ---------------------------------------------------------------------------

def test_rejects_non_batchable_solver():
    with pytest.raises(ServiceError, match="no batched lane step"):
        _service().submit(api.Problem.poisson(4, nblocks=4),
                          api.SolverSpec("gmres"))


def test_rejects_non_diagonal_preconditioner():
    p = api.Problem.poisson(4, nblocks=4, preconditioner="block_jacobi")
    with pytest.raises(ServiceError, match="diagonal"):
        _service().submit(p, api.SolverSpec("pcg"))


def test_rejects_non_stencil_operator():
    from repro.core.poisson import DenseOperator

    n, nblocks = 16, 4
    rng = np.random.default_rng(0)
    a = rng.standard_normal((n, n))
    op = DenseOperator(a @ a.T + n * np.eye(n), nblocks=nblocks)
    p = api.Problem.from_parts(op, np.ones(n))
    with pytest.raises(ServiceError, match="stencil"):
        _service().submit(p, api.SolverSpec("pcg"))


def test_unsurvivable_campaign_raises_at_submission():
    """plan_campaign runs at submit: a PRD kill against a bare nvm-prd
    spec raises before the tenant reaches the queue, naming the event."""
    svc = _service()
    with pytest.raises(UnsurvivableCampaignError, match="prd"):
        svc.submit(api.Problem.poisson(4, nblocks=4),
                   api.SolverSpec("pcg"), "nvm-prd",
                   failures=(FailureEvent(blocks=(1,), at_iteration=3,
                                          prd=True),))
    assert svc.active == 0 and svc.queued == 0


def test_advisor_picks_spec_when_unset():
    """resilience=None routes through api.ResilienceSpec.advise: the
    chosen backend survives the tenant's campaign."""
    svc = _service()
    tk = svc.submit(api.Problem.poisson(4, nblocks=4),
                    api.SolverSpec("pcg", tol=1e-9), None,
                    failures=(FailureEvent(blocks=(1,), at_iteration=3,
                                           prd=True),),
                    tenant="advised")
    svc.drain()
    rep = tk.result.report
    assert rep.converged
    assert rep.failures_recovered >= 1 and rep.storage_failures >= 1
    assert tk.result.backend.capabilities.survives_prd_loss


def test_shard_events_resolve_against_declared_layout():
    """shard= kills resolve against the tenant's declared logical
    ShardLayout — no device mesh anywhere — and per-shard traffic is
    labeled by that layout."""
    svc = _service()
    tk = svc.submit(api.Problem.poisson(4, nblocks=4),
                    api.SolverSpec("pcg", tol=1e-9),
                    "replicated(nvm-prd x2)",
                    failures=(FailureEvent(shard=1, at_iteration=3),),
                    tenant="sharded", nshards=2)
    svc.drain()
    rep = tk.result.report
    assert rep.converged and rep.failures_recovered == 1
    assert rep.nshards == 2
    assert set(rep.persist_bytes_by_shard) == {0, 1}
    # the shard kill lost shard 1's blocks: recovery fetched them back
    assert rep.recovery_fetch_bytes_by_shard.get(1, 0) > 0


def test_service_tracer_taxonomy(request_trace):
    """The service emits its span/event taxonomy (docs/serving.md):
    submit/admit/complete events, the service.step span, and the
    per-tenant pipeline events underneath."""
    reqs = request_trace(2, nrequests=3, failure_rate=1.0,
                         survivable_only=True)
    tr = Tracer()
    svc = api.SolveService(api.ServiceConfig(lanes=2, tracer=tr))
    tickets = svc.replay(reqs)
    counts = tr.counts()
    n_acc = sum(1 for t in tickets.values() if t.accepted)
    assert counts["service.submit"] == len(reqs)
    assert counts["service.admit"] == n_acc
    assert counts["service.complete"] == n_acc
    # every non-idle service step opened a span (idle ticks toward a
    # future arrival advance the clock without spanning)
    assert 0 < counts["service.step"] <= svc.now
    assert counts["solve.begin"] == n_acc
    assert counts["solve.end"] == n_acc
    # per-tenant recovery events flowed through the shared tracer
    total_recovered = sum(t.result.report.failures_recovered
                          for t in tickets.values() if t.accepted)
    assert counts.get("recovery.absorbed", 0) == total_recovered
