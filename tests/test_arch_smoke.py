"""Per-architecture smoke tests: reduced same-family configs, one forward
and one train step on CPU, asserting output shapes and no NaNs; plus a
short prefill+decode round-trip for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry as R
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_step import make_train_step

ARCHS = R.ARCH_IDS


def _batch_for(cfg, b=2, s=32):
    key = jax.random.PRNGKey(7)
    batch = {}
    if cfg.frontend == "vision":
        batch["tokens"] = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s)[None, None], (3, b, s)).astype(jnp.int32)
    else:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (b, cfg.enc_seq, cfg.d_model))
    batch["targets"] = jax.random.randint(key, (b, s), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = R.get_config(arch, smoke=True)
    params, specs = R.init_params(cfg, jax.random.PRNGKey(0))
    # spec tree mirrors the param tree
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, tuple))
    batch = _batch_for(cfg)
    logits, aux = jax.jit(R.make_train_forward(cfg))(params, batch)
    b, s = batch["targets"].shape
    assert logits.shape == (b, s, cfg.vocab)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"
    assert not bool(jnp.isnan(aux)), f"{arch}: NaN aux loss"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = R.get_config(arch, smoke=True)
    params, _ = R.init_params(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(R.make_train_forward(cfg), AdamWConfig(lr=1e-3)))
    opt = adamw_init(params)
    batch = _batch_for(cfg)
    p1, o1, m1 = step(params, opt, batch)
    p2, o2, m2 = step(p1, o1, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"]) + 1.0  # sane progression
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)))
    assert moved, f"{arch}: train step did not update params"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode(arch):
    cfg = R.get_config(arch, smoke=True)
    if cfg.frontend == "vision":
        pytest.skip("decode smoke uses token prompts; vlm covered in forward")
    params, _ = R.init_params(cfg, jax.random.PRNGKey(0))
    b, s, extra = 2, 16, 4
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    caches, _ = R.init_caches(cfg, b, s + extra)
    inputs = {"tokens": toks}
    if cfg.family == "encdec":
        inputs["frames"] = jax.random.normal(key, (b, cfg.enc_seq, cfg.d_model))
    logits, caches = jax.jit(R.make_prefill(cfg))(params, inputs, caches)
    assert logits.shape == (b, s, cfg.vocab)
    decode = jax.jit(R.make_decode(cfg))
    idx = jnp.asarray(s, jnp.int32)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    for _ in range(extra):
        logits1, caches = decode(params, tok, caches, idx)
        assert logits1.shape == (b, 1, cfg.vocab)
        assert not bool(jnp.isnan(logits1).any())
        tok = jnp.argmax(logits1[:, -1:], -1).astype(jnp.int32)
        idx = idx + 1


def test_full_configs_param_counts():
    """Full configs instantiate abstractly (no allocation) with plausible
    parameter counts vs the published sizes."""
    expected = {
        # NOTE: the assigned 48L x 64e x d_ff=1408 (gated) config totals
        # ~28B with 3-matrix GLU experts; the "16B" in the marketing name
        # counts a different layer/expert split.  We build the ASSIGNED
        # shape exactly, so the window reflects it.
        "moonshot_v1_16b_a3b": (22e9, 32e9),
        "dbrx_132b": (110e9, 150e9),
        "granite_20b": (15e9, 25e9),
        "starcoder2_3b": (2.5e9, 4e9),
        "llama3_8b": (6e9, 10e9),
        "gemma3_12b": (9e9, 15e9),
        "whisper_small": (0.15e9, 0.45e9),
        "mamba2_370m": (0.25e9, 0.55e9),
        "recurrentgemma_9b": (7e9, 12e9),
        "qwen2_vl_72b": (60e9, 85e9),
    }
    for arch, (lo, hi) in expected.items():
        cfg = R.get_config(arch)
        structs, specs = R.abstract_params(cfg)
        n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(structs))
        assert lo < n < hi, f"{arch}: param count {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]B"
