"""Overlapped persistence + multi-failure campaign acceptance suite.

The tentpole claims (ISSUE 2):

- every zoo solver survives a campaign that chains a *mid-burst* failure
  (the ESRP burst is interrupted while its last persist is staged but not
  committed, so recovery falls back to the previous durable run), an
  *overlapping* failure (a second block set crashes while the first
  recovery's payload fetch is already in flight, forcing a refetch over
  the enlarged union), and a *repeated* failure after recovery — through
  all three backends, reconstructing to machine precision;
- the overlapped pipeline hides persistence behind compute
  (``persist_hidden_fraction > 0``) while the synchronous baseline pays
  everything on the critical path.
"""
import numpy as np
import pytest

from repro.core import JacobiPreconditioner, make_poisson_problem
from repro.core.esr import InMemoryESR
from repro.core.nvm_esr import NVMESRHomogeneous
from repro.core.state import PCG_SCHEMA
from repro.nvm.store import CostModel, PersistStager
from repro.solvers import (
    SOLVERS,
    FailureCampaign,
    FailureEvent,
    FailurePlan,
    SolveConfig,
    make_backend,
    make_solver,
    solve,
)

ALL_BACKENDS = ("esr", "nvm-homogeneous", "nvm-prd")

# Per-solver campaign schedule, chosen against each solver's convergence
# horizon on the 8x8x8 problem.  With persistence period T and history h,
# overlapped commits trail staging by one iteration, so a failure at the
# listed iteration catches the burst's last persist staged-but-uncommitted
# (mid-burst) and rolls back to krec — the previous durable run's end.
#   fields: (solver opts, T, event1_at, krec1, event2_at, krec2)
CAMPAIGN_CASES = {
    "pcg":       ({},         5, 6, 1, 12, 11),
    "chebyshev": ({},         5, 6, 1, 12, 11),
    "jacobi":    ({},         5, 5, 0, 12, 10),
    "bicgstab":  ({},         5, 5, 0,  9,  5),   # converges at k=12
    "gmres":     ({"m": 4},   3, 3, 0,  7,  6),   # k counts restart cycles
}
assert set(CAMPAIGN_CASES) == set(SOLVERS)

CAPTURE = tuple(range(14))


def _problem():
    op, b = make_poisson_problem(8, 8, 8, nblocks=4)
    return op, b, JacobiPreconditioner(op)


_REF_CACHE = {}


def _reference(solver_name):
    """Fault-free captured states per solver (shared across backends)."""
    if solver_name not in _REF_CACHE:
        op, b, pre = _problem()
        opts = CAMPAIGN_CASES[solver_name][0]
        solver = make_solver(solver_name, op, pre, **opts)
        _, rep, cap = solve(solver, op, b, pre,
                            SolveConfig(tol=1e-10, maxiter=5000),
                            capture_states_at=CAPTURE)
        assert rep.converged
        _REF_CACHE[solver_name] = cap
    return _REF_CACHE[solver_name]


def _state_fields_close(got, want, rtol=1e-8, atol=1e-10):
    for field in got._fields:
        a, c = getattr(got, field), getattr(want, field)
        if hasattr(a, "shape"):
            np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                       rtol=rtol, atol=atol, err_msg=field)


@pytest.mark.parametrize("backend_name", ALL_BACKENDS)
@pytest.mark.parametrize("solver_name", sorted(SOLVERS))
def test_campaign_overlapping_midburst_repeated(solver_name, backend_name):
    """The acceptance criterion: one campaign chains (1) a mid-burst
    failure whose staged persist is torn away, (2) a second failure
    landing during the in-flight recovery (same union refetched), and
    (3) a repeated failure of an already-failed block after recovery —
    every reconstruction matching the fault-free trajectory."""
    op, b, pre = _problem()
    opts, period, e1, krec1, e2, krec2 = CAMPAIGN_CASES[solver_name]
    ref_cap = _reference(solver_name)

    solver = make_solver(solver_name, op, pre, **opts)
    backend = make_backend(backend_name, op, solver=solver)
    campaign = FailureCampaign((
        FailureEvent(blocks=(1, 2), at_iteration=e1),
        FailureEvent(blocks=(0,), during_recovery_at=e1),  # overlapping
        FailureEvent(blocks=(1,), at_iteration=e2),        # repeated block
    ))
    state, rep, cap = solve(
        solver, op, b, pre,
        SolveConfig(tol=1e-10, maxiter=5000, persistence_period=period,
                    persist_mode="overlap"),
        backend=backend, failures=campaign, capture_states_at=CAPTURE)

    assert rep.failures_recovered == 3
    assert rep.recovery_restarts == 1
    assert rep.wasted_iterations == (e1 - krec1) + (e2 - krec2)
    assert rep.converged
    assert rep.persist_hidden_fraction > 0.0

    # Post-recovery states match the fault-free run at the rollback points
    # (captured last by the recovery that produced them).
    _state_fields_close(cap[krec1], ref_cap[krec1])
    _state_fields_close(cap[krec2], ref_cap[krec2])

    res = float(np.linalg.norm(np.asarray(b - op.apply(state.x)))
                / np.linalg.norm(np.asarray(b)))
    assert res < 1e-9


@pytest.mark.parametrize("backend_name", ALL_BACKENDS)
def test_sync_vs_overlap_accounting(backend_name):
    """Same schedule, two pipelines: overlap hides commit cost behind
    compute, sync pays it all exposed; both persist the same events."""
    op, b, pre = _problem()
    reps = {}
    for mode in ("sync", "overlap"):
        solver = make_solver("pcg", op, pre)
        backend = make_backend(backend_name, op, solver=solver)
        _, rep, _ = solve(solver, op, b, pre,
                          SolveConfig(tol=1e-10, maxiter=5000,
                                      persist_mode=mode),
                          backend=backend)
        reps[mode] = rep

    sync, over = reps["sync"], reps["overlap"]
    assert sync.persist_events == over.persist_events > 0
    np.testing.assert_allclose(sync.persist_cost_s, over.persist_cost_s,
                               rtol=1e-12)
    assert sync.persist_hidden_s == 0.0
    assert sync.persist_hidden_fraction == 0.0
    assert sync.persist_exposed_s == pytest.approx(sync.persist_cost_s)
    assert sync.persist_stage_s == 0.0          # no staging copy in sync
    assert over.persist_hidden_fraction > 0.0
    assert over.persist_stage_s > 0.0
    assert over.persist_exposed_s < sync.persist_exposed_s


def test_overlap_with_duck_typed_legacy_backend():
    """Backends without a native begin/commit pipeline get driver-side
    staging: overlap mode works through the legacy adapter too."""
    from repro.core.state import RecoveryPayload

    class OldStyleBackend:
        def __init__(self, nblocks, block_size):
            self.nblocks, self.block_size = nblocks, block_size
            self.slots = {}

        def persist(self, k, beta, p_full):
            self.slots[k] = (beta, np.asarray(p_full).copy())
            return 0.0

        def fail(self, blocks):
            pass

        def recover(self, blocks, k):
            def payload(kk, beta):
                shards = [self.slots[kk][1][b * self.block_size:(b + 1) * self.block_size]
                          for b in blocks]
                return RecoveryPayload(kk, beta, np.concatenate(shards))
            return payload(k - 1, 0.0), payload(k, self.slots[k][0])

    op, b, pre = _problem()
    be = OldStyleBackend(op.nblocks, op.partition.block_size)
    solver = make_solver("pcg", op, pre)
    state, rep, _ = solve(solver, op, b, pre,
                          SolveConfig(tol=1e-10, persist_mode="overlap"),
                          backend=be, failures=[FailurePlan(10, (1, 2))])
    assert rep.failures_recovered == 1 and rep.converged
    # the failure aborted the staged persist of iteration 10
    assert rep.wasted_iterations == 1


def test_invalid_persist_mode_rejected():
    op, b, pre = _problem()
    solver = make_solver("pcg", op, pre)
    with pytest.raises(ValueError, match="persist_mode"):
        solve(solver, op, b, pre, SolveConfig(persist_mode="async"))


def test_campaign_validation():
    with pytest.raises(ValueError, match="at least one block"):
        FailureEvent(blocks=())
    with pytest.raises(ValueError, match="exactly one"):
        FailureEvent(blocks=(1,))
    with pytest.raises(ValueError, match="exactly one"):
        FailureEvent(blocks=(1,), at_iteration=3, during_recovery_at=3)
    with pytest.raises(ValueError, match="at_iteration"):
        FailureEvent(blocks=(1,), at_iteration=0)
    with pytest.raises(ValueError, match="matches no"):
        FailureCampaign((FailureEvent(blocks=(1,), during_recovery_at=5),))
    with pytest.raises(TypeError, match="failures"):
        solve_args = _problem()
        op, b, pre = solve_args
        solve(make_solver("pcg", op, pre), op, b, pre,
              SolveConfig(tol=1e-10), failures=[object()])


# ----------------------------------------------------------------------
# Pipeline unit tests
# ----------------------------------------------------------------------
def test_persist_stager_lifecycle():
    flushed = []

    def flush(k, scalars, vectors):
        flushed.append((k, dict(scalars), {n: v.copy() for n, v in vectors.items()}))
        return 1.5

    cm = CostModel()
    st = PersistStager(flush, cost_model=cm)
    assert st.pending == 0
    assert st.commit() == 0.0          # nothing staged: free no-op

    c0 = st.begin(0, {"beta": 0.5}, {"p": np.arange(4.0)})
    assert c0 > 0.0 and st.pending == 1
    assert cm.seconds["stage"] == pytest.approx(c0)

    # double buffering: a second begin is allowed, a third is a bug
    st.begin(1, {"beta": 0.25}, {"p": np.arange(4.0) + 1})
    with pytest.raises(RuntimeError, match="depth"):
        st.begin(2, {}, {"p": np.arange(4.0)})

    assert st.commit() == 1.5          # oldest first
    assert flushed[0][0] == 0 and flushed[0][1] == {"beta": 0.5}
    assert st.drain() == 1.5
    assert flushed[1][0] == 1
    assert st.pending == 0

    st.begin(2, {}, {"p": np.arange(4.0)})
    assert st.abort() == 1
    assert st.pending == 0 and st.drain() == 0.0
    assert len(flushed) == 2           # aborted payload never flushed


@pytest.mark.parametrize("make_be", [
    lambda: InMemoryESR(4, 8, np.float64, schema=PCG_SCHEMA),
    lambda: NVMESRHomogeneous(4, 8, np.float64, schema=PCG_SCHEMA),
])
def test_staged_persist_dies_with_failure(make_be):
    """Crash consistency through the pipeline: a staged-but-uncommitted
    payload is torn away by a failure and can never be recovered, while
    committed slots survive."""
    be = make_be()
    n = 4 * 8
    for k in range(3):
        be.persist_set(k, {"beta": 0.1 * k}, {"p": np.full(n, float(k))})
    be.persist_begin(3, {"beta": 0.3}, {"p": np.full(n, 3.0)})
    be.fail((0,))

    sets = be.recover_set((0,), (1, 2))            # previous run intact
    assert [s.k for s in sets] == [1, 2]
    np.testing.assert_array_equal(sets[-1].vectors["p"], np.full(8, 2.0))
    with pytest.raises(Exception, match="3"):      # staged slot never landed
        be.recover_set((0,), (2, 3))


def test_prd_drain_barrier_settles_epochs():
    """persist_drain commits staged payloads AND joins the PRD exposure
    epoch, so a subsequent crash of the PRD store loses nothing."""
    be = make_backend("nvm-prd", make_poisson_problem(8, 8, 8, nblocks=4)[0],
                      schema=PCG_SCHEMA)
    n = be.nblocks * be.block_size
    be.persist_set(0, {"beta": 0.0}, {"p": np.zeros(n)})
    be.persist_begin(1, {"beta": 0.5}, {"p": np.ones(n)})
    be.persist_drain()
    be.prd.crash()                                  # durable image only
    sets = be.recover_set((1,), (0, 1))
    assert [s.k for s in sets] == [0, 1]
    np.testing.assert_array_equal(sets[-1].vectors["p"],
                                  np.ones(be.block_size))
