"""The BENCH_solver.json trajectory contracts (ISSUE 6).

The bench must (1) validate against the ``repro-bench/v1`` schema
``tools/check_bench.py`` enforces, (2) be deterministic for a fixed
seed outside its ``wall`` subtrees, and (3) be reachable through the
CLI (``benchmarks/run.py --json``) with ``--seed`` threaded through —
the exact invocations the CI ``bench-smoke`` job runs.
"""
import json
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))
sys.path.insert(0, str(REPO))

from check_bench import (  # noqa: E402
    BenchError,
    check_deterministic,
    strip_nondeterministic,
    validate,
)


@pytest.fixture(scope="module")
def smoke_docs():
    """Two back-to-back smoke builds with the same seed (module-scoped:
    the bench runs 14 solves per build)."""
    from benchmarks import bench_trajectory

    return (bench_trajectory.build(seed=0, smoke=True),
            bench_trajectory.build(seed=0, smoke=True))


def test_build_validates_against_schema(smoke_docs):
    doc, _ = smoke_docs
    validate(doc)
    assert doc["schema"] == "repro-bench/v1"
    assert doc["seed"] == 0 and doc["smoke"] is True
    # one entry per canonical spec family composition
    from benchmarks.bench_trajectory import SPECS

    assert set(doc["specs"]) == set(SPECS)
    families = {e["family"] for e in doc["specs"].values()}
    assert {"esr", "nvm-homogeneous", "nvm-prd", "tiered", "replicated",
            "erasure"} <= families
    # the campaign actually ran: every spec absorbed the block failure
    for spec, entry in doc["specs"].items():
        assert entry["counts"]["failures_recovered"] == 1, spec
        assert entry["counts"]["converged"] is True, spec
        assert entry["modeled"]["persist_s_per_event"] > 0, spec
    # redundancy costs storage: the stripe overhead factors are exact
    specs = doc["specs"]
    assert specs["erasure(nvm-prd x4+p)"]["modeled"][
        "storage_overhead_x"] == pytest.approx(1.25)
    assert specs["replicated(nvm-prd x2)"]["modeled"][
        "storage_overhead_x"] == pytest.approx(2.0)
    # the sharded subtree (DESIGN.md §10): the 1-shard row is always
    # buildable in-process, and the per-shard fetch map sums exactly
    assert "1" in doc["sharded"]
    for n, entry in doc["sharded"].items():
        bts = entry["bytes"]
        assert bts["persist_bytes"] > 0, n
        assert bts["recovery_fetch_bytes"] == sum(
            bts["recovery_fetch_bytes_by_shard"].values()), n
    # strict JSON (allow_nan=False is what run.py writes with)
    json.dumps(doc, allow_nan=False)


def test_build_is_deterministic_outside_wall(smoke_docs):
    doc_a, doc_b = smoke_docs
    check_deterministic(doc_a, doc_b)
    assert strip_nondeterministic(doc_a) == strip_nondeterministic(doc_b)
    # 'wall' subtrees exist and carry the non-deterministic quantities
    for entry in doc_a["specs"].values():
        assert set(entry["wall"]) == {"hidden_fraction",
                                      "exposed_persist_s_per_iter",
                                      "iterations_per_s",
                                      "recovery_latency_s"}
        assert entry["wall"]["recovery_latency_s"] > 0  # traced spans


def test_check_bench_flags_violations(smoke_docs):
    doc, _ = smoke_docs
    broken = json.loads(json.dumps(doc))
    broken["schema"] = "repro-bench/v0"
    with pytest.raises(BenchError, match="schema"):
        validate(broken)

    missing = json.loads(json.dumps(doc))
    spec = next(iter(missing["specs"]))
    del missing["specs"][spec]["counts"]["iterations"]
    with pytest.raises(BenchError, match="missing key 'iterations'"):
        validate(missing)

    drifted = json.loads(json.dumps(doc))
    drifted["specs"][spec]["counts"]["iterations"] += 1
    with pytest.raises(BenchError, match="determinism violation"):
        check_deterministic(doc, drifted)
    # ... but wall drift is explicitly tolerated
    wobbled = json.loads(json.dumps(doc))
    wobbled["specs"][spec]["wall"]["iterations_per_s"] *= 2
    check_deterministic(doc, wobbled)


def test_cli_json_mode_threads_seed(tmp_path):
    """The CI invocation: run.py --smoke --json writes a validating
    document wherever --out points, with --seed reaching the campaign."""
    import os

    out = tmp_path / "bench.json"
    env = dict(os.environ, PYTHONPATH=f"{REPO / 'src'}:{REPO}")
    env.pop("REPRO_BENCH_SMOKE", None)
    proc = subprocess.run(
        [sys.executable, str(REPO / "benchmarks" / "run.py"),
         "--smoke", "--json", "--seed", "3", "--out", str(out)],
        cwd=REPO, capture_output=True, text=True, env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert f"wrote {out}" in proc.stdout

    doc = json.loads(out.read_text())
    validate(doc)
    assert doc["seed"] == 3
    # the seed picks the campaign trigger: 4 + (seed % 5)
    assert doc["problem"]["campaign"]["at_iteration"] == 7
    # the CLI fakes 8 host devices, so the full shard sweep is present
    # and the recovery fetch moves only the lost shard's slots
    assert set(doc["sharded"]) == {"1", "4", "8"}
    fetch = {n: e["bytes"]["recovery_fetch_bytes"]
             for n, e in doc["sharded"].items()}
    assert fetch["1"] == 4 * fetch["4"] == 8 * fetch["8"]

    gate = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_bench.py"), str(out)],
        capture_output=True, text=True)
    assert gate.returncode == 0, gate.stdout + gate.stderr
    assert "OK" in gate.stdout


def test_committed_trajectory_validates():
    """The checked-in BENCH_solver.json at the repo root is the
    trajectory's first point — it must keep validating."""
    path = REPO / "BENCH_solver.json"
    assert path.exists(), "BENCH_solver.json missing from the repo root"
    doc = json.loads(path.read_text())
    validate(doc)
    assert doc["smoke"] is False  # the committed point is the full run


def test_seeded_benchmark_modules_are_deterministic():
    """Satellite (b): every benchmark module that accepts a seed
    produces identical modeled values across two calls (the derived
    column may carry wall-clock text and is not compared)."""
    from benchmarks import persist_homogeneous, persist_prd

    for mod in (persist_homogeneous, persist_prd):
        a = [(name, value) for name, value, _ in mod.rows(seed=11)]
        b = [(name, value) for name, value, _ in mod.rows(seed=11)]
        assert a == b, mod.__name__
