"""Fused GF(256) encode + persist staging vs the numpy oracle.

Three layers, all bit-exact (ISSUE 10):

- the tiled encode kernel (`kernels/gf256_encode.py`) against
  ``gf256.rs_encode`` across K/P/ragged-length sweeps (interpret mode);
- the fused update+staging kernel (`fused_cg_update_persist_pallas`)
  against the unfused update plus an ``ErasureSession._shards``-style
  numpy staging pass;
- whole solves: an erasure-backed overlap solve with
  ``fused_persist=True`` is bit-identical to the numpy persist path,
  including under a mid-solve PRD kill, with matching report counts.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.fused_cg import (
    fused_cg_update_pallas,
    fused_cg_update_persist_pallas,
    fused_pass_traffic,
)
from repro.kernels.gf256_encode import gf256_rs_encode_pallas
from repro.nvm import gf256


def _shards(rng, k_data, length):
    return [rng.integers(0, 256, size=length, dtype=np.uint8)
            for _ in range(k_data)]


@pytest.mark.parametrize("k_data", [2, 4, 6])
@pytest.mark.parametrize("nparity", [1, 2])
@pytest.mark.parametrize("length", [1, 100, 8192, 8205])
def test_encode_kernel_bit_identical(k_data, nparity, length):
    """Ragged tails, tile multiples, sub-tile lengths: every parity
    byte equals the numpy reference."""
    rng = np.random.default_rng(k_data * 1000 + nparity * 10 + length)
    shards = _shards(rng, k_data, length)
    want = gf256.rs_encode(shards, nparity)
    got = gf256_rs_encode_pallas(shards, nparity, interpret=True)
    assert len(got) == len(want) == nparity
    for g, w in zip(got, want):
        assert g.dtype == np.uint8 and g.shape == w.shape
        assert np.array_equal(g, w)


def test_encode_kernel_zero_and_saturated_bytes():
    """The gf_mul zero-masking edge: all-zero and all-0xFF shards."""
    shards = [np.zeros(512, np.uint8), np.full(512, 0xFF, np.uint8),
              np.zeros(512, np.uint8), np.full(512, 0x1D, np.uint8)]
    for nparity in (1, 2):
        want = gf256.rs_encode(shards, nparity)
        got = gf256_rs_encode_pallas(shards, nparity, interpret=True)
        for g, w in zip(got, want):
            assert np.array_equal(g, w)


def test_encode_kernel_validation_matches_reference():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        gf256_rs_encode_pallas(_shards(rng, 4, 64), nparity=3,
                               interpret=True)
    ragged = [np.zeros(64, np.uint8), np.zeros(65, np.uint8)]
    with pytest.raises(ValueError, match="share one shape"):
        gf256_rs_encode_pallas(ragged, nparity=1, interpret=True)


def test_ops_rs_encode_is_the_registered_toggle():
    """Both routes through the dispatch seam agree with the oracle."""
    rng = np.random.default_rng(7)
    shards = _shards(rng, 4, 777)
    want = gf256.rs_encode(shards, 2)
    for mode in ("ref", "pallas"):
        got = ops.rs_encode(shards, 2, mode=mode)
        for g, w in zip(got, want):
            assert np.array_equal(g, w)


# ----------------------------------------------------------------------
# Fused update + persist staging kernel
# ----------------------------------------------------------------------
def _stage_oracle(p, nblocks, k_data, nparity, dtype):
    """ErasureSession._shards, distilled: block-wise chunking on the
    stored dtype, then the numpy parity encode over the raw bytes."""
    bs = p.size // nblocks
    chunk = bs // k_data
    v = np.asarray(p, dtype).reshape(nblocks, bs)
    chunks = [np.ascontiguousarray(v[:, j * chunk:(j + 1) * chunk]
                                   ).reshape(-1)
              for j in range(k_data)]
    parity = gf256.rs_encode([c.view(np.uint8) for c in chunks], nparity)
    return chunks, parity


@pytest.mark.parametrize("nblocks,k_data,nparity",
                         [(8, 4, 1), (8, 6, 2), (4, 2, 2)])
@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
def test_fused_persist_kernel_bit_identical(nblocks, k_data, nparity,
                                            dtype):
    n = nblocks * 128 * 6  # bs = 768: divisible by 128, 2, 4 and 6
    rng = np.random.default_rng(nblocks + k_data + nparity)
    x, r, p, ap, inv = (jnp.asarray(rng.standard_normal(n), dtype)
                        for _ in range(5))
    alpha = jnp.asarray(0.37, dtype)
    # same row tile as the persist grid (one partition block per step)
    # so even the fp32 dual-reduction partials group identically
    xo, ro, zo, rz = fused_cg_update_pallas(x, r, p, ap, alpha, inv,
                                            bm=n // nblocks // 128,
                                            interpret=True)
    xf, rf, zf, rzf, chunks, parity = fused_cg_update_persist_pallas(
        x, r, p, ap, alpha, inv, nblocks=nblocks, k_data=k_data,
        nparity=nparity, interpret=True)
    # the update outputs are the SAME bits as the staging-free kernel
    for a, b in zip((xo, ro, zo, rz), (xf, rf, zf, rzf)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    want_chunks, want_parity = _stage_oracle(
        np.asarray(p), nblocks, k_data, nparity, np.dtype(dtype))
    for j in range(k_data):
        got = np.asarray(chunks[:, j, :]).reshape(-1)
        assert np.array_equal(got, want_chunks[j])
    for i in range(nparity):
        got = np.asarray(parity[:, i, :]).reshape(-1)
        assert np.array_equal(got, want_parity[i])


def test_fused_persist_kernel_alignment_fallback_errors():
    """Sizes the fused pass cannot stripe raise — the driver's cue to
    fall back to the unfused staging path."""
    n = 4 * 128
    v = jnp.zeros((n,), jnp.float64)
    a = jnp.asarray(1.0, jnp.float64)
    with pytest.raises(ValueError, match="not divisible by nblocks"):
        fused_cg_update_persist_pallas(v, v, v, v, a, v, nblocks=3,
                                       k_data=2, nparity=1, interpret=True)
    with pytest.raises(ValueError, match="multiple of 128"):
        fused_cg_update_persist_pallas(v, v, v, v, a, v, nblocks=8,
                                       k_data=2, nparity=1, interpret=True)
    with pytest.raises(ValueError, match="not divisible by k_data"):
        fused_cg_update_persist_pallas(v, v, v, v, a, v, nblocks=4,
                                       k_data=5, nparity=1, interpret=True)


def test_fused_pass_traffic_accounting():
    t = fused_pass_traffic(n=1 << 20, itemsize=8, k_data=6, nparity=2)
    n_bytes = (1 << 20) * 8
    assert t["update_read_bytes"] == 5 * n_bytes
    assert t["update_write_bytes"] == 3 * n_bytes
    assert t["staged_write_bytes"] == n_bytes + n_bytes * 2 // 6
    assert t["total_bytes"] == sum(
        t[k] for k in ("update_read_bytes", "update_write_bytes",
                       "staged_write_bytes"))
    assert 0.0 < t["persist_bw_fraction"] < 1.0
    assert t["unfused_extra_read_bytes"] == n_bytes


# ----------------------------------------------------------------------
# Whole-solve exactness: fused persist path == numpy persist path
# ----------------------------------------------------------------------
def _solve_pair(fused, campaign, spec="erasure(nvm-prd x6+2p)"):
    from repro.core import JacobiPreconditioner, make_poisson_problem
    from repro.solvers import SolveConfig, make_backend, make_solver, solve

    op, b = make_poisson_problem(8, 8, 8, nblocks=4)
    pre = JacobiPreconditioner(op)
    solver = make_solver("pcg", op, pre)
    backend = make_backend(spec, op, solver=solver)
    cfg = SolveConfig(tol=1e-10, maxiter=5000, persist_mode="overlap",
                      fused_persist=fused)
    return solve(solver, op, b, pre, config=cfg, backend=backend,
                 failures=campaign)


@pytest.mark.parametrize("spec", ["erasure(nvm-prd x4+p)",
                                  "erasure(nvm-prd x6+2p)"])
def test_fused_solve_bit_identical_clean(spec):
    st_ref, rep_ref, _ = _solve_pair(False, (), spec)
    st_f, rep_f, _ = _solve_pair(True, (), spec)
    assert np.array_equal(np.asarray(st_ref.x), np.asarray(st_f.x))
    assert rep_ref.iterations == rep_f.iterations
    assert rep_ref.persist_events == rep_f.persist_events


def test_fused_solve_bit_identical_under_prd_kill():
    """Mid-solve PRD node kill + block loss: the fused route recovers
    onto the identical trajectory with identical abort accounting."""
    from repro.solvers import FailureCampaign, FailureEvent

    camp = FailureCampaign((
        FailureEvent(blocks=(1,), at_iteration=6, prd=True),
        FailureEvent(blocks=(2, 3), at_iteration=10),
    ))
    st_ref, rep_ref, _ = _solve_pair(False, camp)
    st_f, rep_f, _ = _solve_pair(True, camp)
    assert np.array_equal(np.asarray(st_ref.x), np.asarray(st_f.x))
    assert rep_ref.iterations == rep_f.iterations
    assert rep_f.failures_recovered == 2
    assert rep_ref.persist_events == rep_f.persist_events
    assert rep_ref.persist_aborts == rep_f.persist_aborts


def test_fused_solve_traced_closes_the_triangle():
    """With tracing on, the fused route's span/event stream still
    satisfies check_trace_report — including the staging conservation
    law (stage.copy == stage.flush + stage.abort drops) — and records
    the encoder route on the encode span."""
    from repro.core import JacobiPreconditioner, make_poisson_problem
    from repro.obs import Tracer, check_trace_report
    from repro.solvers import (FailureCampaign, FailureEvent, SolveConfig,
                               make_backend, make_solver, solve)

    op, b = make_poisson_problem(8, 8, 8, nblocks=4)
    pre = JacobiPreconditioner(op)
    solver = make_solver("pcg", op, pre)
    backend = make_backend("erasure(nvm-prd x6+2p)", op, solver=solver)
    tracer = Tracer()
    cfg = SolveConfig(tol=1e-10, maxiter=5000, persist_mode="overlap",
                      fused_persist=True, tracer=tracer)
    camp = FailureCampaign((
        FailureEvent(blocks=(0,), at_iteration=5, prd=True),))
    _, report, _ = solve(solver, op, b, pre, config=cfg, backend=backend,
                         failures=camp)
    check_trace_report(tracer, report)
    encoders = {rec["args"].get("encoder")
                for rec in tracer.records
                if rec.get("name") == "gf256.rs_encode"}
    assert encoders == {"pallas"}


def test_resilience_spec_forwards_fused_persist():
    from repro.api import Problem, ResilienceSpec, SolverSpec
    from repro.api import solve as api_solve

    problem = Problem.poisson(8, 8, 8, nblocks=4)
    spec = ResilienceSpec("erasure(nvm-prd x4+p)", persist_mode="overlap",
                          fused_persist=True)
    res_f = api_solve(problem, SolverSpec("pcg", tol=1e-10), spec)
    res_r = api_solve(problem, SolverSpec("pcg", tol=1e-10),
                      ResilienceSpec("erasure(nvm-prd x4+p)",
                                     persist_mode="overlap"))
    assert res_f.converged and res_r.converged
    assert np.array_equal(res_f.x, res_r.x)


def test_set_encode_mode_validates_and_propagates():
    from repro.core import make_poisson_problem
    from repro.nvm.backend import create_backend
    from repro.solvers import make_solver

    op, b = make_poisson_problem(8, 8, 8, nblocks=4)
    from repro.core import JacobiPreconditioner

    solver = make_solver("pcg", op, JacobiPreconditioner(op))
    be = create_backend("erasure(nvm-prd x4+p)", op.partition.nblocks,
                        op.partition.block_size, schema=solver.schema)
    session = be.open_session(solver.schema, op.partition)
    assert session._encode_mode == "ref"
    session.set_encode_mode("pallas")
    assert session._encode_mode == "pallas"
    with pytest.raises(ValueError, match="unknown parity encode mode"):
        session.set_encode_mode("simd")
    with pytest.raises(ValueError, match="unknown parity encode mode"):
        create_backend("erasure(nvm-prd x4+p)", op.partition.nblocks,
                       op.partition.block_size, schema=solver.schema,
                       encode="simd")
