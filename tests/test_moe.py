"""MoE dispatch correctness: the sort-based dispatch must equal a naive
per-token reference when capacity is not exceeded, and degrade by
dropping (never corrupting) when it is."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.models.config import ModelConfig
from repro.models.layers import _dense_init
from repro.models.moe import _moe_local, init_moe


def _naive_moe(x, router, w_in, w_gate, w_out, cfg, cap):
    """Per-token loop reference with identical capacity semantics."""
    b, s, d = x.shape
    xt = np.asarray(x.reshape(b * s, d), np.float32)
    logits = xt @ np.asarray(router, np.float32)
    e = cfg.n_experts
    topk = np.argsort(-logits, axis=-1)[:, : cfg.top_k]
    gates = np.take_along_axis(logits, topk, axis=-1)
    gates = np.exp(gates - gates.max(-1, keepdims=True))
    gates = gates / gates.sum(-1, keepdims=True)
    # capacity bookkeeping in the same order as the kernel: tokens sorted
    # by expert with stable order of (token, k-slot) pairs
    flat = [(int(topk[t, j]), t, float(gates[t, j]))
            for t in range(b * s) for j in range(cfg.top_k)]
    flat.sort(key=lambda r: r[0])  # stable: preserves token order per expert
    counts = {}
    out = np.zeros_like(xt)
    for exp, tok, w in flat:
        c = counts.get(exp, 0)
        counts[exp] = c + 1
        if c >= cap:
            continue  # dropped
        h = xt[tok] @ np.asarray(w_in[exp], np.float32)
        if w_gate is not None:
            g = xt[tok] @ np.asarray(w_gate[exp], np.float32)
            h = (g / (1 + np.exp(-g))) * h  # silu(g) * h
        else:
            h = 0.5 * h * (1 + np.tanh(np.sqrt(2 / np.pi) * (h + 0.044715 * h**3)))
        out[tok] += w * (h @ np.asarray(w_out[exp], np.float32))
    return out.reshape(b, s, d)


@pytest.mark.parametrize("act", ["silu_gated", "gelu"])
def test_moe_matches_naive_reference(act):
    cfg = ModelConfig(name="m", family="lm", n_layers=1, d_model=16, n_heads=2,
                      n_kv_heads=1, d_ff=32, vocab=32, n_experts=4, top_k=2,
                      capacity_factor=8.0,  # ample capacity: no drops
                      mlp_act=act, compute_dtype="float32")
    key = jax.random.PRNGKey(0)
    p, _ = init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16), jnp.float32)
    cap = int(np.ceil(16 * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    got, aux = _moe_local(x, p["router"], p["w_in"], p.get("w_gate"), p["w_out"],
                          cfg=cfg, tp_axis=None, fsdp_axis=None, batch_axes=())
    want = _naive_moe(x, p["router"], p["w_in"], p.get("w_gate"), p["w_out"], cfg, cap)
    np.testing.assert_allclose(np.asarray(got, np.float32), want, rtol=2e-3, atol=2e-3)
    assert float(aux) > 0


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor < 1, output norm shrinks but stays finite and
    at most (top_k * tokens) entries can contribute."""
    cfg = ModelConfig(name="m", family="lm", n_layers=1, d_model=8, n_heads=2,
                      n_kv_heads=1, d_ff=16, vocab=32, n_experts=4, top_k=2,
                      capacity_factor=0.5, compute_dtype="float32")
    p, _ = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 8), jnp.float32)
    got, _ = _moe_local(x, p["router"], p["w_in"], p.get("w_gate"), p["w_out"],
                        cfg=cfg, tp_axis=None, fsdp_axis=None, batch_axes=())
    assert np.isfinite(np.asarray(got)).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 500), tokens=st.sampled_from([4, 8, 12]),
       experts=st.sampled_from([2, 4, 8]))
def test_property_moe_token_conservation(seed, tokens, experts):
    """Property: with ample capacity every (token, expert-slot) pair is
    dispatched exactly once — outputs are permutation-invariant wrt the
    sort (checked against the naive reference)."""
    cfg = ModelConfig(name="m", family="lm", n_layers=1, d_model=8, n_heads=2,
                      n_kv_heads=1, d_ff=16, vocab=32, n_experts=experts,
                      top_k=min(2, experts), capacity_factor=8.0,
                      mlp_act="silu_gated", compute_dtype="float32")
    p, _ = init_moe(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, tokens, 8), jnp.float32)
    cap = int(np.ceil(tokens * cfg.top_k / experts * 8.0))
    got, _ = _moe_local(x, p["router"], p["w_in"], p.get("w_gate"), p["w_out"],
                        cfg=cfg, tp_axis=None, fsdp_axis=None, batch_axes=())
    want = _naive_moe(x, p["router"], p["w_in"], p.get("w_gate"), p["w_out"], cfg, cap)
    np.testing.assert_allclose(np.asarray(got, np.float32), want, rtol=5e-3, atol=5e-3)
