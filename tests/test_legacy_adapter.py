"""Unit coverage for ``driver._LegacyBackendAdapter`` (ISSUE 2 satellite).

The adapter bridges pre-zoo duck-typed backends (``persist(k, beta, p)`` /
``recover(blocks, k)``, PCG payloads only) into the schema-driven
``persist_set``/``recover_set`` contract.  The end-to-end path is covered
by ``test_solver_zoo``; these tests pin the adapter's own behavior —
round-trip fidelity, attribute passthrough, the stale-pair refusal for
untrusted external contracts, and the non-PCG schema rejection.
"""
import numpy as np
import pytest

from repro.core.state import PCG_SCHEMA, RecoveryPayload
from repro.solvers import make_solver
from repro.solvers.driver import _LegacyBackendAdapter
from repro.solvers.gmres import GMRES_SCHEMA


class _OldStyle:
    """Minimal pre-zoo backend: full-vector slots keyed by iteration."""

    custom_attr = "passthrough"

    def __init__(self, block_size=8):
        self.block_size = block_size
        self.slots = {}
        self.failed = []

    def persist(self, k, beta, p_full):
        self.slots[k] = (beta, np.asarray(p_full).copy())
        return 0.125

    def fail(self, blocks):
        self.failed.append(tuple(blocks))

    def recover(self, blocks, k):
        def payload(kk):
            beta, p = self.slots[kk]
            shards = [p[b * self.block_size:(b + 1) * self.block_size]
                      for b in blocks]
            return RecoveryPayload(kk, beta, np.concatenate(shards))
        return payload(k - 1), payload(k)


def test_persist_recover_round_trip():
    be = _OldStyle()
    ad = _LegacyBackendAdapter(be, PCG_SCHEMA)

    p0 = np.arange(32, dtype=np.float64)
    p1 = p0 + 100.0
    assert ad.persist_set(0, {"beta": 0.0}, {"p": p0}) == 0.125
    assert ad.persist_set(1, {"beta": 0.25}, {"p": p1}) == 0.125

    sets = ad.recover_set([1, 2], (0, 1))
    assert [s.k for s in sets] == [0, 1]
    assert sets[-1].scalars["beta"] == 0.25
    np.testing.assert_array_equal(sets[0].vectors["p"], p0[8:24])
    np.testing.assert_array_equal(sets[-1].vectors["p"], p1[8:24])

    # non-shim attributes fall through to the wrapped backend
    assert ad.custom_attr == "passthrough"
    ad.fail((1, 2))
    assert be.failed == [(1, 2)]


def test_stale_pair_refused():
    """An external backend returning the wrong iteration pair must not be
    silently reconstructed from — the adapter refuses loudly."""

    class StaleBackend(_OldStyle):
        def recover(self, blocks, k):
            prev, cur = super().recover(blocks, k)
            return prev._replace(k=prev.k - 1), cur  # off-by-one pair

    ad = _LegacyBackendAdapter(StaleBackend(), PCG_SCHEMA)
    ad.persist_set(4, {"beta": 0.0}, {"p": np.zeros(32)})
    ad.persist_set(5, {"beta": 0.5}, {"p": np.ones(32)})
    with pytest.raises(RuntimeError, match="legacy backend .* returned"):
        ad.recover_set([0], (4, 5))


def test_non_pcg_schema_rejected():
    """The legacy wire format carries PCG payloads only; adapting a
    backend for any other schema is a loud, early error."""
    with pytest.raises(ValueError, match="legacy"):
        _LegacyBackendAdapter(_OldStyle(), GMRES_SCHEMA)


def test_driver_wraps_legacy_backend_lazily():
    """solve() only wraps backends lacking persist_set; the adapter is an
    internal detail the caller never constructs for modern backends."""
    from repro.core import JacobiPreconditioner, make_poisson_problem
    from repro.solvers import SolveConfig, solve

    op, b = make_poisson_problem(8, 8, 8, nblocks=4)
    pre = JacobiPreconditioner(op)
    be = _OldStyle(op.partition.block_size)
    solver = make_solver("pcg", op, pre)
    _, rep, _ = solve(solver, op, b, pre, SolveConfig(tol=1e-10), backend=be)
    assert rep.converged and rep.persist_events > 0
    assert be.slots  # persisted through the adapter shim
