"""Shim so property tests degrade to skips when hypothesis is absent.

The container baseline does not ship ``hypothesis`` (see
requirements-dev.txt for the full dev environment).  Importing this
module instead of ``hypothesis`` directly keeps every *deterministic*
test in the same file collectible and running; only ``@given`` property
tests are skipped.

Usage in a test module::

    from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _StrategyStub:
        """Stand-in for ``hypothesis.strategies``: every strategy factory
        returns None — fine, since the decorated test is skipped and the
        strategies are never drawn from."""

        def __getattr__(self, name):
            def stub(*_args, **_kwargs):
                return None

            return stub

    st = _StrategyStub()
