"""GF(2^8) + Reed-Solomon unit tests (ISSUE 5 satellite).

Covers the field tables (mul/div/pow consistency against the axioms),
the P/Q Vandermonde (row 0 == XOR, MDS refusal beyond 2 parities), the
encode -> drop-any-<=2 -> decode roundtrip — byte-identical across
chunk shapes including ragged tails — and the stripe's rotation
metadata roundtrip (recorded durably per stripe, read back by fetch,
never leaked into the solver-facing recovery sets).
"""
import itertools

import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st  # noqa: F401
from repro.nvm import gf256
from repro.nvm.backend import (
    STRIPE_ROT_SCALAR,
    create_backend,
    stripe_child_schema,
)


# ------------------------------------------------------------ the field
def test_exp_log_tables_are_inverse():
    for a in range(1, 256):
        assert int(gf256.EXP[int(gf256.LOG[a])]) == a
    for i in range(255):
        assert int(gf256.LOG[int(gf256.EXP[i])]) == i
    # the doubled half lets gf_mul skip one modulo
    assert np.array_equal(gf256.EXP[255:510], gf256.EXP[0:255])
    # EXP[0..254] enumerates the whole multiplicative group
    assert len(set(gf256.EXP[:255].tolist())) == 255


def test_mul_axioms():
    rng = np.random.default_rng(1)
    a = rng.integers(0, 256, 512, dtype=np.uint8)
    b = rng.integers(0, 256, 512, dtype=np.uint8)
    c = rng.integers(0, 256, 512, dtype=np.uint8)
    assert np.array_equal(gf256.gf_mul(a, b), gf256.gf_mul(b, a))
    assert np.array_equal(gf256.gf_mul(gf256.gf_mul(a, b), c),
                          gf256.gf_mul(a, gf256.gf_mul(b, c)))
    # distributive over the field's addition (XOR)
    assert np.array_equal(gf256.gf_mul(a, b ^ c),
                          gf256.gf_mul(a, b) ^ gf256.gf_mul(a, c))
    assert np.array_equal(gf256.gf_mul(a, np.uint8(1)), a)
    assert not gf256.gf_mul(a, np.uint8(0)).any()


def test_div_inverts_mul_and_refuses_zero():
    rng = np.random.default_rng(2)
    a = rng.integers(0, 256, 512, dtype=np.uint8)
    b = rng.integers(1, 256, 512, dtype=np.uint8)
    assert np.array_equal(gf256.gf_div(gf256.gf_mul(a, b), b), a)
    with pytest.raises(ZeroDivisionError):
        gf256.gf_div(a, np.uint8(0))
    with pytest.raises(ZeroDivisionError):
        gf256.gf_inv(0)
    for x in (1, 2, 37, 255):
        assert int(gf256.gf_mul(x, gf256.gf_inv(x))) == 1


def test_pow_consistency():
    for a in (0, 1, 2, 7, 255):
        acc = 1
        for n in range(9):
            assert gf256.gf_pow(a, n) == acc
            acc = int(gf256.gf_mul(acc, a))
    assert gf256.gf_pow(0, 0) == 1 and gf256.gf_pow(0, 5) == 0


def test_vandermonde_rows():
    v = gf256.vandermonde(2, 6)
    assert np.array_equal(v[0], np.ones(6, np.uint8))       # P row == XOR
    assert np.array_equal(
        v[1], np.array([gf256.gf_pow(gf256.GENERATOR, j) for j in range(6)],
                       np.uint8))
    assert len(set(v[1].tolist())) == 6                     # Q weights distinct
    with pytest.raises(ValueError, match="MDS"):
        gf256.vandermonde(3, 4)                             # beyond P+Q
    with pytest.raises(ValueError, match="k_data"):
        gf256.vandermonde(1, 0)


# --------------------------------------------------------- Reed-Solomon
def test_p1_parity_is_xor():
    rng = np.random.default_rng(3)
    data = [rng.integers(0, 256, 33, dtype=np.uint8) for _ in range(4)]
    (parity,) = gf256.rs_encode(data, 1)
    xor = np.zeros(33, np.uint8)
    for d in data:
        xor ^= d
    assert np.array_equal(parity, xor)


@pytest.mark.parametrize("k_data", [2, 3, 6])
@pytest.mark.parametrize("nparity", [1, 2])
@pytest.mark.parametrize("length", [1, 7, 16, 33])
def test_encode_drop_any_decode_roundtrip(k_data, nparity, length):
    """The satellite roundtrip: drop ANY combination of up to `nparity`
    shards (data-data, data-parity, parity-parity) and reconstruction
    is byte-identical — np.array_equal, not allclose — across shard
    lengths including ragged tails."""
    rng = np.random.default_rng(1000 * k_data + 10 * nparity + length)
    data = [rng.integers(0, 256, length, dtype=np.uint8)
            for _ in range(k_data)]
    stripe = data + gf256.rs_encode(data, nparity)
    for ndrop in range(nparity + 1):
        for kill in itertools.combinations(range(k_data + nparity), ndrop):
            shards = [None if i in kill else stripe[i]
                      for i in range(k_data + nparity)]
            rec = gf256.rs_reconstruct(shards, k_data)
            for j in range(k_data):
                assert np.array_equal(rec[j], data[j]), (kill, j)


def test_reconstruct_refuses_beyond_distance():
    rng = np.random.default_rng(4)
    data = [rng.integers(0, 256, 8, dtype=np.uint8) for _ in range(4)]
    stripe = data + gf256.rs_encode(data, 2)
    # three losses on a distance-3 code
    shards = [None, None, data[2], data[3], None, stripe[5]]
    with pytest.raises(ValueError, match="beyond the code's remaining"):
        gf256.rs_reconstruct(shards, 4)
    # two data losses with only ONE surviving parity
    shards = [None, None, data[2], data[3], stripe[4], None]
    with pytest.raises(ValueError, match="beyond the code's remaining"):
        gf256.rs_reconstruct(shards, 4)
    # a stripe with no parity at all is malformed
    with pytest.raises(ValueError, match="no parity"):
        gf256.rs_reconstruct(data, 4)


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=0, max_value=10**9))
def test_roundtrip_property(seed):
    """Property variant of the roundtrip sweep (skips without
    hypothesis; the deterministic sweep above always runs)."""
    rng = np.random.default_rng(seed)
    k_data = int(rng.integers(2, 9))
    nparity = int(rng.integers(1, 3))
    length = int(rng.integers(1, 64))
    data = [rng.integers(0, 256, length, dtype=np.uint8)
            for _ in range(k_data)]
    stripe = data + gf256.rs_encode(data, nparity)
    kill = rng.choice(k_data + nparity, size=nparity, replace=False)
    shards = [None if i in kill else stripe[i]
              for i in range(k_data + nparity)]
    rec = gf256.rs_reconstruct(shards, k_data)
    for j in range(k_data):
        assert np.array_equal(rec[j], data[j])


# ------------------------------------------------- rotation metadata
def _pcg_stripe(k_data=6, nparity=2, nblocks=4, block_size=22):
    """A stripe over a ragged chunk (block_size not divisible by K)."""
    from repro.core.state import PCG_SCHEMA

    spec = f"erasure(nvm-prd x{k_data}+{nparity}p)" if nparity > 1 \
        else f"erasure(nvm-prd x{k_data}+p)"
    return create_backend(spec, nblocks, block_size, np.float64,
                          schema=PCG_SCHEMA), PCG_SCHEMA


def test_rotation_metadata_roundtrips():
    """The rotation offset is *recorded* per stripe in every child's
    slot scalars, read back by fetch (not re-derived), balanced
    round-robin, and stripped from the solver-facing recovery sets."""
    be, schema = _pcg_stripe()
    nchildren = be.k_data + be.nparity
    session = be.open_session(schema)
    rng = np.random.default_rng(5)
    n = be.nblocks * be.block_size
    blocks = (0, 2)
    vecs = [rng.standard_normal(n) for _ in range(nchildren + 3)]
    for k, v in enumerate(vecs):
        session.persist(k, {"beta": 0.25 * k}, {"p": v})
        # recorded metadata: each child slot carries the stripe's
        # offset, advancing by P per stripe (the balanced RAID-6
        # rotation) — probed while the slot is still in the ring
        raw = session._children[0].fetch(blocks, (k,))[0]
        assert raw.scalars[STRIPE_ROT_SCALAR] == float(
            (be.nparity * k) % nchildren)

    # parity-write balance: counts differ by <= 1 stripe at any prefix
    assert max(session.parity_writes) - min(session.parity_writes) <= 1

    # the roundtrip: healthy and any-2-children-degraded fetches agree
    # bit-for-bit, and the rotation scalar never leaks upward
    ks = (len(vecs) - 2, len(vecs) - 1)   # the newest durable pair
    healthy = session.fetch(blocks, ks)
    for got, kk in zip(healthy, ks):
        bs = be.block_size
        want = np.concatenate(
            [vecs[kk][b * bs:(b + 1) * bs] for b in blocks])
        assert np.array_equal(got.vectors["p"], want)
        assert set(got.scalars) == set(schema.scalars)
    session.fail_storage()
    session.fail_storage()
    degraded = session.fetch(blocks, ks)
    for h, d in zip(healthy, degraded):
        assert d.k == h.k and d.scalars == h.scalars
        assert np.array_equal(d.vectors["p"], h.vectors["p"])


def test_stripe_child_schema_is_idempotent_and_reserved():
    from repro.core.state import PCG_SCHEMA

    extended = stripe_child_schema(PCG_SCHEMA)
    assert extended.scalars == ("beta", STRIPE_ROT_SCALAR)
    assert stripe_child_schema(extended) == extended
    assert PCG_SCHEMA.scalars == ("beta",)  # the original is untouched
    import dataclasses

    hijacked = dataclasses.replace(
        PCG_SCHEMA, scalars=(STRIPE_ROT_SCALAR, "beta"))
    with pytest.raises(ValueError, match="reserved scalar"):
        stripe_child_schema(hijacked)
