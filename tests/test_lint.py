"""repro-lint test matrix (ISSUE 8).

Three layers:

1. **Fixture matrix** — for every rule family: a trigger fixture the
   rule must fire on, a clean fixture it must stay silent on, a
   suppressed-with-reason fixture (finding kept but silenced), and the
   suppression-*without*-reason refusal (RL001 + the original finding
   stays unsuppressed).
2. **Self-clean** — ``src/`` itself lints clean (the merge gate), with
   the justified suppressions visible in the report as an audit trail.
3. **Negative controls** — on a scratch copy of ``src/``: deleting one
   tracer guard (RL301) or one ABC method implementation (RL401) flips
   the CLI exit status, proving the gate actually guards the invariants
   it claims to.
"""
import ast
import json
import pathlib
import shutil
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

from repro_lint import (  # noqa: E402
    ALL_RULES,
    META_RULES,
    lint_paths,
    lint_source,
    rule_families,
)

SOLVER_PATH = "src/repro/solvers/zoo.py"   # inside the linted tree
NEUTRAL_PATH = "scripts/plot.py"           # outside solvers//core/


def rules_of(findings):
    return sorted(f.rule for f in findings)


def live(findings):
    return [f for f in findings if not f.suppressed]


# ---------------------------------------------------------------------------
# 1. the per-family fixture matrix
# ---------------------------------------------------------------------------

MINI_ABC = """\
import abc


class PersistSession(abc.ABC):
    @abc.abstractmethod
    def begin(self, k, scalars, vectors):
        ...

    @abc.abstractmethod
    def commit(self):
        ...
"""

FAMILIES = {
    "RL101": dict(
        trigger=("import jax\nrun = jax.shard_map(lambda x: x)\n",
                 SOLVER_PATH),
        clean=("import jax\nrun = jax.shard_map(lambda x: x)\n",
               "src/repro/compat.py"),
        noqa_line="run = jax.shard_map(lambda x: x)",
    ),
    "RL201": dict(
        trigger=("import jax.numpy as jnp\nrr = jnp.vdot(r, r)\n",
                 "src/repro/core/x.py"),
        clean=("import jax.numpy as jnp\nrr = jnp.vdot(r, r)\n",
               NEUTRAL_PATH),
        noqa_line="rr = jnp.vdot(r, r)",
    ),
    "RL301": dict(
        trigger=("def f(t, k):\n"
                 "    t.event('iteration.step', k=k)\n",
                 SOLVER_PATH),
        clean=("def f(t, k):\n"
               "    if t is not None:\n"
               "        t.event('iteration.step', k=k)\n",
               SOLVER_PATH),
        noqa_line="    t.event('iteration.step', k=k)",
    ),
    "RL401": dict(
        trigger=(MINI_ABC
                 + "\n\nclass HalfSession(PersistSession):\n"
                 "    def begin(self, k, scalars, vectors):\n"
                 "        return k\n",
                 SOLVER_PATH),
        clean=(MINI_ABC
               + "\n\nclass FullSession(PersistSession):\n"
               "    def begin(self, k, scalars, vectors):\n"
               "        return k\n\n"
               "    def commit(self):\n"
               "        return None\n",
               SOLVER_PATH),
        noqa_line="class HalfSession(PersistSession):",
    ),
    "RL501": dict(
        trigger=("def f(x=[]):\n    return x\n", SOLVER_PATH),
        clean=("def f(x=None):\n    return [] if x is None else x\n",
               SOLVER_PATH),
        noqa_line="def f(x=[]):",
    ),
}


@pytest.mark.parametrize("rule", sorted(FAMILIES))
def test_family_fires_on_trigger(rule):
    src, path = FAMILIES[rule]["trigger"]
    assert rule in rules_of(lint_source(src, path=path)), rule


@pytest.mark.parametrize("rule", sorted(FAMILIES))
def test_family_silent_on_clean(rule):
    src, path = FAMILIES[rule]["clean"]
    assert lint_source(src, path=path) == []


@pytest.mark.parametrize("rule", sorted(FAMILIES))
def test_family_suppressed_with_reason(rule):
    fx = FAMILIES[rule]
    src, path = fx["trigger"]
    src = src.replace(
        fx["noqa_line"],
        fx["noqa_line"] + f"  # repro-lint: noqa[{rule}] -- fixture: "
        f"exercising the suppression path", 1)
    findings = lint_source(src, path=path)
    mine = [f for f in findings if f.rule == rule]
    assert mine and all(f.suppressed for f in mine)
    assert all("suppression path" in f.reason for f in mine)
    assert live(findings) == []


@pytest.mark.parametrize("rule", sorted(FAMILIES))
def test_family_suppression_without_reason_refused(rule):
    fx = FAMILIES[rule]
    src, path = fx["trigger"]
    src = src.replace(fx["noqa_line"],
                      fx["noqa_line"] + f"  # repro-lint: noqa[{rule}]", 1)
    findings = lint_source(src, path=path)
    assert "RL001" in rules_of(findings)          # the refusal itself
    mine = [f for f in findings if f.rule == rule]
    assert mine and not any(f.suppressed for f in mine)   # still gates


def test_meta_rules_cannot_be_suppressed():
    src = ("def f(x=[]):  # repro-lint: noqa[RL501,RL001]\n"
           "    return x\n")
    findings = lint_source(src, path=SOLVER_PATH)
    assert not any(f.suppressed for f in findings)
    assert "RL001" in rules_of(findings)


# ---------------------------------------------------------------------------
# the remaining rule ids, one trigger each
# ---------------------------------------------------------------------------

EXTRA_TRIGGERS = [
    ("RL102", "from jax.sharding import AxisType\n", SOLVER_PATH),
    ("RL103", "from jax.sharding import Mesh\nm = Mesh(devs, ('data',))\n",
     SOLVER_PATH),
    ("RL202", "import time\nt0 = time.time()\n", SOLVER_PATH),
    ("RL203", "import random\nx = random.random()\n", SOLVER_PATH),
    ("RL204", "from repro.kernels.gf256_encode import "
              "gf256_rs_encode_pallas\n"
              "parity = gf256_rs_encode_pallas(chunks, 2)\n",
     "src/repro/nvm/backend.py"),
    ("RL302", "def f(t, name):\n"
              "    if t is not None:\n"
              "        t.event(name)\n", SOLVER_PATH),
    ("RL402", MINI_ABC + "\n\nclass DriftSession(PersistSession):\n"
              "    def begin(self, kk, scalars, vectors):\n"
              "        return kk\n\n"
              "    def commit(self):\n"
              "        return None\n", SOLVER_PATH),
    ("RL403", "def run(s, k):\n    s.begin(k)\n", SOLVER_PATH),
    ("RL502", "try:\n    x = 1\nexcept:\n    pass\n", SOLVER_PATH),
    ("RL503", "__all__ = ['ghost']\n", SOLVER_PATH),
]


@pytest.mark.parametrize("rule,src,path", EXTRA_TRIGGERS,
                         ids=[t[0] for t in EXTRA_TRIGGERS])
def test_every_rule_id_fires(rule, src, path):
    assert rule in rules_of(lint_source(src, path=path))


def test_fused_encode_route_rule_scoping():
    """RL204 fires only inside nvm/ and only on the direct kernel entry
    points — the registered toggle (ops.rs_encode) stays clean, and the
    kernels package itself may reference its own entry points."""
    direct = ("from repro.kernels.gf256_encode import "
              "gf256_rs_encode_pallas\n"
              "parity = gf256_rs_encode_pallas(chunks, 2)\n")
    routed = ("from repro.kernels.ops import rs_encode\n"
              "parity = rs_encode(chunks, 2, mode='pallas')\n")
    nvm = "src/repro/nvm/backend.py"
    assert "RL204" in rules_of(lint_source(direct, path=nvm))
    assert "RL204" not in rules_of(lint_source(routed, path=nvm))
    assert "RL204" not in rules_of(
        lint_source(direct, path="src/repro/kernels/ops.py"))


def test_registry_covers_five_families_and_meta():
    fams = rule_families()
    assert {"RL1", "RL2", "RL3", "RL4", "RL5"} <= set(fams)
    assert set(META_RULES) == {"RL001", "RL002"}
    fired = {t[0] for t in EXTRA_TRIGGERS} | set(FAMILIES)
    assert fired == set(ALL_RULES), "every registered id has a fixture"


# ---------------------------------------------------------------------------
# RL301's guard analysis: every guarded idiom src/ actually uses
# ---------------------------------------------------------------------------

GUARDED_IDIOMS = [
    ("inline", "def f(t):\n"
               "    if t is not None:\n"
               "        t.event('a.b')\n"),
    ("early-exit", "def f(t):\n"
                   "    if t is None:\n"
                   "        return 0\n"
                   "    t.event('a.b')\n"),
    ("else-branch", "def f(t):\n"
                    "    if t is None:\n"
                    "        pass\n"
                    "    else:\n"
                    "        t.event('a.b')\n"),
    ("and-conjunct", "def f(t, drained):\n"
                     "    if t is not None and drained:\n"
                     "        t.event('a.b')\n"),
    ("conditional-expr", "def f(t):\n"
                         "    return t.event('a.b') if t is not None "
                         "else None\n"),
]


@pytest.mark.parametrize("name,src", GUARDED_IDIOMS,
                         ids=[g[0] for g in GUARDED_IDIOMS])
def test_guard_idioms_accepted(name, src):
    assert lint_source(src, path=SOLVER_PATH) == []


def test_guard_does_not_cross_function_boundary():
    src = ("def f(t):\n"
           "    if t is not None:\n"
           "        def g():\n"
           "            t.event('a.b')\n"   # closure: t may be swapped
           "        return g\n")
    assert "RL301" in rules_of(lint_source(src, path=SOLVER_PATH))


# ---------------------------------------------------------------------------
# 2. self-clean: the merge gate over the real tree
# ---------------------------------------------------------------------------

def test_src_lints_clean_with_audit_trail():
    result = lint_paths([str(REPO / "src")])
    assert result.exit_code == 0, result.render()
    assert result.unsuppressed == []
    suppressed = [f for f in result.findings if f.suppressed]
    assert suppressed, "the justified suppressions stay in the report"
    assert all(f.reason for f in suppressed)


# ---------------------------------------------------------------------------
# 3. negative controls on a scratch copy of src/
# ---------------------------------------------------------------------------

def _lint_cli(*args):
    return subprocess.run(
        [sys.executable, "-m", "tools.repro_lint", *map(str, args)],
        cwd=REPO, capture_output=True, text=True)


@pytest.fixture
def src_copy(tmp_path):
    dst = tmp_path / "src"
    shutil.copytree(REPO / "src", dst,
                    ignore=shutil.ignore_patterns("__pycache__"))
    assert _lint_cli(dst).returncode == 0, "scratch baseline must be clean"
    return dst


def test_deleting_a_tracer_guard_flips_exit(src_copy):
    drv = src_copy / "repro" / "solvers" / "driver.py"
    text = drv.read_text()
    needle = 'if trace is not None:\n        trace.event("solve.begin"'
    assert needle in text
    drv.write_text(text.replace(
        needle, 'if True:\n        trace.event("solve.begin"', 1))
    out = _lint_cli(src_copy)
    assert out.returncode == 1
    assert "RL301" in out.stdout and "solve.begin" not in out.stderr


def test_deleting_an_abc_method_flips_exit(src_copy):
    be = src_copy / "repro" / "nvm" / "backend.py"
    tree = ast.parse(be.read_text())
    cls = next(n for n in ast.walk(tree)
               if isinstance(n, ast.ClassDef)
               and n.name == "ReplicatedSession")
    fn = next(n for n in cls.body if isinstance(n, ast.FunctionDef)
              and n.name == "durable_run")
    lines = be.read_text().splitlines(keepends=True)
    start = min([fn.lineno] + [d.lineno for d in fn.decorator_list]) - 1
    del lines[start:fn.end_lineno]
    be.write_text("".join(lines))
    out = _lint_cli(src_copy)
    assert out.returncode == 1
    assert "RL401" in out.stdout and "durable_run" in out.stdout


def test_signature_drift_flips_exit(src_copy):
    be = src_copy / "repro" / "nvm" / "backend.py"
    tree = ast.parse(be.read_text())
    cls = next(n for n in ast.walk(tree)
               if isinstance(n, ast.ClassDef)
               and n.name == "ReplicatedSession")
    fn = next(n for n in cls.body if isinstance(n, ast.FunctionDef)
              and n.name == "fail")
    lines = be.read_text().splitlines()
    lines[fn.lineno - 1] = lines[fn.lineno - 1].replace(
        "(self, blocks", "(self, block_ids")
    be.write_text("\n".join(lines) + "\n")
    out = _lint_cli(src_copy)
    assert out.returncode == 1
    assert "RL402" in out.stdout


# ---------------------------------------------------------------------------
# CLI surface: --json schema, --list-rules, --select
# ---------------------------------------------------------------------------

def test_cli_json_schema_on_src():
    out = _lint_cli("src", "--json")
    assert out.returncode == 0, out.stdout + out.stderr
    doc = json.loads(out.stdout)
    assert doc["schema"] == "repro-lint/v1"
    assert doc["unsuppressed"] == 0
    assert doc["files_scanned"] > 0
    assert {"span_names", "backend_families", "erasure_arities",
            "tracer_sites"} <= set(doc["facts"])
    assert "iteration.step" in doc["facts"]["span_names"]
    assert "erasure" in doc["facts"]["backend_families"]
    assert doc["facts"]["erasure_arities"] == ["+p", "+2p"]
    for f in doc["findings"]:
        assert {"rule", "file", "line", "col", "message", "hint",
                "suppressed", "reason"} <= set(f)
        assert f["suppressed"] and f["reason"]   # src is clean otherwise


def test_cli_list_rules_names_every_id():
    out = _lint_cli("--list-rules")
    assert out.returncode == 0
    for rid in list(ALL_RULES) + list(META_RULES):
        assert rid in out.stdout, rid


def test_cli_select_narrows_the_run(tmp_path):
    bad = tmp_path / "src" / "repro" / "solvers" / "zoo.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import jax\nrun = jax.shard_map(lambda x: x)\n")
    assert _lint_cli(bad).returncode == 1
    assert _lint_cli(bad, "--select", "RL5").returncode == 0
    narrowed = _lint_cli(bad, "--select", "RL1")
    assert narrowed.returncode == 1 and "RL101" in narrowed.stdout
