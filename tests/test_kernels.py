"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


@pytest.mark.parametrize("shape,bz", [
    ((16, 8, 128), 8), ((32, 16, 256), 4), ((8, 8, 128), 8),
    ((24, 10, 130), 4), ((8, 16, 64), 2), ((64, 8, 128), 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stencil7_kernel_matches_ref(shape, bz, dtype):
    u = jax.random.normal(jax.random.PRNGKey(0), shape, dtype)
    got = ops.stencil7(u, mode="pallas", bz=bz).astype(jnp.float32)
    want = ref.stencil7_ref(u).astype(jnp.float32)
    tol = 1e-5 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_stencil7_kernel_matches_core_operator():
    """The kernel computes the same operator the solver uses."""
    from repro.core.poisson import StencilOperator
    op = StencilOperator(16, 8, 128, nblocks=4, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (op.n,), jnp.float32)
    got = ops.stencil7(x.reshape(op.grid), mode="pallas").reshape(-1)
    want = op.apply(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,bm", [(128 * 8, 8), (128 * 64, 16), (128 * 256, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_cg_kernel_matches_ref(n, bm, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x, r, p, ap, inv = [jax.random.normal(k, (n,), dtype) for k in ks]
    alpha = jnp.asarray(0.37, dtype)
    got = ops.fused_cg_update(x, r, p, ap, alpha, inv, mode="pallas", bm=bm)
    want = ref.fused_cg_update_ref(x, r, p, ap, alpha, inv)
    tol = 2e-5 if dtype == jnp.float32 else 2e-1
    for g, w, name in zip(got[:3], want[:3], ("x", "r", "z")):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   rtol=tol, atol=tol, err_msg=name)
    rz_rel = abs(float(got[3]) - float(want[3])) / (abs(float(want[3])) + 1e-9)
    # both sides accumulate in fp32; bf16 slack covers the final downcast
    # (bf16 eps = 2^-7 ~ 0.8%, plus cancellation-ordering noise)
    assert rz_rel < (1e-4 if dtype == jnp.float32 else 3e-2)


@settings(max_examples=10, deadline=None)
@given(
    nz=st.sampled_from([8, 16, 24]),
    ny=st.sampled_from([8, 12]),
    nx=st.sampled_from([128, 130]),
    seed=st.integers(0, 1000),
)
def test_property_stencil_linearity(nz, ny, nx, seed):
    """A(au + bv) == a*Au + b*Av through the Pallas kernel."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    u = jax.random.normal(k1, (nz, ny, nx), jnp.float32)
    v = jax.random.normal(k2, (nz, ny, nx), jnp.float32)
    a, b = 1.7, -0.3
    lhs = ops.stencil7(a * u + b * v, mode="pallas", bz=8 if nz % 8 == 0 else 4)
    rhs = a * ops.stencil7(u, mode="pallas", bz=8 if nz % 8 == 0 else 4) \
        + b * ops.stencil7(v, mode="pallas", bz=8 if nz % 8 == 0 else 4)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m", [384, 7, 100, 255])
def test_fused_cg_kernel_default_bm_any_row_count(m):
    """Post-fix (ISSUE 10): the default tiling accepts ANY lane-aligned
    n — m = 384 (not a divisor-friendly power of two), prime m = 7, ...
    — by falling back to the largest divisor of m <= DEFAULT_BM."""
    from repro.kernels.fused_cg import DEFAULT_BM, largest_divisor_bm

    bm = largest_divisor_bm(m)
    assert 1 <= bm <= min(DEFAULT_BM, m) and m % bm == 0
    n = m * 128
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x, r, p, ap, inv = [jax.random.normal(k, (n,), jnp.float32) for k in ks]
    alpha = jnp.asarray(-0.21, jnp.float32)
    got = ops.fused_cg_update(x, r, p, ap, alpha, inv, mode="pallas")
    want = ref.fused_cg_update_ref(x, r, p, ap, alpha, inv)
    for g, w, name in zip(got[:3], want[:3], ("x", "r", "z")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-5, atol=2e-5, err_msg=name)


def test_fused_cg_kernel_explicit_invalid_bm_still_raises():
    """The divisor fallback repairs only the DEFAULT; a caller-passed
    bm that does not divide m stays a hard error."""
    n = 7 * 128
    v = jnp.zeros((n,), jnp.float32)
    a = jnp.asarray(1.0, jnp.float32)
    with pytest.raises(ValueError, match="not divisible by block rows"):
        ops.fused_cg_update(v, v, v, v, a, v, mode="pallas", bm=2)


def test_fused_cg_inside_solver_iteration():
    """One CG iteration computed with the fused kernel equals the plain
    jnp iteration (the kernel is a drop-in for Algorithm 1 lines 4-7a)."""
    n = 128 * 16
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (n,), jnp.float32)
    p = jax.random.normal(ks[1], (n,), jnp.float32)
    r = jax.random.normal(ks[2], (n,), jnp.float32)
    inv = jnp.full((n,), 1.0 / 6.0, jnp.float32)
    ap = p * 2.0 + jnp.roll(p, 1) * -0.5
    alpha = jnp.asarray(0.11, jnp.float32)
    xk, rk, zk, rzk = ops.fused_cg_update(x, r, p, ap, alpha, inv, mode="pallas")
    x2 = x + alpha * p
    r2 = r - alpha * ap
    z2 = r2 * inv
    rz2 = jnp.sum(r2 * z2)
    np.testing.assert_allclose(np.asarray(xk), np.asarray(x2), rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(rk), np.asarray(r2), rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(zk), np.asarray(z2), rtol=1e-4, atol=1e-6)
    assert abs(float(rzk) - float(rz2)) / abs(float(rz2)) < 1e-4
