"""Regression tests for bugs found during the build, plus roofline-parser
units and a true multi-device elastic-restore test."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FailurePlan,
    InMemoryESR,
    JacobiPreconditioner,
    NVMESRHomogeneous,
    NVMESRPRD,
    PCGConfig,
    make_poisson_problem,
    solve,
)


# ----------------------------------------------------------------------
# REGRESSION: ESRP mid-burst failure (k%S slot rings overwrite the last
# complete pair when persistence has gaps — found by examples/, fixed with
# event-addressed slots + content-matched recovery)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("backend_cls", [InMemoryESR, NVMESRHomogeneous, NVMESRPRD])
@pytest.mark.parametrize("fail_at", [30, 31, 32])
def test_esrp_mid_burst_failure_recovers(backend_cls, fail_at):
    """Period-5 bursts persist k=25,26 then k=30,31...  A failure at k=30
    (right after the FIRST write of the new burst) must still recover
    from the (25,26) pair; at k=31 from (30,31)."""
    op, b = make_poisson_problem(32, 16, 16, nblocks=8)
    pre = JacobiPreconditioner(op)
    be = backend_cls(op.nblocks, op.partition.block_size, np.float64)
    st, rep, _ = solve(op, b, pre,
                       PCGConfig(tol=1e-10, persistence_period=5),
                       backend=be, failures=[FailurePlan(fail_at, (1, 2))])
    assert rep.failures_recovered == 1
    assert rep.converged
    res = float(jnp.linalg.norm(b - op.apply(st.x)) / jnp.linalg.norm(b))
    assert res < 1e-9


# ----------------------------------------------------------------------
# roofline collective parser units
# ----------------------------------------------------------------------
def test_collective_bytes_parser():
    from repro.launch.roofline import collective_bytes

    hlo = """
  %all-gather.8 = f32[16,4096,4096]{2,0,1} all-gather(%x), replica_groups=[16,16]<=[256]
  %ar = bf16[256]{0} all-reduce(%y), to_apply=%sum
  %cp = f32[5,1026,1026]{2,1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %aa = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(%a, %b)
  %unrelated = f32[2,2]{1,0} add(%p, %q)
"""
    got = collective_bytes(hlo)
    assert got["all-gather"] == 16 * 4096 * 4096 * 4
    assert got["all-reduce"] == 256 * 2
    assert got["collective-permute"] == 5 * 1026 * 1026 * 4
    assert got["all-to-all"] == 2 * 8 * 8 * 4
    assert "add" not in got


def test_corrected_collectives_model():
    from repro.launch.report import corrected_coll_bytes

    row = {"coll_by_kind": {"all-gather": 100, "all-reduce": 80,
                            "collective-permute": 20}}
    # bf16 model: 0.5*(AG+CP) + 0.25*AR
    assert corrected_coll_bytes(row, bf16=True) == 0.5 * 120 + 0.25 * 80
    assert corrected_coll_bytes(row, bf16=False) == 200


def test_roofline_terms_and_bottleneck():
    from repro.launch.roofline import Roofline

    r = Roofline(flops=197e12, hbm_bytes=819e9 / 2, coll_bytes=50e9 * 2,
                 coll_by_kind={}, chips=256)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 0.5) < 1e-9
    assert abs(r.t_collective - 2.0) < 1e-9
    assert r.bottleneck == "collective"
    assert r.step_time_lb == r.t_collective


# ----------------------------------------------------------------------
# elastic restore: checkpoint saved on 1 device restored across 8
# ----------------------------------------------------------------------
_SUB = r"""
import json, sys, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.ft.checkpoint import CheckpointConfig, NVMCheckpointManager
from repro.launch.mesh import compat_make_mesh

ckpt_dir = sys.argv[1]
mgr = NVMCheckpointManager(CheckpointConfig(ckpt_dir))
like = {"w": jnp.zeros((32, 16)), "b": jnp.zeros((8,))}
mesh = compat_make_mesh((8,), ("data",))
sh = {"w": NamedSharding(mesh, P("data", None)), "b": NamedSharding(mesh, P())}
got = mgr.restore(like, shardings=sh)
assert got is not None
tree, step, _ = got
ndev = len(tree["w"].sharding.device_set)
print(json.dumps({"step": step, "ndev": ndev,
                  "sum": float(tree["w"].sum())}))
"""


@pytest.mark.multi_device
def test_elastic_restore_across_device_counts(tmp_path, multi_device):
    from repro.ft.checkpoint import CheckpointConfig, NVMCheckpointManager

    # save on THIS process (1 device)
    mgr = NVMCheckpointManager(CheckpointConfig(str(tmp_path)))
    w = jnp.arange(32 * 16, dtype=jnp.float32).reshape(32, 16)
    tree = {"w": w, "b": jnp.ones((8,))}
    mgr.save(tree, step=42)

    out = multi_device.run(_SUB, ndevices=8, argv=[str(tmp_path)],
                           timeout=240)
    assert out["step"] == 42
    assert out["ndev"] == 8                      # resharded onto 8 devices
    assert abs(out["sum"] - float(w.sum())) < 1e-3
