"""Test configuration.

x64 is enabled so exact-state-reconstruction tests run in float64 (the
paper's exactness claim is a double-precision one).  Model code declares
its dtypes explicitly (bf16/f32) and is unaffected.

NOTE: no ``xla_force_host_platform_device_count`` here — smoke tests and
benches must see 1 device (the 512-device flag belongs to dryrun.py ONLY).
"""
import jax

jax.config.update("jax_enable_x64", True)
