"""Test configuration.

x64 is enabled so exact-state-reconstruction tests run in float64 (the
paper's exactness claim is a double-precision one).  Model code declares
its dtypes explicitly (bf16/f32) and is unaffected.

NOTE: no ``xla_force_host_platform_device_count`` in THIS process —
smoke tests and benches must see 1 device.  Faked multi-device runs
live in the :func:`multi_device` fixture's subprocesses only: the XLA
flag must be set before jax imports, and this process already imported
jax, so every multi-device test ships its payload to a fresh
interpreter.  The fixture centralizes that plumbing (it used to be
copy-pasted across test_esrp_and_roofline.py / test_dryrun_small.py),
probes once per session per device count that devices can be faked at
all, and skips cleanly when they cannot.
"""
import json
import os
import subprocess
import sys

import jax
import pytest

jax.config.update("jax_enable_x64", True)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multi_device: runs a payload under faked XLA host devices in a "
        "subprocess (skipped when devices cannot be faked)")


#: prepended to every payload — the flag must land before jax imports
_PROLOGUE = (
    "import os\n"
    "os.environ[\"XLA_FLAGS\"] = "
    "\"--xla_force_host_platform_device_count={n}\"\n")

_PROBE = """
import jax, json
print(json.dumps({"ndev": jax.device_count()}))
"""


class MultiDeviceRunner:
    """Session-wide runner for faked-multi-device payloads.

    ``run(source, ndevices)`` executes ``source`` in a subprocess that
    sees ``ndevices`` faked host devices (PYTHONPATH=src, any inherited
    XLA_FLAGS stripped), asserts it exited 0, and returns its **last
    stdout line parsed as JSON** — the payload's verdict.  The first
    use of each device count probes that XLA really fakes that many
    devices on this platform and ``pytest.skip``s the test if not.
    """

    def __init__(self):
        self._probed = {}

    @staticmethod
    def _env():
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        env.pop("XLA_FLAGS", None)  # never inherit a stray device count
        return env

    def require(self, ndevices: int = 8) -> None:
        ok = self._probed.get(ndevices)
        if ok is None:
            res = subprocess.run(
                [sys.executable, "-c",
                 _PROLOGUE.format(n=ndevices) + _PROBE],
                capture_output=True, text=True, env=self._env(),
                timeout=240)
            ok = False
            if res.returncode == 0:
                try:
                    out = json.loads(res.stdout.strip().splitlines()[-1])
                    ok = out.get("ndev") == ndevices
                except (ValueError, IndexError):
                    ok = False
            self._probed[ndevices] = ok
        if not ok:
            pytest.skip(f"cannot fake {ndevices} XLA host devices "
                        f"on this platform")

    def run(self, source: str, ndevices: int = 8, argv=(), timeout=480):
        self.require(ndevices)
        res = subprocess.run(
            [sys.executable, "-c",
             _PROLOGUE.format(n=ndevices) + source, *map(str, argv)],
            capture_output=True, text=True, env=self._env(),
            timeout=timeout)
        assert res.returncode == 0, res.stderr[-2000:]
        return json.loads(res.stdout.strip().splitlines()[-1])


@pytest.fixture(scope="session")
def multi_device():
    """Centralized ``--xla_force_host_platform_device_count`` plumbing
    (see :class:`MultiDeviceRunner`)."""
    return MultiDeviceRunner()


@pytest.fixture(scope="session")
def request_trace():
    """The shared deterministic service request-trace generator
    (repro.serving.trace.generate_request_trace), exposed as a fixture
    so the service tests, the campaign-fuzz service leg, and the
    benchmark replay the SAME seeded traces.  Call it with a seed (and
    any generator kwargs) to get a tuple of ServiceRequest."""
    from repro.serving.trace import generate_request_trace

    return generate_request_trace
