"""Prefill+decode must reproduce the teacher-forced forward pass exactly
(f32) for every family — the KV-cache/ring-buffer/recurrent-state
bookkeeping is only correct if the logits agree token-for-token."""
import dataclasses as dc

import jax
import jax.numpy as jnp
import pytest

from repro.models import registry as R
from repro.models.transformer import decoder_forward
from repro.models import encdec as E

TOL = 5e-4  # f32 accumulation-order noise


def _f32(cfg):
    kw = {"compute_dtype": "float32"}
    if cfg.n_experts > 0:
        # capacity drops legitimately differ between a 24-token prefill
        # and a 1-token decode step; ample capacity removes drops so the
        # dispatch math itself must agree exactly
        kw["capacity_factor"] = 16.0
    return dc.replace(cfg, **kw)


@pytest.mark.parametrize("arch", ["llama3_8b", "starcoder2_3b", "gemma3_12b",
                                  "mamba2_370m", "recurrentgemma_9b",
                                  "granite_20b", "moonshot_v1_16b_a3b"])
def test_decode_matches_forward(arch):
    cfg = _f32(R.get_config(arch, smoke=True))
    params, _ = R.init_params(cfg, jax.random.PRNGKey(0))
    b, s, extra = 2, 24, 5
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    cont = jax.random.randint(jax.random.PRNGKey(2), (b, extra), 0, cfg.vocab)
    full = jnp.concatenate([toks, cont], 1)

    ref, _, _ = jax.jit(lambda p, t: decoder_forward(p, t, cfg))(params, full)

    caches, _ = R.init_caches(cfg, b, s + extra)
    lp, caches = jax.jit(R.make_prefill(cfg))(params, {"tokens": toks}, caches)
    errs = [float(jnp.max(jnp.abs(lp[:, -1] - ref[:, s - 1])))]
    decode = jax.jit(R.make_decode(cfg))
    idx = jnp.asarray(s, jnp.int32)
    for t in range(extra - 1):
        ld, caches = decode(params, full[:, s + t : s + t + 1], caches, idx)
        errs.append(float(jnp.max(jnp.abs(ld[:, 0] - ref[:, s + t]))))
        idx = idx + 1
    assert max(errs) < TOL, f"{arch}: decode/forward divergence {errs}"


def test_encdec_decode_matches_forward():
    cfg = _f32(R.get_config("whisper_small", smoke=True))
    params, _ = R.init_params(cfg, jax.random.PRNGKey(0))
    b, s, extra = 2, 16, 4
    key = jax.random.PRNGKey(1)
    frames = jax.random.normal(key, (b, cfg.enc_seq, cfg.d_model))
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    cont = jax.random.randint(jax.random.PRNGKey(2), (b, extra), 0, cfg.vocab)
    full = jnp.concatenate([toks, cont], 1)

    enc = jax.jit(lambda p, f: E.encode(p, f, cfg))(params, frames)
    ref, _ = jax.jit(lambda p, t, e: E.decode(p, t, e, cfg))(params, full, enc)

    caches, _ = R.init_caches(cfg, b, s + extra)
    lp, caches = jax.jit(R.make_prefill(cfg))(
        params, {"frames": frames, "tokens": toks}, caches)
    errs = [float(jnp.max(jnp.abs(lp[:, -1] - ref[:, s - 1])))]
    decode = jax.jit(R.make_decode(cfg))
    idx = jnp.asarray(s, jnp.int32)
    for t in range(extra - 1):
        ld, caches = decode(params, full[:, s + t : s + t + 1], caches, idx)
        errs.append(float(jnp.max(jnp.abs(ld[:, 0] - ref[:, s + t]))))
        idx = idx + 1
    assert max(errs) < TOL, f"whisper: decode/forward divergence {errs}"


def test_ring_cache_long_generation_past_window():
    """Sliding-window ring caches must stay correct well past one window
    wrap-around (slot reuse + position masks)."""
    cfg = _f32(R.get_config("starcoder2_3b", smoke=True))  # window=32
    assert cfg.window == 32
    params, _ = R.init_params(cfg, jax.random.PRNGKey(0))
    b, s, extra = 1, 40, 50  # generate > window beyond prefill
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    cont = jax.random.randint(jax.random.PRNGKey(2), (b, extra), 0, cfg.vocab)
    full = jnp.concatenate([toks, cont], 1)
    ref, _, _ = jax.jit(lambda p, t: decoder_forward(p, t, cfg))(params, full)

    caches, _ = R.init_caches(cfg, b, s + extra)
    lp, caches = jax.jit(R.make_prefill(cfg))(params, {"tokens": toks}, caches)
    decode = jax.jit(R.make_decode(cfg))
    idx = jnp.asarray(s, jnp.int32)
    worst = float(jnp.max(jnp.abs(lp[:, -1] - ref[:, s - 1])))
    for t in range(extra - 1):
        ld, caches = decode(params, full[:, s + t : s + t + 1], caches, idx)
        worst = max(worst, float(jnp.max(jnp.abs(ld[:, 0] - ref[:, s + t]))))
        idx = idx + 1
    assert worst < TOL, f"ring cache drifted after wrap: {worst}"
