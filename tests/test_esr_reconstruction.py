"""Exact state reconstruction: the paper's central correctness claim.

After k iterations, fail a set of blocks, reconstruct via Algorithm 3/5,
and compare against the fault-free state at the same iteration —
element-wise, at double-precision tolerance.  Hypothesis drives the
property over operators, failed subsets, and failure times.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (
    BlockJacobiPreconditioner,
    DenseOperator,
    FailurePlan,
    InMemoryESR,
    IdentityPreconditioner,
    JacobiPreconditioner,
    NVMESRHomogeneous,
    NVMESRPRD,
    PCGConfig,
    UnrecoverableFailure,
    make_poisson_problem,
    random_spd,
    solve,
)
from repro.nvm.store import Tier

BACKENDS = {
    "inmemory": lambda op: InMemoryESR(op.nblocks, op.partition.block_size, np.float64),
    "nvm-homogeneous": lambda op: NVMESRHomogeneous(op.nblocks, op.partition.block_size, np.float64),
    "nvm-prd": lambda op: NVMESRPRD(op.nblocks, op.partition.block_size, np.float64),
    "nvm-prd-sync": lambda op: NVMESRPRD(op.nblocks, op.partition.block_size,
                                         np.float64, async_drain=False),
    "nvm-homogeneous-ssd": lambda op: NVMESRHomogeneous(
        op.nblocks, op.partition.block_size, np.float64, tier=Tier.SSD),
}


def _exactness(op, b, pre, backend, fail_at, blocks, period=1):
    ref_state, ref_rep, ref_cap = solve(op, b, pre, PCGConfig(tol=1e-11),
                                        capture_states_at=[fail_at])
    st_, rep, cap = solve(
        op, b, pre, PCGConfig(tol=1e-11, persistence_period=period),
        backend=backend, failures=[FailurePlan(fail_at, tuple(blocks))],
        capture_states_at=[fail_at])
    assert rep.failures_recovered == 1
    assert rep.converged
    # exact reconstruction: state at the recovery point matches fault-free
    k_rec = fail_at - rep.wasted_iterations
    ref2 = ref_cap.get(fail_at) if period == 1 else None
    if period == 1 and ref2 is not None and fail_at in cap:
        for field in ("x", "r", "z", "p"):
            a = np.asarray(getattr(cap[fail_at], field))
            c = np.asarray(getattr(ref2, field))
            np.testing.assert_allclose(a, c, rtol=1e-9, atol=1e-9, err_msg=field)
    # and the final solution is right regardless
    res = float(jnp.linalg.norm(b - op.apply(st_.x)) / jnp.linalg.norm(b))
    assert res < 1e-9
    return rep


@pytest.mark.parametrize("backend_name", list(BACKENDS))
def test_exact_reconstruction_poisson(backend_name):
    op, b = make_poisson_problem(16, 8, 6, nblocks=8)
    pre = JacobiPreconditioner(op)
    _exactness(op, b, pre, BACKENDS[backend_name](op), fail_at=20, blocks=[2, 5])


@pytest.mark.parametrize("precond_cls", [IdentityPreconditioner,
                                         JacobiPreconditioner,
                                         BlockJacobiPreconditioner])
def test_exact_reconstruction_preconditioners(precond_cls):
    op, b = make_poisson_problem(16, 6, 5, nblocks=8)
    pre = precond_cls(op)
    fail_at = 5 if precond_cls is BlockJacobiPreconditioner else 15
    _exactness(op, b, pre, BACKENDS["nvm-prd"](op), fail_at=fail_at, blocks=[0, 7])


def test_adjacent_multiblock_failure():
    """Adjacent failed slabs couple through the stencil: the union solve
    A[F,F] must include the cross-block coupling."""
    op, b = make_poisson_problem(16, 6, 5, nblocks=8)
    pre = JacobiPreconditioner(op)
    _exactness(op, b, pre, BACKENDS["nvm-homogeneous"](op), fail_at=12,
               blocks=[3, 4, 5])


def test_esrp_periodic_persistence_wastes_iterations():
    op, b = make_poisson_problem(16, 6, 5, nblocks=8)
    pre = JacobiPreconditioner(op)
    be = BACKENDS["nvm-prd"](op)
    st_, rep, _ = solve(op, b, pre, PCGConfig(tol=1e-11, persistence_period=7),
                        backend=be, failures=[FailurePlan(25, (1,))])
    assert rep.converged
    assert 0 < rep.wasted_iterations < 7  # ESRP discard cost bounded by T
    assert rep.persist_events < rep.iterations  # fewer persists than iters


def test_repeated_failures():
    op, b = make_poisson_problem(16, 6, 5, nblocks=8)
    pre = JacobiPreconditioner(op)
    be = BACKENDS["nvm-prd"](op)
    st_, rep, _ = solve(op, b, pre, PCGConfig(tol=1e-11), backend=be,
                        failures=[FailurePlan(8, (0,)), FailurePlan(16, (3, 4)),
                                  FailurePlan(24, (7,))])
    assert rep.failures_recovered == 3
    assert rep.converged


def test_inmemory_esr_insufficient_copies_raises():
    """c+1 copies tolerate c failures; c+1 simultaneous failures of
    adjacent ranks can destroy every copy -> UnrecoverableFailure."""
    op, b = make_poisson_problem(16, 6, 5, nblocks=8)
    pre = JacobiPreconditioner(op)
    be = InMemoryESR(op.nblocks, op.partition.block_size, np.float64, copies=1)
    with pytest.raises(UnrecoverableFailure):
        solve(op, b, pre, PCGConfig(tol=1e-11), backend=be,
              failures=[FailurePlan(10, (2, 3))])  # block 2's only copy is on 3


def test_nvm_esr_survives_what_inmemory_cannot():
    """The paper's point: NVM-ESR recovers ANY number of simultaneous
    compute failures with a single persisted copy."""
    op, b = make_poisson_problem(16, 6, 5, nblocks=8)
    pre = JacobiPreconditioner(op)
    be = BACKENDS["nvm-prd"](op)
    st_, rep, _ = solve(op, b, pre, PCGConfig(tol=1e-11), backend=be,
                        failures=[FailurePlan(10, (0, 1, 2, 3, 4, 5, 6))])
    assert rep.failures_recovered == 1
    assert rep.converged


def test_memory_accounting_matches_paper_model():
    """§3.1: in-memory ESR ~ 2*copies*n values of RAM; NVM-ESR: 0 RAM,
    O(n) NVM."""
    op, b = make_poisson_problem(16, 6, 5, nblocks=8)
    pre = JacobiPreconditioner(op)
    esr = InMemoryESR(op.nblocks, op.partition.block_size, np.float64)
    solve(op, b, pre, PCGConfig(tol=1e-11, maxiter=30), backend=esr)
    n = op.n
    ram = esr.memory_overhead_values()
    # paper model: 2*copies*n live + 1 staging slot (mid-burst safety)
    assert 3 * (op.nblocks - 1) * n <= ram <= 3.3 * (op.nblocks - 1) * n
    nvm = BACKENDS["nvm-prd"](op)
    solve(op, b, pre, PCGConfig(tol=1e-11, maxiter=30), backend=nvm)
    assert nvm.memory_overhead_values() == 0
    assert nvm.nvm_values() == 4 * n  # 4-slot ring of shards


@settings(max_examples=15, deadline=None)
@given(
    nblocks=st.sampled_from([4, 8]),
    seed=st.integers(0, 10_000),
    fail_at=st.integers(3, 12),
    data=st.data(),
)
def test_property_exact_reconstruction_dense(nblocks, seed, fail_at, data):
    """Property: for random SPD systems, any proper subset of failed
    blocks reconstructs exactly (dense local solves)."""
    n = 64
    op = DenseOperator(random_spd(n, seed=seed, cond=30.0), nblocks=nblocks)
    rng = np.random.default_rng(seed + 1)
    b = jnp.asarray(rng.standard_normal(n))
    blocks = data.draw(st.lists(st.integers(0, nblocks - 1), min_size=1,
                                max_size=nblocks - 1, unique=True))
    pre = JacobiPreconditioner(op)
    ref, _, ref_cap = solve(op, b, pre, PCGConfig(tol=1e-11, local_solve="dense"),
                            capture_states_at=[fail_at])
    be = NVMESRPRD(op.nblocks, op.partition.block_size, np.float64)
    st2, rep, cap = solve(op, b, pre, PCGConfig(tol=1e-11, local_solve="dense"),
                          backend=be, failures=[FailurePlan(fail_at, tuple(blocks))],
                          capture_states_at=[fail_at])
    if fail_at in ref_cap and fail_at in cap:
        np.testing.assert_allclose(np.asarray(cap[fail_at].x),
                                   np.asarray(ref_cap[fail_at].x),
                                   rtol=1e-8, atol=1e-8)
        np.testing.assert_allclose(np.asarray(cap[fail_at].r),
                                   np.asarray(ref_cap[fail_at].r),
                                   rtol=1e-8, atol=1e-8)
    assert rep.converged or rep.iterations < fail_at  # converged pre-failure
