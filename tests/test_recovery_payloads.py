"""Generalized recovery payloads: codec, crash consistency, schedules.

Property-style tests run as seeded sweeps (no hypothesis dependency) so
they execute everywhere the container does; install requirements-dev.txt
for the full hypothesis suites elsewhere.
"""
import numpy as np
import pytest

from repro.core.esr import UnrecoverableFailure
from repro.core.nvm_esr import NVMESRHomogeneous, ring_slots
from repro.core.state import (
    PCG_SCHEMA,
    RecoverySchema,
    encode_payload,
    payload_nbytes,
)
from repro.solvers import should_persist
from repro.solvers.bicgstab import BICGSTAB_SCHEMA

MULTI = RecoverySchema("multi", vectors=("r", "p", "q"),
                       scalars=("a", "b"), history=1)


# ---------------------------------------------------------------- codec
@pytest.mark.parametrize("schema", [PCG_SCHEMA, BICGSTAB_SCHEMA, MULTI])
@pytest.mark.parametrize("dtype", [np.float64, np.float32])
def test_schema_roundtrip(schema, dtype):
    rng = np.random.default_rng(7)
    for trial in range(20):
        bs = int(rng.integers(1, 64))
        shards = {v: rng.standard_normal(bs).astype(dtype)
                  for v in schema.vectors}
        scalars = {s: float(rng.standard_normal()) for s in schema.scalars}
        k = int(rng.integers(0, 1 << 40))
        raw = schema.encode(k, scalars, shards)
        assert len(raw) == schema.slot_nbytes(bs, dtype)
        got = schema.decode(raw, dtype)
        assert got.k == k
        for s in schema.scalars:
            assert got.scalars[s] == scalars[s]
        for v in schema.vectors:
            np.testing.assert_array_equal(got.vectors[v], shards[v])


def test_pcg_wire_format_unchanged():
    """The generic codec is byte-identical to the legacy PCG layout, so
    pools written before the zoo migration stay readable."""
    p = np.arange(5, dtype=np.float64)
    legacy = encode_payload(3, 0.5, p)
    generic = PCG_SCHEMA.encode(3, {"beta": 0.5}, {"p": p})
    assert legacy == generic
    assert len(legacy) == payload_nbytes(5, np.float64)


def test_schema_validation():
    with pytest.raises(ValueError, match="at least one vector"):
        RecoverySchema("bad", vectors=())
    with pytest.raises(ValueError, match="history"):
        RecoverySchema("bad", vectors=("x",), history=0)


# ------------------------------------------------- crash consistency
def _persist_iters(be, schema, n, ks, seed=0):
    rng = np.random.default_rng(seed)
    payloads = {}
    for k in ks:
        vectors = {v: rng.standard_normal(n) for v in schema.vectors}
        scalars = {s: float(k) + i / 10 for i, s in enumerate(schema.scalars)}
        be.persist_set(k, scalars, vectors)
        payloads[k] = (scalars, vectors)
    return payloads


@pytest.mark.parametrize("schema", [BICGSTAB_SCHEMA, MULTI])
def test_multi_vector_crash_keeps_last_run(schema):
    """A node crash tearing unflushed writes never loses the last durable
    recovery run of a multi-vector set."""
    nblocks, bs = 4, 8
    be = NVMESRHomogeneous(nblocks, bs, np.float64, schema=schema)
    payloads = _persist_iters(be, schema, nblocks * bs, ks=range(4))
    be.fail([1, 2])  # crash() rewinds unflushed bytes on the failed pools
    (got,) = be.recover_set([1, 2], (3,))
    scalars, vectors = payloads[3]
    assert got.scalars == scalars
    for v in schema.vectors:
        want = np.concatenate([vectors[v][1 * bs:2 * bs], vectors[v][2 * bs:3 * bs]])
        np.testing.assert_array_equal(got.vectors[v], want)


@pytest.mark.parametrize("seed", range(12))
def test_multi_vector_torn_write_never_corrupts(seed):
    """Property-style sweep: a torn fragment landing anywhere in the slot
    ring can invalidate the in-flight slot but never yields a payload that
    was not fully committed (CRC-bound headers), and the previous
    iteration remains recoverable."""
    rng = np.random.default_rng(seed)
    schema = MULTI
    nblocks, bs = 2, 8
    be = NVMESRHomogeneous(nblocks, bs, np.float64, schema=schema)
    payloads = _persist_iters(be, schema, nblocks * bs, ks=(0, 1), seed=seed)
    store = be.pools[0].store
    torn_at = int(rng.integers(0, store.size - 1))
    frag = rng.bytes(int(rng.integers(1, 48)))
    frag = frag[: store.size - torn_at]
    store.crash(torn_write=(torn_at, frag))
    be.pools[0].recover()
    # every readable slot decodes to one of the committed payloads
    for s in range(be.slots):
        raw = be.pools[0].read(f"slot{s}")
        if raw is None:
            continue
        got = schema.decode(raw, np.float64)
        assert got.k in payloads
        scalars, vectors = payloads[got.k]
        assert got.scalars == scalars
        for v in schema.vectors:
            np.testing.assert_array_equal(got.vectors[v], vectors[v][:bs])


def test_ring_depth_follows_history():
    assert ring_slots(PCG_SCHEMA) == 4        # the paper's pair ring
    assert ring_slots(BICGSTAB_SCHEMA) == 2   # single-state double buffer
    assert ring_slots(RecoverySchema("h3", vectors=("x",), history=3)) == 6


@pytest.mark.parametrize("history", [1, 2, 3, 4])
def test_inmemory_ring_survives_interrupted_burst(history):
    """Regression (found in review): the in-memory ring must hold the last
    complete history-run through a PARTIAL new burst.  With the old
    ``history+1`` sizing, history>=3 lost slot k=0 to the second write of
    the next burst; the 2h-1 ring provably cannot."""
    from repro.core.esr import InMemoryESR

    schema = RecoverySchema("h", vectors=("x",), history=history)
    nblocks, bs = 4, 4
    be = InMemoryESR(nblocks, bs, np.float64, schema=schema)
    # complete run 0..h-1, then an interrupted burst missing its last write
    ks = list(range(history)) + list(range(history + 3, 2 * history + 2))
    payloads = _persist_iters(be, schema, nblocks * bs, ks=ks)
    be.fail([1])
    sets = be.recover_set([1], tuple(range(history)))
    for kk, got in zip(range(history), sets):
        assert got.k == kk
        np.testing.assert_array_equal(
            got.vectors["x"], payloads[kk][1]["x"][bs:2 * bs])


def test_recover_missing_iteration_raises():
    be = NVMESRHomogeneous(2, 4, np.float64, schema=MULTI)
    _persist_iters(be, MULTI, 8, ks=(0,))
    with pytest.raises(UnrecoverableFailure):
        be.recover_set([0], (5,))


# ---------------------------------------------------- ESRP schedule
def test_should_persist_classic_esr_every_iteration():
    assert all(should_persist(k, 1, h) for k in range(10) for h in (1, 2))
    assert all(should_persist(k, 0, 2) for k in range(10))


@pytest.mark.parametrize("period", [2, 3, 5, 7])
def test_should_persist_pair_bursts_at_period_boundaries(period):
    """History-2 (PCG-style) ESRP: exactly the first two iterations of
    each period persist, so every burst completes a recovery pair."""
    for k in range(4 * period):
        expected = k % period in (0, 1)
        assert should_persist(k, period, history=2) == expected


@pytest.mark.parametrize("period", [2, 3, 5])
def test_should_persist_history1_single_shots(period):
    for k in range(4 * period):
        assert should_persist(k, period, history=1) == (k % period == 0)


def test_should_persist_burst_never_splits():
    """At every period boundary the burst is history-long and contiguous —
    a run that would split across periods could never complete a pair."""
    for period in (3, 5, 8):
        for history in (1, 2):
            ks = [k for k in range(6 * period)
                  if should_persist(k, period, history)]
            runs, run = [], [ks[0]]
            for a, bb in zip(ks, ks[1:]):
                if bb == a + 1:
                    run.append(bb)
                else:
                    runs.append(run)
                    run = [bb]
            runs.append(run)
            assert all(len(r) == history for r in runs)
            assert all(r[0] % period == 0 for r in runs)
