"""End-to-end fault-tolerant training: the paper's persistence protocol
wrapped around the NN training loop (DESIGN.md §4 integration)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.ft.checkpoint import CheckpointConfig, NVMCheckpointManager
from repro.ft.period import PersistencePeriodTuner
from repro.ft.recovery import TrainingRecovery, inject_host_failure
from repro.models import registry as R
from repro.training.data import SyntheticCorpus
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_step import make_train_step


def _setup():
    cfg = R.get_config("llama3_8b", smoke=True)
    params, _ = R.init_params(cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(R.make_train_forward(cfg), AdamWConfig(lr=3e-4)))
    data = SyntheticCorpus(vocab=cfg.vocab, batch=4, seq=32, seed=3)
    return cfg, params, step_fn, data


def _to_jax(b):
    return {k: jnp.asarray(v) for k, v in b.items()}


def test_train_recover_resume_matches_uninterrupted(tmp_path):
    """Train 8 steps; in a parallel universe, crash at step 5, recover
    from the NVM checkpoint at step 4, resume — final params must match
    the uninterrupted run exactly (deterministic data-by-step pipeline)."""
    cfg, params0, step_fn, data = _setup()
    opt0 = adamw_init(params0)

    # --- uninterrupted reference ---
    p, o = params0, opt0
    for s in range(8):
        p, o, _ = step_fn(p, o, _to_jax(data.batch_at(s)))
    ref = p

    # --- fault-tolerant run with failure at step 5 ---
    mgr = NVMCheckpointManager(CheckpointConfig(str(tmp_path), async_drain=False))
    tuner = PersistencePeriodTuner(mtbf_s=1e9, min_period=4, max_period=4)
    rec = TrainingRecovery(mgr, tuner)
    p, o = params0, opt0
    s = 0
    injected = False
    while s < 8:
        if s == 5 and not injected:
            injected = True
            p = inject_host_failure(p)  # volatile state gone
            state, ck_step, extra = rec.recover({"params": p, "opt": o}, s)
            p, o = state["params"], state["opt"]
            s = ck_step  # data cursor restored from the checkpoint step
            continue
        p, o, _ = step_fn(p, o, _to_jax(data.batch_at(s)))
        s += 1
        if s % tuner.period == 0:
            mgr.save({"params": p, "opt": o}, step=s)

    assert rec.failures_recovered == 1
    assert rec.steps_wasted == 1  # failed at 5, checkpoint at 4
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_loss_decreases_over_short_run():
    cfg, params, step_fn, data = _setup()
    opt = adamw_init(params)
    losses = []
    batch = _to_jax(data.batch_at(0))
    for s in range(6):
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
