"""NVM training-checkpoint manager: double buffering, async drain,
crash consistency, elastic restore, Young/Daly period."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ft.checkpoint import CheckpointConfig, NVMCheckpointManager
from repro.ft.period import PersistencePeriodTuner, optimal_period
from repro.ft.recovery import TrainingRecovery, inject_host_failure
from repro.nvm.store import Tier


def _tree(seed=0, scale=1.0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (32, 16)) * scale,
        "nested": {"b": jnp.arange(8, dtype=jnp.float32) * scale},
        "step_arr": jnp.asarray([seed], jnp.int32),
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = NVMCheckpointManager(CheckpointConfig(str(tmp_path)))
    t = _tree(1)
    mgr.save(t, step=7, extra={"cursor": 7})
    got, step, extra = mgr.restore(t)
    assert step == 7 and extra["cursor"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_double_buffer_two_slots_alternate(tmp_path):
    mgr = NVMCheckpointManager(CheckpointConfig(str(tmp_path)))
    mgr.save(_tree(1), step=1)
    mgr.save(_tree(2), step=2)
    got, step, _ = mgr.restore(_tree(0))
    assert step == 2
    # corrupt the newest slot -> restore falls back to the previous
    _, slot = mgr._latest_valid()
    for f in os.listdir(slot):
        if f.endswith(".npy"):
            with open(os.path.join(slot, f), "r+b") as fh:
                fh.seek(60)
                fh.write(b"\xde\xad\xbe\xef")
            break
    got, step, _ = mgr.restore(_tree(0))
    assert step == 1  # CRC catches the torn payload; previous slot wins


def test_crash_mid_persist_keeps_previous(tmp_path):
    mgr = NVMCheckpointManager(CheckpointConfig(str(tmp_path)))
    mgr.save(_tree(1), step=1)
    # simulate crash mid-write of slot for step 2: payload without manifest
    seq = mgr._seq + 1
    slot = mgr._slot_dir(seq)
    os.makedirs(slot, exist_ok=True)
    with open(os.path.join(slot, "w.npy"), "wb") as f:
        np.save(f, np.zeros((32, 16)))
    # no MANIFEST -> invalid
    mgr2 = NVMCheckpointManager(CheckpointConfig(str(tmp_path)))
    got, step, _ = mgr2.restore(_tree(0))
    assert step == 1


def test_async_drain_overlaps_and_joins(tmp_path):
    mgr = NVMCheckpointManager(CheckpointConfig(str(tmp_path), async_drain=True))
    t = _tree(3)
    mgr.save_async(t, step=3)
    mgr.join()
    got, step, _ = mgr.restore(t)
    assert step == 3


def test_elastic_restore_with_shardings(tmp_path):
    """Restore re-places arrays with jax.device_put under the current
    device topology (elastic scaling path; 1 device here)."""
    mgr = NVMCheckpointManager(CheckpointConfig(str(tmp_path)))
    t = _tree(4)
    mgr.save(t, step=4)
    sh = jax.tree.map(lambda a: jax.devices()[0], t)
    got, step, _ = mgr.restore(t, shardings=sh)
    assert step == 4
    assert all(d.devices() == {jax.devices()[0]}
               for d in jax.tree.leaves(got))


def test_training_recovery_cycle(tmp_path):
    mgr = NVMCheckpointManager(CheckpointConfig(str(tmp_path)))
    tuner = PersistencePeriodTuner(mtbf_s=10.0, min_period=1)
    rec = TrainingRecovery(mgr, tuner)
    state = _tree(5)
    rec.maybe_persist(state, step=0)
    mgr.join()
    dead = inject_host_failure(state)
    assert bool(jnp.isnan(dead["w"]).all())
    restored, step, _ = rec.recover(state, failed_step=3)
    assert step == 0 and rec.failures_recovered == 1 and rec.steps_wasted == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))


def test_young_daly_period():
    # delta=1s, MTBF=1h, step=1s -> T_opt = sqrt(2*1*3600) = 84.8 steps
    assert optimal_period(1.0, 3600.0, 1.0) == 85
    # more frequent failures -> shorter period
    assert optimal_period(1.0, 36.0, 1.0) < optimal_period(1.0, 3600.0, 1.0)
    t = PersistencePeriodTuner(mtbf_s=3600.0)
    for _ in range(5):
        t.observe(persist_cost_s=1.0, step_time_s=1.0)
    assert 60 <= t.period <= 110
    assert 0 < t.expected_overhead_fraction() < 0.1


def test_modeled_tier_costs(tmp_path):
    costs = {}
    for tier in (Tier.DRAM, Tier.NVM, Tier.SSD):
        d = tmp_path / tier.value
        mgr = NVMCheckpointManager(CheckpointConfig(str(d), tier=tier))
        costs[tier] = mgr.save(_tree(1), step=1)
    assert costs[Tier.DRAM] < costs[Tier.NVM] < costs[Tier.SSD]


def test_straggler_monitor_classifies_and_advises():
    from repro.ft.straggler import StragglerMonitor

    mon = StragglerMonitor(window=20, spike_mad=5.0, persist_k=3, warmup=5)
    for _ in range(10):
        a = mon.observe(0.100)
    assert a.classification == "normal" and not a.defer_persistence
    # one transient spike: defer persistence but no eviction
    a = mon.observe(1.0)
    assert a.classification == "transient"
    assert a.defer_persistence and not a.suggest_eviction
    # recovery resets the streak
    a = mon.observe(0.101)
    assert a.classification == "normal"
    # persistent straggle: eviction advised after persist_k spikes
    for _ in range(3):
        a = mon.observe(1.0)
    assert a.classification == "persistent" and a.suggest_eviction
    # the baseline median was never poisoned by the spikes
    assert abs(mon.median_step_s - 0.100) < 0.01
