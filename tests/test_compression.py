"""int8 + error-feedback gradient compression: numerics and convergence."""
import jax
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st

from repro.models import registry as R
from repro.training.compression import (
    GradCompression,
    compressed_bytes,
    decompress,
)
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_step import TrainConfig, make_train_step


def test_roundtrip_error_bounded():
    tree = {"a": jnp.linspace(-3, 3, 128), "b": {"c": jnp.ones((4, 4)) * 0.1}}
    ef = GradCompression.init(tree)
    c, ef = ef.compress(tree)
    back = decompress(c)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        scale = float(jnp.max(jnp.abs(x))) / 127.0
        assert float(jnp.max(jnp.abs(x - y))) <= scale * 0.5 + 1e-9


def test_compression_ratio():
    tree = {"w": jnp.zeros((1024, 1024), jnp.float32)}
    ef = GradCompression.init(tree)
    c, _ = ef.compress(tree)
    raw = 1024 * 1024 * 4
    assert compressed_bytes(c) < raw / 3.9  # ~4x


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), steps=st.integers(2, 6))
def test_error_feedback_accumulates_to_truth(seed, steps):
    """Property: summed dequantized grads + final residual == summed true
    grads exactly — error feedback loses nothing over time."""
    key = jax.random.PRNGKey(seed)
    tree = {"w": jax.random.normal(key, (64,))}
    ef = GradCompression.init(tree)
    total_q = jnp.zeros((64,))
    total_true = jnp.zeros((64,))
    for s in range(steps):
        g = {"w": jax.random.normal(jax.random.fold_in(key, s), (64,)) * (0.1 ** s)}
        total_true = total_true + g["w"]
        c, ef = ef.compress(g)
        total_q = total_q + decompress(c)["w"]
    np.testing.assert_allclose(np.asarray(total_q + ef.residual["w"]),
                               np.asarray(total_true), rtol=1e-5, atol=1e-5)


def test_compressed_training_converges_like_uncompressed():
    cfg = R.get_config("llama3_8b", smoke=True)
    params, _ = R.init_params(cfg, jax.random.PRNGKey(0))
    fwd = R.make_train_forward(cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab),
             "targets": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)}

    def run(compress):
        step = jax.jit(make_train_step(fwd, AdamWConfig(lr=1e-3),
                                       TrainConfig(compress_grads=compress)))
        p, o = params, adamw_init(params)
        losses = []
        for _ in range(8):
            p, o, m = step(p, o, batch)
            losses.append(float(m["loss"]))
        return losses

    plain = run(False)
    comp = run(True)
    assert comp[-1] < comp[0]                       # it learns
    assert abs(comp[-1] - plain[-1]) < 0.25 * plain[0]  # tracks the baseline
