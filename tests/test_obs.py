"""Unit tests for ``repro.obs`` (ISSUE 6): the tracer's record model
and exports, and the metrics registry's instrument semantics — no
solver in the loop (the pipeline-level contracts live in
``tests/test_obs_pipeline.py``)."""
import json
import math

import pytest

from repro.obs import (
    NULL_TRACER,
    MetricsRegistry,
    NullTracer,
    Tracer,
    check_report_consistency,
    check_trace_report,
    from_jsonl,
)


def _fake_clock(start=100.0, step=0.5):
    """A deterministic monotonic clock: 100.0, 100.5, 101.0, ..."""
    t = [start - step]

    def clock():
        t[0] += step
        return t[0]

    return clock


# ----------------------------------------------------------------------
# Tracer: spans, events, nesting
# ----------------------------------------------------------------------
def test_span_nesting_and_ordering():
    tr = Tracer(clock=_fake_clock())
    with tr.span("outer", level=1):
        tr.event("mark", at="inside")
        with tr.span("inner", level=2):
            pass
    tr.event("mark", at="after")

    # spans record at close: child before parent, events at their instant
    assert [r["name"] for r in tr.records] == ["mark", "inner", "outer",
                                               "mark"]
    outer = next(r for r in tr.records if r["name"] == "outer")
    inner = next(r for r in tr.records if r["name"] == "inner")
    assert outer["depth"] == 0 and inner["depth"] == 1
    # the child opens after and closes before its parent
    assert inner["ts"] >= outer["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    # the mid-span event carries the nesting depth at its instant
    mark_inside, mark_after = [r for r in tr.records if r["name"] == "mark"]
    assert mark_inside["depth"] == 1 and mark_after["depth"] == 0
    assert tr.names() == ["mark", "inner", "outer"]
    assert tr.counts() == {"mark": 2, "inner": 1, "outer": 1}


def test_span_records_on_exception():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("doomed"):
            raise RuntimeError("boom")
    assert [r["name"] for r in tr.records] == ["doomed"]
    assert tr._depth == 0  # depth restored, tracer reusable


def test_timestamps_are_relative_and_monotonic():
    tr = Tracer(clock=_fake_clock(start=50.0, step=0.25))
    tr.event("a")
    tr.event("b")
    a, b = tr.records
    assert a["ts"] >= 0 and b["ts"] > a["ts"]


# ----------------------------------------------------------------------
# Label sanitization (the JSON-safety contract)
# ----------------------------------------------------------------------
def test_label_escaping_and_json_safety():
    class Weird:
        def __repr__(self):
            return 'Weird("quote\\n")'

    tr = Tracer()
    tr.event("labels",
             s='a "quoted"\nline',
             nan=float("nan"),
             inf=float("-inf"),
             ok=1.5,
             seq=(1, 2.0, "x"),
             mapping={"k": float("inf"), 7: "v"},
             obj=Weird())
    rec = tr.records[0]
    # strict JSON round-trip (allow_nan=False is what the exports use)
    blob = json.dumps(rec, allow_nan=False)
    assert json.loads(blob) == rec
    args = rec["args"]
    assert args["s"] == 'a "quoted"\nline'
    assert args["nan"] == "nan" and args["inf"] == "-inf"
    assert args["ok"] == 1.5
    assert args["seq"] == [1, 2.0, "x"]
    assert args["mapping"] == {"k": "inf", "7": "v"}
    assert args["obj"] == 'Weird("quote\\n")'


# ----------------------------------------------------------------------
# Exports: JSONL round-trip, Chrome structure
# ----------------------------------------------------------------------
def test_jsonl_round_trip(tmp_path):
    tr = Tracer(clock=_fake_clock())
    with tr.span("s", k=3):
        tr.event("e", blocks=(1, 2))
    path = tmp_path / "trace.jsonl"
    assert tr.to_jsonl(path) == 2
    assert from_jsonl(path) == tr.records


def test_chrome_export_structure(tmp_path):
    tr = Tracer(clock=_fake_clock(step=0.001))
    with tr.span("s", k=3):
        tr.event("e")
    path = tmp_path / "trace.json"
    assert tr.to_chrome(path) == 2
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    assert len(events) == 2
    span = next(e for e in events if e["ph"] == "X")
    inst = next(e for e in events if e["ph"] == "i")
    # microseconds, as the trace-event format requires
    span_rec = next(r for r in tr.records if r["type"] == "span")
    assert span["dur"] == pytest.approx(span_rec["dur"] * 1e6)
    assert span["ts"] == pytest.approx(span_rec["ts"] * 1e6)
    assert inst["s"] == "t"
    for e in events:
        assert e["cat"] == "repro" and "ts" in e and "args" in e


# ----------------------------------------------------------------------
# The disabled path
# ----------------------------------------------------------------------
def test_null_tracer_is_falsy_noop_singleton():
    assert not NULL_TRACER and not NullTracer()
    assert bool(Tracer())
    s1 = NULL_TRACER.span("x", k=1)
    s2 = NULL_TRACER.span("y")
    assert s1 is s2  # one cached context manager: no allocations
    with s1:
        pass
    assert NULL_TRACER.event("z") is None
    assert NULL_TRACER.records == []
    assert NULL_TRACER.counts() == {} and NULL_TRACER.names() == []


# ----------------------------------------------------------------------
# Metrics: instruments
# ----------------------------------------------------------------------
def test_counter_monotone():
    reg = MetricsRegistry()
    c = reg.counter("n")
    c.inc()
    c.inc(3)
    assert c.value == 4
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1)
    assert reg.counter_value("n") == 4
    assert reg.counter_value("never") == 0


def test_gauge_last_write_wins():
    reg = MetricsRegistry()
    g = reg.gauge("g")
    g.set(2.5)
    g.set(1.0)
    assert g.value == 1.0


def test_histogram_totals_and_percentiles():
    reg = MetricsRegistry()
    h = reg.histogram("h")
    for v in (3.0, 1.0, 2.0, 4.0):
        h.observe(v)
    assert h.count == 4
    assert h.total == pytest.approx(10.0)
    assert h.mean == pytest.approx(2.5)
    assert h.percentile(50) == 2.0   # nearest-rank
    assert h.percentile(95) == 4.0
    assert h.percentile(0) == 1.0
    s = h.summary()
    assert s["min"] == 1.0 and s["max"] == 4.0 and s["count"] == 4
    assert reg.histogram_total("h") == pytest.approx(10.0)
    assert reg.histogram_total("absent") == 0.0


def test_empty_histogram_summary():
    h = MetricsRegistry().histogram("h")
    assert h.summary() == {"count": 0, "total": 0.0}
    assert math.isnan(h.mean) and math.isnan(h.percentile(50))


def test_histogram_total_matches_plus_equals_accumulation():
    """The derived-view guarantee: Histogram.total accumulates in
    observation order, so report totals derived from the registry are
    bit-identical to the old ``+=`` bookkeeping."""
    import random

    rng = random.Random(0)
    values = [rng.random() * 10 ** rng.randint(-8, 2) for _ in range(500)]
    h = MetricsRegistry().histogram("h")
    acc = 0.0
    for v in values:
        h.observe(v)
        acc += v
    assert h.total == acc  # exact equality, not approx


# ----------------------------------------------------------------------
# Metrics: registry semantics
# ----------------------------------------------------------------------
def test_registry_base_labels_merge_and_identity():
    reg = MetricsRegistry(solver="pcg", mode="overlap")
    a = reg.histogram("persist.commit_s", phase="persist")
    b = reg.histogram("persist.commit_s", phase="persist")
    c = reg.histogram("persist.commit_s", phase="recovery")
    assert a is b and a is not c
    assert dict(a.labels) == {"solver": "pcg", "mode": "overlap",
                              "phase": "persist"}
    # label-qualified reads
    a.observe(1.0)
    c.observe(2.0)
    assert reg.histogram_total("persist.commit_s",
                               phase="persist") == pytest.approx(1.0)
    assert reg.histogram_total("persist.commit_s",
                               phase="recovery") == pytest.approx(2.0)


def test_registry_refuses_kind_conflict():
    reg = MetricsRegistry()
    reg.counter("n")
    with pytest.raises(ValueError, match="already registered as a counter"):
        reg.gauge("n")


def test_registry_iteration_and_snapshot():
    reg = MetricsRegistry(solver="pcg")
    reg.counter("b").inc(2)
    reg.gauge("a").set(1.5)
    reg.histogram("c").observe(0.5)
    assert len(reg) == 3
    assert [i.name for i in reg] == ["a", "b", "c"]  # sorted view
    snap = reg.snapshot()
    assert json.loads(json.dumps(snap)) == snap  # JSON-ready
    by_name = {e["name"]: e for e in snap}
    assert by_name["b"]["value"] == 2
    assert by_name["a"]["value"] == 1.5
    assert by_name["c"]["count"] == 1
    assert all(e["labels"]["solver"] == "pcg" for e in snap)


# ----------------------------------------------------------------------
# Cross-checks
# ----------------------------------------------------------------------
class _FakeReport:
    def __init__(self, metrics=None, **counts):
        self.metrics = metrics
        self.failures_recovered = counts.get("failures_recovered", 0)
        self.recovery_restarts = counts.get("recovery_restarts", 0)
        self.storage_failures = counts.get("storage_failures", 0)
        self.persist_events = counts.get("persist_events", 0)
        self.persist_aborts = counts.get("persist_aborts", 0)


def test_check_report_consistency():
    reg = MetricsRegistry()
    reg.counter("persist.commit").inc(5)
    ok = _FakeReport(metrics=reg, persist_events=5)
    check_report_consistency(ok)
    check_report_consistency(_FakeReport(metrics=None, persist_events=9))
    bad = _FakeReport(metrics=reg, persist_events=4)
    with pytest.raises(ValueError, match="metrics/report disagreement"):
        check_report_consistency(bad)


def test_check_trace_report():
    tr = Tracer()
    tr.event("persist.commit")
    tr.event("persist.commit")
    tr.event("recovery.absorbed")
    rep = _FakeReport(persist_events=2, failures_recovered=1)
    compared = check_trace_report(tr, rep)
    assert compared["persist_events"] == 2
    assert compared["failures_recovered"] == 1
    with pytest.raises(ValueError, match="trace/report disagreement"):
        check_trace_report(tr, _FakeReport(persist_events=3,
                                           failures_recovered=1))


def test_metrics_table_rendering():
    from repro.launch.report import metrics_table

    assert metrics_table(None) == "(no metrics)"
    assert metrics_table(MetricsRegistry()) == "(no metrics)"
    reg = MetricsRegistry(solver="pcg", mode="sync")
    reg.counter("persist.commit").inc(3)
    reg.histogram("persist.commit_s", phase="persist").observe(1e-3)
    table = metrics_table(reg)
    assert "persist.commit" in table and "phase=persist" in table
    # base labels are factored out of the labels column
    assert "solver=pcg" not in table
