"""Sharded-solve lockdown (ISSUE 7): bit-exactness, device-mapped
failures, per-shard recovery traffic.

Three claims from DESIGN.md §10, each asserted against a single
subprocess sweep under 8 faked host devices (the ``multi_device``
fixture; the flag must precede the jax import, so the payload cannot
run in-process):

- **bit-exactness**: every registered solver, in both persist modes,
  against every persistence family, produces a device-sharded
  trajectory bitwise equal to the unsharded one — with and without a
  kill-and-recover in the middle;
- **device-mapped failures**: ``FailureEvent(shard=...)`` kills
  exactly the blocks of that device shard and recovery absorbs it;
- **traffic**: the recovery fetch moves exactly one shard's slot
  bytes — read back from the metrics registry (the same counters
  ``SolveReport`` derives from), never re-derived from the trace — and
  scales with ``blocks_per_shard`` as the shard count varies.
"""
import pytest

_SUB = r"""
import json
import numpy as np
import jax
jax.config.update("jax_enable_x64", True)
from repro.core.poisson import make_poisson_problem, PRECONDITIONERS
from repro.distributed.sharding import shard_problem
from repro.obs.metrics import check_report_consistency
from repro.solvers import driver as drv
from repro.solvers.registry import make_solver, make_backend

SOLVERS = ("pcg", "bicgstab", "gmres", "chebyshev", "jacobi")
MODES = ("sync", "overlap")
SPECS = ("nvm-homogeneous", "nvm-prd", "replicated(nvm-prd x2)",
         "erasure(nvm-prd x4+p)")

op, b = make_poisson_problem(8, 8, 8, nblocks=4)
pre = PRECONDITIONERS["jacobi"](op)
sop, sb = shard_problem(op, b, 4)   # 4 shards -> 1 block per shard


def run(name, the_op, the_b, the_pre, spec, mode, failures):
    solver = make_solver(name, the_op, the_pre)
    backend = make_backend(spec, op if the_op.nblocks == 4 else the_op,
                           solver=solver)
    cfg = drv.SolveConfig(tol=0.0, maxiter=8, persistence_period=2,
                          persist_mode=mode)
    st, rep, _ = drv.solve(solver, the_op, the_b, the_pre, config=cfg,
                           backend=backend, failures=failures)
    check_report_consistency(rep)
    return solver, st, rep


out = {"sweep": [], "nofail": [], "scaling": []}
kill_block = [drv.FailureEvent(blocks=(1,), at_iteration=4)]
kill_shard = [drv.FailureEvent(shard=1, at_iteration=4)]

# --- kill-and-recover bit-exactness sweep -----------------------------
for name in SOLVERS:
    for mode in MODES:
        _, st0, _ = run(name, op, b, pre, "nvm-homogeneous", mode,
                        kill_block)
        bx = np.asarray(st0.x).tobytes()
        br = np.asarray(st0.r).tobytes()
        for spec in SPECS:
            solver, st1, rep1 = run(name, sop, sb, pre, spec, mode,
                                    kill_shard)
            slot = solver.schema.slot_nbytes(op.partition.block_size,
                                             np.dtype(b.dtype))
            m = rep1.metrics
            out["sweep"].append({
                "solver": name, "mode": mode, "spec": spec,
                "x_ok": np.asarray(st1.x).tobytes() == bx,
                "r_ok": np.asarray(st1.r).tobytes() == br,
                "recovered": rep1.failures_recovered,
                "nshards": rep1.nshards,
                # registry reads, NOT re-derived from the trace
                "fetch_registry":
                    m.counter_total("recovery.fetch_bytes"),
                "fetch_by_shard": {
                    str(k): v for k, v in m.counter_by_label(
                        "recovery.fetch_bytes", "shard").items()},
                # one shard == one block here
                "want_fetch": solver.schema.history * 1 * slot,
            })

# --- plain sharded solves (no failure) match too ----------------------
for name in SOLVERS:
    _, st0, _ = run(name, op, b, pre, "nvm-homogeneous", "sync", [])
    _, st1, _ = run(name, sop, sb, pre, "nvm-homogeneous", "sync", [])
    out["nofail"].append({
        "solver": name,
        "x_ok": np.asarray(st1.x).tobytes()
                == np.asarray(st0.x).tobytes(),
        "r_ok": np.asarray(st1.r).tobytes()
                == np.asarray(st0.r).tobytes(),
    })

# --- recovery traffic scales with blocks-per-shard --------------------
op8, b8 = make_poisson_problem(8, 8, 8, nblocks=8)
pre8 = PRECONDITIONERS["jacobi"](op8)
for nshards in (2, 4, 8):
    sop8, sb8 = shard_problem(op8, b8, nshards)
    solver, st, rep = run("pcg", sop8, sb8, pre8, "nvm-homogeneous",
                          "sync",
                          [drv.FailureEvent(shard=0, at_iteration=4)])
    slot = solver.schema.slot_nbytes(op8.partition.block_size,
                                     np.dtype(b8.dtype))
    out["scaling"].append({
        "nshards": nshards,
        "fetch": rep.metrics.counter_total("recovery.fetch_bytes"),
        "want": solver.schema.history * (8 // nshards) * slot,
    })

print(json.dumps(out))
"""


@pytest.mark.multi_device
def test_sharded_bit_exactness_failures_and_traffic(multi_device):
    out = multi_device.run(_SUB, ndevices=8, timeout=1800)

    sweep = out["sweep"]
    assert len(sweep) == 5 * 2 * 4
    for case in sweep:
        ctx = (case["solver"], case["mode"], case["spec"])
        assert case["x_ok"] and case["r_ok"], ctx
        assert case["recovered"] == 1, ctx
        assert case["nshards"] == 4, ctx
        # fetched bytes == one shard's slot bytes, from the registry,
        # attributed to the killed shard
        assert case["fetch_registry"] == case["want_fetch"], ctx
        assert case["fetch_by_shard"] == {"1": case["want_fetch"]}, ctx

    assert len(out["nofail"]) == 5
    for case in out["nofail"]:
        assert case["x_ok"] and case["r_ok"], case["solver"]

    scaling = {c["nshards"]: c for c in out["scaling"]}
    assert set(scaling) == {2, 4, 8}
    for nshards, case in scaling.items():
        assert case["fetch"] == case["want"], case
    # halving the shard count doubles the bytes a recovery must move
    assert scaling[2]["fetch"] == 2 * scaling[4]["fetch"]
    assert scaling[4]["fetch"] == 2 * scaling[8]["fetch"]
