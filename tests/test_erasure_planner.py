"""Erasure-coded persistence + campaign planning (ISSUE 4 tentpole).

Covers:

- the XOR-parity stripe (`ErasureCodedBackend`, DESIGN.md §8): healthy
  and *degraded* fetches are bit-exact for every zoo solver's schema,
  losing the parity node costs nothing, and losing two children raises
  `UnrecoverableFailure` with a per-child diagnosis,
- the acceptance criterion: `erasure(nvm-prd x4+p)` survives a
  `FailureEvent(prd=True)` campaign with exact reconstruction for all
  5 zoo solvers in both persist modes, at < 2x storage overhead,
- the campaign planner (`plan_campaign`): provably-unsurvivable
  campaigns are rejected before iteration 0 with an error naming the
  violating `FailureEvent`; survivable ones return a `CampaignPlan`
  that mirrors the runtime trajectory,
- the `durable_run` rollback-agreement cross-check: a backend whose
  slots disagree with the driver's snapshot is refused loudly.
"""
import numpy as np
import pytest

from repro.core import JacobiPreconditioner, make_poisson_problem
from repro.core.nvm_esr import NVMESRPRD
from repro.core.state import PCG_SCHEMA, shard_vectors, typed_vectors
from repro.nvm.backend import (
    BackendCapabilities,
    ErasureCodedBackend,
    UnrecoverableFailure,
    create_backend,
)
from repro.solvers import (
    SOLVERS,
    FailureCampaign,
    FailureEvent,
    SolveConfig,
    UnsurvivableCampaignError,
    make_backend,
    make_solver,
    plan_campaign,
    solve,
)

# (fail_at, solver opts): gmres counts restart cycles, not iterations
SOLVER_CASES = {
    "pcg": (10, {}),
    "jacobi": (10, {}),
    "chebyshev": (10, {}),
    "bicgstab": (10, {}),
    "gmres": (3, {"m": 4}),
}
assert set(SOLVER_CASES) == set(SOLVERS)

ERASURE = "erasure(nvm-prd x4+p)"
ERASURE2 = "erasure(nvm-prd x6+2p)"


def _problem(nblocks=4):
    op, b = make_poisson_problem(8, 8, 8, nblocks=nblocks)
    return op, b, JacobiPreconditioner(op)


def _state_fields_close(got, want, rtol=1e-9, atol=1e-9):
    for field in got._fields:
        a, c = getattr(got, field), getattr(want, field)
        if hasattr(a, "shape"):
            np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                       rtol=rtol, atol=atol, err_msg=field)


# ------------------------------------------------------------ the stripe
def _synthetic_events(schema, n, history):
    """Deterministic per-solver payload stream for the bit-exactness
    sweeps (seeded by the schema so solvers differ)."""
    rng = np.random.default_rng(abs(hash(schema.solver)) % 2**32)
    events = []
    for k in range(history):
        scalars = {s: float(rng.standard_normal()) for s in schema.scalars}
        vectors = {v: rng.standard_normal(n) for v in schema.vectors}
        events.append((k, scalars, vectors))
    return events


@pytest.mark.parametrize("solver_name", sorted(SOLVERS))
def test_degraded_fetch_bit_exact_sweep(solver_name):
    """The satellite sweep: for every solver schema, a fetch served in
    degraded mode (one data child lost, chunk rebuilt from parity) is
    BIT-identical to the healthy fetch — np.array_equal, not allclose."""
    op, _, pre = _problem()
    solver = make_solver(solver_name, op, pre, **SOLVER_CASES[solver_name][1])
    schema = solver.schema
    failed, n, bs = (1, 3), op.n, op.partition.block_size

    def run(kill_child):
        be = make_backend(ERASURE, op, solver=solver)
        session = be.open_session(schema)
        for k, scalars, vectors in _synthetic_events(schema, n, schema.history):
            session.persist(k, scalars, vectors)
        if kill_child is not None:
            session._children[kill_child].fail_storage()
        return session.fetch(failed, tuple(range(schema.history)))

    healthy = run(None)
    for kill in (0, 2, -1):                   # two data children + parity
        degraded = run(kill)
        for h, d in zip(healthy, degraded):
            assert d.k == h.k
            assert d.scalars == h.scalars
            for name in schema.vectors:
                assert np.array_equal(d.vectors[name], h.vectors[name]), \
                    (solver_name, kill, name)
    # and the healthy fetch itself matches the persisted shards exactly
    for (k, scalars, vectors), got in zip(
            _synthetic_events(schema, n, schema.history), healthy):
        typed = typed_vectors(schema, vectors, np.float64)
        for name in schema.vectors:
            want = np.concatenate(
                [shard_vectors(schema, typed, b, bs)[name] for b in failed])
            assert np.array_equal(got.vectors[name], want)


def test_two_lost_children_raise_with_diagnosis():
    op, _, _ = _problem()
    be = make_backend(ERASURE, op)
    session = be.open_session(PCG_SCHEMA)
    session.persist(0, {"beta": 0.0}, {"p": np.zeros(op.n)})
    session.persist(1, {"beta": 0.5}, {"p": np.ones(op.n)})
    session.fail_storage()                       # data child 0
    session.fetch((2,), (0, 1))                  # degraded: still served
    session.fail_storage()                       # data child 1: distance 2
    with pytest.raises(UnrecoverableFailure, match="lost 2 of 5"):
        session.fetch((2,), (0, 1))
    assert session.durable_run() is None


def test_degraded_writes_stay_reconstructible():
    """RAID degraded mode: events persisted AFTER a data child is lost
    are still exact — parity is computed from the full payload, so the
    dead child's chunk of new events is reconstructible too."""
    op, _, _ = _problem()
    be = make_backend(ERASURE, op)
    session = be.open_session(PCG_SCHEMA)
    session.persist(0, {"beta": 0.0}, {"p": np.zeros(op.n)})
    session.fail_storage()                       # data child 0 dies ...
    rng = np.random.default_rng(7)
    p1 = rng.standard_normal(op.n)
    session.persist(1, {"beta": 0.5}, {"p": p1})  # ... then k=1 lands
    sets = session.fetch((0, 2), (0, 1))
    bs = op.partition.block_size
    want = np.concatenate([p1[:bs], p1[2 * bs:3 * bs]])
    assert np.array_equal(sets[1].vectors["p"], want)
    assert session.durable_run() == 1


def test_erasure_footprint_beats_mirroring():
    """The paper's footprint argument at the redundancy layer: the 4+p
    stripe stores ~1.25x a single backend's values — strictly below the
    2x mirror — while declaring the same single-PRD-loss survival."""
    op, _, _ = _problem()
    single = make_backend("nvm-prd", op)
    stripe = make_backend(ERASURE, op)
    mirror = make_backend("replicated(nvm-prd x2)", op)
    ratio = stripe.nvm_values() / single.nvm_values()
    assert ratio == pytest.approx(1.25)          # 128 % 4 == 0: no padding
    assert ratio < mirror.nvm_values() / single.nvm_values() == 2.0
    assert stripe.memory_overhead_values() == 0  # still zero RAM redundancy


@pytest.mark.parametrize("solver_name", sorted(SOLVERS))
def test_x6_2p_any_two_losses_bit_exact_sweep(solver_name):
    """The ISSUE 5 acceptance sweep: for every solver schema, the
    x6+2p Reed-Solomon stripe serves a BIT-identical fetch after ANY
    two simultaneous storage-child losses — all C(8,2)=28 pairs, plus
    every single loss — np.array_equal, not allclose."""
    import itertools

    op, _, pre = _problem()
    solver = make_solver(solver_name, op, pre, **SOLVER_CASES[solver_name][1])
    schema = solver.schema
    failed, n = (1, 3), op.n

    def run(kill):
        be = make_backend(ERASURE2, op, solver=solver)
        session = be.open_session(schema)
        for k, scalars, vectors in _synthetic_events(schema, n, schema.history):
            session.persist(k, scalars, vectors)
        for child in kill:
            session._children[child].fail_storage()
        return session.fetch(failed, tuple(range(schema.history)))

    healthy = run(())
    kills = ([(c,) for c in range(8)]
             + list(itertools.combinations(range(8), 2)))
    for kill in kills:
        degraded = run(kill)
        for h, d in zip(healthy, degraded):
            assert d.k == h.k and d.scalars == h.scalars
            for name in schema.vectors:
                assert np.array_equal(d.vectors[name], h.vectors[name]), \
                    (solver_name, kill, name)


def test_x6_2p_three_losses_raise_with_diagnosis():
    op, _, _ = _problem()
    be = make_backend(ERASURE2, op)
    session = be.open_session(PCG_SCHEMA)
    session.persist(0, {"beta": 0.0}, {"p": np.zeros(op.n)})
    session.persist(1, {"beta": 0.5}, {"p": np.ones(op.n)})
    session.fail_storage()
    session.fail_storage()                       # two losses: degraded
    session.fetch((2,), (0, 1))
    session.fail_storage()                       # third: distance 3 exceeded
    with pytest.raises(UnrecoverableFailure, match="lost 3 of 8"):
        session.fetch((2,), (0, 1))
    assert session.durable_run() is None


@pytest.mark.parametrize("persist_mode", ["sync", "overlap"])
@pytest.mark.parametrize("solver_name", sorted(SOLVERS))
def test_x6_2p_survives_double_prd_kill_exactly(solver_name, persist_mode):
    """The ISSUE 5 acceptance criterion at the solve level: a campaign
    whose single recovery fetches after TWO simultaneous storage-child
    losses is planned as survivable on the x6+2p stripe and recovered
    to machine precision, for every zoo solver, in both persist
    modes."""
    op, b, pre = _problem()
    fail_at, opts = SOLVER_CASES[solver_name]
    ref_cap = _reference(solver_name)

    solver = make_solver(solver_name, op, pre, **opts)
    backend = make_backend(ERASURE2, op, solver=solver)
    campaign = FailureCampaign((
        FailureEvent(blocks=(), at_iteration=max(2, fail_at - 1), prd=True),
        FailureEvent(blocks=(1, 2), at_iteration=fail_at, prd=True),
    ))
    plan = plan_campaign(campaign, backend.capabilities)
    assert plan.storage_losses == 2
    assert plan.recoveries[-1].storage_losses == 2

    state, rep, cap = solve(
        solver, op, b, pre,
        SolveConfig(tol=1e-10, maxiter=5000, persist_mode=persist_mode),
        backend=backend, failures=campaign,
        capture_states_at=[fail_at - 1, fail_at])

    assert rep.storage_failures == 2
    assert rep.failures_recovered == 1
    assert rep.converged
    k_rec = fail_at - rep.wasted_iterations
    _state_fields_close(cap[k_rec], ref_cap[k_rec])
    res = float(np.linalg.norm(np.asarray(b - op.apply(state.x)))
                / np.linalg.norm(np.asarray(b)))
    assert res < 1e-9


def test_erasure_k2p_validation():
    """ISSUE 5 satellite: the wide-code composition refuses K < 2,
    P outside {1, 2}, aliased children, and plain-schema children —
    at composition time, with actionable errors."""
    from repro.nvm.backend import stripe_child_schema

    op, _, _ = _problem()
    with pytest.raises(ValueError, match=">= 2 data children"):
        make_backend("erasure(nvm-prd x1+2p)", op)
    with pytest.raises(ValueError, match=">= 2 data children"):
        make_backend("erasure", op, data=("nvm-prd",), nparity=2)
    with pytest.raises(ValueError, match=r"1 \(xK\+p\) or 2 \(xK\+2p\)"):
        make_backend("erasure", op, nparity=3)
    with pytest.raises(ValueError, match=r"1 \(xK\+p\) or 2 \(xK\+2p\)"):
        make_backend("erasure", op, nparity=0)

    stripe_schema = stripe_child_schema(PCG_SCHEMA)
    kids = [create_backend("nvm-prd", 4, 32, np.float64,
                           schema=stripe_schema) for _ in range(4)]
    # an aliased parity pair: one node wearing both parity hats
    with pytest.raises(ValueError, match="distinct backend instances"):
        ErasureCodedBackend(kids[:2], [kids[2], kids[2]], block_size=64)
    # a data child doubling as parity
    with pytest.raises(ValueError, match="distinct backend instances"):
        ErasureCodedBackend(kids[:2], [kids[1], kids[3]], block_size=64)
    # three parity children: beyond the P+Q construction
    with pytest.raises(ValueError, match=r"1 \(xK\+p\) or 2 \(xK\+2p\)"):
        ErasureCodedBackend(kids[:2], [kids[2], kids[3], kids[0]] + [
            create_backend("nvm-prd", 4, 32, np.float64,
                           schema=stripe_schema)], block_size=64)
    # children bound to the bare solver schema cannot record rotation
    plain = [create_backend("nvm-prd", 4, 32, np.float64,
                            schema=PCG_SCHEMA) for _ in range(3)]
    with pytest.raises(ValueError, match="stripe_child_schema"):
        ErasureCodedBackend(plain[:2], plain[2], block_size=64)
    # the x6+2p spec string composes cleanly end to end
    be = make_backend(ERASURE2, op)
    assert be.capabilities.max_storage_failures == 2
    assert be.nparity == 2 and be.k_data == 6


def test_erasure_validation():
    op, _, _ = _problem()
    with pytest.raises(ValueError, match=">= 2 data children"):
        make_backend("erasure", op, data=("nvm-prd",))
    pcg = create_backend("nvm-prd", 4, 32, np.float64, schema=PCG_SCHEMA)
    from repro.solvers.bicgstab import BICGSTAB_SCHEMA

    bicg = create_backend("nvm-prd", 4, 32, np.float64,
                          schema=BICGSTAB_SCHEMA)
    pcg2 = create_backend("nvm-prd", 4, 32, np.float64, schema=PCG_SCHEMA)
    with pytest.raises(ValueError, match="same schema"):
        ErasureCodedBackend([pcg, bicg], pcg2, block_size=64)
    pcg3 = create_backend("nvm-prd", 4, 32, np.float64, schema=PCG_SCHEMA)
    with pytest.raises(ValueError, match="chunk"):
        ErasureCodedBackend([pcg, pcg2], pcg3, block_size=128)  # 128/2 != 32
    # an aliased child silently drops its second write — refused up front
    with pytest.raises(ValueError, match="distinct backend instances"):
        ErasureCodedBackend([pcg, pcg2], pcg, block_size=64)
    with pytest.raises(ValueError, match="distinct backend instances"):
        ErasureCodedBackend([pcg, pcg], pcg3, block_size=64)
    # the factory default parity spec would alias pre-built data children
    from repro.nvm.backend import _erasure_factory

    with pytest.raises(ValueError, match="distinct backend instances"):
        _erasure_factory(4, 64, np.float64, data=(pcg, pcg2))


# ------------------------------------- acceptance: the PRD-loss campaign
_REF_CACHE = {}


def _reference(solver_name):
    if solver_name not in _REF_CACHE:
        op, b, pre = _problem()
        fail_at, opts = SOLVER_CASES[solver_name]
        solver = make_solver(solver_name, op, pre, **opts)
        _, rep, cap = solve(solver, op, b, pre,
                            SolveConfig(tol=1e-10, maxiter=5000),
                            capture_states_at=[fail_at - 1, fail_at])
        assert rep.converged
        _REF_CACHE[solver_name] = cap
    return _REF_CACHE[solver_name]


@pytest.mark.parametrize("persist_mode", ["sync", "overlap"])
@pytest.mark.parametrize("solver_name", sorted(SOLVERS))
def test_erasure_survives_prd_kill_exactly(solver_name, persist_mode):
    """The acceptance criterion: a campaign event that crashes a stripe
    node AND two compute blocks is recovered to machine precision by
    the 4+p stripe, for every zoo solver, in both persist modes — with
    the campaign planner accepting the campaign up front."""
    op, b, pre = _problem()
    fail_at, opts = SOLVER_CASES[solver_name]
    ref_cap = _reference(solver_name)

    solver = make_solver(solver_name, op, pre, **opts)
    backend = make_backend(ERASURE, op, solver=solver)
    campaign = FailureCampaign((
        FailureEvent(blocks=(1, 2), at_iteration=fail_at, prd=True),))
    plan = plan_campaign(campaign, backend.capabilities)
    assert plan.recoveries[0].blocks == (1, 2)
    assert plan.storage_losses == 1

    state, rep, cap = solve(
        solver, op, b, pre,
        SolveConfig(tol=1e-10, maxiter=5000, persist_mode=persist_mode),
        backend=backend, failures=campaign,
        capture_states_at=[fail_at - 1, fail_at])

    assert rep.failures_recovered == 1
    assert rep.storage_failures == 1
    assert rep.converged
    assert rep.wasted_iterations == (1 if persist_mode == "overlap" else 0)
    k_rec = fail_at - rep.wasted_iterations
    _state_fields_close(cap[k_rec], ref_cap[k_rec])
    res = float(np.linalg.norm(np.asarray(b - op.apply(state.x)))
                / np.linalg.norm(np.asarray(b)))
    assert res < 1e-9


def test_stripe_node_dies_during_inflight_recovery():
    """Overlapping campaign over the stripe: a data node dies while the
    recovery of an earlier block failure is in flight — the refetch is
    served degraded, from parity."""
    op, b, pre = _problem()
    solver = make_solver("pcg", op, pre)
    backend = make_backend(ERASURE, op, solver=solver)
    campaign = FailureCampaign((
        FailureEvent(blocks=(1, 2), at_iteration=8),
        FailureEvent(blocks=(), during_recovery_at=8, prd=True),
    ))
    state, rep, _ = solve(solver, op, b, pre,
                          SolveConfig(tol=1e-10, persist_mode="overlap"),
                          backend=backend, failures=campaign)
    assert rep.converged
    assert rep.recovery_restarts == 1
    assert rep.storage_failures == 1
    res = float(np.linalg.norm(np.asarray(b - op.apply(state.x)))
                / np.linalg.norm(np.asarray(b)))
    assert res < 1e-9


# ----------------------------------------------------- campaign planning
def test_planner_rejects_double_prd_loss_on_stripe_accepts_on_x3():
    """The ISSUE's decision pair: two PRD losses feeding a recovery are
    beyond the stripe's distance-2 parity (rejected up front, naming
    the violating event) but inside a triple mirror's budget."""
    op, b, pre = _problem()
    campaign = FailureCampaign((
        FailureEvent(blocks=(1,), at_iteration=8, prd=True),
        FailureEvent(blocks=(2,), at_iteration=12, prd=True),
    ))

    solver = make_solver("pcg", op, pre)
    stripe = make_backend(ERASURE, op, solver=solver)
    with pytest.raises(UnsurvivableCampaignError,
                       match=r"iteration 12 .* 2 persistence-service"):
        solve(solver, op, b, pre, SolveConfig(tol=1e-10),
              backend=stripe, failures=campaign)
    # the error names the violating event precisely
    with pytest.raises(UnsurvivableCampaignError, match="at_iteration=12"):
        plan_campaign(campaign, stripe.capabilities)

    mirror3 = make_backend("replicated(nvm-prd x3)", op, solver=solver)
    plan = plan_campaign(campaign, mirror3.capabilities)
    assert [r.storage_losses for r in plan.recoveries] == [1, 2]
    state, rep, _ = solve(solver, op, b, pre, SolveConfig(tol=1e-10),
                          backend=mirror3, failures=campaign)
    assert rep.converged and rep.storage_failures == 2


def test_planner_budgets_overlapping_prd_losses():
    """A during-recovery PRD loss counts against the refetch it forces:
    one at-event loss + one overlapping loss = 2 by the final fetch."""
    campaign = FailureCampaign((
        FailureEvent(blocks=(1,), at_iteration=8, prd=True),
        FailureEvent(blocks=(2,), during_recovery_at=8, prd=True),
    ))
    stripe_caps = BackendCapabilities(
        "nvm", True, True, overlap="native", max_storage_failures=1)
    with pytest.raises(UnsurvivableCampaignError, match="during_recovery_at=8"):
        plan_campaign(campaign, stripe_caps)
    x3_caps = BackendCapabilities(
        "nvm", True, True, overlap="native", max_storage_failures=2)
    plan = plan_campaign(campaign, x3_caps)
    assert plan.recoveries[0].blocks == (1, 2)
    assert plan.recoveries[0].restarts == 1
    assert plan.recoveries[0].storage_losses == 2


def test_planner_rejects_block_union_beyond_copies():
    """Peer-RAM ESR with c copies cannot fetch a (c+1)-block union; the
    planner proves it from max_block_failures before iteration 0."""
    op, b, pre = _problem()
    solver = make_solver("pcg", op, pre)
    backend = make_backend("esr", op, solver=solver, copies=1)
    campaign = FailureCampaign((
        FailureEvent(blocks=(1,), at_iteration=6),
        FailureEvent(blocks=(3,), during_recovery_at=6),  # union {1, 3}
    ))
    with pytest.raises(UnsurvivableCampaignError,
                       match=r"union \(1, 3\).*max_block_failures=1"):
        solve(solver, op, b, pre, SolveConfig(tol=1e-10),
              backend=backend, failures=campaign)
    # two copies cover the same union
    plan = plan_campaign(campaign,
                         make_backend("esr", op, solver=solver,
                                      copies=2).capabilities)
    assert plan.recoveries[0].blocks == (1, 3)


def test_planner_accepts_latent_storage_loss():
    """A PRD loss with no later fetch is survivable (the solve just runs
    unprotected from there) — the planner must NOT reject it."""
    caps = BackendCapabilities("nvm", True, False, overlap="native")
    plan = plan_campaign(
        FailureCampaign((FailureEvent(blocks=(), at_iteration=5,
                                      prd=True),)), caps)
    assert plan.recoveries == () and plan.storage_losses == 1
    # ... but the same loss followed by any recovery is provably fatal
    with pytest.raises(UnsurvivableCampaignError, match="at_iteration=5"):
        plan_campaign(FailureCampaign((
            FailureEvent(blocks=(), at_iteration=5, prd=True),
            FailureEvent(blocks=(1,), at_iteration=9),
        )), caps)


def test_planner_accepts_plain_sequences():
    from repro.solvers import FailurePlan

    caps = BackendCapabilities("nvm", True, False, overlap="native")
    plan = plan_campaign([FailurePlan(4, (0, 2))], caps)
    assert plan.recoveries[0].blocks == (0, 2)


def test_plan_campaign_disabled_runs_runtime_path():
    """plan_campaign=False runs the same campaign unplanned: the failure
    surfaces at the recovery fetch as a runtime UnrecoverableFailure
    (and NOT as the planner's subclass)."""
    op, b, pre = _problem()
    solver = make_solver("pcg", op, pre)
    backend = make_backend("nvm-prd", op, solver=solver)
    campaign = FailureCampaign((
        FailureEvent(blocks=(1,), at_iteration=8, prd=True),))
    with pytest.raises(UnrecoverableFailure) as exc:
        solve(solver, op, b, pre,
              SolveConfig(tol=1e-10, plan_campaign=False),
              backend=backend, failures=campaign)
    assert not isinstance(exc.value, UnsurvivableCampaignError)


def test_api_facade_plans_campaigns():
    from repro import api

    problem = api.Problem.poisson(8, nblocks=4)
    failures = [api.FailureEvent(blocks=(1,), at_iteration=8, prd=True)]
    with pytest.raises(api.UnsurvivableCampaignError):
        api.solve(problem, "pcg", "nvm-prd", failures=failures)
    # the stripe spec string works end to end through the façade
    result = api.solve(problem, "pcg",
                       api.ResilienceSpec(ERASURE, persist_mode="overlap"),
                       failures=failures)
    assert result.converged and result.report.storage_failures == 1
    assert result.capabilities.max_storage_failures == 1


# ---------------------------------------- durable_run rollback agreement
class _LyingPRD(NVMESRPRD):
    """A backend whose slots claim a different durable run than the
    driver's snapshot — the cross-check must refuse to reconstruct."""

    def durable_run(self):
        run = NVMESRPRD.durable_run(self)
        return None if run is None else run + 1


def test_durable_run_crosscheck_catches_disagreement():
    op, b, pre = _problem()
    solver = make_solver("pcg", op, pre)
    backend = _LyingPRD(op.nblocks, op.partition.block_size, np.float64,
                        schema=solver.schema)
    with pytest.raises(RuntimeError, match="rollback-point disagreement"):
        solve(solver, op, b, pre, SolveConfig(tol=1e-10),
              backend=backend,
              failures=FailureCampaign((
                  FailureEvent(blocks=(1,), at_iteration=8),)))


def test_durable_run_crosscheck_passes_on_honest_backends(monkeypatch):
    """The cross-check is exercised (not skipped) on every recovery of
    an honest backend: durable_run answers, and equals the snapshot —
    here across a mid-burst ESRP rollback over the stripe."""
    from repro.nvm.backend import ErasureSession

    answered = []
    orig = ErasureSession.durable_run

    def spy(self):
        run = orig(self)
        answered.append(run)
        return run

    monkeypatch.setattr(ErasureSession, "durable_run", spy)
    op, b, pre = _problem()
    solver = make_solver("pcg", op, pre)
    backend = make_backend(ERASURE, op, solver=solver)
    state, rep, _ = solve(solver, op, b, pre,
                          SolveConfig(tol=1e-10, persistence_period=5,
                                      persist_mode="overlap"),
                          backend=backend,
                          failures=FailureCampaign((
                              FailureEvent(blocks=(1, 2), at_iteration=6),)))
    assert rep.converged and rep.failures_recovered == 1
    # the mid-burst rollback point (k=1) was cross-checked and agreed
    assert 1 in answered and None not in answered
