"""The persistence-backend API acceptance suite (ISSUE 3 tentpole).

Covers the formal protocol (`repro.nvm.backend`):

- every registered backend declares complete `BackendCapabilities`,
- capability *enforcement*: a backend that forbids PRD loss raises
  `UnrecoverableFailure` (never silently corrupts) when a campaign
  kills its PRD node,
- the ROADMAP closure: `ReplicatedBackend` over two PRD children
  recovers a campaign that crashes the PRD node itself — exactly, for
  all 5 zoo solvers, over both NVM child backends, in both sync and
  overlap persist modes,
- the composable registry (spec strings, did-you-mean errors),
- `TieredBackend` (RAM front over any child) and session lifecycle,
- the `repro.api` façade end to end.
"""
import numpy as np
import pytest

from repro.core import JacobiPreconditioner, make_poisson_problem
from repro.core.esr import InMemoryESR
from repro.core.nvm_esr import NVMESRHomogeneous, NVMESRPRD
from repro.core.state import PCG_SCHEMA
from repro.nvm.backend import (
    BackendCapabilities,
    PersistenceBackend,
    ReplicatedBackend,
    TieredBackend,
    UnrecoverableFailure,
    backend_names,
    create_backend,
    parse_backend_spec,
)
from repro.solvers import (
    SOLVERS,
    FailureCampaign,
    FailureEvent,
    SolveConfig,
    make_backend,
    make_solver,
    solve,
)

NVM_CHILDREN = ("nvm-prd", "nvm-homogeneous")

# (fail_at, solver opts): gmres counts restart cycles, not iterations
SOLVER_CASES = {
    "pcg": (10, {}),
    "jacobi": (10, {}),
    "chebyshev": (10, {}),
    "bicgstab": (10, {}),
    "gmres": (3, {"m": 4}),
}
assert set(SOLVER_CASES) == set(SOLVERS)


def _problem(nblocks=4):
    op, b = make_poisson_problem(8, 8, 8, nblocks=nblocks)
    return op, b, JacobiPreconditioner(op)


def _state_fields_close(got, want, rtol=1e-9, atol=1e-9):
    for field in got._fields:
        a, c = getattr(got, field), getattr(want, field)
        if hasattr(a, "shape"):
            np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                       rtol=rtol, atol=atol, err_msg=field)


# ---------------------------------------------------------- capabilities
def test_every_registered_backend_declares_complete_capabilities():
    """The check_api.py CI gate, in-suite: construction through the
    registry yields a PersistenceBackend with a fully populated record."""
    for name in backend_names():
        be = create_backend(name, nblocks=4, block_size=8,
                            dtype=np.float64, schema=PCG_SCHEMA)
        assert isinstance(be, PersistenceBackend), name
        caps = be.capabilities
        assert isinstance(caps, BackendCapabilities), name
        assert caps.durability and isinstance(caps.durability, str), name
        assert isinstance(caps.survives_node_loss, bool), name
        assert isinstance(caps.survives_prd_loss, bool), name
        assert caps.overlap in ("native", "driver-staged"), name


def test_capability_matrix_expectations():
    """The declared guarantees match the architectures' semantics."""
    op, _, _ = _problem()
    esr = make_backend("esr", op)
    assert esr.capabilities.durability == "ram"
    assert esr.capabilities.max_block_failures == esr.copies
    assert not esr.capabilities.survives_prd_loss

    prd = make_backend("nvm-prd", op)
    assert prd.capabilities.durability == "nvm"
    assert prd.capabilities.survives_node_loss
    assert not prd.capabilities.survives_prd_loss

    repl = make_backend("replicated(nvm-prd x2)", op)
    assert repl.capabilities.survives_prd_loss  # the composition's point
    assert repl.capabilities.durability == "nvm"
    assert repl.capabilities.max_storage_failures == 1
    assert make_backend("replicated(nvm-prd x3)",
                        op).capabilities.max_storage_failures == 2

    erasure = make_backend("erasure(nvm-prd x4+p)", op)
    assert erasure.capabilities.survives_prd_loss
    assert erasure.capabilities.max_storage_failures == 1  # code distance 2
    assert erasure.capabilities.durability == "nvm"
    assert erasure.capabilities.overlap == "native"

    tiered = make_backend("tiered(nvm-homogeneous)", op)
    assert tiered.capabilities.overlap == "native"
    assert not tiered.capabilities.survives_prd_loss  # child's guarantee
    assert tiered.capabilities.max_storage_failures == 0


def test_capabilities_validate_fields():
    with pytest.raises(ValueError, match="overlap"):
        BackendCapabilities("nvm", True, False, overlap="sometimes")
    with pytest.raises(ValueError, match="durability"):
        BackendCapabilities("", True, False, overlap="native")
    with pytest.raises(ValueError, match="max_storage_failures"):
        BackendCapabilities("nvm", True, False, overlap="native",
                            max_storage_failures=-1)
    # survives_prd_loss is max_storage_failures viewed as a boolean;
    # declaring one without the other is incoherent
    with pytest.raises(ValueError, match="incoherent"):
        BackendCapabilities("nvm", True, True, overlap="native")
    with pytest.raises(ValueError, match="incoherent"):
        BackendCapabilities("nvm", True, False, overlap="native",
                            max_storage_failures=1)


# ------------------------------------------------- capability enforcement
@pytest.mark.parametrize("planned", [True, False],
                         ids=["planner-reject", "runtime-raise"])
@pytest.mark.parametrize("backend_name", ["esr", "nvm-homogeneous", "nvm-prd"])
def test_prd_loss_without_mirror_raises_not_corrupts(backend_name, planned):
    """The satellite criterion: a backend whose capabilities forbid PRD
    loss must raise UnrecoverableFailure — not silently reconstruct from
    unreachable or stale data — when a campaign kills its PRD node.
    With the planner on the campaign is rejected before iteration 0;
    unplanned, the same guarantee holds at the recovery fetch."""
    op, b, pre = _problem()
    solver = make_solver("pcg", op, pre)
    backend = make_backend(backend_name, op, solver=solver)
    assert not backend.capabilities.survives_prd_loss
    assert backend.capabilities.max_storage_failures == 0
    campaign = FailureCampaign((
        FailureEvent(blocks=(1, 2), at_iteration=8, prd=True),))
    with pytest.raises(UnrecoverableFailure, match="PRD"):
        solve(solver, op, b, pre,
              SolveConfig(tol=1e-10, plan_campaign=planned),
              backend=backend, failures=campaign)


@pytest.mark.parametrize("persist_mode", ["sync", "overlap"])
def test_prd_only_event_is_survived_until_recovery_is_needed(persist_mode):
    """A PRD crash with no block failure loses no compute state: the
    solve converges (storage_failures counts the event).  But the loss
    is latent — the same run with a LATER block failure must raise."""
    op, b, pre = _problem()
    solver = make_solver("pcg", op, pre)
    backend = make_backend("nvm-prd", op, solver=solver)
    _, rep, _ = solve(
        solver, op, b, pre, SolveConfig(tol=1e-10, persist_mode=persist_mode),
        backend=backend,
        failures=FailureCampaign((FailureEvent(blocks=(), at_iteration=5,
                                               prd=True),)))
    assert rep.converged and rep.storage_failures == 1
    assert rep.failures_recovered == 0

    solver = make_solver("pcg", op, pre)
    backend = make_backend("nvm-prd", op, solver=solver)
    with pytest.raises(UnrecoverableFailure):
        solve(solver, op, b, pre,
              SolveConfig(tol=1e-10, persist_mode=persist_mode),
              backend=backend,
              failures=FailureCampaign((
                  FailureEvent(blocks=(), at_iteration=5, prd=True),
                  FailureEvent(blocks=(1,), at_iteration=8),
              )))


def test_replicated_all_mirrors_lost_raises():
    """Redundancy is not magic: when every mirror's PRD dies, the fetch
    refuses with a per-mirror diagnosis.  The campaign planner would
    reject this campaign before iteration 0 (see
    tests/test_erasure_planner.py); ``plan_campaign=False`` runs it
    unplanned to exercise the runtime quorum path itself."""
    op, b, pre = _problem()
    solver = make_solver("pcg", op, pre)
    backend = make_backend("replicated(nvm-prd x2)", op, solver=solver)
    campaign = FailureCampaign((
        FailureEvent(blocks=(), at_iteration=4, prd=True),   # mirror 0 dies
        FailureEvent(blocks=(1,), at_iteration=8, prd=True), # mirror 1 + block
    ))
    with pytest.raises(UnrecoverableFailure, match="no mirror"):
        solve(solver, op, b, pre,
              SolveConfig(tol=1e-10, plan_campaign=False),
              backend=backend, failures=campaign)


# ------------------------------------- the ROADMAP closure (acceptance)
_REF_CACHE = {}


def _reference(solver_name):
    """Fault-free captured states per solver (shared across cases)."""
    if solver_name not in _REF_CACHE:
        op, b, pre = _problem()
        fail_at, opts = SOLVER_CASES[solver_name]
        solver = make_solver(solver_name, op, pre, **opts)
        _, rep, cap = solve(solver, op, b, pre,
                            SolveConfig(tol=1e-10, maxiter=5000),
                            capture_states_at=[fail_at - 1, fail_at])
        assert rep.converged
        _REF_CACHE[solver_name] = cap
    return _REF_CACHE[solver_name]


@pytest.mark.parametrize("persist_mode", ["sync", "overlap"])
@pytest.mark.parametrize("child", NVM_CHILDREN)
@pytest.mark.parametrize("solver_name", sorted(SOLVERS))
def test_replicated_prd_kill_recovers_exactly(solver_name, child,
                                              persist_mode):
    """The acceptance criterion: a FailureCampaign event that crashes
    the PRD node itself — simultaneously with two compute blocks — is
    recovered to machine precision by ReplicatedBackend over two
    mirrors, for every zoo solver, over both NVM child backends, in
    both persist modes."""
    op, b, pre = _problem()
    fail_at, opts = SOLVER_CASES[solver_name]
    ref_cap = _reference(solver_name)

    solver = make_solver(solver_name, op, pre, **opts)
    backend = make_backend(f"replicated({child} x2)", op, solver=solver)
    campaign = FailureCampaign((
        FailureEvent(blocks=(1, 2), at_iteration=fail_at, prd=True),))
    state, rep, cap = solve(
        solver, op, b, pre,
        SolveConfig(tol=1e-10, maxiter=5000, persist_mode=persist_mode),
        backend=backend, failures=campaign,
        capture_states_at=[fail_at - 1, fail_at])

    assert rep.failures_recovered == 1
    assert rep.storage_failures == 1
    assert rep.converged
    # T=1 sync: the recovery point IS the failure iteration.  In overlap
    # mode the event tears the staged-but-uncommitted persist of the
    # failure iteration, so the durable point is one iteration back.
    assert rep.wasted_iterations == (1 if persist_mode == "overlap" else 0)
    k_rec = fail_at - rep.wasted_iterations
    _state_fields_close(cap[k_rec], ref_cap[k_rec])
    res = float(np.linalg.norm(np.asarray(b - op.apply(state.x)))
                / np.linalg.norm(np.asarray(b)))
    assert res < 1e-9


def test_mirror_dies_during_inflight_recovery():
    """An overlapping campaign: mirror 0's PRD dies while the recovery
    of an earlier block failure is in flight — the stale fetch is
    discarded and the refetch proceeds from the surviving mirror."""
    op, b, pre = _problem()
    solver = make_solver("pcg", op, pre)
    backend = make_backend("replicated(nvm-prd x2)", op, solver=solver)
    campaign = FailureCampaign((
        FailureEvent(blocks=(1, 2), at_iteration=8),
        FailureEvent(blocks=(), during_recovery_at=8, prd=True),
    ))
    state, rep, _ = solve(solver, op, b, pre,
                          SolveConfig(tol=1e-10, persist_mode="overlap"),
                          backend=backend, failures=campaign)
    assert rep.converged
    assert rep.recovery_restarts == 1
    assert rep.storage_failures == 1
    res = float(np.linalg.norm(np.asarray(b - op.apply(state.x)))
                / np.linalg.norm(np.asarray(b)))
    assert res < 1e-9


def test_replicated_mirroring_costs_sum_over_children():
    """Mirroring is visible in the accounting: the replicated persist
    cost is the sum of its children's (origin-NIC serialization), and
    its NVM footprint doubles."""
    op, b, pre = _problem()
    reps = {}
    for name in ("nvm-prd", "replicated(nvm-prd x2)"):
        solver = make_solver("pcg", op, pre)
        be = make_backend(name, op, solver=solver)
        _, rep, _ = solve(solver, op, b, pre, SolveConfig(tol=1e-10),
                          backend=be, failures=())
        reps[name] = (rep, be)
    single, repl = reps["nvm-prd"], reps["replicated(nvm-prd x2)"]
    assert single[0].persist_events == repl[0].persist_events
    np.testing.assert_allclose(repl[0].persist_cost_s,
                               2 * single[0].persist_cost_s, rtol=1e-9)
    assert repl[1].nvm_values() == 2 * single[1].nvm_values()


# ------------------------------------------------------------- registry
def test_backend_registry_lists_composites():
    names = backend_names()
    for expected in ("esr", "nvm-homogeneous", "nvm-prd", "replicated",
                     "tiered", "erasure"):
        assert expected in names


def test_parse_backend_spec():
    assert parse_backend_spec("nvm-prd") == ("nvm-prd", {})
    assert parse_backend_spec("replicated(nvm-prd x2)") == (
        "replicated", {"children": ("nvm-prd", "nvm-prd")})
    assert parse_backend_spec("replicated(nvm-prd×3)") == (
        "replicated", {"children": ("nvm-prd",) * 3})
    assert parse_backend_spec("replicated(nvm-prd, nvm-homogeneous)") == (
        "replicated", {"children": ("nvm-prd", "nvm-homogeneous")})
    assert parse_backend_spec("tiered(nvm-prd)") == (
        "tiered", {"child": "nvm-prd"})
    assert parse_backend_spec("erasure(nvm-prd x4+p)") == (
        "erasure", {"data": ("nvm-prd",) * 4, "nparity": 1})
    assert parse_backend_spec("erasure(nvm-prd x6+2p)") == (
        "erasure", {"data": ("nvm-prd",) * 6, "nparity": 2})
    assert parse_backend_spec("erasure(nvm-homogeneous ×2 + p)") == (
        "erasure", {"data": ("nvm-homogeneous",) * 2, "nparity": 1})
    with pytest.raises(ValueError, match="malformed"):
        parse_backend_spec("replicated(nvm-prd")
    with pytest.raises(ValueError, match="xK\\+Pp"):
        parse_backend_spec("erasure(nvm-prd x4)")
    with pytest.raises(ValueError, match="no spec arguments"):
        create_backend("esr(nvm-prd)", 4, 8)


def test_registry_did_you_mean():
    op, b, pre = _problem()
    with pytest.raises(KeyError, match="did you mean 'pcg'"):
        make_solver("pgc", op, pre)
    with pytest.raises(KeyError, match="did you mean 'nvm-prd'"):
        make_backend("nvm-prdd", op)
    with pytest.raises(KeyError, match="did you mean 'replicated'"):
        make_backend("replicate(nvm-prd x2)", op)
    # no close match: plain unknown-name error, with the inventory
    with pytest.raises(KeyError, match="unknown solver"):
        make_solver("zzz", op, pre)


def test_replicated_validation():
    op, _, _ = _problem()
    with pytest.raises(ValueError, match=">= 2 children"):
        make_backend("replicated", op, children=("nvm-prd",))
    pcg_child = create_backend("nvm-prd", op.nblocks,
                               op.partition.block_size, np.float64,
                               schema=PCG_SCHEMA)
    from repro.solvers.bicgstab import BICGSTAB_SCHEMA

    bicg_child = create_backend("nvm-prd", op.nblocks,
                                op.partition.block_size, np.float64,
                                schema=BICGSTAB_SCHEMA)
    with pytest.raises(ValueError, match="same schema"):
        ReplicatedBackend([pcg_child, bicg_child])


def test_session_schema_mismatch_rejected():
    """Opening a session for the wrong schema refuses up front — same
    guarantee as the old driver check, now at the protocol layer."""
    op, b, pre = _problem()
    pcg = make_solver("pcg", op, pre)
    backend = make_backend("replicated(nvm-prd x2)", op, solver=pcg)
    bicg = make_solver("bicgstab", op, pre)
    with pytest.raises(ValueError, match="schema"):
        solve(bicg, op, b, pre, SolveConfig(tol=1e-10), backend=backend)


# ---------------------------------------------------------------- tiered
def test_tiered_backend_stages_then_flushes_to_child():
    op, _, _ = _problem()
    child = make_backend("nvm-homogeneous", op)
    be = TieredBackend(child)
    session = be.open_session(PCG_SCHEMA)
    n = op.n

    c = session.begin(0, {"beta": 0.0}, {"p": np.zeros(n)})
    assert c > 0.0
    assert child.durable_run() is None          # still only in the RAM front
    session.commit()
    session.begin(1, {"beta": 0.5}, {"p": np.ones(n)})
    session.drain()
    assert child.durable_run() == 1             # flushed through the child
    sets = session.fetch((2,), (0, 1))
    assert [s.k for s in sets] == [0, 1]
    bs = op.partition.block_size
    np.testing.assert_array_equal(sets[-1].vectors["p"], np.ones(bs))
    assert session.durable_run() == 1


def test_tiered_rejects_uncalibrated_front_at_construction():
    from repro.nvm.store import Tier

    op, _, _ = _problem()
    child = make_backend("nvm-homogeneous", op)
    with pytest.raises(ValueError, match="DRAM front"):
        TieredBackend(child, front_tier=Tier.NVM)


def test_tiered_staged_event_dies_with_failure():
    op, _, _ = _problem()
    be = make_backend("tiered(nvm-homogeneous)", op)
    session = be.open_session(PCG_SCHEMA)
    n = op.n
    for k in range(2):
        session.persist(k, {"beta": 0.1 * k}, {"p": np.full(n, float(k))})
    session.begin(2, {"beta": 0.2}, {"p": np.full(n, 2.0)})
    session.fail((0,))                           # the RAM front is volatile
    sets = session.fetch((0,), (0, 1))
    assert [s.k for s in sets] == [0, 1]
    with pytest.raises(Exception, match="2"):
        session.fetch((0,), (1, 2))


# ------------------------------------------------------------ durable_run
@pytest.mark.parametrize("backend_name", ["esr", "nvm-homogeneous",
                                          "nvm-prd"])
def test_durable_run_tracks_complete_history_runs(backend_name):
    """durable_run answers the driver's rollback question from the
    backend's own slots: the newest complete history-run, gaps and all."""
    op, _, _ = _problem()
    be = make_backend(backend_name, op)          # PCG schema, history=2
    session = be.open_session(PCG_SCHEMA)
    n = op.n
    assert session.durable_run() is None
    session.persist(0, {"beta": 0.0}, {"p": np.zeros(n)})
    assert session.durable_run() is None         # half a pair
    session.persist(1, {"beta": 0.1}, {"p": np.ones(n)})
    assert session.durable_run() == 1
    # ESRP gap: iterations 5 alone does not form a run; 5,6 does
    session.persist(5, {"beta": 0.5}, {"p": np.full(n, 5.0)})
    assert session.durable_run() == 1
    session.persist(6, {"beta": 0.6}, {"p": np.full(n, 6.0)})
    assert session.durable_run() == 6


# ------------------------------------------------------------ repro.api
def test_api_facade_end_to_end():
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro import api

    result = api.solve(
        api.Problem.poisson(8, nblocks=4),
        api.SolverSpec("pcg"),
        api.ResilienceSpec("replicated(nvm-prd x2)", persist_mode="overlap"),
        failures=[api.FailureEvent(blocks=(1, 2), at_iteration=8, prd=True)],
    )
    assert result.converged
    assert result.report.failures_recovered == 1
    assert result.report.storage_failures == 1
    assert result.capabilities.survives_prd_loss
    assert result.x.shape == (8 * 8 * 8,)
    assert result.relres < 1e-9


def test_api_accepts_bare_names_and_unprotected_runs():
    from repro import api

    r = api.solve(api.Problem.poisson(8, nblocks=4), "jacobi")
    assert r.converged and r.backend is None and r.capabilities is None
    r2 = api.solve(api.Problem.poisson(8, nblocks=4), "bicgstab",
                   "tiered(nvm-homogeneous)")
    assert r2.converged and r2.backend.capabilities.overlap == "native"


def test_api_surface_is_importable():
    """Every name in repro.api.__all__ resolves (the check_api gate)."""
    from repro import api

    for name in api.__all__:
        assert getattr(api, name) is not None, name
