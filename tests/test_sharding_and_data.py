"""Sharding-rule degradation, data-pipeline determinism/resume, serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import AxisRules, DEFAULT_RULES, spec_for_shape
from repro.training.data import MemmapCorpus, SyntheticCorpus, write_token_file


class _FakeMesh:
    """Minimal mesh stand-in: axis_names + shape only (no devices)."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def _rules(shape):
    return AxisRules(_FakeMesh(shape), dict(DEFAULT_RULES))


def test_spec_divisible_axes_kept():
    r = _rules({"pod": 2, "data": 16, "model": 16})
    sp = spec_for_shape(r, (256, 4096, 32, 128), ("batch", None, "heads", None))
    assert sp == P(("pod", "data"), None, "model", None)


def test_spec_nondivisible_axis_dropped():
    r = _rules({"pod": 2, "data": 16, "model": 16})
    # kv_heads=8 does not divide model=16 -> replicated
    sp = spec_for_shape(r, (4096, 8, 128), ("fsdp", "kv_heads", None))
    assert sp == P("data", None, None)


def test_spec_tuple_prefix_kept():
    r = _rules({"pod": 2, "data": 16, "model": 16})
    # batch=2 divides pod=2 but not pod*data -> keep ("pod",) only
    sp = spec_for_shape(r, (2, 64), ("batch", None))
    assert sp == P("pod", None)
    # batch=1 shards nothing
    sp1 = spec_for_shape(r, (1, 64), ("batch", None))
    assert sp1 == P(None, None)


def test_single_pod_rules_drop_pod_axis():
    r = _rules({"data": 16, "model": 16})
    sp = spec_for_shape(r, (256, 4096), ("batch", None))
    assert sp == P("data", None)


# ---------------------------------------------------------------- data
def test_synthetic_corpus_deterministic_and_resumable():
    c = SyntheticCorpus(vocab=1000, batch=4, seq=16, seed=9)
    a = c.batch_at(5)
    b = c.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # targets are next-token shifted
    c2 = SyntheticCorpus(vocab=1000, batch=4, seq=16, seed=9)
    d = c2.batch_at(5)
    np.testing.assert_array_equal(a["targets"], d["targets"])
    assert not np.array_equal(a["tokens"], c.batch_at(6)["tokens"])


def test_synthetic_corpus_host_sharding_disjoint():
    full = SyntheticCorpus(vocab=100, batch=8, seq=8, seed=1)
    h0 = SyntheticCorpus(vocab=100, batch=8, seq=8, seed=1, host_index=0, host_count=2)
    h1 = SyntheticCorpus(vocab=100, batch=8, seq=8, seed=1, host_index=1, host_count=2)
    assert h0.batch_at(0)["tokens"].shape[0] == 4
    assert not np.array_equal(h0.batch_at(0)["tokens"], h1.batch_at(0)["tokens"])


def test_memmap_corpus_roundtrip(tmp_path):
    path = str(tmp_path / "toks.bin")
    rng = np.random.default_rng(0)
    write_token_file(path, rng.integers(0, 500, size=10_000))
    c = MemmapCorpus(path, vocab=500, batch=4, seq=32)
    b0 = c.batch_at(0)
    assert b0["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b0["tokens"][:, 1:], b0["targets"][:, :-1])
    # resumable: same step -> same batch after re-open
    c2 = MemmapCorpus(path, vocab=500, batch=4, seq=32)
    np.testing.assert_array_equal(c2.batch_at(0)["tokens"], b0["tokens"])


# ---------------------------------------------------------------- serving
def test_serve_engine_greedy_generate():
    from repro.models import registry as R
    from repro.serving.engine import ServeEngine

    cfg = R.get_config("llama3_8b", smoke=True)
    params, _ = R.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(
        prefill_fn=lambda p, t, c: R.make_prefill(cfg)(p, {"tokens": t}, c),
        decode_fn=R.make_decode(cfg),
        cache_init=lambda b, s: R.init_caches(cfg, b, s)[0],
    )
    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, cfg.vocab)
    out = eng.generate(params, prompt, steps=6)
    assert out.shape == (2, 6)
    assert not bool(jnp.isnan(out.astype(jnp.float32)).any())
    # greedy decode is deterministic
    out2 = eng.generate(params, prompt, steps=6)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
