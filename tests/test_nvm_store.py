"""NVM substrate semantics: durability, crash consistency, epoch discipline."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.nvm.pmdk import HEADER_SIZE, PmemPool
from repro.nvm.prd import PRDNode
from repro.nvm.store import Store, Tier, TIER_SPECS
from repro.nvm.windows import EpochError, Window


# ---------------------------------------------------------------- store
def test_store_flush_durability():
    s = Store(1024, Tier.NVM)
    s.write(0, b"hello")
    s.crash()  # unflushed -> lost
    assert s.read(0, 5)[0] == b"\x00" * 5
    s.write(0, b"hello")
    s.flush()
    s.crash()
    assert s.read(0, 5)[0] == b"hello"


def test_volatile_tier_loses_everything():
    s = Store(64, Tier.DRAM)
    s.write(0, b"x" * 64)
    s.flush()
    s.crash()
    assert s.read(0, 64)[0] == b"\x00" * 64


def test_cost_model_ordering():
    """Modeled write costs: DRAM < NVM < SSD (paper Fig. 9 ordering)."""
    payload = b"y" * (1 << 20)
    costs = {}
    for tier in (Tier.DRAM, Tier.NVM, Tier.SSD):
        s = Store(1 << 21, tier)
        costs[tier] = s.write(0, payload) + s.flush()
    assert costs[Tier.DRAM] < costs[Tier.NVM] < costs[Tier.SSD]


# ---------------------------------------------------------------- pmdk
def test_pool_persist_read_roundtrip():
    pool = PmemPool(Store(4096, Tier.NVM))
    pool.create("obj", 256)
    arr = np.arange(16, dtype=np.float64)
    pool.persist_array("obj", arr)
    got = pool.read_array("obj", np.float64, (16,))
    np.testing.assert_array_equal(got, arr)


def test_pool_double_buffer_keeps_previous_on_crash():
    pool = PmemPool(Store(4096, Tier.NVM))
    pool.create("obj", 64)
    pool.persist("obj", b"A" * 64)
    # write payload of v2 but crash BEFORE the header commit
    store = pool.store
    pool._seq["obj"] += 1  # simulate being mid-persist of seq 2
    off0, off1, cap = pool._slot_offsets("obj")
    target = off0 if pool._seq["obj"] % 2 == 0 else off1
    store.write(target + HEADER_SIZE, b"B" * 64)  # payload, no flush, no header
    store.crash()
    pool.recover()
    assert pool.read("obj") == b"A" * 64  # previous slot intact


@settings(max_examples=25, deadline=None)
@given(torn_at=st.integers(0, 80), frag=st.binary(min_size=1, max_size=40))
def test_pool_torn_write_never_corrupts(torn_at, frag):
    """Property: a torn write landing anywhere in the in-flight slot can
    never make read() return something other than a fully-committed
    payload."""
    pool = PmemPool(Store(4096, Tier.NVM))
    pool.create("obj", 64)
    pool.persist("obj", b"A" * 64)
    committed = {b"A" * 64}
    # begin v2, crash with a torn fragment somewhere in slot space
    off0, off1, cap = pool._slot_offsets("obj")
    next_slot = off0 if (pool._seq["obj"] + 1) % 2 == 0 else off1
    span = HEADER_SIZE + cap
    pool.store.crash(torn_write=(next_slot + (torn_at % span),
                                 frag[: span - (torn_at % span)]))
    pool.recover()
    got = pool.read("obj")
    assert got in committed


# ---------------------------------------------------------------- windows
def test_pscw_epoch_discipline():
    w = Window(Store(1024, Tier.NVM))
    with pytest.raises(EpochError):
        w.put(0, 0, b"x")  # RMA outside any epoch
    w.post([0, 1])
    w.start(0)
    w.put(0, 0, b"abc")
    with pytest.raises(EpochError):
        w.wait()  # origins not complete
    w.complete(0)
    with pytest.raises(EpochError):
        w.wait()  # origin 1 still missing
    w.start(1)
    w.complete(1)
    w.wait(persist=True)
    assert w.store.read(0, 3)[0] == b"abc"


def test_pscw_wait_persists_before_epoch_close():
    store = Store(1024, Tier.NVM)
    w = Window(store)
    w.post([0])
    w.start(0)
    w.put(0, 0, b"zzz")
    w.complete(0)
    # crash BEFORE wait: data must be gone (window dies with the node)
    store.crash()
    assert store.read(0, 3)[0] == b"\x00\x00\x00"
    # rebooted node, new window; with wait_persist the data survives
    w2 = Window(store)
    w2.post([0])
    w2.start(0)
    w2.put(0, 0, b"zzz")
    w2.complete(0)
    w2.wait(persist=True)
    store.crash()
    assert store.read(0, 3)[0] == b"zzz"


def test_passive_target_lock_unlock():
    w = Window(Store(256, Tier.NVM))
    w.lock(3)
    w.put(3, 0, b"q")
    with pytest.raises(EpochError):
        w.lock(4)
    w.unlock(3)
    w.lock(4)
    w.unlock(4)


# ---------------------------------------------------------------- PRD
def test_prd_pscw_roundtrip_and_async_drain():
    prd = PRDNode(nranks=4, capacity_per_rank=64, async_drain=True)
    costs = prd.persist_all([bytes([i]) * 32 for i in range(4)], seq=1)
    assert costs["origin"] > 0
    prd.join()
    for r in range(4):
        seq, payload = prd.read_latest(r)
        assert seq == 1 and payload == bytes([r]) * 32


def test_prd_survives_compute_failures_not_own_crash():
    prd = PRDNode(nranks=2, capacity_per_rank=32, async_drain=False)
    prd.persist_all([b"a" * 16, b"b" * 16], seq=1)
    # compute-node failures don't touch PRD data
    assert prd.read_latest(0)[1] == b"a" * 16
    # a PRD-node crash after persist retains flushed epochs
    prd.crash()
    assert prd.read_latest(1)[1] == b"b" * 16


def test_prd_crash_mid_epoch_loses_only_inflight():
    prd = PRDNode(nranks=1, capacity_per_rank=32, async_drain=False)
    prd.persist_all([b"v1" + b"." * 14], seq=1)
    # begin epoch 2 but crash before wait_persist
    prd.begin_epoch([0])
    prd.put_rank(0, b"v2" + b"." * 14, seq=2)
    prd.crash()
    got = prd.read_latest(0)
    assert got is not None and got[1].startswith(b"v1")
