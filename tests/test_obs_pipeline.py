"""Pipeline-level observability contracts (ISSUE 6, DESIGN.md §9).

Three things only an end-to-end solve can establish:

1. **Enabled coverage** — a traced campaign solve emits the span/event
   taxonomy (docs/observability.md §2) across every layer: driver loop,
   session compositions (mirror/stripe/tier), stager.
2. **The zero-overhead disabled contract** — with no tracer configured
   the driver executes *zero tracer callables* per iteration: a
   counting falsy tracer passed as ``config.tracer`` sees exactly one
   ``__bool__`` normalization and no ``span``/``event`` calls at all.
3. **The acceptance sweep** — every registered solver, run under a
   failure campaign with tracing on, produces a Chrome trace that
   parses as trace-event JSON and agrees with its own report
   (``check_trace_report``).
"""
import json

import pytest

from repro.core import JacobiPreconditioner, make_poisson_problem
from repro.obs import NullTracer, Tracer, check_trace_report
from repro.solvers import (
    SOLVERS,
    FailureCampaign,
    FailureEvent,
    SolveConfig,
    make_backend,
    make_solver,
    solve,
)

# (solver opts, failure iteration): gmres counts restart cycles
SOLVER_CASES = {
    "pcg": ({}, 6),
    "jacobi": ({}, 6),
    "chebyshev": ({}, 6),
    "bicgstab": ({}, 6),
    "gmres": ({"m": 4}, 3),
}
assert set(SOLVER_CASES) == set(SOLVERS)


def _problem(nblocks=4):
    op, b = make_poisson_problem(8, 8, 8, nblocks=nblocks)
    return op, b, JacobiPreconditioner(op)


def _traced_solve(spec, campaign=(), mode="overlap", solver_name="pcg",
                  opts=None, nblocks=4):
    op, b, pre = _problem(nblocks)
    solver = make_solver(solver_name, op, pre, **(opts or {}))
    backend = make_backend(spec, op, solver=solver)
    tracer = Tracer()
    state, report, _ = solve(
        solver, op, b, pre,
        SolveConfig(tol=1e-10, maxiter=5000, persist_mode=mode,
                    tracer=tracer),
        backend=backend, failures=campaign)
    return tracer, report


# ----------------------------------------------------------------------
# 1. Enabled coverage, layer by layer
# ----------------------------------------------------------------------
def test_traced_overlap_solve_emits_driver_and_stager_taxonomy():
    campaign = FailureCampaign((FailureEvent(blocks=(1,), at_iteration=6),))
    tracer, report = _traced_solve("nvm-prd", campaign)
    assert report.converged and report.failures_recovered == 1

    names = set(tracer.names())
    # driver loop
    assert {"solve.begin", "iteration.step", "persist.begin",
            "persist.commit", "failure.inject", "recovery.absorbed",
            "persist.drain", "recovery.fetch", "recovery.reconstruct",
            "recovery.rollback", "solve.end"} <= names
    # stager (the begin/commit cost split of DESIGN.md §6)
    assert {"stage.copy", "stage.flush"} <= names

    counts = tracer.counts()
    assert counts["solve.begin"] == 1 and counts["solve.end"] == 1
    assert counts["iteration.step"] >= report.iterations
    assert counts["persist.commit"] == report.persist_events
    assert counts["recovery.absorbed"] == 1
    # every iteration.step span carries its iteration label
    steps = [r for r in tracer.records if r["name"] == "iteration.step"]
    assert all(isinstance(r["args"]["k"], int) for r in steps)
    # the commit events carry the hidden/exposed attribution
    commit = next(r for r in tracer.records if r["name"] == "persist.commit")
    assert {"k", "cost_s", "hidden_s", "exposed_s"} <= set(commit["args"])


def test_traced_replicated_session_emits_mirror_events():
    campaign = FailureCampaign((
        FailureEvent(blocks=(), at_iteration=4, prd=True),
        FailureEvent(blocks=(1,), at_iteration=7),
    ))
    tracer, report = _traced_solve("replicated(nvm-prd x2)", campaign)
    assert report.converged and report.storage_failures == 1

    counts = tracer.counts()
    # both mirrors commit per persistence event until one dies
    assert counts["mirror.commit"] > report.persist_events
    fetches = [r for r in tracer.records if r["name"] == "mirror.fetch"]
    assert fetches, "the recovery fetch must name its serving mirror"
    assert all({"mirror", "served"} <= set(r["args"]) for r in fetches)
    assert counts["storage.kill"] == 1
    check_trace_report(tracer, report)


def test_traced_erasure_session_emits_stripe_taxonomy():
    campaign = FailureCampaign((
        FailureEvent(blocks=(), at_iteration=4, prd=True),
        FailureEvent(blocks=(1, 2), at_iteration=7),
    ))
    tracer, report = _traced_solve("erasure(nvm-prd x4+p)", campaign)
    assert report.converged and report.failures_recovered == 1

    names = set(tracer.names())
    assert {"gf256.rs_encode", "stripe.write", "stripe.degraded",
            "gf256.rs_decode"} <= names
    # one stripe.write per child per committed stripe: shards labeled
    writes = [r for r in tracer.records if r["name"] == "stripe.write"]
    assert all({"child", "shard", "parity", "rot"} <= set(r["args"])
               for r in writes)
    assert any(r["args"]["parity"] for r in writes), "parity shards traced"
    degraded = next(r for r in tracer.records
                    if r["name"] == "stripe.degraded")
    assert degraded["args"]["missing"] and degraded["args"]["nparity"] == 1
    check_trace_report(tracer, report)


def test_traced_tiered_session_reaches_the_inner_stager():
    campaign = FailureCampaign((FailureEvent(blocks=(2,), at_iteration=5),))
    tracer, report = _traced_solve("tiered(nvm-homogeneous)", campaign)
    assert report.converged
    names = set(tracer.names())
    assert {"stage.copy", "stage.flush", "persist.commit",
            "recovery.fetch"} <= names
    check_trace_report(tracer, report)


def test_sync_mode_is_traced_too():
    tracer, report = _traced_solve("nvm-prd", mode="sync")
    names = set(tracer.names())
    assert {"solve.begin", "iteration.step", "persist.commit",
            "solve.end"} <= names
    # the sync write-through path is the session's persist() call
    assert "backend.write" in names
    # sync bypasses staging: no overlap begin/flush split
    assert "persist.begin" not in names
    check_trace_report(tracer, report)


# ----------------------------------------------------------------------
# 2. The zero-overhead disabled contract
# ----------------------------------------------------------------------
class _CountingNullTracer(NullTracer):
    """Falsy (disabled) tracer that records every callable invocation —
    the probe for the zero-callable guarantee."""

    def __init__(self):
        self.bool_calls = 0
        self.span_calls = 0
        self.event_calls = 0

    def __bool__(self):
        self.bool_calls += 1
        return False

    def span(self, name, **labels):
        self.span_calls += 1
        return super().span(name, **labels)

    def event(self, name, **labels):
        self.event_calls += 1
        return None


def test_disabled_tracer_sees_zero_callables():
    op, b, pre = _problem()
    solver = make_solver("pcg", op, pre)
    backend = make_backend("replicated(nvm-prd x2)", op, solver=solver)
    probe = _CountingNullTracer()
    _, report, _ = solve(
        solver, op, b, pre,
        SolveConfig(tol=1e-10, maxiter=5000, persist_mode="overlap",
                    tracer=probe),
        backend=backend,
        failures=[FailureEvent(blocks=(1,), at_iteration=6)])
    assert report.converged and report.iterations > 10
    # one truthiness normalization (`config.tracer or None`), then the
    # identity guards keep every span/event call off the hot path
    assert probe.span_calls == 0
    assert probe.event_calls == 0
    assert probe.bool_calls == 1


def test_disabled_and_absent_tracer_produce_identical_reports():
    def run(tracer):
        op, b, pre = _problem()
        solver = make_solver("pcg", op, pre)
        backend = make_backend("nvm-prd", op, solver=solver)
        _, report, _ = solve(
            solver, op, b, pre,
            SolveConfig(tol=1e-10, maxiter=5000, persist_mode="overlap",
                        tracer=tracer),
            backend=backend,
            failures=[FailureEvent(blocks=(1,), at_iteration=6)])
        return report

    none_rep = run(None)
    null_rep = run(NullTracer())
    traced_rep = run(Tracer())
    for field in ("iterations", "converged", "persist_events",
                  "persist_aborts", "failures_recovered",
                  "wasted_iterations", "final_relres"):
        assert getattr(null_rep, field) == getattr(none_rep, field), field
        assert getattr(traced_rep, field) == getattr(none_rep, field), field


# ----------------------------------------------------------------------
# 3. The acceptance sweep: every solver, traced, Perfetto-loadable
# ----------------------------------------------------------------------
@pytest.mark.parametrize("solver_name", sorted(SOLVERS))
def test_solver_sweep_produces_valid_chrome_trace(solver_name, tmp_path):
    opts, fail_at = SOLVER_CASES[solver_name]
    campaign = FailureCampaign((
        FailureEvent(blocks=(1,), at_iteration=fail_at),))
    tracer, report = _traced_solve("replicated(nvm-prd x2)", campaign,
                                   solver_name=solver_name, opts=opts)
    assert report.converged and report.failures_recovered == 1
    check_trace_report(tracer, report)

    path = tmp_path / f"trace_{solver_name}.json"
    n = tracer.to_chrome(path)
    doc = json.loads(path.read_text())  # strict JSON: Perfetto-loadable
    events = doc["traceEvents"]
    assert len(events) == n > 0
    assert {e["ph"] for e in events} <= {"X", "i"}
    assert all(e["ts"] >= 0 for e in events)
    spans = [e for e in events if e["ph"] == "X"]
    assert spans and all(e["dur"] >= 0 for e in spans)
    assert {"solve.begin", "iteration.step", "recovery.fetch",
            "solve.end"} <= {e["name"] for e in events}
