"""The solver zoo acceptance suite.

Every registered solver must (1) converge on the 3-D Poisson problem and
(2) after an injected multi-block failure at mid-solve, recover through
BOTH NVM-ESR backends with a post-recovery state matching the
failure-free run to solver precision — the paper's exactness claim,
generalized beyond PCG.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    JacobiPreconditioner,
    NVMESRHomogeneous,
    make_poisson_problem,
)
from repro.solvers import (
    SOLVERS,
    FailurePlan,
    SolveConfig,
    make_backend,
    make_solver,
    solve,
    spectral_bounds,
)

NVM_BACKENDS = ("nvm-homogeneous", "nvm-prd")

# (fail_at, solver opts): gmres counts restart cycles, not iterations
SOLVER_CASES = {
    "pcg": (10, {}),
    "jacobi": (10, {}),
    "chebyshev": (10, {}),
    "bicgstab": (10, {}),
    "gmres": (3, {"m": 4}),
}
assert set(SOLVER_CASES) == set(SOLVERS)


def _problem(nblocks=4):
    op, b = make_poisson_problem(8, 8, 8, nblocks=nblocks)
    return op, b, JacobiPreconditioner(op)


def _state_fields_close(got, want, rtol=1e-9, atol=1e-9):
    for field in got._fields:
        a, c = getattr(got, field), getattr(want, field)
        if hasattr(a, "shape"):
            np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                       rtol=rtol, atol=atol, err_msg=field)


@pytest.mark.parametrize("solver_name", sorted(SOLVERS))
def test_solver_converges_poisson(solver_name):
    op, b, pre = _problem()
    fail_at, opts = SOLVER_CASES[solver_name]
    solver = make_solver(solver_name, op, pre, **opts)
    state, report, _ = solve(solver, op, b, pre,
                             SolveConfig(tol=1e-10, maxiter=5000))
    assert report.converged, report
    res = float(jnp.linalg.norm(b - op.apply(state.x)) / jnp.linalg.norm(b))
    assert res < 1e-9


@pytest.mark.parametrize("backend_name", NVM_BACKENDS)
@pytest.mark.parametrize("solver_name", sorted(SOLVERS))
def test_multi_block_failure_recovers_exactly(solver_name, backend_name):
    """The acceptance criterion: mid-solve multi-block failure, recovery
    through both NVM architectures, post-recovery state element-wise equal
    to the fault-free run at the same iteration."""
    op, b, pre = _problem()
    fail_at, opts = SOLVER_CASES[solver_name]
    cfg = SolveConfig(tol=1e-10, maxiter=5000)

    ref_solver = make_solver(solver_name, op, pre, **opts)
    _, ref_report, ref_cap = solve(ref_solver, op, b, pre, cfg,
                                   capture_states_at=[fail_at])

    solver = make_solver(solver_name, op, pre, **opts)
    backend = make_backend(backend_name, op, solver=solver)
    state, report, cap = solve(
        solver, op, b, pre, cfg, backend=backend,
        failures=[FailurePlan(fail_at, (1, 2))],
        capture_states_at=[fail_at])

    assert report.failures_recovered == 1
    assert report.converged
    # T=1: the recovery point IS the failure iteration -> exact match
    assert report.wasted_iterations == 0
    _state_fields_close(cap[fail_at], ref_cap[fail_at])
    res = float(jnp.linalg.norm(b - op.apply(state.x)) / jnp.linalg.norm(b))
    assert res < 1e-9


@pytest.mark.parametrize("solver_name", ["jacobi", "bicgstab", "gmres"])
def test_history1_periodic_persistence(solver_name):
    """History-1 solvers under ESRP: persistence every T iterations only,
    failure rolls back to the last persisted iteration (<T wasted)."""
    op, b, pre = _problem()
    _, opts = SOLVER_CASES[solver_name]
    solver = make_solver(solver_name, op, pre, **opts)
    backend = make_backend("nvm-prd", op, solver=solver)
    fail_at = 5 if solver_name == "gmres" else 10
    state, report, _ = solve(
        solver, op, b, pre,
        SolveConfig(tol=1e-10, maxiter=5000, persistence_period=4),
        backend=backend, failures=[FailurePlan(fail_at, (0, 3))])
    assert report.failures_recovered == 1
    assert report.converged
    assert 0 < report.wasted_iterations < 4   # rolled back inside one period
    assert report.persist_events < report.iterations


def test_all_blocks_but_one_fail_nvm():
    """NVM-ESR's defining property holds zoo-wide: any number of
    simultaneous compute failures recovers from one persisted copy."""
    op, b, pre = _problem(nblocks=8)
    solver = make_solver("bicgstab", op, pre)
    backend = make_backend("nvm-prd", op, solver=solver)
    state, report, _ = solve(solver, op, b, pre, SolveConfig(tol=1e-10),
                             backend=backend,
                             failures=[FailurePlan(6, tuple(range(7)))])
    assert report.failures_recovered == 1
    assert report.converged


def test_repeated_failures_across_solvers():
    op, b, pre = _problem(nblocks=8)
    for name in ("chebyshev", "bicgstab"):
        solver = make_solver(name, op, pre)
        backend = make_backend("nvm-homogeneous", op, solver=solver)
        state, report, _ = solve(
            solver, op, b, pre, SolveConfig(tol=1e-10, maxiter=5000),
            backend=backend,
            failures=[FailurePlan(5, (0,)), FailurePlan(9, (2, 3))])
        assert report.failures_recovered == 2, name
        assert report.converged, name


def test_schema_mismatch_rejected():
    """A backend sized for one solver's payload cannot silently persist
    another's: the driver refuses up front."""
    op, b, pre = _problem()
    pcg = make_solver("pcg", op, pre)
    backend = make_backend("nvm-prd", op, solver=pcg)
    bicg = make_solver("bicgstab", op, pre)
    with pytest.raises(ValueError, match="schema"):
        solve(bicg, op, b, pre, SolveConfig(tol=1e-10), backend=backend)


def test_multi_vector_slots_sized_by_schema():
    """BiCGStab persists two vectors + three scalars per slot; the NVM
    footprint follows the schema, not a hard-coded PCG layout."""
    op, b, pre = _problem()
    bicg = make_solver("bicgstab", op, pre)
    be = make_backend("nvm-prd", op, solver=bicg)
    # history=1 -> 2-slot ring; 2 vectors per slot
    assert be.nvm_values() == 2 * 2 * op.n
    pcg_be = make_backend("nvm-prd", op, solver=make_solver("pcg", op, pre))
    assert pcg_be.nvm_values() == 4 * op.n  # the paper's 4-slot pair ring


def test_failure_at_iteration_zero_rejected():
    """A plan that could never fire would silently disarm every later
    plan (injection matches the sorted list head) — the driver refuses."""
    op, b, pre = _problem()
    solver = make_solver("pcg", op, pre)
    backend = make_backend("nvm-prd", op, solver=solver)
    with pytest.raises(ValueError, match="at_iteration"):
        solve(solver, op, b, pre, SolveConfig(tol=1e-10), backend=backend,
              failures=[FailurePlan(0, (1,)), FailurePlan(5, (2,))])


def test_registry_errors():
    op, b, pre = _problem()
    with pytest.raises(KeyError, match="unknown solver"):
        make_solver("sor", op, pre)
    with pytest.raises(KeyError, match="unknown backend"):
        make_backend("tape", op)


def test_spectral_bounds_routes():
    """Closed form (stencil) and dense (generic) bound estimates agree."""
    op, b, pre = _problem()
    lo_cf, hi_cf = spectral_bounds(op, pre)

    class _NotAStencil:
        def __init__(self, op):
            self._op = op
            self.n, self.dtype, self.partition = op.n, op.dtype, op.partition

        def apply(self, v):
            return self._op.apply(v)

    lo_d, hi_d = spectral_bounds(_NotAStencil(op), pre)
    np.testing.assert_allclose([lo_cf, hi_cf], [lo_d, hi_d], rtol=1e-8)


def test_legacy_duck_typed_backend_still_drives_pcg_solve():
    """External backends written against the pre-zoo contract (persist /
    recover / fail only, PCG payloads) keep working through the generic
    driver, and are cleanly rejected for non-PCG schemas."""
    from repro.core.state import RecoveryPayload

    class OldStyleBackend:
        def __init__(self, nblocks, block_size):
            self.nblocks, self.block_size = nblocks, block_size
            self.slots = {}

        def persist(self, k, beta, p_full):
            self.slots[k] = (beta, np.asarray(p_full).copy())
            return 0.0

        def fail(self, blocks):
            pass

        def recover(self, blocks, k):
            def payload(kk, beta):
                shards = [self.slots[kk][1][b * self.block_size:(b + 1) * self.block_size]
                          for b in blocks]
                return RecoveryPayload(kk, beta, np.concatenate(shards))
            return payload(k - 1, 0.0), payload(k, self.slots[k][0])

    op, b, pre = _problem()
    be = OldStyleBackend(op.nblocks, op.partition.block_size)
    solver = make_solver("pcg", op, pre)
    state, report, _ = solve(solver, op, b, pre, SolveConfig(tol=1e-10),
                             backend=be, failures=[FailurePlan(10, (1, 2))])
    assert report.failures_recovered == 1 and report.converged

    with pytest.raises(ValueError, match="legacy"):
        solve(make_solver("bicgstab", op, pre), op, b, pre,
              SolveConfig(tol=1e-10), backend=OldStyleBackend(
                  op.nblocks, op.partition.block_size))


def test_legacy_backend_api_still_serves_pcg():
    """The pre-zoo persist/recover entry points (used by the Fig. 9/10
    benchmarks) stay wire-compatible with the schema-driven path."""
    op, b, pre = _problem()
    be = NVMESRHomogeneous(op.nblocks, op.partition.block_size, np.float64)
    p0 = np.arange(op.n, dtype=np.float64)
    p1 = p0 + 1.0
    be.persist(0, 0.0, p0)
    be.persist(1, 0.25, p1)
    prev, cur = be.recover([1, 2], 1)
    assert prev.k == 0 and cur.k == 1 and cur.beta == 0.25
    bs = op.partition.block_size
    np.testing.assert_array_equal(prev.p, p0[bs:3 * bs])
    np.testing.assert_array_equal(cur.p, p1[bs:3 * bs])
