#!/usr/bin/env python3
"""API-surface gate (ISSUE 3 satellite: docs/CI tooling).

Two invariants the docs CI job enforces on every push:

1. **Façade integrity** — ``repro.api`` imports cleanly and every name
   in its ``__all__`` resolves (a broken re-export is a broken
   quickstart).
2. **Capability completeness** — every backend in the single registry
   (``repro.nvm.backend``) constructs through its factory and declares
   a fully populated :class:`BackendCapabilities` record with sane
   field types — including the storage-failure budget
   (``max_storage_failures``) the campaign planner consumes, which
   must cohere with ``survives_prd_loss``.  A backend that cannot
   state its guarantees cannot be composed safely (or planned against).
3. **Planner surface** — ``plan_campaign`` / ``UnsurvivableCampaignError``
   / ``CampaignPlan`` and ``ErasureCodedBackend`` resolve from their
   public homes, and a smoke plan confirms the planner rejects a
   two-loss campaign on a distance-2 stripe while accepting it on a
   triple mirror.

Usage: ``PYTHONPATH=src python tools/check_api.py``
Exit status is non-zero when anything is broken.  Requires jax+numpy
(the package imports them); the CI docs job installs both.
"""
from __future__ import annotations

import sys
import traceback


def check_api_surface() -> list:
    errors = []
    try:
        from repro import api
    except Exception:
        return [f"repro.api failed to import:\n{traceback.format_exc()}"]
    if not getattr(api, "__all__", None):
        return ["repro.api has no __all__"]
    for name in api.__all__:
        if getattr(api, name, None) is None:
            errors.append(f"repro.api.__all__ lists {name!r} but it does "
                          f"not resolve")
    print(f"repro.api: {len(api.__all__)} public names resolve")
    return errors


def check_backend_capabilities() -> list:
    import numpy as np

    from repro.core.state import PCG_SCHEMA
    from repro.nvm.backend import (
        BackendCapabilities,
        PersistenceBackend,
        backend_names,
        create_backend,
    )

    errors = []
    for name in backend_names():
        try:
            be = create_backend(name, nblocks=4, block_size=8,
                                dtype=np.float64, schema=PCG_SCHEMA)
        except Exception as e:  # noqa: BLE001
            errors.append(f"backend {name!r}: factory failed: {e!r}")
            continue
        if not isinstance(be, PersistenceBackend):
            errors.append(f"backend {name!r}: factory returned "
                          f"{type(be).__name__}, not a PersistenceBackend")
            continue
        try:
            caps = be.capabilities
        except Exception as e:  # noqa: BLE001
            errors.append(f"backend {name!r}: capabilities raised {e!r}")
            continue
        if not isinstance(caps, BackendCapabilities):
            errors.append(f"backend {name!r}: capabilities is "
                          f"{type(caps).__name__}")
            continue
        problems = []
        if not (caps.durability and isinstance(caps.durability, str)):
            problems.append("durability must be a non-empty str")
        if not isinstance(caps.survives_node_loss, bool):
            problems.append("survives_node_loss must be a bool")
        if not isinstance(caps.survives_prd_loss, bool):
            problems.append("survives_prd_loss must be a bool")
        if caps.overlap not in ("native", "driver-staged"):
            problems.append(f"overlap {caps.overlap!r} invalid")
        if caps.max_block_failures is not None and not (
                isinstance(caps.max_block_failures, int)
                and caps.max_block_failures >= 1):
            problems.append("max_block_failures must be None or int >= 1")
        if not (isinstance(caps.max_storage_failures, int)
                and caps.max_storage_failures >= 0):
            problems.append("max_storage_failures must be an int >= 0")
        elif caps.survives_prd_loss != (caps.max_storage_failures > 0):
            problems.append(
                f"survives_prd_loss={caps.survives_prd_loss} incoherent "
                f"with max_storage_failures={caps.max_storage_failures}")
        if problems:
            errors.append(f"backend {name!r}: incomplete capabilities: "
                          + "; ".join(problems))
        else:
            print(f"backend {name!r}: {caps}")
    return errors


def check_planner_surface() -> list:
    """The ISSUE 4 exports resolve, and the planner's decision table
    holds on its canonical pair: two PRD losses feeding a recovery are
    rejected on a distance-2 stripe, accepted on a triple mirror."""
    errors = []
    try:
        from repro.nvm.backend import ErasureCodedBackend  # noqa: F401
        from repro.nvm import ErasureCodedBackend as _nvm_export  # noqa: F401
        from repro.solvers import (
            CampaignPlan,
            FailureCampaign,
            FailureEvent,
            UnsurvivableCampaignError,
            plan_campaign,
        )
    except Exception:
        return [f"planner/erasure exports missing:\n{traceback.format_exc()}"]

    from repro.nvm.backend import BackendCapabilities

    campaign = FailureCampaign((
        FailureEvent(blocks=(1,), at_iteration=4, prd=True),
        FailureEvent(blocks=(2,), at_iteration=8, prd=True),
    ))
    stripe = BackendCapabilities("nvm", True, True, overlap="native",
                                 max_storage_failures=1)
    mirror3 = BackendCapabilities("nvm", True, True, overlap="native",
                                  max_storage_failures=2)
    try:
        plan_campaign(campaign, stripe)
        errors.append("plan_campaign accepted a 2-loss campaign on a "
                      "distance-2 stripe")
    except UnsurvivableCampaignError as e:
        if "at_iteration=8" not in str(e):
            errors.append(f"planner rejection does not name the violating "
                          f"event: {e}")
    try:
        plan = plan_campaign(campaign, mirror3)
        if not isinstance(plan, CampaignPlan) or plan.storage_losses != 2:
            errors.append(f"unexpected plan on the triple mirror: {plan}")
    except Exception as e:  # noqa: BLE001
        errors.append(f"plan_campaign rejected a survivable campaign: {e!r}")
    if not errors:
        print("planner surface: plan_campaign decision pair holds")
    return errors


def main() -> int:
    errors = (check_api_surface() + check_backend_capabilities()
              + check_planner_surface())
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
