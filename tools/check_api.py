#!/usr/bin/env python3
"""API-surface gate (ISSUE 3 satellite: docs/CI tooling).

Two invariants the docs CI job enforces on every push:

1. **Façade integrity** — ``repro.api`` imports cleanly and every name
   in its ``__all__`` resolves (a broken re-export is a broken
   quickstart).
2. **Capability completeness** — every backend in the single registry
   (``repro.nvm.backend``) constructs through its factory and declares
   a fully populated :class:`BackendCapabilities` record with sane
   field types.  A backend that cannot state its guarantees cannot be
   composed safely.

Usage: ``PYTHONPATH=src python tools/check_api.py``
Exit status is non-zero when anything is broken.  Requires jax+numpy
(the package imports them); the CI docs job installs both.
"""
from __future__ import annotations

import sys
import traceback


def check_api_surface() -> list:
    errors = []
    try:
        from repro import api
    except Exception:
        return [f"repro.api failed to import:\n{traceback.format_exc()}"]
    if not getattr(api, "__all__", None):
        return ["repro.api has no __all__"]
    for name in api.__all__:
        if getattr(api, name, None) is None:
            errors.append(f"repro.api.__all__ lists {name!r} but it does "
                          f"not resolve")
    print(f"repro.api: {len(api.__all__)} public names resolve")
    return errors


def check_backend_capabilities() -> list:
    import numpy as np

    from repro.core.state import PCG_SCHEMA
    from repro.nvm.backend import (
        BackendCapabilities,
        PersistenceBackend,
        backend_names,
        create_backend,
    )

    errors = []
    for name in backend_names():
        try:
            be = create_backend(name, nblocks=4, block_size=8,
                                dtype=np.float64, schema=PCG_SCHEMA)
        except Exception as e:  # noqa: BLE001
            errors.append(f"backend {name!r}: factory failed: {e!r}")
            continue
        if not isinstance(be, PersistenceBackend):
            errors.append(f"backend {name!r}: factory returned "
                          f"{type(be).__name__}, not a PersistenceBackend")
            continue
        try:
            caps = be.capabilities
        except Exception as e:  # noqa: BLE001
            errors.append(f"backend {name!r}: capabilities raised {e!r}")
            continue
        if not isinstance(caps, BackendCapabilities):
            errors.append(f"backend {name!r}: capabilities is "
                          f"{type(caps).__name__}")
            continue
        problems = []
        if not (caps.durability and isinstance(caps.durability, str)):
            problems.append("durability must be a non-empty str")
        if not isinstance(caps.survives_node_loss, bool):
            problems.append("survives_node_loss must be a bool")
        if not isinstance(caps.survives_prd_loss, bool):
            problems.append("survives_prd_loss must be a bool")
        if caps.overlap not in ("native", "driver-staged"):
            problems.append(f"overlap {caps.overlap!r} invalid")
        if caps.max_block_failures is not None and not (
                isinstance(caps.max_block_failures, int)
                and caps.max_block_failures >= 1):
            problems.append("max_block_failures must be None or int >= 1")
        if problems:
            errors.append(f"backend {name!r}: incomplete capabilities: "
                          + "; ".join(problems))
        else:
            print(f"backend {name!r}: {caps}")
    return errors


def main() -> int:
    errors = check_api_surface() + check_backend_capabilities()
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
