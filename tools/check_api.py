#!/usr/bin/env python3
"""API-surface gate (ISSUE 3 satellite: docs/CI tooling).

Two invariants the docs CI job enforces on every push:

1. **Façade integrity** — ``repro.api`` imports cleanly and every name
   in its ``__all__`` resolves (a broken re-export is a broken
   quickstart).
2. **Capability completeness** — every backend in the single registry
   (``repro.nvm.backend``) constructs through its factory and declares
   a fully populated :class:`BackendCapabilities` record with sane
   field types — including the storage-failure budget
   (``max_storage_failures``) the campaign planner consumes, which
   must cohere with ``survives_prd_loss``.  A backend that cannot
   state its guarantees cannot be composed safely (or planned against).
3. **Planner surface** — ``plan_campaign`` / ``UnsurvivableCampaignError``
   / ``CampaignPlan`` and ``ErasureCodedBackend`` resolve from their
   public homes, and a smoke plan confirms the planner rejects a
   two-loss campaign on a distance-2 stripe while accepting it on a
   triple mirror.
4. **Erasure parity coherence** (ISSUE 5) — for every supported parity
   arity, ``erasure(... xK+Pp)`` must declare
   ``max_storage_failures == P`` (and a parity arity the GF(256) P/Q
   construction cannot honor must be refused at composition time).
5. **Advisor surface** — ``advise_spec`` / ``SpecAdvice`` resolve from
   ``repro.solvers`` and ``repro.api``, and a smoke advise confirms
   the double-loss campaign picks the K+2p stripe over the triple
   mirror on footprint grounds.
6. **Shard-axis coherence** (ISSUE 7) — every backend's
   ``max_shard_failures(blocks_per_shard)`` is a coherent view of its
   block budget, and the façade's shard fields (``Problem.nshards``,
   ``ResilienceSpec.nshards``) are enforced.
7. **Lint surface** (ISSUE 8) — ``tools/repro_lint`` imports cleanly
   (stdlib-only, so this runs even before jax is installed), exports
   its rule registry with all five families present and every rule
   carrying a title and a fix hint, and a smoke ``lint_source`` call
   actually fires.

Usage: ``PYTHONPATH=src python tools/check_api.py``
Exit status is non-zero when anything is broken.  Requires jax+numpy
(the package imports them); the CI docs job installs both.
"""
from __future__ import annotations

import sys
import traceback


def check_api_surface() -> list:
    errors = []
    try:
        from repro import api
    except Exception:
        return [f"repro.api failed to import:\n{traceback.format_exc()}"]
    if not getattr(api, "__all__", None):
        return ["repro.api has no __all__"]
    for name in api.__all__:
        if getattr(api, name, None) is None:
            errors.append(f"repro.api.__all__ lists {name!r} but it does "
                          f"not resolve")
    print(f"repro.api: {len(api.__all__)} public names resolve")
    return errors


def check_backend_capabilities() -> list:
    import numpy as np

    from repro.core.state import PCG_SCHEMA
    from repro.nvm.backend import (
        BackendCapabilities,
        PersistenceBackend,
        backend_names,
        create_backend,
    )

    errors = []
    for name in backend_names():
        try:
            be = create_backend(name, nblocks=4, block_size=8,
                                dtype=np.float64, schema=PCG_SCHEMA)
        except Exception as e:  # noqa: BLE001
            errors.append(f"backend {name!r}: factory failed: {e!r}")
            continue
        if not isinstance(be, PersistenceBackend):
            errors.append(f"backend {name!r}: factory returned "
                          f"{type(be).__name__}, not a PersistenceBackend")
            continue
        try:
            caps = be.capabilities
        except Exception as e:  # noqa: BLE001
            errors.append(f"backend {name!r}: capabilities raised {e!r}")
            continue
        if not isinstance(caps, BackendCapabilities):
            errors.append(f"backend {name!r}: capabilities is "
                          f"{type(caps).__name__}")
            continue
        problems = []
        if not (caps.durability and isinstance(caps.durability, str)):
            problems.append("durability must be a non-empty str")
        if not isinstance(caps.survives_node_loss, bool):
            problems.append("survives_node_loss must be a bool")
        if not isinstance(caps.survives_prd_loss, bool):
            problems.append("survives_prd_loss must be a bool")
        if caps.overlap not in ("native", "driver-staged"):
            problems.append(f"overlap {caps.overlap!r} invalid")
        if caps.max_block_failures is not None and not (
                isinstance(caps.max_block_failures, int)
                and caps.max_block_failures >= 1):
            problems.append("max_block_failures must be None or int >= 1")
        if not (isinstance(caps.max_storage_failures, int)
                and caps.max_storage_failures >= 0):
            problems.append("max_storage_failures must be an int >= 0")
        elif caps.survives_prd_loss != (caps.max_storage_failures > 0):
            problems.append(
                f"survives_prd_loss={caps.survives_prd_loss} incoherent "
                f"with max_storage_failures={caps.max_storage_failures}")
        if problems:
            errors.append(f"backend {name!r}: incomplete capabilities: "
                          + "; ".join(problems))
        else:
            print(f"backend {name!r}: {caps}")
    return errors


def check_planner_surface() -> list:
    """The ISSUE 4 exports resolve, and the planner's decision table
    holds on its canonical pair: two PRD losses feeding a recovery are
    rejected on a distance-2 stripe, accepted on a triple mirror."""
    errors = []
    try:
        from repro.nvm.backend import ErasureCodedBackend  # noqa: F401
        from repro.nvm import ErasureCodedBackend as _nvm_export  # noqa: F401
        from repro.solvers import (
            CampaignPlan,
            FailureCampaign,
            FailureEvent,
            UnsurvivableCampaignError,
            plan_campaign,
        )
    except Exception:
        return [f"planner/erasure exports missing:\n{traceback.format_exc()}"]

    from repro.nvm.backend import BackendCapabilities

    campaign = FailureCampaign((
        FailureEvent(blocks=(1,), at_iteration=4, prd=True),
        FailureEvent(blocks=(2,), at_iteration=8, prd=True),
    ))
    stripe = BackendCapabilities("nvm", True, True, overlap="native",
                                 max_storage_failures=1)
    mirror3 = BackendCapabilities("nvm", True, True, overlap="native",
                                  max_storage_failures=2)
    try:
        plan_campaign(campaign, stripe)
        errors.append("plan_campaign accepted a 2-loss campaign on a "
                      "distance-2 stripe")
    except UnsurvivableCampaignError as e:
        if "at_iteration=8" not in str(e):
            errors.append(f"planner rejection does not name the violating "
                          f"event: {e}")
    try:
        plan = plan_campaign(campaign, mirror3)
        if not isinstance(plan, CampaignPlan) or plan.storage_losses != 2:
            errors.append(f"unexpected plan on the triple mirror: {plan}")
    except Exception as e:  # noqa: BLE001
        errors.append(f"plan_campaign rejected a survivable campaign: {e!r}")
    if not errors:
        print("planner surface: plan_campaign decision pair holds")
    return errors


def check_erasure_parity_coherence() -> list:
    """The ISSUE 5 capability rule: an erasure spec's declared storage
    budget must equal its parity arity (``max_storage_failures == P``),
    for every supported P — and unsupported arities must be refused."""
    import numpy as np

    from repro.core.state import PCG_SCHEMA
    from repro.nvm.backend import create_backend

    errors = []
    for spec, p in (("erasure(nvm-prd x4+p)", 1),
                    ("erasure(nvm-prd x6+2p)", 2),
                    ("erasure(nvm-prd x4+1p)", 1),
                    ("erasure(nvm-prd x3+2p)", 2)):
        try:
            be = create_backend(spec, nblocks=4, block_size=12,
                                dtype=np.float64, schema=PCG_SCHEMA)
        except Exception as e:  # noqa: BLE001
            errors.append(f"{spec}: factory failed: {e!r}")
            continue
        caps = be.capabilities
        if caps.max_storage_failures != p:
            errors.append(
                f"{spec}: declares max_storage_failures="
                f"{caps.max_storage_failures}, must equal P={p}")
        if not caps.survives_prd_loss:
            errors.append(f"{spec}: must declare survives_prd_loss")
    try:
        create_backend("erasure(nvm-prd x4+3p)", nblocks=4, block_size=12,
                       dtype=np.float64, schema=PCG_SCHEMA)
        errors.append("erasure(... x4+3p) was not refused — the GF(256) "
                      "P/Q rows are not MDS beyond P=2")
    except ValueError:
        pass
    if not errors:
        print("erasure parity coherence: max_storage_failures == P for "
              "P in {1, 2}; P=3 refused")
    return errors


def check_shard_axis_coherence() -> list:
    """The ISSUE 7 capability rule: every backend's shard-axis failure
    budget (``max_shard_failures``) must be a coherent view of its
    block budget — identity at one block per shard, monotone
    non-increasing as shards grow, and never promising more blocks
    than ``max_block_failures`` covers.  Plus the façade's shard-axis
    fields: an unsharded problem reports ``nshards == 1`` and a
    ``ResilienceSpec`` pinned to a different shard count is refused by
    ``api.solve`` before anything runs."""
    import numpy as np

    from repro.core.state import PCG_SCHEMA
    from repro.nvm.backend import backend_names, create_backend

    errors = []
    for name in backend_names():
        be = create_backend(name, nblocks=8, block_size=8,
                            dtype=np.float64, schema=PCG_SCHEMA)
        caps = be.capabilities
        msf = [caps.max_shard_failures(bps) for bps in (1, 2, 4, 8)]
        if msf[0] != caps.max_block_failures:
            errors.append(
                f"backend {name!r}: max_shard_failures(1)={msf[0]} must "
                f"equal max_block_failures={caps.max_block_failures}")
        bounded = [m for m in msf if m is not None]
        if None in msf and bounded:
            errors.append(f"backend {name!r}: shard budget mixes "
                          f"unbounded and bounded views: {msf}")
        if bounded != sorted(bounded, reverse=True):
            errors.append(f"backend {name!r}: max_shard_failures must be "
                          f"monotone non-increasing in shard size: {msf}")
        if caps.max_block_failures is not None:
            for bps, m in zip((1, 2, 4, 8), msf):
                if m * bps > caps.max_block_failures:
                    errors.append(
                        f"backend {name!r}: {m} shard failures of {bps} "
                        f"blocks exceed max_block_failures="
                        f"{caps.max_block_failures}")
        try:
            caps.max_shard_failures(0)
            errors.append(f"backend {name!r}: max_shard_failures(0) "
                          f"was not refused")
        except ValueError:
            pass

    from repro import api

    problem = api.Problem.poisson(8, nblocks=4)
    if problem.nshards != 1:
        errors.append(f"unsharded Problem reports nshards="
                      f"{problem.nshards}, expected 1")
    try:
        api.solve(problem, "pcg", api.ResilienceSpec(nshards=2))
        errors.append("api.solve accepted a ResilienceSpec pinned to "
                      "nshards=2 on an unsharded problem")
    except ValueError:
        pass
    if not errors:
        print("shard axis coherence: max_shard_failures coheres with "
              "max_block_failures for every backend; façade shard pins "
              "enforced")
    return errors


def check_lint_surface() -> list:
    """The ISSUE 8 gate: the linter package imports cleanly and exports
    a complete rule registry — five families, titled and hinted rules,
    the meta ids — and its engine fires on a one-line smoke fixture."""
    errors = []
    try:  # script mode puts tools/ first on sys.path; -m mode does not
        from repro_lint import ALL_RULES, META_RULES, lint_source
        from repro_lint import rule_families
    except ImportError:
        try:
            from tools.repro_lint import (ALL_RULES, META_RULES,
                                          lint_source, rule_families)
        except Exception:
            return [f"tools.repro_lint failed to import:\n"
                    f"{traceback.format_exc()}"]
    except Exception:
        return [f"tools.repro_lint failed to import:\n"
                f"{traceback.format_exc()}"]

    fams = rule_families()
    missing = [f"RL{i}" for i in range(1, 6) if f"RL{i}" not in fams]
    if missing:
        errors.append(f"rule registry misses famil(ies) {missing}; "
                      f"has {sorted(fams)}")
    for rid, rule in ALL_RULES.items():
        if not rule.title or not rule.hint:
            errors.append(f"rule {rid}: registry entries must carry a "
                          f"title and a fix hint")
    if not {"RL001", "RL002"} <= set(META_RULES):
        errors.append(f"meta rules incomplete: {sorted(META_RULES)}")
    smoke = lint_source("def f(x=[]):\n    return x\n")
    if [f.rule for f in smoke] != ["RL501"]:
        errors.append(f"lint_source smoke fixture fired "
                      f"{[f.rule for f in smoke]}, expected ['RL501']")
    if not errors:
        print(f"lint surface: {len(ALL_RULES)} rule ids across "
              f"{len(fams)} families, engine fires")
    return errors


def check_advisor_surface() -> list:
    """The advisor exports resolve and the canonical footprint decision
    holds: a double-storage-loss campaign picks the K+2p stripe over
    the 3x triple mirror."""
    import numpy as np

    errors = []
    try:
        from repro import api  # noqa: F401
        from repro.api import advise  # noqa: F401
        from repro.core.state import PCG_SCHEMA
        from repro.nvm.backend import create_backend
        from repro.solvers import (
            FailureCampaign,
            FailureEvent,
            SpecAdvice,
            advise_spec,
        )
    except Exception:
        return [f"advisor exports missing:\n{traceback.format_exc()}"]

    campaign = FailureCampaign((
        FailureEvent(blocks=(1,), at_iteration=4, prd=True),
        FailureEvent(blocks=(2,), at_iteration=8, prd=True),
    ))
    candidates = {
        spec: create_backend(spec, nblocks=4, block_size=12,
                             dtype=np.float64, schema=PCG_SCHEMA)
        for spec in ("nvm-prd", "replicated(nvm-prd x3)",
                     "erasure(nvm-prd x6+2p)")
    }
    advice = advise_spec(campaign, candidates, probe_values=48)
    if not isinstance(advice, SpecAdvice):
        errors.append(f"advise_spec returned {type(advice).__name__}")
    elif advice.chosen != "erasure(nvm-prd x6+2p)":
        errors.append(f"advisor chose {advice.chosen!r} for the "
                      f"double-loss campaign, expected the K+2p stripe "
                      f"on footprint grounds")
    elif {r.spec for r in advice.rejected} != {"nvm-prd"}:
        errors.append(f"advisor rejections wrong: "
                      f"{[r.spec for r in advice.rejected]}")
    if not errors:
        print("advisor surface: double-loss campaign picks the K+2p "
              "stripe over the triple mirror")
    return errors


def main() -> int:
    errors = (check_api_surface() + check_backend_capabilities()
              + check_planner_surface() + check_erasure_parity_coherence()
              + check_shard_axis_coherence() + check_advisor_surface()
              + check_lint_surface())
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
