"""RL4xx — persistence-session lifecycle and ABC conformance.

Crash consistency (docs/recovery-format.md) hangs on two structural
facts the runtime can only probe, never prove:

- every concrete :class:`PersistenceBackend` / :class:`PersistSession`
  implements the *full* abstract surface with the declared signatures —
  a subclass that silently misses ``abort`` falls back to a parent's
  (or raises ``TypeError`` at construction deep inside a campaign), and
  a renamed parameter breaks keyword call sites in the driver;
- every code path that stages a persistence event (``.begin(...)``)
  pairs it with ``commit`` and an abort/teardown edge, so a staged-but-
  uncommitted event can never surface after a crash (the "aborted
  events never surface" rule of DESIGN.md §6).

The ABC surface is read from the scanned tree itself (the class that
defines ``@abc.abstractmethod`` members under the well-known names), so
the rule tracks the real contract, not a vendored copy of it.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .core import FileContext, Finding, Project, Rule

#: roots of the persistence contract (abstract surfaces live here)
ABC_NAMES = ("PersistSession", "PersistenceBackend")

_ABSTRACT_DECOS = ("abc.abstractmethod", "abstractmethod",
                   "abc.abstractproperty", "abstractproperty")
_PROPERTY_DECOS = ("property", "abc.abstractproperty", "abstractproperty",
                   "cached_property", "functools.cached_property")


def _deco_names(fn: ast.FunctionDef) -> List[str]:
    return [ast.unparse(d) for d in fn.decorator_list]


def _is_abstract(fn: ast.FunctionDef) -> bool:
    return any(d in _ABSTRACT_DECOS for d in _deco_names(fn))


def _is_property(fn: ast.FunctionDef) -> bool:
    return any(d in _PROPERTY_DECOS for d in _deco_names(fn))


def _arg_names(fn: ast.FunctionDef) -> Tuple[Tuple[str, ...], bool]:
    """Positional parameter names (kind-insensitive) and whether the
    implementation is fully variadic (``*args, **kwargs``)."""
    a = fn.args
    names = tuple(p.arg for p in (*a.posonlyargs, *a.args))
    variadic = a.vararg is not None and a.kwarg is not None
    return names, variadic


class _ClassInfo:
    def __init__(self, ctx: FileContext, node: ast.ClassDef):
        self.ctx = ctx
        self.node = node
        self.name = node.name
        self.base_names = [b.attr if isinstance(b, ast.Attribute) else
                           b.id if isinstance(b, ast.Name) else ""
                           for b in node.bases]
        self.methods: Dict[str, ast.FunctionDef] = {}
        self.class_attrs: Set[str] = set()
        self.self_attrs: Set[str] = set()
        for stmt in node.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.methods[stmt.name] = stmt
            elif isinstance(stmt, ast.Assign):
                for tgt in stmt.targets:
                    if isinstance(tgt, ast.Name):
                        self.class_attrs.add(tgt.id)
            elif isinstance(stmt, ast.AnnAssign) \
                    and isinstance(stmt.target, ast.Name):
                self.class_attrs.add(stmt.target.id)
        for sub in ast.walk(node):
            if (isinstance(sub, ast.Attribute)
                    and isinstance(sub.ctx, ast.Store)
                    and isinstance(sub.value, ast.Name)
                    and sub.value.id == "self"):
                self.self_attrs.add(sub.attr)

    @property
    def is_abstract(self) -> bool:
        return any(_is_abstract(fn) for fn in self.methods.values())


def _class_table(project: Project) -> Dict[str, _ClassInfo]:
    table: Dict[str, _ClassInfo] = {}
    for ctx in project.files:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef):
                table.setdefault(node.name, _ClassInfo(ctx, node))
    return table


def _chain(info: _ClassInfo, table: Dict[str, _ClassInfo],
           stop: str) -> List[_ClassInfo]:
    """MRO-ish linearization within the project, ``info`` first, up to
    (excluding) the class named ``stop``."""
    out: List[_ClassInfo] = []
    seen: Set[str] = set()
    frontier = [info]
    while frontier:
        cur = frontier.pop(0)
        if cur.name in seen or cur.name == stop:
            continue
        seen.add(cur.name)
        out.append(cur)
        frontier.extend(table[b] for b in cur.base_names if b in table)
    return out


def _descends_from(info: _ClassInfo, table: Dict[str, _ClassInfo],
                   root: str) -> bool:
    seen: Set[str] = set()
    frontier = list(info.base_names)
    while frontier:
        name = frontier.pop(0)
        if name in seen:
            continue
        seen.add(name)
        if name == root:
            return True
        if name in table:
            frontier.extend(table[name].base_names)
    return False


class AbcSurfaceRule(Rule):
    """RL401 missing member + RL402 signature drift, one project pass."""

    rule_id = "RL401"
    title = "concrete backend/session misses part of the ABC surface"
    hint = "implement every @abc.abstractmethod of PersistSession / " \
           "PersistenceBackend (docs/backend-api.md lists the contract)"
    invariant = "DESIGN.md §7: the driver speaks only the session ABC; " \
                "a partial implementation fails mid-campaign, not at review"

    MISMATCH_ID = "RL402"
    MISMATCH_TITLE = "backend/session method signature drifts from the ABC"
    MISMATCH_HINT = ("match the abstract method's parameter names — the "
                     "driver and composites call them by keyword")

    def check_project(self, project: Project) -> Iterable[Finding]:
        table = _class_table(project)
        # names used as a base by some other project class: intermediate
        # bases defer the remaining surface to their leaves (ABCMeta
        # blocks direct instantiation anyway), so only leaves carry the
        # full-surface obligation
        base_of = {b for c in table.values() for b in c.base_names}
        for root_name in ABC_NAMES:
            root = table.get(root_name)
            if root is None:
                continue
            spec = {name: fn for name, fn in root.methods.items()
                    if _is_abstract(fn)}
            if not spec:
                continue
            for info in table.values():
                if info is root or info.is_abstract \
                        or info.name in base_of \
                        or not _descends_from(info, table, root_name):
                    continue
                chain = _chain(info, table, stop=root_name)
                for mname, abstract_fn in sorted(spec.items()):
                    impl = next((c.methods[mname] for c in chain
                                 if mname in c.methods), None)
                    if impl is None:
                        if _is_property(abstract_fn) and any(
                                mname in c.class_attrs
                                or mname in c.self_attrs for c in chain):
                            continue  # property satisfied by an attribute
                        yield self.finding(
                            info.ctx, info.node,
                            f"{info.name} (concrete subclass of "
                            f"{root_name}) does not implement abstract "
                            f"{mname!r}")
                        continue
                    want, _ = _arg_names(abstract_fn)
                    got, variadic = _arg_names(impl)
                    if not variadic and want != got:
                        yield Finding(
                            rule=self.MISMATCH_ID, file=info.ctx.rel,
                            line=impl.lineno, col=impl.col_offset,
                            message=(
                                f"{info.name}.{mname} signature "
                                f"{got} drifts from the {root_name} "
                                f"contract {want}"),
                            hint=self.MISMATCH_HINT)


class BeginPairingRule(Rule):
    rule_id = "RL403"
    title = "staged persist (.begin) without commit/abort pairing"
    hint = "pair every .begin(...) with .commit() on the success path " \
           "and .abort()/.fail()/drain teardown on every failure edge " \
           "(DESIGN.md §6: aborted events never surface)"
    invariant = "DESIGN.md §6 + docs/recovery-format.md crash-" \
                "consistency: a staged-but-uncommitted event must never " \
                "be fetchable"

    _ABORTERS = ("abort", "fail", "fail_storage", "drain",
                 "persist_abort")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        begin_calls = []
        has_commit = False
        has_abort = False
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Attribute):
                if node.func.attr in ("begin", "persist_begin"):
                    begin_calls.append(node)
                elif node.func.attr in ("commit", "persist_commit"):
                    has_commit = True
                elif node.func.attr in self._ABORTERS:
                    has_abort = True
            elif isinstance(node.func, ast.Name):
                if node.func.id in ("persist_begin",):
                    begin_calls.append(node)
                elif node.func.id in ("persist_commit",):
                    has_commit = True
                elif node.func.id in ("persist_abort",):
                    has_abort = True
        if not begin_calls:
            return
        if not has_commit:
            yield self.finding(
                ctx, begin_calls[0], "module stages persistence events "
                "(.begin) but never commits them — staged payloads leak")
        if not has_abort:
            yield self.finding(
                ctx, begin_calls[0], "module stages persistence events "
                "(.begin) with no abort/teardown edge — a failure here "
                "leaves uncommitted state that may surface after a crash")
        for call in begin_calls:
            yield from self._check_try_edges(ctx, call)

    def _check_try_edges(self, ctx: FileContext,
                         call: ast.Call) -> Iterable[Finding]:
        """A begin inside a ``try`` body must have an except/finally that
        commits or tears down — otherwise the exception edge leaks the
        staged event."""
        prev: ast.AST = call
        for anc in ctx.ancestors(call):
            if isinstance(anc, ast.Try) and any(
                    self._in_subtree(stmt, prev, ctx)
                    for stmt in anc.body):
                cleanup = list(anc.finalbody)
                for handler in anc.handlers:
                    cleanup.extend(handler.body)
                if not self._has_teardown(cleanup):
                    yield self.finding(
                        ctx, call, "staged .begin(...) inside try has no "
                        "commit/abort on its except/finally edge")
                return
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return
            prev = anc

    @staticmethod
    def _in_subtree(stmt: ast.AST, node: ast.AST,
                    ctx: FileContext) -> bool:
        cur: Optional[ast.AST] = node
        while cur is not None:
            if cur is stmt:
                return True
            cur = ctx.parents.get(cur)
        return False

    def _has_teardown(self, stmts) -> bool:
        for stmt in stmts:
            for node in ast.walk(stmt):
                if isinstance(node, ast.Call):
                    name = (node.func.attr
                            if isinstance(node.func, ast.Attribute)
                            else node.func.id
                            if isinstance(node.func, ast.Name) else "")
                    if name in self._ABORTERS + ("commit",
                                                 "persist_commit"):
                        return True
        return False


RULES: List[Rule] = [AbcSurfaceRule(), BeginPairingRule()]
