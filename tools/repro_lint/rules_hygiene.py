"""RL5xx — hygiene: the small defects that become heisenbugs at scale.

Mutable default arguments alias state across calls (a campaign list
that remembers the previous solve's failures); a bare ``except``
swallows ``UnrecoverableFailure`` and ``KeyboardInterrupt`` alike,
turning the exact-or-raise recovery contract into silent divergence; an
``__all__`` naming a ghost breaks ``from module import *`` and the
check_api façade gate at the worst possible time (a user's first
import).
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Set

from .core import FileContext, Finding, Rule

_MUTABLE_DISPLAYS = (ast.List, ast.Dict, ast.Set, ast.ListComp,
                     ast.DictComp, ast.SetComp)
_MUTABLE_CTORS = ("list", "dict", "set", "bytearray", "defaultdict",
                  "OrderedDict", "Counter", "deque")


def _is_mutable_default(node: ast.AST) -> bool:
    if isinstance(node, _MUTABLE_DISPLAYS):
        return True
    if isinstance(node, ast.Call):
        name = (node.func.id if isinstance(node.func, ast.Name)
                else node.func.attr
                if isinstance(node.func, ast.Attribute) else "")
        return name in _MUTABLE_CTORS
    return False


class MutableDefaultRule(Rule):
    rule_id = "RL501"
    title = "mutable default argument"
    hint = "default to None and materialize inside the function " \
           "(x = [] if x is None else x), or use a tuple"

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef, ast.Lambda)):
                continue
            defaults = list(node.args.defaults) + [
                d for d in node.args.kw_defaults if d is not None]
            for d in defaults:
                if _is_mutable_default(d):
                    fname = getattr(node, "name", "<lambda>")
                    yield self.finding(
                        ctx, d, f"mutable default {ast.unparse(d)!r} in "
                        f"{fname}() is shared across every call")


class BareExceptRule(Rule):
    rule_id = "RL502"
    title = "bare except"
    hint = "catch the narrowest type that can actually occur; " \
           "UnrecoverableFailure must always propagate (exact-or-raise)"

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ExceptHandler) and node.type is None:
                yield self.finding(
                    ctx, node, "bare 'except:' swallows "
                    "UnrecoverableFailure, KeyboardInterrupt and "
                    "SystemExit alike")


class AllGhostRule(Rule):
    rule_id = "RL503"
    title = "__all__ names that do not resolve"
    hint = "every __all__ entry must be bound at module top level " \
           "(def/class/import/assignment) — check_api's façade gate " \
           "imports them all"

    def _top_level_bindings(self, tree: ast.Module) -> Set[str]:
        """Names bound at module scope, descending into top-level
        If/Try/With bodies (version-guarded imports) but not into
        functions or classes.  Returns ``{"*"}``-augmented set when a
        star import makes static resolution impossible."""
        bound: Set[str] = set()
        stack = list(tree.body)
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                bound.add(node.name)
            elif isinstance(node, ast.Import):
                for alias in node.names:
                    bound.add(alias.asname or alias.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom):
                for alias in node.names:
                    if alias.name == "*":
                        bound.add("*")
                    else:
                        bound.add(alias.asname or alias.name)
            elif isinstance(node, (ast.Assign, ast.AnnAssign,
                                   ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for tgt in targets:
                    for sub in ast.walk(tgt):
                        if isinstance(sub, ast.Name):
                            bound.add(sub.id)
            elif isinstance(node, ast.If):
                stack.extend(node.body)
                stack.extend(node.orelse)
            elif isinstance(node, ast.Try):
                stack.extend(node.body)
                stack.extend(node.finalbody)
                for h in node.handlers:
                    stack.extend(h.body)
            elif isinstance(node, ast.With):
                stack.extend(node.body)
        return bound

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        all_node = None
        for node in ctx.tree.body:
            if isinstance(node, ast.Assign) and any(
                    isinstance(t, ast.Name) and t.id == "__all__"
                    for t in node.targets):
                all_node = node
        if all_node is None or not isinstance(all_node.value,
                                              (ast.List, ast.Tuple)):
            return
        names = [e.value for e in all_node.value.elts
                 if isinstance(e, ast.Constant) and isinstance(e.value, str)]
        bound = self._top_level_bindings(ctx.tree)
        if "*" in bound:
            return  # star import: not statically resolvable, runtime
            # gate (check_api) still covers it
        for name in names:
            if name not in bound:
                yield self.finding(
                    ctx, all_node, f"__all__ lists {name!r} but the "
                    f"module never binds it")


RULES: List[Rule] = [MutableDefaultRule(), BareExceptRule(), AllGhostRule()]
