"""The rule registry: five families plus the framework's meta rules.

``ALL_RULES`` maps every reportable rule id to the rule object that
emits it; one object may own several ids (the ABC-surface pass emits
both RL401 missing-member and RL402 signature-drift findings), so
consumers running rules must deduplicate by object identity — the
runner does.  ``META_RULES`` are produced by the framework itself
(suppression hygiene, parse failures) and can never be suppressed.
"""
from __future__ import annotations

from typing import Dict, List

from .core import Rule
from . import (
    rules_compat,
    rules_determinism,
    rules_hygiene,
    rules_session,
    rules_tracer,
)

#: framework-emitted ids -> human description (not Rule objects)
META_RULES: Dict[str, str] = {
    "RL001": "suppression without a written justification",
    "RL002": "file does not parse",
}


def _build() -> Dict[str, Rule]:
    table: Dict[str, Rule] = {}
    for mod in (rules_compat, rules_determinism, rules_tracer,
                rules_session, rules_hygiene):
        for rule in mod.RULES:
            assert rule.rule_id not in table, rule.rule_id
            table[rule.rule_id] = rule
            # secondary ids emitted by the same pass (e.g. RL402)
            extra = getattr(rule, "MISMATCH_ID", None)
            if extra:
                table[extra] = rule
    return table


ALL_RULES: Dict[str, Rule] = _build()


def rule_families() -> Dict[str, List[str]]:
    """``{"RL1": ["RL101", ...], ...}`` — the five shipped families."""
    fams: Dict[str, List[str]] = {}
    for rid in sorted(ALL_RULES):
        fams.setdefault(rid[:3], []).append(rid)
    return fams
