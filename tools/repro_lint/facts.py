"""AST-extracted project facts — the linter's gift to the doc gates.

``tools/check_docs.py`` used to derive its freshness gates (span
taxonomy, backend-family matrix, erasure arities) from regexes over raw
source text, which made them hostage to grep-able formatting: a span
call split across lines, a backend registered through an alias, or a
reformatted ``MAX_PARITY`` assignment silently emptied the gate.  These
extractors walk the *AST*, so the facts survive any formatting.

Completeness of the span-name fact relies on rule RL302 (span/event
names must be string literals at the call site) — the same style rule
the textual scan assumed, now enforced instead of hoped for.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Set

from .core import FileContext, Project

TRACER_METHODS = ("span", "event")
REGISTER_FUNCS = ("register_backend", "register_backend_class")
METRIC_METHODS = ("counter", "gauge", "histogram")


def _call_name(func: ast.AST) -> str:
    """Trailing identifier of a call target (``a.b.c`` -> ``c``)."""
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return ""


def span_names(tree: ast.Module) -> Set[str]:
    """Span/event names emitted by this module — string literals at
    ``.span("...")`` / ``.event("...")`` call sites."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in TRACER_METHODS and node.args
                and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, str)):
            names.add(node.args[0].value)
    return names


def metric_names(tree: ast.Module) -> Set[str]:
    """Metric instrument names created by this module — string literals
    at ``.counter("...")`` / ``.gauge("...")`` / ``.histogram("...")``
    call sites (the MetricsRegistry get-or-create surface)."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in METRIC_METHODS and node.args
                and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, str)):
            names.add(node.args[0].value)
    return names


def backend_families(tree: ast.Module) -> Set[str]:
    """Backend spec families registered by this module — string literals
    at ``register_backend("name", ...)`` call sites."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and _call_name(node.func) in REGISTER_FUNCS and node.args
                and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, str)):
            names.add(node.args[0].value)
    return names


def max_parity(tree: ast.Module) -> int:
    """``MAX_PARITY`` module constant (0 when the module has none)."""
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if (isinstance(tgt, ast.Name) and tgt.id == "MAX_PARITY"
                        and isinstance(node.value, ast.Constant)
                        and isinstance(node.value.value, int)):
                    return node.value.value
        if (isinstance(node, ast.AnnAssign)
                and isinstance(node.target, ast.Name)
                and node.target.id == "MAX_PARITY"
                and isinstance(node.value, ast.Constant)
                and isinstance(node.value.value, int)):
            return node.value.value
    return 0


def erasure_arities_from_parity(parity: int) -> List[str]:
    if parity < 1:
        return []
    return ["+p"] + [f"+{p}p" for p in range(2, parity + 1)]


def collect_facts(project: Project) -> dict:
    """The machine-readable facts block of ``--json`` output."""
    spans: Set[str] = set()
    families: Set[str] = set()
    service_metrics: Set[str] = set()
    parity = 0
    tracer_sites = 0
    for ctx in project.files:
        spans |= span_names(ctx.tree)
        families |= backend_families(ctx.tree)
        if "serving" in ctx.rel.split("/"):
            service_metrics |= metric_names(ctx.tree)
        if ctx.path_endswith("gf256.py"):
            parity = max(parity, max_parity(ctx.tree))
        for node in ast.walk(ctx.tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in TRACER_METHODS):
                tracer_sites += 1
    return {
        "span_names": sorted(spans),
        "backend_families": sorted(families),
        "service_metric_names": sorted(service_metrics),
        "erasure_arities": erasure_arities_from_parity(parity),
        "tracer_sites": tracer_sites,
    }


def _parse_root(src_root) -> Project:
    files = []
    for path in sorted(Path(src_root).rglob("*.py")):
        if "__pycache__" in path.parts:
            continue
        try:
            files.append(FileContext(path, path.as_posix(),
                                     path.read_text()))
        except SyntaxError:
            continue  # check_docs must stay usable on a broken tree
    return Project(files)


def collect_facts_from_root(src_root) -> dict:
    """Standalone entry point for ``check_docs.py`` (no runner needed):
    parse everything under ``src_root`` and return the facts block."""
    return collect_facts(_parse_root(src_root))
