"""CLI: ``python -m tools.repro_lint src/ [--json] [--select ...]``.

Exit status 0 iff every finding is suppressed-with-reason; any
unsuppressed finding (including RL001 justification-less suppressions
and RL002 parse failures) exits 1 — that is the CI lint gate.
"""
from __future__ import annotations

import argparse
import sys

from .core import lint_paths, main_json
from .registry import ALL_RULES, META_RULES, rule_families


def _list_rules() -> str:
    lines = ["repro-lint rule catalog (docs/static-analysis.md has the "
             "full rationale):"]
    for fam, ids in sorted(rule_families().items()):
        lines.append(f"  {fam}xx:")
        for rid in ids:
            rule = ALL_RULES[rid]
            title = rule.title
            if rid == getattr(rule, "MISMATCH_ID", None):
                title = getattr(rule, "MISMATCH_TITLE", title)
            lines.append(f"    {rid}  {title}")
    lines.append("  meta (framework, never suppressable):")
    for rid, desc in sorted(META_RULES.items()):
        lines.append(f"    {rid}  {desc}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro_lint",
        description="AST-based invariant linter for the resilience stack")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directory roots to lint (default: src)")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable output (repro-lint/v1 schema, "
                         "findings + AST-extracted project facts)")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule ids or family prefixes "
                         "(e.g. RL3,RL501)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        print(_list_rules())
        return 0

    select = args.select.split(",") if args.select else None
    result = lint_paths(args.paths or ["src"], select=select)
    if args.json:
        print(main_json(result))
    else:
        print(result.render())
    return result.exit_code


if __name__ == "__main__":
    sys.exit(main())
