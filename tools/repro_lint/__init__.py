"""repro-lint — AST-based invariant linter for the resilience stack.

The stack's exactness guarantees (bit-identical recovery, placement-
stable reductions, the zero-overhead disabled-tracer path, the
session-lifecycle crash-consistency rules) were previously enforced
only at runtime — by the campaign-fuzz harness and a counting probe —
or by textual greps in ``tools/check_docs.py``.  This package enforces
them **statically, at review time**, on the stdlib ``ast`` module with
zero third-party dependencies (the CI lint job runs on a bare Python).

Public surface:

- :func:`lint_paths` / :func:`lint_source` — run the rule set, return a
  :class:`~repro_lint.core.LintResult` (or plain findings for a source
  snippet).
- ``ALL_RULES`` — the rule registry (``{rule_id: Rule}``), five
  families: RL1xx compat, RL2xx determinism, RL3xx tracer guards,
  RL4xx session lifecycle, RL5xx hygiene (plus RL0xx meta rules).
- :mod:`repro_lint.facts` — AST-extracted project facts (span names,
  backend families, erasure arities) consumed by ``check_docs.py``'s
  freshness gates, replacing its textual scans.

CLI: ``python -m tools.repro_lint src/ [--json] [--select RL3,RL5]``.
Suppressions: ``# repro-lint: noqa[RL201] -- <written justification>``
— the justification is mandatory; a bare ``noqa`` is itself a finding
(RL001) and cannot be suppressed.  See ``docs/static-analysis.md``.
"""
from .core import (  # noqa: F401
    Finding,
    LintResult,
    Rule,
    lint_paths,
    lint_source,
)
from .registry import ALL_RULES, META_RULES, rule_families  # noqa: F401

__version__ = "1.0"

__all__ = [
    "Finding",
    "LintResult",
    "Rule",
    "lint_paths",
    "lint_source",
    "ALL_RULES",
    "META_RULES",
    "rule_families",
    "__version__",
]
