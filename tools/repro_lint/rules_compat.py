"""RL1xx — jax version-compat isolation.

The installed jax is 0.4.37: ``jax.shard_map`` and
``jax.sharding.AxisType`` do not exist, and ``jax.make_mesh`` has no
``axis_types`` kwarg (ROADMAP standing constraint).  The repo's answer
is a single compat seam — ``repro/compat.py`` (:func:`shard_map`) and
``repro/launch/mesh.py`` (:func:`compat_make_mesh`) — and these rules
keep every other file off the raw surfaces, so a jax upgrade or
downgrade is a two-file change instead of a tree-wide audit.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Set, Tuple

from .core import FileContext, Finding, Rule

#: the only files allowed to touch the raw version-dependent surfaces
COMPAT_FILES = ("repro/compat.py", "repro/launch/mesh.py")


def _jax_imports(ctx: FileContext) -> Tuple[Set[str], Set[str]]:
    """Names bound in this file by ``from jax... import`` — returns
    ({names bound to Mesh}, {names bound to make_mesh})."""
    mesh_names: Set[str] = set()
    make_mesh_names: Set[str] = set()
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.ImportFrom) and node.module \
                and node.module.split(".")[0] == "jax":
            for alias in node.names:
                bound = alias.asname or alias.name
                if alias.name == "Mesh":
                    mesh_names.add(bound)
                if alias.name == "make_mesh":
                    make_mesh_names.add(bound)
    return mesh_names, make_mesh_names


class RawShardMapRule(Rule):
    rule_id = "RL101"
    title = "direct jax.shard_map outside the compat seam"
    hint = "call repro.compat.shard_map (version-shimmed) instead"
    invariant = "ROADMAP standing constraint: jax 0.4.37 has no " \
                "jax.shard_map; all call sites route through repro.compat"

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        if ctx.path_endswith(*COMPAT_FILES):
            return
        for node in ast.walk(ctx.tree):
            if (isinstance(node, ast.Attribute)
                    and node.attr == "shard_map"
                    and isinstance(node.value, ast.Name)
                    and node.value.id == "jax"):
                yield self.finding(
                    ctx, node, "direct jax.shard_map reference — absent "
                    "on the installed jax 0.4.37")
            if isinstance(node, ast.ImportFrom) and node.module in (
                    "jax", "jax.experimental.shard_map"):
                for alias in node.names:
                    if alias.name == "shard_map":
                        yield self.finding(
                            ctx, node, f"shard_map imported from "
                            f"{node.module!r} — version-dependent surface")


class RawAxisTypeRule(Rule):
    rule_id = "RL102"
    title = "jax.sharding.AxisType outside the compat seam"
    hint = "use repro.launch.mesh.compat_make_mesh, which applies " \
           "AxisType only where the installed jax supports it"
    invariant = "ROADMAP standing constraint: jax.sharding.AxisType " \
                "does not exist before jax 0.5"

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        if ctx.path_endswith(*COMPAT_FILES):
            return
        for node in ast.walk(ctx.tree):
            if (isinstance(node, ast.Attribute) and node.attr == "AxisType"
                    and ast.unparse(node.value) == "jax.sharding"):
                yield self.finding(
                    ctx, node, "jax.sharding.AxisType reference — absent "
                    "on the installed jax 0.4.37")
            if isinstance(node, ast.ImportFrom) \
                    and node.module == "jax.sharding":
                for alias in node.names:
                    if alias.name == "AxisType":
                        yield self.finding(
                            ctx, node, "AxisType imported from "
                            "jax.sharding — version-dependent surface")


class RawMeshConstructionRule(Rule):
    rule_id = "RL103"
    title = "raw Mesh construction outside the compat seam"
    hint = "build meshes with repro.launch.mesh.compat_make_mesh (or " \
           "make_mesh_for); importing Mesh for type annotations is fine"
    invariant = "DESIGN.md §10: every mesh is built by compat_make_mesh " \
                "so axis-type semantics match across jax versions"

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        if ctx.path_endswith(*COMPAT_FILES):
            return
        mesh_names, make_mesh_names = _jax_imports(ctx)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            if isinstance(func, ast.Name) and (
                    func.id in mesh_names or func.id in make_mesh_names):
                yield self.finding(
                    ctx, node, f"raw {func.id}(...) construction — mesh "
                    f"geometry must go through the compat seam")
            elif isinstance(func, ast.Attribute):
                dotted = ast.unparse(func)
                if dotted in ("jax.sharding.Mesh", "jax.make_mesh",
                              "jax.experimental.maps.Mesh"):
                    yield self.finding(
                        ctx, node, f"raw {dotted}(...) construction — "
                        f"mesh geometry must go through the compat seam")


RULES: List[Rule] = [RawShardMapRule(), RawAxisTypeRule(),
                     RawMeshConstructionRule()]
