"""Framework: findings, suppressions, file/project contexts, the runner.

Design notes (docs/static-analysis.md has the user-facing version):

- Two pass granularities.  A *file* rule sees one :class:`FileContext`
  (source, AST, parent map, suppression table) at a time; a *project*
  rule sees the whole :class:`Project` — that is where the symbol-table
  passes live (ABC-surface conformance needs every class definition in
  the tree at once).
- Suppressions are **justified or refused**.  The only accepted form is
  ``# repro-lint: noqa[RLxxx] -- reason`` (comma-separated ids allowed)
  on the finding's line or on a comment line directly above it.  A
  suppression with no ``-- reason`` suppresses nothing and raises an
  RL001 finding of its own; RL0xx meta findings cannot be suppressed.
  Comments are located with :mod:`tokenize`, not regexes over raw
  lines, so a ``# repro-lint:`` inside a string literal is inert.
- Findings are deterministic: sorted by (file, line, col, rule) so two
  runs over the same tree emit byte-identical reports (the same
  determinism contract the solvers hold their reductions to).
"""
from __future__ import annotations

import ast
import dataclasses
import io
import json
import re
import tokenize
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*noqa\[(?P<ids>[A-Z0-9,\s]+)\]"
    r"(?:\s*--\s*(?P<reason>.*\S))?")

#: meta findings — produced by the framework itself, never suppressable
META_SUPPRESSION = "RL001"
META_SYNTAX = "RL002"


@dataclasses.dataclass
class Finding:
    """One rule violation at a source location."""

    rule: str
    file: str
    line: int
    col: int
    message: str
    hint: str = ""
    suppressed: bool = False
    reason: Optional[str] = None

    def render(self) -> str:
        tag = f" [suppressed: {self.reason}]" if self.suppressed else ""
        hint = f"  (fix: {self.hint})" if self.hint and not self.suppressed \
            else ""
        return (f"{self.file}:{self.line}:{self.col}: {self.rule} "
                f"{self.message}{hint}{tag}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Suppression:
    line: int
    ids: Tuple[str, ...]
    reason: Optional[str]
    used: bool = False


class FileContext:
    """One parsed source file plus everything rules need to query it."""

    def __init__(self, path: Path, rel: str, source: str):
        self.path = path
        self.rel = rel.replace("\\", "/")
        self.source = source
        self.tree = ast.parse(source)  # caller handles SyntaxError
        self.parents: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self.parents[child] = node
        self.suppressions: Dict[int, List[Suppression]] = {}
        self._scan_suppressions()

    # -- path predicates (rules scope themselves by tree position) ------
    def path_endswith(self, *suffixes: str) -> bool:
        return any(self.rel.endswith(s) for s in suffixes)

    def in_dir(self, *parts: str) -> bool:
        """True when any of ``parts`` is a path segment of this file."""
        segments = self.rel.split("/")
        return any(p in segments for p in parts)

    # -- suppression table ----------------------------------------------
    def _scan_suppressions(self) -> None:
        try:
            tokens = tokenize.generate_tokens(
                io.StringIO(self.source).readline)
            comments = [(t.start[0], t.string) for t in tokens
                        if t.type == tokenize.COMMENT]
        except tokenize.TokenError:
            comments = []
        for lineno, text in comments:
            m = SUPPRESS_RE.search(text)
            if not m:
                continue
            ids = tuple(s.strip() for s in m.group("ids").split(",")
                        if s.strip())
            reason = m.group("reason")
            self.suppressions.setdefault(lineno, []).append(
                Suppression(lineno, ids, reason))

    def suppression_for(self, rule: str, line: int) -> Optional[Suppression]:
        """A justified suppression covering ``rule`` at ``line`` — on the
        line itself or on a comment line directly above it."""
        if rule.startswith("RL0"):
            return None  # meta findings are not suppressable
        for at in (line, line - 1):
            for sup in self.suppressions.get(at, ()):
                if rule in sup.ids and sup.reason:
                    sup.used = True
                    return sup
        return None

    # -- AST ancestry helpers -------------------------------------------
    def ancestors(self, node: ast.AST) -> Iterable[ast.AST]:
        cur = node
        while cur in self.parents:
            cur = self.parents[cur]
            yield cur

    def enclosing_function(self, node: ast.AST):
        for anc in self.ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return anc
        return None


class Project:
    """Every successfully parsed file, for project-wide passes."""

    def __init__(self, files: Sequence[FileContext]):
        self.files = list(files)


class Rule:
    """Rule protocol.  Subclasses set the class attributes and override
    :meth:`check` (file scope) and/or :meth:`check_project`."""

    rule_id: str = ""
    title: str = ""
    hint: str = ""
    #: the DESIGN.md / docs invariant this rule encodes (for the catalog)
    invariant: str = ""

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        return ()

    def check_project(self, project: Project) -> Iterable[Finding]:
        return ()

    def finding(self, ctx: FileContext, node: ast.AST, message: str,
                hint: Optional[str] = None) -> Finding:
        return Finding(rule=self.rule_id, file=ctx.rel,
                       line=getattr(node, "lineno", 1),
                       col=getattr(node, "col_offset", 0),
                       message=message,
                       hint=self.hint if hint is None else hint)


@dataclasses.dataclass
class LintResult:
    findings: List[Finding]
    files: int
    facts: dict

    @property
    def unsuppressed(self) -> List[Finding]:
        return [f for f in self.findings if not f.suppressed]

    @property
    def exit_code(self) -> int:
        return 1 if self.unsuppressed else 0

    def to_json(self) -> dict:
        return {
            "schema": "repro-lint/v1",
            "files_scanned": self.files,
            "findings": [f.to_dict() for f in self.findings],
            "unsuppressed": len(self.unsuppressed),
            "facts": self.facts,
        }

    def render(self) -> str:
        lines = [f.render() for f in self.findings]
        n_sup = sum(1 for f in self.findings if f.suppressed)
        lines.append(
            f"repro-lint: {self.files} file(s), "
            f"{len(self.unsuppressed)} finding(s), {n_sup} suppressed")
        return "\n".join(lines)


def _iter_py_files(paths: Sequence[str]) -> List[Tuple[Path, str]]:
    out: List[Tuple[Path, str]] = []
    for raw in paths:
        p = Path(raw)
        if p.is_file() and p.suffix == ".py":
            out.append((p, p.as_posix()))
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if "__pycache__" in f.parts:
                    continue
                out.append((f, f.as_posix()))
    return out


def _select_rules(select: Optional[Sequence[str]]):
    from .registry import ALL_RULES

    if not select:
        picked = ALL_RULES.values()
    else:
        wanted = {s.strip() for s in select if s.strip()}
        picked = [r for rid, r in ALL_RULES.items()
                  if rid in wanted or any(rid.startswith(w)
                                          for w in wanted)]
    # one pass may own several ids (RL401/RL402) — run each object once
    seen, rules = set(), []
    for r in picked:
        if id(r) not in seen:
            seen.add(id(r))
            rules.append(r)
    return rules


def _apply_suppressions(findings: List[Finding],
                        contexts: Dict[str, FileContext]) -> List[Finding]:
    out = []
    for f in findings:
        ctx = contexts.get(f.file)
        if ctx is not None:
            sup = ctx.suppression_for(f.rule, f.line)
            if sup is not None:
                f.suppressed, f.reason = True, sup.reason
        out.append(f)
    # a suppression comment with no justification is a finding in itself
    for ctx in contexts.values():
        for sups in ctx.suppressions.values():
            for sup in sups:
                if not sup.reason:
                    out.append(Finding(
                        rule=META_SUPPRESSION, file=ctx.rel, line=sup.line,
                        col=0,
                        message=(
                            "suppression without a justification: write "
                            "'# repro-lint: noqa[RLxxx] -- <reason>' — "
                            "the reason is mandatory and is reviewed like "
                            "code"),
                        hint="append '-- <why this invariant is safe to "
                             "waive here>'"))
    return out


def lint_paths(paths: Sequence[str],
               select: Optional[Sequence[str]] = None) -> LintResult:
    """Lint ``paths`` (files and/or directory roots) and return the
    full :class:`LintResult` (findings + AST-extracted project facts)."""
    from . import facts as facts_mod

    rules = _select_rules(select)
    contexts: Dict[str, FileContext] = {}
    findings: List[Finding] = []
    nfiles = 0
    for path, rel in _iter_py_files(paths):
        nfiles += 1
        try:
            source = path.read_text()
            ctx = FileContext(path, rel, source)
        except SyntaxError as e:
            findings.append(Finding(
                rule=META_SYNTAX, file=rel, line=e.lineno or 1, col=0,
                message=f"file does not parse: {e.msg}",
                hint="fix the syntax error"))
            continue
        contexts[ctx.rel] = ctx
    project = Project(list(contexts.values()))
    for rule in rules:
        for ctx in project.files:
            findings.extend(rule.check(ctx))
        findings.extend(rule.check_project(project))
    findings = _apply_suppressions(findings, contexts)
    findings.sort(key=lambda f: (f.file, f.line, f.col, f.rule))
    return LintResult(findings=findings, files=nfiles,
                      facts=facts_mod.collect_facts(project))


def lint_source(source: str, path: str = "snippet.py",
                select: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint one in-memory snippet (fixture/doc entry point).  ``path``
    is the pretend location — path-scoped rules (e.g. the RL2xx
    determinism rules, active under ``solvers/`` and ``core/``) key off
    it.  Returns the findings, suppressed ones included."""
    rules = _select_rules(select)
    ctx = FileContext(Path(path), path, source)
    findings: List[Finding] = []
    project = Project([ctx])
    for rule in rules:
        findings.extend(rule.check(ctx))
        findings.extend(rule.check_project(project))
    findings = _apply_suppressions(findings, {ctx.rel: ctx})
    findings.sort(key=lambda f: (f.file, f.line, f.col, f.rule))
    return findings


def main_json(result: LintResult) -> str:
    return json.dumps(result.to_json(), indent=2, sort_keys=True)
