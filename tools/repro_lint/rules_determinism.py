"""RL2xx — bit-determinism of the solve trajectory.

The sharded-exactness contract (DESIGN.md §10) requires every reduction
touching solver state to run through the order-pinned block-hierarchical
forms (``solver_dot(op)`` / ``make_det_dot`` / ``make_det_rowdots``): a
raw ``jnp.vdot``/``jnp.sum`` lets XLA pick a reduction order per
compiled program, so the same mathematical dot produces different
low-order bits under different placements.  Library code must also stay
off wall-clock time and unseeded RNG — both make a "deterministic"
trajectory diverge between two runs that should be bit-identical.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from .core import FileContext, Finding, Rule

#: raw XLA reductions whose combine order is placement-dependent
RAW_REDUCTIONS = ("vdot", "dot", "sum")
#: module aliases that mean jax.numpy
JNP_ALIASES = ("jnp", "jax.numpy")
#: wall-clock call targets (time.perf_counter — a monotonic duration
#: meter, never a timestamp that leaks into results — is allowed)
WALL_CLOCK = ("time.time", "time.time_ns", "datetime.now",
              "datetime.utcnow", "datetime.datetime.now",
              "datetime.datetime.utcnow", "datetime.date.today",
              "date.today")
#: numpy legacy global-RNG functions (unseeded process-global stream)
NP_GLOBAL_RNG = ("rand", "randn", "randint", "random", "random_sample",
                 "standard_normal", "normal", "uniform", "choice",
                 "shuffle", "permutation", "seed")


class RawReductionRule(Rule):
    rule_id = "RL201"
    title = "raw jnp reduction on solver state in solvers//core/"
    hint = "route through solver_dot(op) / make_det_dot / " \
           "make_det_rowdots (repro.core.spmv) — the order-pinned forms"
    invariant = "DESIGN.md §10: solver-state reductions are " \
                "block-hierarchical with a pinned combine order, so a " \
                "sharded solve is bitwise identical to the unsharded one"

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        if not ctx.in_dir("solvers", "core"):
            return
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in RAW_REDUCTIONS):
                continue
            if ast.unparse(node.func.value) in JNP_ALIASES:
                yield self.finding(
                    ctx, node, f"raw jnp.{node.func.attr}(...) — XLA "
                    f"reassociates its reduction order per placement")


class WallClockRule(Rule):
    rule_id = "RL202"
    title = "wall-clock time in library code"
    hint = "use time.perf_counter() for durations; thread timestamps " \
           "in from the caller if one is genuinely needed"
    invariant = "DESIGN.md §9: BENCH/trace determinism excludes wall " \
                "subtrees; library results must not embed wall-clock time"

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        from_time_names = set()
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ImportFrom) and node.module == "time":
                for alias in node.names:
                    if alias.name in ("time", "time_ns"):
                        from_time_names.add(alias.asname or alias.name)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            if isinstance(func, ast.Attribute) \
                    and ast.unparse(func) in WALL_CLOCK:
                yield self.finding(
                    ctx, node, f"wall-clock call {ast.unparse(func)}()")
            elif isinstance(func, ast.Name) and func.id in from_time_names:
                yield self.finding(
                    ctx, node, f"wall-clock call {func.id}() "
                    f"(imported from time)")


class UnseededRngRule(Rule):
    rule_id = "RL203"
    title = "unseeded / process-global RNG in library code"
    hint = "use np.random.default_rng(seed) / np.random.SeedSequence " \
           "with an explicit seed, or jax.random with a threaded key"
    invariant = "the fuzz/bench contract: every randomized path is " \
                "seeded, so campaigns and benches replay bit-identically"

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        stdlib_random = any(
            isinstance(node, ast.Import)
            and any(a.name == "random" for a in node.names)
            for node in ast.walk(ctx.tree))
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)):
                continue
            dotted = ast.unparse(node.func)
            recv = ast.unparse(node.func.value)
            if stdlib_random and recv == "random":
                yield self.finding(
                    ctx, node, f"stdlib {dotted}() draws from the "
                    f"unseeded process-global stream")
            elif recv in ("np.random", "numpy.random") \
                    and node.func.attr in NP_GLOBAL_RNG:
                yield self.finding(
                    ctx, node, f"{dotted}() uses numpy's process-global "
                    f"RNG state")
            elif node.func.attr == "default_rng" \
                    and recv in ("np.random", "numpy.random") \
                    and not node.args and not node.keywords:
                yield self.finding(
                    ctx, node, f"{dotted}() without a seed is entropy-"
                    f"seeded — unreproducible")


class FusedEncodeRouteRule(Rule):
    rule_id = "RL204"
    title = "fused GF(256) encode bypassing the registered toggle in nvm/"
    hint = "call repro.kernels.ops.rs_encode(shards, nparity, mode=...) " \
           "— the one seam that dispatches between numpy and the " \
           "fused Pallas kernel"
    invariant = "ISSUE 10 / DESIGN.md §13: backends route every parity " \
                "encode through the registered toggle so one seam " \
                "decides the route and both stay bit-identical"

    #: direct-entry points only the kernels package itself may touch
    KERNEL_MODULE = "repro.kernels.gf256_encode"
    KERNEL_CALLS = ("gf256_rs_encode_pallas",
                    "fused_cg_update_persist_pallas")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        if not ctx.in_dir("nvm"):
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name.startswith(self.KERNEL_MODULE):
                        yield self.finding(
                            ctx, node, f"direct import of {alias.name} "
                            f"from a persistence backend")
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if mod.startswith(self.KERNEL_MODULE):
                    yield self.finding(
                        ctx, node, f"direct import from {mod} from a "
                        f"persistence backend")
                elif any(a.name in self.KERNEL_CALLS for a in node.names):
                    yield self.finding(
                        ctx, node, "direct import of a fused persist "
                        "kernel entry point from a persistence backend")
            elif isinstance(node, ast.Call):
                func = node.func
                name = func.attr if isinstance(func, ast.Attribute) \
                    else getattr(func, "id", "")
                if name in self.KERNEL_CALLS:
                    yield self.finding(
                        ctx, node, f"direct call to {name}(...) from a "
                        f"persistence backend")


RULES: List[Rule] = [RawReductionRule(), WallClockRule(), UnseededRngRule(),
                     FusedEncodeRouteRule()]
