"""RL3xx — the zero-callable disabled-tracer invariant.

DESIGN.md §9: tracing must cost *zero tracer callables per iteration*
when disabled.  The idiom is normalize-once (``trace = tracer or None``
turns any falsy tracer into ``None``) then identity-guard every record
site (``if trace is not None: trace.event(...)``) — never a truthiness
check, which would invoke ``NullTracer.__bool__`` on the hot path, and
never an unguarded call.  Before this linter the invariant was held by
ONE runtime counting probe over ~22 sites; RL301 proves *every* site is
dominated by an identity guard, at review time.

RL302 is the companion style rule: span/event names must be string
literals at the call site.  That is what makes the span-taxonomy
freshness gate (``tools/check_docs.py`` on ``docs/observability.md``,
fed by :mod:`repro_lint.facts`) complete — a computed name could never
be statically enumerated.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional

from .core import FileContext, Finding, Rule

TRACER_METHODS = ("span", "event")


def _is_identity_test(test: ast.AST, recv: str, want_none: bool) -> bool:
    """``recv is not None`` (want_none=False) / ``recv is None``
    (want_none=True), possibly as one conjunct of an ``and`` chain."""
    if isinstance(test, ast.BoolOp) and isinstance(test.op, ast.And):
        return any(_is_identity_test(v, recv, want_none)
                   for v in test.values)
    if isinstance(test, ast.Compare) and len(test.ops) == 1:
        wanted_op = ast.Is if want_none else ast.IsNot
        comp = test.comparators[0]
        if (isinstance(test.ops[0], wanted_op)
                and isinstance(comp, ast.Constant) and comp.value is None):
            return ast.unparse(test.left) == recv
    return False


def _contains(stmts, node: ast.AST, ctx: FileContext) -> bool:
    """Is ``node`` inside the subtree of any statement in ``stmts``?
    (Checked by parent-chain membership, not a re-walk.)"""
    targets = set(map(id, stmts))
    cur: Optional[ast.AST] = node
    while cur is not None:
        if id(cur) in targets:
            return True
        cur = ctx.parents.get(cur)
    return False


def _early_exit_dominates(ctx: FileContext, call: ast.Call,
                          recv: str) -> bool:
    """An ``if recv is None: return/raise/continue/break`` earlier in the
    enclosing function body (the mirror-commit idiom).  Lexical-order
    approximation of dominance — sound for this codebase's straight-line
    method bodies, and a linter may demand the clearer form anyway."""
    fn = ctx.enclosing_function(call)
    if fn is None:
        return False
    for node in ast.walk(fn):
        if (isinstance(node, ast.If) and node.lineno < call.lineno
                and _is_identity_test(node.test, recv, want_none=True)
                and node.body
                and isinstance(node.body[-1],
                               (ast.Return, ast.Raise, ast.Continue,
                                ast.Break))):
            return True
    return False


def _is_guarded(ctx: FileContext, call: ast.Call, recv: str) -> bool:
    prev: ast.AST = call
    for anc in ctx.ancestors(call):
        if isinstance(anc, ast.If):
            if _contains(anc.body, prev, ctx) and \
                    _is_identity_test(anc.test, recv, want_none=False):
                return True
            if _contains(anc.orelse, prev, ctx) and \
                    _is_identity_test(anc.test, recv, want_none=True):
                return True
        elif isinstance(anc, ast.IfExp):
            if anc.body is prev and \
                    _is_identity_test(anc.test, recv, want_none=False):
                return True
            if anc.orelse is prev and \
                    _is_identity_test(anc.test, recv, want_none=True):
                return True
        elif isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
            break  # guards do not cross function boundaries
        prev = anc
    return _early_exit_dominates(ctx, call, recv)


class UnguardedTracerSiteRule(Rule):
    rule_id = "RL301"
    title = "span/event record site not dominated by an identity guard"
    hint = "wrap in 'if <tracer> is not None:' (or early-exit 'if " \
           "<tracer> is None: return') on an 'x or None'-normalized " \
           "tracer — see DESIGN.md §9"
    invariant = "DESIGN.md §9: zero tracer callables per iteration when " \
                "tracing is disabled (the counting-probe contract)"

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in TRACER_METHODS):
                continue
            recv = ast.unparse(node.func.value)
            if not _is_guarded(ctx, node, recv):
                yield self.finding(
                    ctx, node, f"{recv}.{node.func.attr}(...) runs "
                    f"unconditionally — with tracing disabled this is a "
                    f"per-iteration callable the §9 contract forbids")


class NonLiteralSpanNameRule(Rule):
    rule_id = "RL302"
    title = "span/event name is not a string literal"
    hint = "pass the name as a literal; put variability in labels " \
           "(span('recovery.fetch', blocks=...)), not the name"
    invariant = "docs/observability.md taxonomy freshness: names are " \
                "statically enumerable only if they are literals"

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in TRACER_METHODS):
                continue
            if not node.args:
                yield self.finding(
                    ctx, node, f".{node.func.attr}(...) without a "
                    f"positional name argument")
            elif not (isinstance(node.args[0], ast.Constant)
                      and isinstance(node.args[0].value, str)):
                yield self.finding(
                    ctx, node, f".{node.func.attr}({ast.unparse(node.args[0])}, "
                    f"...) — computed span/event name defeats the "
                    f"taxonomy freshness gate")


RULES: List[Rule] = [UnguardedTracerSiteRule(), NonLiteralSpanNameRule()]
