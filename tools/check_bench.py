#!/usr/bin/env python3
"""Gate on the BENCH_solver.json perf trajectory (ISSUE 6).

Dependency-free (stdlib json only), so CI can run it before any heavy
imports.  Two modes:

  python tools/check_bench.py BENCH_solver.json
      Validate the schema: version string, top-level keys, non-empty
      specs, per-spec ``modeled`` / ``counts`` / ``wall`` subtrees
      with the required numeric keys, and the ``sharded`` subtree
      (per shard count: deterministic ``bytes`` whose per-shard fetch
      map sums to the total, plus a wall-clock ``hidden_fraction``).

  python tools/check_bench.py A.json B.json
      Validate both, then assert the determinism contract: the two
      documents must be identical after stripping every ``wall``
      subtree (and any ``generated`` stamp) — the bench promises that
      everything else is a pure function of ``(seed, smoke)``.

Exit status 0 on success; 1 with a diagnostic on the first violation.
Schema: docs/observability.md §4.
"""
from __future__ import annotations

import json
import sys

SCHEMA_VERSION = "repro-bench/v1"

TOP_KEYS = ("schema", "bench", "seed", "smoke", "solver", "problem", "specs",
            "sharded", "service", "persist_kernels")
MODELED_KEYS = ("persist_s_per_event", "persist_s_per_iter",
                "exposed_persist_s_per_iter", "drain_s",
                "storage_overhead_x")
COUNT_KEYS = ("iterations", "converged", "persist_events", "persist_aborts",
              "failures_recovered", "recovery_restarts", "storage_failures",
              "wasted_iterations")
WALL_KEYS = ("hidden_fraction", "exposed_persist_s_per_iter",
             "iterations_per_s", "recovery_latency_s")
SHARDED_BYTE_KEYS = ("blocks_per_shard", "slot_nbytes", "persist_bytes",
                     "recovery_fetch_bytes")
SERVICE_LOADS = ("no_failures", "with_failures")
SERVICE_COUNT_KEYS = ("requests", "completed", "rejected", "converged",
                      "failures_recovered", "service_steps",
                      "queue_wait_steps_p50", "queue_wait_steps_p99",
                      "batch_occupancy_mean")
SERVICE_WALL_KEYS = ("elapsed_s", "solves_per_s")
PK_GEOMETRY_KEYS = ("k_data", "nparity", "chunk_values", "itemsize",
                    "encode_read_bytes_per_event", "parity_bytes_per_event")
PK_FUSED_PASS_KEYS = ("update_read_bytes", "update_write_bytes",
                      "staged_write_bytes", "total_bytes",
                      "persist_bw_fraction", "unfused_extra_read_bytes")
PK_COUNT_KEYS = ("iterations", "persist_events", "persist_aborts")
PK_WALL_KEYS = ("hidden_fraction_ref", "hidden_fraction_fused",
                "iterations_per_s_ref", "iterations_per_s_fused")
#: the tentpole threshold for the committed (non-smoke) document: the
#: fused route must hide strictly more than this fraction of persist
#: cost behind compute (ISSUE 10 acceptance; smoke walls are too noisy)
PK_MIN_FUSED_HIDDEN_FRACTION = 0.94


class BenchError(Exception):
    pass


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise BenchError(msg)


def _numeric(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def validate(doc: dict, path: str = "<doc>") -> None:
    """Raise :class:`BenchError` on the first schema violation."""
    _require(isinstance(doc, dict), f"{path}: document must be an object")
    for k in TOP_KEYS:
        _require(k in doc, f"{path}: missing top-level key {k!r}")
    _require(doc["schema"] == SCHEMA_VERSION,
             f"{path}: schema {doc['schema']!r} != {SCHEMA_VERSION!r}")
    _require(doc["bench"] == "solver",
             f"{path}: bench {doc['bench']!r} != 'solver'")
    _require(isinstance(doc["seed"], int) and not isinstance(doc["seed"], bool),
             f"{path}: seed must be an int")
    _require(isinstance(doc["smoke"], bool), f"{path}: smoke must be a bool")
    _require(isinstance(doc["specs"], dict) and doc["specs"],
             f"{path}: specs must be a non-empty object")
    for spec, entry in doc["specs"].items():
        where = f"{path}: specs[{spec!r}]"
        _require(isinstance(entry, dict), f"{where} must be an object")
        _require(isinstance(entry.get("family"), str) and entry["family"],
                 f"{where}.family must be a non-empty string")
        _require(spec.split("(")[0] == entry["family"],
                 f"{where}.family {entry['family']!r} does not prefix the spec")
        for sub, keys, numeric in (("modeled", MODELED_KEYS, MODELED_KEYS),
                                   ("counts", COUNT_KEYS,
                                    tuple(k for k in COUNT_KEYS
                                          if k != "converged")),
                                   ("wall", WALL_KEYS, WALL_KEYS)):
            tree = entry.get(sub)
            _require(isinstance(tree, dict), f"{where}.{sub} must be an object")
            for k in keys:
                _require(k in tree, f"{where}.{sub} missing key {k!r}")
            for k in numeric:
                _require(_numeric(tree[k]),
                         f"{where}.{sub}.{k} must be numeric, got "
                         f"{type(tree[k]).__name__}")
        _require(isinstance(entry["counts"]["converged"], bool),
                 f"{where}.counts.converged must be a bool")
    sharded = doc["sharded"]
    _require(isinstance(sharded, dict) and sharded,
             f"{path}: sharded must be a non-empty object")
    _require("1" in sharded,
             f"{path}: sharded must carry the 1-shard row")
    for n, entry in sharded.items():
        where = f"{path}: sharded[{n!r}]"
        _require(n.isdigit() and str(int(n)) == n and int(n) >= 1,
                 f"{where}: key must be a positive decimal shard count")
        _require(isinstance(entry, dict), f"{where} must be an object")
        bts = entry.get("bytes")
        _require(isinstance(bts, dict), f"{where}.bytes must be an object")
        for k in SHARDED_BYTE_KEYS:
            _require(_numeric(bts.get(k)),
                     f"{where}.bytes.{k} must be numeric")
        by_shard = bts.get("recovery_fetch_bytes_by_shard")
        _require(isinstance(by_shard, dict),
                 f"{where}.bytes.recovery_fetch_bytes_by_shard must be "
                 f"an object")
        _require(all(_numeric(v) for v in by_shard.values()),
                 f"{where}.bytes.recovery_fetch_bytes_by_shard values "
                 f"must be numeric")
        _require(sum(by_shard.values()) == bts["recovery_fetch_bytes"],
                 f"{where}.bytes: per-shard fetch bytes do not sum to "
                 f"recovery_fetch_bytes")
        wall = entry.get("wall")
        _require(isinstance(wall, dict) and _numeric(
                     wall.get("hidden_fraction")),
                 f"{where}.wall.hidden_fraction must be numeric")
    pk = doc["persist_kernels"]
    where = f"{path}: persist_kernels"
    _require(isinstance(pk, dict), f"{where} must be an object")
    _require(isinstance(pk.get("spec"), str) and pk["spec"],
             f"{where}.spec must be a non-empty string")
    geom = pk.get("geometry")
    _require(isinstance(geom, dict), f"{where}.geometry must be an object")
    for k in PK_GEOMETRY_KEYS:
        _require(_numeric(geom.get(k)), f"{where}.geometry.{k} must be "
                                        f"numeric")
    fp = geom.get("fused_pass")
    _require(isinstance(fp, dict), f"{where}.geometry.fused_pass must be "
                                   f"an object")
    for k in PK_FUSED_PASS_KEYS:
        _require(_numeric(fp.get(k)),
                 f"{where}.geometry.fused_pass.{k} must be numeric")
    _require(fp["total_bytes"] == fp["update_read_bytes"]
             + fp["update_write_bytes"] + fp["staged_write_bytes"],
             f"{where}.geometry.fused_pass: traffic terms do not sum to "
             f"total_bytes")
    counts = pk.get("counts")
    _require(isinstance(counts, dict), f"{where}.counts must be an object")
    for k in PK_COUNT_KEYS:
        _require(_numeric(counts.get(k)), f"{where}.counts.{k} must be "
                                          f"numeric")
    # the exactness cross-checks are part of the gate, not just data:
    # a fused route that drifts from the numpy route fails validation
    _require(counts.get("bit_identical") is True,
             f"{where}.counts.bit_identical: fused and numpy persist "
             f"routes must produce bit-identical solves")
    _require(counts.get("counts_match_ref") is True,
             f"{where}.counts.counts_match_ref: fused route's persist "
             f"accounting must match the numpy route")
    wall = pk.get("wall")
    _require(isinstance(wall, dict), f"{where}.wall must be an object")
    for k in PK_WALL_KEYS:
        _require(_numeric(wall.get(k)), f"{where}.wall.{k} must be numeric")
    for k in ("hidden_fraction_ref", "hidden_fraction_fused"):
        _require(0.0 <= wall[k] <= 1.0,
                 f"{where}.wall.{k} must lie in [0, 1]")
    if not doc["smoke"]:
        _require(wall["hidden_fraction_fused"]
                 > PK_MIN_FUSED_HIDDEN_FRACTION,
                 f"{where}.wall.hidden_fraction_fused = "
                 f"{wall['hidden_fraction_fused']:.4f} must exceed "
                 f"{PK_MIN_FUSED_HIDDEN_FRACTION} on the committed "
                 f"non-smoke run (ISSUE 10 acceptance)")
    service = doc["service"]
    _require(isinstance(service, dict),
             f"{path}: service must be an object")
    trace = service.get("trace")
    _require(isinstance(trace, dict), f"{path}: service.trace must be an "
                                      f"object")
    for k in ("seed", "requests", "lanes"):
        _require(_numeric(trace.get(k)),
                 f"{path}: service.trace.{k} must be numeric")
    for load in SERVICE_LOADS:
        where = f"{path}: service[{load!r}]"
        entry = service.get(load)
        _require(isinstance(entry, dict), f"{where} must be an object")
        counts = entry.get("counts")
        _require(isinstance(counts, dict), f"{where}.counts must be an object")
        for k in SERVICE_COUNT_KEYS:
            _require(_numeric(counts.get(k)),
                     f"{where}.counts.{k} must be numeric")
        _require(counts["completed"] + counts["rejected"]
                 == counts["requests"],
                 f"{where}.counts: completed + rejected != requests")
        _require(counts["queue_wait_steps_p50"]
                 <= counts["queue_wait_steps_p99"],
                 f"{where}.counts: queue-wait p50 exceeds p99")
        wall = entry.get("wall")
        _require(isinstance(wall, dict), f"{where}.wall must be an object")
        for k in SERVICE_WALL_KEYS:
            _require(_numeric(wall.get(k)),
                     f"{where}.wall.{k} must be numeric")


def strip_nondeterministic(doc: dict) -> dict:
    """The determinism view: the document minus every ``wall`` subtree
    and any top-level ``generated`` stamp."""
    out = {k: v for k, v in doc.items() if k != "generated"}
    out["specs"] = {spec: {k: v for k, v in entry.items() if k != "wall"}
                    for spec, entry in doc["specs"].items()}
    out["sharded"] = {n: {k: v for k, v in entry.items() if k != "wall"}
                      for n, entry in doc.get("sharded", {}).items()}
    out["service"] = {
        load: ({k: v for k, v in entry.items() if k != "wall"}
               if isinstance(entry, dict) else entry)
        for load, entry in doc.get("service", {}).items()}
    out["persist_kernels"] = {
        k: v for k, v in doc.get("persist_kernels", {}).items()
        if k != "wall"}
    return out


def _diff(a, b, path: str = "$") -> str:
    """First divergence between two stripped documents, as a path."""
    if isinstance(a, dict) and isinstance(b, dict):
        for k in sorted(set(a) | set(b)):
            if k not in a:
                return f"{path}.{k}: only in second"
            if k not in b:
                return f"{path}.{k}: only in first"
            d = _diff(a[k], b[k], f"{path}.{k}")
            if d:
                return d
        return ""
    if a != b:
        return f"{path}: {a!r} != {b!r}"
    return ""


def check_deterministic(doc_a: dict, doc_b: dict) -> None:
    """Raise :class:`BenchError` if the two documents differ outside
    their ``wall`` subtrees."""
    a, b = strip_nondeterministic(doc_a), strip_nondeterministic(doc_b)
    d = _diff(a, b)
    _require(not d, f"determinism violation (outside 'wall'): {d}")


def main(argv) -> int:
    if len(argv) not in (1, 2):
        print("usage: check_bench.py BENCH.json [SECOND_RUN.json]",
              file=sys.stderr)
        return 2
    docs = []
    try:
        for path in argv:
            with open(path) as f:
                docs.append(json.load(f))
        for path, doc in zip(argv, docs):
            validate(doc, path)
            print(f"OK {path}: schema {doc['schema']}, "
                  f"{len(doc['specs'])} specs, seed={doc['seed']}, "
                  f"smoke={doc['smoke']}")
        if len(docs) == 2:
            check_deterministic(docs[0], docs[1])
            print("OK deterministic: documents identical outside 'wall'")
    except (BenchError, OSError, json.JSONDecodeError) as e:
        print(f"FAIL: {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
