#!/usr/bin/env python3
"""Lightweight documentation checker (ISSUE 2 satellite: docs CI job).

Dependency-free on purpose — the CI docs job runs it on a bare Python
without jax installed.  Two classes of rot it catches:

1. **Snippet rot** — every fenced ```python block must at least compile
   (SyntaxError = broken example).  Full *execution* of the snippets
   happens in the tier-1 suite (``tests/test_docs.py``), which has the
   real runtime available.
2. **Link rot** — every relative markdown link / image target must exist
   in the repository (``[text](path)``; external ``http(s)://`` and
   ``#anchor`` links are skipped).
3. **Span-taxonomy rot** (freshness, ISSUE 6) — every span/event name
   emitted anywhere under ``src/`` (a string literal at a
   ``.span("...")`` / ``.event("...")`` call site — the tracing style
   rule, now *enforced* as repro-lint RL302) must appear in
   ``docs/observability.md``, so new instrumentation cannot land
   undocumented.  Runs whenever an ``observability.md`` is among the
   checked files.
4. **Matrix rot** (freshness, ISSUE 4/5) — every backend *spec family*
   registered in the source tree (``register_backend("name", ...)`` /
   ``register_backend_class("name", ...)``) must appear in the README's
   backend matrix, so a new backend cannot land undocumented.  Runs
   whenever a README is among the checked files.  For the ``erasure``
   family, every parity arity the stripe grammar supports (derived from
   ``MAX_PARITY`` in the GF(2^8) module: ``+p`` and ``+2p``) must be
   named too — a wider code cannot land with only the distance-2 row
   documented.
5. **Rule-catalog rot** (freshness, ISSUE 8) — two directions: every
   rule id the linter registry ships must appear in
   ``docs/static-analysis.md``, and every ``RLxxx`` token that doc
   names must exist in the registry (a doc describing a ghost rule
   fails).  Runs whenever a ``static-analysis.md`` is checked.

Since ISSUE 8 the freshness facts (3)–(5) come from ``repro_lint``'s
AST extractors (``tools/repro_lint/facts.py``), not regexes over raw
source text: a span call split across lines or a reformatted
``MAX_PARITY`` assignment no longer silently empties a gate.  Still
dependency-free — repro_lint is stdlib-only.

Usage: ``python tools/check_docs.py README.md DESIGN.md docs/*.md``
Exit status is non-zero when anything is broken.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

try:  # script mode: sys.path[0] is tools/
    from repro_lint import facts as _lint_facts
    from repro_lint.registry import ALL_RULES, META_RULES
except ImportError:  # module mode from the repo root
    from tools.repro_lint import facts as _lint_facts
    from tools.repro_lint.registry import ALL_RULES, META_RULES

FENCE_RE = re.compile(r"^```(\w*)\s*$")
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
RULE_ID_RE = re.compile(r"\bRL\d{3}\b")

_FACTS_CACHE: dict = {}


def _facts(src_root: Path) -> dict:
    """AST-extracted facts for ``src_root`` (cached per root — the
    README and observability gates share one parse of the tree)."""
    key = str(Path(src_root).resolve())
    if key not in _FACTS_CACHE:
        _FACTS_CACHE[key] = _lint_facts.collect_facts_from_root(src_root)
    return _FACTS_CACHE[key]


def python_blocks(text: str):
    """Yield (start_line, source) for each fenced ```python block."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = FENCE_RE.match(lines[i])
        if m and m.group(1) == "python":
            start = i + 1
            body = []
            i += 1
            while i < len(lines) and not lines[i].startswith("```"):
                body.append(lines[i])
                i += 1
            yield start + 1, "\n".join(body)
        i += 1


def relative_links(text: str):
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        yield target.split("#")[0]


def registered_backend_families(src_root: Path) -> set:
    """Backend spec families registered anywhere under ``src/`` — the
    static counterpart of ``repro.nvm.backend.backend_names()``,
    AST-extracted from ``register_backend(_class)`` call sites."""
    return set(_facts(src_root)["backend_families"])


def check_backend_matrix(readme: Path, repo_root: Path) -> list:
    """Freshness gate: every registered backend family must be named in
    the README (as `` `name` `` or `` `name(...)` `` in the matrix)."""
    families = registered_backend_families(repo_root / "src")
    if not families:
        return [f"{readme}: no registered backend families found under "
                f"{repo_root / 'src'} — is the tree intact?"]
    text = readme.read_text()
    missing = [name for name in sorted(families)
               if not re.search(rf"`{re.escape(name)}[`(]", text)]
    print(f"{readme}: backend matrix covers "
          f"{len(families) - len(missing)}/{len(families)} registered "
          f"spec families")
    errors = [f"{readme}: registered backend family {name!r} is missing "
              f"from the README backend matrix — document it (see the "
              f"'Solver / backend matrix' section)" for name in missing]
    if "erasure" in families:
        arities = supported_erasure_arities(repo_root / "src")
        undocumented = [a for a in arities if a not in text]
        if undocumented:
            errors.append(
                f"{readme}: erasure parity arity(ies) "
                f"{', '.join(repr(a) for a in undocumented)} missing from "
                f"the README — every supported 'xK{undocumented[0]}'-style "
                f"spec form needs a matrix row")
        else:
            print(f"{readme}: erasure matrix names all supported parity "
                  f"arities ({', '.join(arities)})")
    return errors


def emitted_span_names(src_root: Path) -> set:
    """Every span/event name emitted under ``src/`` — string literals
    at ``.span(``/``.event(`` call sites, AST-extracted (repro-lint
    RL302 is the style rule that makes this scan complete)."""
    return set(_facts(src_root)["span_names"])


def check_span_taxonomy(doc: Path, repo_root: Path) -> list:
    """Freshness gate: every emitted span/event name must appear in the
    observability doc's taxonomy."""
    names = emitted_span_names(repo_root / "src")
    if not names:
        return [f"{doc}: no span/event call sites found under "
                f"{repo_root / 'src'} — is the tree intact?"]
    text = doc.read_text()
    missing = sorted(n for n in names if n not in text)
    print(f"{doc}: span taxonomy covers {len(names) - len(missing)}/"
          f"{len(names)} emitted span/event names")
    return [f"{doc}: emitted span/event name {n!r} is missing from the "
            f"taxonomy — document it (see the 'Span and event taxonomy' "
            f"section)" for n in missing]


def check_service_metrics(doc: Path, repo_root: Path) -> list:
    """Freshness gate (ISSUE 9): every metric instrument name the
    service layer creates (string literals at ``.counter(`` /
    ``.gauge(`` / ``.histogram(`` call sites under ``serving/``) must
    appear in the serving doc's metric table — new service
    instrumentation cannot land undocumented.  The service's span/event
    names ride the observability taxonomy gate like everyone else's."""
    names = set(_facts(repo_root / "src")["service_metric_names"])
    if not names:
        return [f"{doc}: no service metric call sites found under "
                f"{repo_root / 'src'} — is the serving layer intact?"]
    text = doc.read_text()
    missing = sorted(n for n in names if n not in text)
    print(f"{doc}: service metric table covers {len(names) - len(missing)}/"
          f"{len(names)} emitted metric names")
    return [f"{doc}: service metric name {n!r} is missing from the metric "
            f"table — document it (see the 'Metrics and spans' section)"
            for n in missing]


def supported_erasure_arities(src_root: Path) -> list:
    """The ``+p`` / ``+2p`` / ... spec suffixes the stripe grammar
    accepts, derived from the ``MAX_PARITY`` constant in the GF(2^8)
    module's AST (default 2 when the scan finds nothing)."""
    arities = _facts(src_root)["erasure_arities"]
    return arities or _lint_facts.erasure_arities_from_parity(2)


def check_rule_catalog(doc: Path, repo_root: Path) -> list:
    """Two-direction freshness gate for the linter's rule catalog:
    registry ⊆ doc (a shipped rule cannot stay undocumented) and
    doc ⊆ registry (the doc cannot describe a ghost rule)."""
    known = set(ALL_RULES) | set(META_RULES)
    text = doc.read_text()
    documented = set(RULE_ID_RE.findall(text))
    missing = sorted(known - documented)
    ghosts = sorted(documented - known)
    print(f"{doc}: rule catalog covers {len(known - set(missing))}/"
          f"{len(known)} registered rule ids")
    errors = [f"{doc}: registered lint rule {rid!r} is missing from the "
              f"catalog — document it (python -m tools.repro_lint "
              f"--list-rules)" for rid in missing]
    errors.extend(
        f"{doc}: documents rule {rid!r} which no longer exists in the "
        f"repro_lint registry — delete the stale catalog entry"
        for rid in ghosts)
    return errors


def check_file(path: Path, repo_root: Path) -> list:
    errors = []
    text = path.read_text()
    nblocks = 0
    for line_no, src in python_blocks(text):
        nblocks += 1
        try:
            compile(src, f"{path}:{line_no}", "exec")
        except SyntaxError as e:
            errors.append(f"{path}:{line_no}: python block does not compile: {e}")
    nlinks = 0
    for target in relative_links(text):
        if not target:
            continue
        nlinks += 1
        base = repo_root if target.startswith("/") else path.parent
        resolved = (base / target.lstrip("/")).resolve()
        if not resolved.exists():
            errors.append(f"{path}: broken relative link -> {target}")
    print(f"{path}: {nblocks} python block(s), {nlinks} relative link(s)")
    return errors


def main(argv) -> int:
    if not argv:
        print(__doc__)
        return 2
    repo_root = Path(__file__).resolve().parent.parent
    errors = []
    for name in argv:
        p = Path(name)
        if not p.exists():
            errors.append(f"{name}: file not found")
            continue
        errors.extend(check_file(p, repo_root))
        if p.name == "README.md":
            errors.extend(check_backend_matrix(p, repo_root))
        if p.name == "observability.md":
            errors.extend(check_span_taxonomy(p, repo_root))
        if p.name == "serving.md":
            errors.extend(check_service_metrics(p, repo_root))
        if p.name == "static-analysis.md":
            errors.extend(check_rule_catalog(p, repo_root))
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
